"""Version shims for the jax surface this repo is written against.

The codebase targets the current jax API (``jax.shard_map`` with
``check_vma=``, ``jax.tree.flatten_with_path``, ``jax.make_mesh`` with
``axis_types=``).  The container ships an older jax where shard_map lives in
``jax.experimental`` with the flag spelled ``check_rep``, path-aware tree
flattening lives in ``jax.tree_util``, and meshes have no axis types.  All
call sites import from here so the rest of the code stays written against
the modern names.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, replication check named check_vma
    from jax import shard_map as _shard_map
    _VMA_KW = "check_vma"
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _VMA_KW = "check_rep"


def _ensure_optimization_barrier_batchable():
    """Old jax ships no vmap rule for ``lax.optimization_barrier`` (the
    mock-ups' anti-DCE attach point); the barrier is elementwise-transparent
    so batching is the identity on batch dims."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
    except ImportError:
        return
    if optimization_barrier_p not in batching.primitive_batchers:
        batching.primitive_batchers[optimization_barrier_p] = \
            lambda args, dims: (optimization_barrier_p.bind(*args), dims)


_ensure_optimization_barrier_batchable()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
    """``jax.shard_map`` accepting ``check_vma=`` on every jax version."""
    kw[_VMA_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` with a ``jax.tree_util`` fallback."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where supported, else None (old meshes are
    untyped — equivalent to all-Auto)."""
    at = getattr(jax.sharding, "AxisType", None)
    return (at.Auto,) * n if at is not None else None


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` tolerating the missing ``axis_types`` kwarg."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kw)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def mesh_with_axis_types(devices_array, axis_names):
    """``jax.sharding.Mesh`` with all-Auto axis types where supported."""
    types = auto_axis_types(len(axis_names))
    if types is not None:
        try:
            return jax.sharding.Mesh(devices_array, axis_names,
                                     axis_types=types)
        except TypeError:
            pass
    return jax.sharding.Mesh(devices_array, axis_names)
