"""Version shims for the jax surface this repo is written against.

The codebase targets the current jax API (``jax.shard_map`` with
``check_vma=``, ``jax.tree.flatten_with_path``, ``jax.make_mesh`` with
``axis_types=``).  Older jax (0.4.x) spells these differently; every call
site imports from here so the rest of the code stays written against the
modern names.

Each shim PROBES for the native API at import time and self-disables —
becoming a plain pass-through — when the native surface exists, so nothing
here needs manual removal when the container's jax catches up.  One
``warnings.warn`` at import summarizes which shims are still live (empty
list -> no warning): the signal that this module can be deleted.
"""
from __future__ import annotations

import inspect
import warnings

import jax

#: shims that had to activate on this jax version (empty on current jax)
LIVE_SHIMS: list[str] = []

# -- shard_map: top-level export + check_vma spelling ------------------------
try:  # jax >= 0.6: top-level export, replication check named check_vma
    from jax import shard_map as _shard_map
    _VMA_KW = "check_vma"
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _VMA_KW = "check_rep"
    LIVE_SHIMS.append("shard_map (jax.experimental, check_rep= spelling)")


def _ensure_optimization_barrier_batchable():
    """Old jax ships no vmap rule for ``lax.optimization_barrier`` (the
    mock-ups' anti-DCE attach point); the barrier is elementwise-transparent
    so batching is the identity on batch dims.  No-op (native) when the
    rule already exists."""
    try:
        from jax._src.lax.lax import optimization_barrier_p
        from jax.interpreters import batching
    except ImportError:
        return
    if optimization_barrier_p not in batching.primitive_batchers:
        batching.primitive_batchers[optimization_barrier_p] = \
            lambda args, dims: (optimization_barrier_p.bind(*args), dims)
        LIVE_SHIMS.append("optimization_barrier vmap batching rule")


_ensure_optimization_barrier_batchable()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
    """``jax.shard_map`` accepting ``check_vma=`` on every jax version."""
    kw[_VMA_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


# -- path-aware tree flatten -------------------------------------------------
if hasattr(jax.tree, "flatten_with_path"):
    def tree_flatten_with_path(tree, is_leaf=None):
        return jax.tree.flatten_with_path(tree, is_leaf=is_leaf)
else:
    LIVE_SHIMS.append("tree.flatten_with_path (jax.tree_util fallback)")

    def tree_flatten_with_path(tree, is_leaf=None):
        return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


# -- mesh axis types ---------------------------------------------------------
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)
if _AXIS_TYPE is None:
    LIVE_SHIMS.append("sharding.AxisType missing (untyped meshes)")


def _accepts_kwarg(fn, name: str) -> bool:
    """Signature probe; VAR_KEYWORD (or an uninspectable C++ wrapper)
    counts as accepting — the callers below keep a TypeError guard for
    those, so optimism only costs one failed call on old jax."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic wrappers
        return True
    return name in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


_MAKE_MESH_AXIS_TYPES = _accepts_kwarg(jax.make_mesh, "axis_types")
if not _MAKE_MESH_AXIS_TYPES:
    LIVE_SHIMS.append("make_mesh(axis_types=) dropped")


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where supported, else None (old meshes are
    untyped — equivalent to all-Auto)."""
    return (_AXIS_TYPE.Auto,) * n if _AXIS_TYPE is not None else None


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` tolerating the missing ``axis_types`` kwarg."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _MAKE_MESH_AXIS_TYPES:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=axis_types, **kw)
        except TypeError:  # probe was optimistic (opaque wrapper)
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def mesh_with_axis_types(devices_array, axis_names):
    """``jax.sharding.Mesh`` with all-Auto axis types where supported.

    ``Mesh`` is a C++-wrapped class whose ``__init__`` signature is not
    inspectable on ANY jax version, so the native probe here is the
    presence of ``AxisType`` itself, with a TypeError guard for jax
    versions that expose the enum before the ``Mesh`` kwarg."""
    types = auto_axis_types(len(axis_names))
    if types is not None:
        try:
            return jax.sharding.Mesh(devices_array, axis_names,
                                     axis_types=types)
        except TypeError:
            pass
    return jax.sharding.Mesh(devices_array, axis_names)


if LIVE_SHIMS:
    warnings.warn(
        f"repro._compat: {len(LIVE_SHIMS)} jax compatibility shim(s) live "
        f"on jax {jax.__version__}: " + "; ".join(LIVE_SHIMS)
        + ". Each self-disables once the native API exists; when this "
        "warning disappears the module can be deleted.",
        stacklevel=2)
