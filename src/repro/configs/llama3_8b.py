"""llama3-8b [dense] — 32L d=4096 32H (GQA kv=8) ff=14336 V=128256.
[arXiv:2407.21783; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128_256, head_dim=128,
    rope_theta=500_000.0, tie_embeddings=False,
)
