"""whisper-medium [audio] — 24+24L d=1024 16H ff=4096 V=51865; enc-dec,
conv frontend STUB (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51_865, head_dim=64,
    encdec=EncDecConfig(n_enc_layers=24, dec_ratio=8),
    tie_embeddings=True,
)
