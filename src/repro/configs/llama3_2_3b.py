"""llama3.2-3b [dense] — 28L d=3072 24H (GQA kv=8) ff=8192 V=128256.
[hf:meta-llama/Llama-3.2-3B; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=128,
    rope_theta=500_000.0, tie_embeddings=True,
)
