"""phi3.5-moe-42b-a6.6b [moe] — 32L d=4096 32H (kv=8) expert-ff=6400
V=32064, 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32_064, head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
    tie_embeddings=False,
)
