"""gemma2-9b [dense] — 42L d=3584 16H (kv=8) ff=14336 V=256000;
local+global alternating, logit softcaps.  [arXiv:2408.00118; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab_size=256_000, head_dim=256,
    layer_pattern=("attn_local", "attn"),
    window=4096, attn_softcap=50.0, final_softcap=30.0,
    tie_embeddings=True, scale_embed=True,
)
