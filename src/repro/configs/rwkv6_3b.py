"""rwkv6-3b [ssm] — 32L d=2560 (attn-free) ff=8960 V=65536; Finch
data-dependent decay.  40 wkv heads (hd=64) padded to 48 for TP=16.
[arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65_536, head_dim=64,
    layer_pattern=("rwkv",),
    ssm=SSMConfig(kind="rwkv6", head_dim=64, decay_lora_rank=32),
    tie_embeddings=False, subquadratic=True,
)
