"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

ARCHS = (
    "llama3.2-3b",
    "gemma3-1b",
    "gemma2-9b",
    "llama3-8b",
    "phi3.5-moe-42b-a6.6b",
    "deepseek-v3-671b",
    "whisper-medium",
    "paligemma-3b",
    "rwkv6-3b",
    "zamba2-1.2b",
)

_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "gemma3-1b": "gemma3_1b",
    "gemma2-9b": "gemma2_9b",
    "llama3-8b": "llama3_8b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "deepseek-v3-671b": "deepseek_v3",
    "whisper-medium": "whisper_medium",
    "paligemma-3b": "paligemma_3b",
    "rwkv6-3b": "rwkv6_3b",
    "zamba2-1.2b": "zamba2_1_2b",
}


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
