"""deepseek-v3-671b [moe] — 61L d=7168 128H ff(expert)=2048 V=129280;
MLA, 1 shared + 256 routed top-8.  [arXiv:2412.19437; hf]

Simplifications vs the full paper model (documented in DESIGN.md): every
layer is MoE (the real model has 3 dense lead-in layers) and the MTP head is
omitted.  Optimizer is Adafactor — bf16-Adam state for 671B params does not
fit a single v5e-256 pod (see EXPERIMENTS.md memory table)."""
from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab_size=129_280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1),
    tie_embeddings=False, optimizer="adafactor",
)
