"""paligemma-3b [vlm] — 18L d=2048 8H (kv=1) ff=16384 V=257216; SigLIP
patch embeddings STUBBED, gemma backbone, prefix-LM mask.
[arXiv:2407.07726; hf]"""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=257_216, head_dim=256,
    vlm=VLMConfig(patch_dim=1152, n_patches=256),
    tie_embeddings=True, scale_embed=True,
)
