"""gemma3-1b [dense] — 26L d=1152 4H (kv=1) ff=6912 V=262144; 5:1
local:global, 128k context.  [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262_144, head_dim=256,
    layer_pattern=("attn_local",) * 5 + ("attn",),
    window=512, qk_norm=True, rope_theta=1_000_000.0,
    tie_embeddings=True, scale_embed=True,
    subquadratic=True,   # 5:1 local; global layers use seq-sharded decode
)
