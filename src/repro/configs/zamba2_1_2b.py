"""zamba2-1.2b [hybrid] — 38L mamba2 d=2048, shared attn block (32H kv=32,
ff=8192) every 6 layers, ssm_state=64, V=32000.  [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_000, head_dim=64,
    layer_pattern=("mamba",),
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2),
    hybrid_period=6,
    tie_embeddings=False, subquadratic=True,
)
