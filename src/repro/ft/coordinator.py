"""Fleet coordinator: retune on DRIFT and FAILURE, not on a schedule.

PR 7's loop retunes every fixed number of steps — fine on a quiet bench,
wrong in the field: a calm fleet re-publishes identical profiles forever
(wasted tuning + manifest churn), while a drifting fleet waits out the
schedule serving a stale plan.  The ROADMAP asks for the inversion: watch
what the fleet actually reports and act when it diverges.

``FleetCoordinator.scan()`` is one poll cycle over the shard directory:

* **Liveness** — a server beats its heartbeat whenever its newest shard
  epoch advances (a crashed server simply stops producing shards, which
  is exactly what a real crash leaves behind).  Silence past
  ``heartbeat_timeout`` marks it dead; the injectable clock makes the
  chaos bench's death assertions exact, not timing-dependent.
* **Stragglers** — a live server whose newest shard lags the fleet's
  newest epoch by more than ``straggler_epochs`` generations.
* **Drift** — merge the shards (quarantine accounting via
  ``Trace.merge_shards``; a quarantined shard's ``#@lat`` measurements
  are not trusted either) and price the merged workload under the LIVE
  stores twice: once on the base (modeled) backend and once on a
  ``FeedbackBackend`` over the fleet's own latency observations.  Their
  ratio is how wrong the live epoch's model is about current hardware/
  load; outside ``[1/drift_threshold, drift_threshold]`` the scan
  recommends a retune.

``scan`` only OBSERVES and recommends (``FleetStatus.retune``); the
serving harness owns the actual tune/publish/poll cycle, so the
coordinator stays safe to run anywhere — including dry in a test.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
import warnings

from repro.core import trace as trace_mod
from repro.core.trace import Trace, load_shard_latencies
from repro.ft.watchdog import Heartbeats


@dataclasses.dataclass
class FleetStatus:
    """One ``scan``'s verdict on the fleet."""
    fleet_epoch: int                 # newest shard epoch seen (-1: none)
    alive: list[str]
    dead: list[str]
    stragglers: list[str]
    drift: float | None              # observed/modeled cost ratio
    quarantined: int                 # shards excluded by the merge
    retune: bool
    reasons: list[str]

    def summary(self) -> str:
        head = (f"fleet e{self.fleet_epoch}: {len(self.alive)} alive, "
                f"{len(self.dead)} dead, {len(self.stragglers)} "
                f"straggling, drift "
                f"{'n/a' if self.drift is None else f'{self.drift:.2f}x'}")
        if self.retune:
            head += " -> RETUNE (" + "; ".join(self.reasons) + ")"
        return head


class FleetCoordinator:
    """Watches a fleet's shard directory; recommends retunes.

    ``backend`` is the modeled (base) tuner backend drift is judged
    against; ``ref`` is the live ``StoreRef`` whose stores price the
    merged workload.  ``clock`` feeds the heartbeat bookkeeping — pass a
    fake for determinism.
    """

    def __init__(self, shard_dir, ref, *, backend=None,
                 heartbeat_timeout: float = 60.0,
                 straggler_epochs: int = 1,
                 drift_threshold: float = 1.5,
                 min_observed: int = 1,
                 clock=time.monotonic):
        self.shard_dir = pathlib.Path(shard_dir)
        self.ref = ref
        self.backend = backend
        self.straggler_epochs = int(straggler_epochs)
        self.drift_threshold = float(drift_threshold)
        self.min_observed = int(min_observed)
        self.heartbeats = Heartbeats(timeout=heartbeat_timeout, clock=clock)
        self._newest: dict[str, int] = {}    # server -> newest shard epoch

    # -- one poll cycle ------------------------------------------------------
    def scan(self) -> FleetStatus:
        fleet_epoch = self._scan_liveness()
        dead = self.heartbeats.dead()
        alive = self.heartbeats.alive()
        stragglers = sorted(
            s for s in alive
            if self._newest.get(s, -1)
            < fleet_epoch - self.straggler_epochs)
        drift, quarantined = self._scan_drift()
        reasons = []
        if dead:
            reasons.append(f"server(s) dead: {', '.join(dead)}")
        if drift is not None and (
                drift > self.drift_threshold
                or drift < 1.0 / self.drift_threshold):
            reasons.append(f"cost drift {drift:.2f}x outside "
                           f"[{1.0 / self.drift_threshold:.2f}, "
                           f"{self.drift_threshold:.2f}]")
        return FleetStatus(fleet_epoch=fleet_epoch, alive=alive, dead=dead,
                           stragglers=stragglers, drift=drift,
                           quarantined=quarantined,
                           retune=bool(reasons), reasons=reasons)

    # -- internals -----------------------------------------------------------
    def _scan_liveness(self) -> int:
        """Beat every server whose newest shard epoch advanced; the
        fleet epoch is the max over all shards ever seen."""
        fleet_epoch = -1
        newest: dict[str, int] = {}
        if self.shard_dir.is_dir():
            for p in sorted(self.shard_dir.glob("shard-*.jsonl")):
                parts = trace_mod._shard_name_parts(p.name)
                if parts is None:
                    continue
                server, epoch = parts
                newest[server] = max(newest.get(server, -1), epoch)
                fleet_epoch = max(fleet_epoch, epoch)
        for server, epoch in newest.items():
            if epoch > self._newest.get(server, -1):
                self.heartbeats.beat(server, epoch=epoch)
                self._newest[server] = epoch
        return fleet_epoch

    def _scan_drift(self) -> tuple[float | None, int]:
        """Observed-vs-modeled cost ratio of the merged shard workload
        under the LIVE stores (None: nothing merged, no observations,
        or no modeled cost to compare against)."""
        from repro.core.tuner import (CostModelBackend, FeedbackBackend,
                                      estimate_trace_cost)
        from repro.core import costmodel
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # scan is periodic; the
            report = Trace.merge_shards(self.shard_dir)  # merge warns once
            skip = [n.path for n in report.quarantined]
            observed = load_shard_latencies(self.shard_dir, skip=skip)
        quarantined = len(report.quarantined)
        if report.trace.total() == 0:
            return None, quarantined
        n_obs = sum(len(v) for v in observed.values())
        if n_obs < self.min_observed:
            return None, quarantined
        base_backend = self.backend or CostModelBackend(costmodel.V5E_ICI)
        fb = FeedbackBackend(base_backend, observed)
        kw = dict(base=self.ref.base, phases=self.ref.phases)
        modeled = sum(estimate_trace_cost(
            report.trace, base_backend, **kw).values())
        obs = sum(estimate_trace_cost(report.trace, fb, **kw).values())
        if modeled <= 0.0:
            return None, quarantined
        return obs / modeled, quarantined
