"""Deterministic fault injection for the fleet retuning harness.

The fleet loop's failure modes are all FILE-shaped: a server dies holding
a half-written shard (torn write), bit rot or a buggy serializer corrupts
a JSONL line, a publisher races the manifest against its profiles
(manifest/profile skew), a server silently stops flushing (death
mid-epoch), and a latency reservoir picks up a network hiccup 100× the
true cost (spike outlier).  ``ChaosMonkey`` injects each of these
DETERMINISTICALLY — a seeded RNG, explicit targets, and an event log —
so the chaos bench's gates are exact assertions, not flake tolerances:
every injected fault is recorded as a ``ChaosEvent`` and the harness
checks that ingestion quarantined/rolled-back/flagged *exactly* those.

Injection happens at rest (mutating files a healthy writer already
produced) rather than by patching writers: the faults modeled here are
precisely the ones that occur AFTER the writer's own code ran correctly
— crashes between write and rename, storage corruption, concurrent
publishes — so post-hoc mutation is the honest simulation.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import random

from repro.core.trace import LAT_PREFIX, SHARD_HEADER


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One injected fault, for exact-accounting assertions."""
    kind: str      # "torn-shard" | "corrupt-line" | "header-skew" |
                   # "profile-skew" | "kill-server" | "latency-spike"
    target: str    # file path or server name
    detail: str = ""


class ChaosMonkey:
    """A seeded injector; every method mutates one target and logs it.

    All randomness flows from the constructor seed, so a fixed-seed
    chaos bench replays the identical fault schedule every run.
    """

    def __init__(self, seed: int = 0):
        self._rng = random.Random(seed)
        self.events: list[ChaosEvent] = []

    def _log(self, kind: str, target, detail: str = "") -> None:
        self.events.append(ChaosEvent(kind, str(target), detail))

    def of_kind(self, kind: str) -> list[ChaosEvent]:
        return [e for e in self.events if e.kind == kind]

    # -- shard faults --------------------------------------------------------
    def tear_shard(self, path: str | pathlib.Path,
                   keep_frac: float | None = None) -> pathlib.Path:
        """Truncate a shard's BODY mid-line — the on-disk state of a
        writer that died between ``write`` and ``os.replace`` on a
        filesystem that persisted a prefix.  The header (and its sha256
        claim) survives, so merge sees a digest mismatch."""
        p = pathlib.Path(path)
        text = p.read_text()
        head, _sep, body = text.partition("\n")
        if keep_frac is None:
            keep_frac = 0.25 + 0.5 * self._rng.random()
        cut = max(1, int(len(body) * keep_frac))
        p.write_text(head + "\n" + body[:cut])
        self._log("torn-shard", p, f"body cut to {cut}/{len(body)} bytes")
        return p

    def corrupt_line(self, path: str | pathlib.Path,
                     line: int | None = None) -> pathlib.Path:
        """Overwrite one body line with garbage (bit rot / serializer
        bug).  The digest no longer matches either, but with
        ``verify_digest=False`` this exercises the parse-error
        quarantine path on its own."""
        p = pathlib.Path(path)
        lines = p.read_text().splitlines()
        data_idx = [i for i, ln in enumerate(lines)
                    if ln.strip() and not ln.lstrip().startswith("#")]
        if not data_idx:
            data_idx = [len(lines) - 1]
        i = data_idx[line if line is not None
                     else self._rng.randrange(len(data_idx))]
        lines[i] = '{"op": "allreduce", "p": 4, "nbytes": ####CORRUPT####'
        p.write_text("\n".join(lines) + "\n")
        self._log("corrupt-line", p, f"line {i + 1} garbled")
        return p

    def skew_header(self, path: str | pathlib.Path, *,
                    server: str | None = None,
                    epoch: int | None = None) -> pathlib.Path:
        """Rewrite the ``#@shard`` header so it disagrees with the
        filename (a replayed/renamed shard, or tampering) — the header
        is re-serialized VALID, with a digest matching the body, so only
        the meta-skew check can catch it."""
        p = pathlib.Path(path)
        text = p.read_text()
        head, _sep, body = text.partition("\n")
        meta = json.loads(head[len(SHARD_HEADER):])
        if server is not None:
            meta["server"] = server
        if epoch is not None:
            meta["epoch"] = int(epoch)
        if server is None and epoch is None:
            meta["epoch"] = int(meta.get("epoch", 0)) + 1
        p.write_text(SHARD_HEADER + json.dumps(meta) + "\n" + body)
        self._log("header-skew", p,
                  f"header now ({meta.get('server')!r}, "
                  f"e{meta.get('epoch')})")
        return p

    def spike_latencies(self, path: str | pathlib.Path, *,
                        factor: float = 100.0,
                        per_line: int = 1) -> int:
        """Multiply ``per_line`` random samples in each ``#@lat``
        reservoir by ``factor`` — the exploration step that landed on a
        network hiccup.  The shard stays VALID (digest recomputed): the
        point is that ``FeedbackBackend``'s MAD filter, not quarantine,
        must absorb these.  Returns the number of spiked samples."""
        from repro.core.trace import _body_digest
        p = pathlib.Path(path)
        text = p.read_text()
        head, _sep, body = text.partition("\n")
        out, spiked = [], 0
        for ln in body.splitlines():
            if ln.startswith(LAT_PREFIX):
                m = json.loads(ln[len(LAT_PREFIX):])
                lat = m.get("lat_s", [])
                for _ in range(min(per_line, len(lat))):
                    i = self._rng.randrange(len(lat))
                    lat[i] = lat[i] * factor
                    spiked += 1
                m["lat_s"] = lat
                ln = LAT_PREFIX + json.dumps(m)
            out.append(ln)
        new_body = "".join(ln + "\n" for ln in out)
        meta = json.loads(head[len(SHARD_HEADER):])
        meta["sha256"] = _body_digest(new_body)
        p.write_text(SHARD_HEADER + json.dumps(meta) + "\n" + new_body)
        self._log("latency-spike", p, f"{spiked} sample(s) ×{factor:g}")
        return spiked

    # -- publisher faults ----------------------------------------------------
    def skew_profiles(self, directory: str | pathlib.Path) -> pathlib.Path:
        """Flip a profile file AFTER its manifest was written — the
        manifest/profile skew of a publisher racing a second writer (or
        a partial rollout).  ``StoreRef.poll`` must refuse the epoch on
        the ``profiles_digest`` mismatch."""
        d = pathlib.Path(directory)
        targets = sorted(p for p in d.rglob("*")
                         if p.is_file() and p.suffix in (".pgtune", ".json")
                         and p.name != "MANIFEST.json")
        if not targets:
            raise ValueError(f"no profile files under {d} to skew")
        t = targets[self._rng.randrange(len(targets))]
        with open(t, "a") as f:
            f.write("# skewed after publish\n")
        self._log("profile-skew", t, "appended after manifest write")
        return t

    # -- server faults -------------------------------------------------------
    def kill_server(self, server: str, *, at_epoch: int) -> None:
        """Mark ``server`` dead from ``at_epoch`` on.  The harness checks
        ``alive(server, epoch)`` before letting a server serve/flush —
        death is simply the absence of every later shard and heartbeat,
        exactly what a real crash leaves behind."""
        self._log("kill-server", server, f"at epoch {at_epoch}")

    def alive(self, server: str, epoch: int) -> bool:
        for e in self.events:
            if e.kind == "kill-server" and e.target == server:
                if epoch >= int(e.detail.rsplit(" ", 1)[1]):
                    return False
        return True
