from repro.ft.watchdog import Heartbeats, StepWatchdog  # noqa: F401
from repro.ft.restart import run_with_restarts  # noqa: F401
from repro.ft.chaos import ChaosEvent, ChaosMonkey  # noqa: F401
from repro.ft.coordinator import FleetCoordinator, FleetStatus  # noqa: F401
