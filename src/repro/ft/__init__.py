from repro.ft.watchdog import StepWatchdog  # noqa: F401
from repro.ft.restart import run_with_restarts  # noqa: F401
