"""Straggler / hang / liveness detection.

At thousand-node scale a single slow host drags every collective; detection
must be local and cheap.  ``StepWatchdog`` tracks a robust running median of
step wall-times; a step slower than ``ratio``× the median flags a straggler
event, and ``hang_timeout`` arms a background timer that fires if a step
never completes (collective deadlock after a peer died).  Upstream, the
launcher maps these events to: reroute traffic off the slow host (straggler)
or kill + restart from the last checkpoint (hang) — see ft/restart.py.

``Heartbeats`` is the FLEET-level counterpart: passive liveness from
periodic beats (``ft.coordinator`` beats a server whenever its shard
output advances), with an injectable clock so death detection is
deterministic in tests and the chaos bench.
"""
from __future__ import annotations

import statistics
import threading
import time


class Heartbeats:
    """Last-beat liveness tracking over named peers.

    ``beat(name)`` stamps a peer at the current clock; ``dead()`` lists
    peers whose last beat is older than ``timeout``.  The clock is
    injectable (any zero-arg callable returning seconds) because real
    wall clocks make death detection a flake: the chaos bench advances a
    fake clock by exact amounts and asserts exactly which server died.
    A beat can carry the peer's current ``epoch`` so epoch-lag
    stragglers fall out of the same bookkeeping.
    """

    def __init__(self, *, timeout: float, clock=time.monotonic):
        self.timeout = float(timeout)
        self._clock = clock
        self._last: dict[str, float] = {}
        self._epoch: dict[str, int] = {}

    def beat(self, name: str, *, epoch: int | None = None) -> None:
        self._last[name] = float(self._clock())
        if epoch is not None:
            self._epoch[name] = int(epoch)

    def seen(self) -> list[str]:
        return sorted(self._last)

    def epoch_of(self, name: str) -> int | None:
        return self._epoch.get(name)

    def dead(self) -> list[str]:
        now = float(self._clock())
        return sorted(n for n, t in self._last.items()
                      if now - t > self.timeout)

    def alive(self) -> list[str]:
        now = float(self._clock())
        return sorted(n for n, t in self._last.items()
                      if now - t <= self.timeout)


class StepWatchdog:
    def __init__(self, *, ratio: float = 3.0, window: int = 32,
                 hang_timeout: float | None = None, on_hang=None):
        self.ratio = ratio
        self.window = window
        self.hang_timeout = hang_timeout
        self.on_hang = on_hang or (lambda: None)
        self.times: list[float] = []
        self.straggler_steps: list[int] = []
        self._step = 0
        self._t0: float | None = None
        self._timer: threading.Timer | None = None

    # -- per-step protocol ---------------------------------------------------
    def start_step(self):
        self._t0 = time.perf_counter()
        if self.hang_timeout is not None:
            self._timer = threading.Timer(self.hang_timeout, self.on_hang)
            self._timer.daemon = True
            self._timer.start()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        dt = time.perf_counter() - self._t0
        straggler = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            straggler = dt > self.ratio * med
        if straggler:
            self.straggler_steps.append(self._step)
        self.times.append(dt)
        self._step += 1
        return straggler

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
