"""Straggler / hang detection.

At thousand-node scale a single slow host drags every collective; detection
must be local and cheap.  ``StepWatchdog`` tracks a robust running median of
step wall-times; a step slower than ``ratio``× the median flags a straggler
event, and ``hang_timeout`` arms a background timer that fires if a step
never completes (collective deadlock after a peer died).  Upstream, the
launcher maps these events to: reroute traffic off the slow host (straggler)
or kill + restart from the last checkpoint (hang) — see ft/restart.py.
"""
from __future__ import annotations

import statistics
import threading
import time


class StepWatchdog:
    def __init__(self, *, ratio: float = 3.0, window: int = 32,
                 hang_timeout: float | None = None, on_hang=None):
        self.ratio = ratio
        self.window = window
        self.hang_timeout = hang_timeout
        self.on_hang = on_hang or (lambda: None)
        self.times: list[float] = []
        self.straggler_steps: list[int] = []
        self._step = 0
        self._t0: float | None = None
        self._timer: threading.Timer | None = None

    # -- per-step protocol ---------------------------------------------------
    def start_step(self):
        self._t0 = time.perf_counter()
        if self.hang_timeout is not None:
            self._timer = threading.Timer(self.hang_timeout, self.on_hang)
            self._timer.daemon = True
            self._timer.start()

    def end_step(self) -> bool:
        """Returns True if this step was a straggler."""
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        dt = time.perf_counter() - self._t0
        straggler = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            straggler = dt > self.ratio * med
        if straggler:
            self.straggler_steps.append(self._step)
        self.times.append(dt)
        self._step += 1
        return straggler

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0
