"""Checkpoint/restart driver: run a step function with periodic checkpoints,
resuming from the newest checkpoint after (injected or real) failures.

``run_with_restarts`` is deliberately synchronous and exception-driven: at
cluster scale the same loop runs under a scheduler that re-launches dead
jobs; determinism comes from the synthetic data pipeline being keyed by
step number, so a resumed run replays the exact batch sequence.
"""
from __future__ import annotations

from typing import Callable

from repro.ckpt import checkpoint as ck


def run_with_restarts(init_state_fn: Callable[[], dict],
                      step_fn: Callable[[dict, int], dict],
                      *, n_steps: int, ckpt_dir, ckpt_every: int = 10,
                      max_restarts: int = 10,
                      state_like_fn=None) -> tuple[dict, dict]:
    """Run ``n_steps``; on any exception, restore and continue.

    Returns (final_state, stats).  ``step_fn`` may raise (fault injection in
    tests, real XLA/host errors in production).
    """
    stats = {"restarts": 0, "completed": 0, "resumed_from": []}
    state = None
    step = 0
    restarts = 0
    while step < n_steps:
        try:
            if state is None:
                last = ck.latest_step(ckpt_dir)
                if last is not None:
                    like = (state_like_fn() if state_like_fn
                            else init_state_fn())
                    state = ck.restore(ckpt_dir, last, like)
                    step = last
                    stats["resumed_from"].append(last)
                else:
                    state = init_state_fn()
                    step = 0
            state = step_fn(state, step)
            step += 1
            stats["completed"] += 1
            if step % ckpt_every == 0:
                ck.save(ckpt_dir, step, state)
        except KeyboardInterrupt:
            raise
        except Exception:
            restarts += 1
            stats["restarts"] = restarts
            if restarts > max_restarts:
                raise
            state = None   # force restore on next iteration
    ck.save(ckpt_dir, step, state)
    return state, stats
