"""Unified model configuration covering all ten assigned architectures.

One ``ModelConfig`` describes dense / MoE / SSM / hybrid / enc-dec / VLM
stacks through a per-layer ``block_pattern``.  Exact arch instances live in
``repro/configs/<id>.py``; reduced smoke variants come from
``ModelConfig.smoke()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

BlockKind = Literal["attn", "attn_local", "mamba", "rwkv", "shared_attn"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0               # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # capacity from the GLOBAL token count: per-expert keep decisions use a
    # data-axis-wide position (one extra tunable allreduce on router
    # stats), so data-sharded runs drop exactly the tokens a single-device
    # run would — at the cost of a dp-times-larger worst-case dispatch
    # buffer.  Off by default (the classic local-capacity GShard behavior).
    global_capacity: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: Literal["rwkv6", "mamba2"] = "mamba2"
    state_dim: int = 64             # N (mamba) / head size (rwkv)
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model (mamba2)
    conv_kernel: int = 4            # causal depthwise conv (mamba2)
    decay_lora_rank: int = 32       # data-dependent decay LoRA (rwkv6)
    chunk: int = 64                 # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 24
    dec_ratio: int = 8              # dec_len = seq_len // dec_ratio


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    patch_dim: int = 1152           # SigLIP output width (stub frontend)
    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "audio", "vlm", "ssm", "hybrid"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention features ------------------------------------------------
    layer_pattern: tuple[BlockKind, ...] = ("attn",)   # cycled over layers
    window: int = 4096              # sliding window for attn_local
    attn_softcap: float | None = None
    final_softcap: float | None = None
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_impl: str = "ref"          # "ref" (paper-faithful baseline) |
                                    # "flash" (chunked online-softmax, §Perf)
    mla: MLAConfig | None = None
    # moe ----------------------------------------------------------------
    moe: MoEConfig | None = None
    # ssm / hybrid ---------------------------------------------------------
    ssm: SSMConfig | None = None
    hybrid_period: int = 0          # shared_attn every k layers (zamba2)
    # enc-dec / vlm ---------------------------------------------------------
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    # embeddings / output ---------------------------------------------------
    tie_embeddings: bool = True
    scale_embed: bool = False       # gemma-style sqrt(d_model) scaling
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # long-context capability (which serve shapes are lowered)
    subquadratic: bool = False
    # training ---------------------------------------------------------------
    scan_layers: bool = True        # False: unroll (serving — per-layer
                                    # cache buffers alias in place)
    remat: bool = True
    optimizer: str = "adamw"        # "adamw" | "adafactor"

    # -- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 256) * 256   # Megatron-style pad

    def heads_padded(self, tp: int) -> int:
        return -(-self.n_heads // tp) * tp

    def kv_heads_padded(self, tp: int) -> int:
        # replicate KV heads up to the TP degree when kv < tp (GQA)
        if self.n_kv_heads >= tp:
            assert self.n_kv_heads % tp == 0
            return self.n_kv_heads
        return tp

    def pattern(self) -> tuple[BlockKind, ...]:
        """Per-layer block kinds, length n_layers (decoder for enc-dec)."""
        out = []
        for i in range(self.n_layers):
            out.append(self.layer_pattern[i % len(self.layer_pattern)])
        return tuple(out)

    def param_count(self) -> int:
        """Approximate dense-equivalent parameter count (for 6ND roofline)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        hq = self.n_heads * self.hd
        hkv = self.n_kv_heads * self.hd
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                    + d * (m.kv_lora_rank + m.rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * hq + 2 * d * hkv + hq * d
        mlp = 3 * d * f
        if self.moe is not None:
            mlp = (3 * d * self.moe.d_ff_expert
                   * (self.moe.n_experts + self.moe.n_shared)
                   + d * self.moe.n_experts)
        ssm = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            if self.ssm.kind == "mamba2":
                ssm = d * (2 * di + 2 * self.ssm.state_dim
                           + di // self.ssm.head_dim) + di * d
            else:
                ssm = 5 * d * d + d * self.d_ff * 2
        per_layer = {"attn": attn + mlp, "attn_local": attn + mlp,
                     "mamba": ssm, "rwkv": ssm, "shared_attn": 0}
        total = sum(per_layer[k] for k in self.pattern())
        if self.hybrid_period:
            total += attn + mlp  # one shared block
        if self.encdec is not None:
            # encoder layers + cross-attention in decoder
            total += self.encdec.n_enc_layers * (attn + mlp)
            total += self.n_layers * attn
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        moe_total = 3 * d * self.moe.d_ff_expert * (
            self.moe.n_experts + self.moe.n_shared)
        moe_active = 3 * d * self.moe.d_ff_expert * (
            self.moe.top_k + self.moe.n_shared)
        n_moe_layers = sum(1 for k in self.pattern()
                           if k in ("attn", "attn_local"))
        return self.param_count() - n_moe_layers * (moe_total - moe_active)

    # -- smoke variant ------------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for 1-device CPU tests."""
        return dataclasses.replace(
            self,
            n_layers=min(4, self.n_layers),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            window=32,
            moe=None if self.moe is None else MoEConfig(
                n_experts=4, top_k=2, d_ff_expert=64,
                n_shared=min(self.moe.n_shared, 1)),
            mla=None if self.mla is None else MLAConfig(
                q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                nope_head_dim=16, v_head_dim=16),
            ssm=None if self.ssm is None else dataclasses.replace(
                self.ssm, state_dim=16, head_dim=16, chunk=8,
                decay_lora_rank=8),
            encdec=None if self.encdec is None else EncDecConfig(
                n_enc_layers=2, dec_ratio=2),
            vlm=None if self.vlm is None else VLMConfig(
                patch_dim=48, n_patches=8),
            hybrid_period=2 if self.hybrid_period else 0,
        )
