"""Model assembly: spec trees, forward pass, loss, prefill/decode.

The same code path serves all ten architectures:

* dense / MoE decoder-only LMs  (llama / gemma / phi / deepseek)
* SSM (rwkv6) and hybrid (zamba2: mamba + shared attention block)
* encoder-decoder (whisper: stub frame embeddings + cross-attention)
* VLM (paligemma: stub patch embeddings + prefix-LM mask)

Layer stacks are grouped into ``lax.scan``s over stacked parameters (compile
time stays flat in depth); heterogeneous patterns (gemma local:global cycles)
scan over the repeating unit, with a remainder group.

Everything is written for manual-SPMD: call inside ``shard_map`` (or plain
jit on one device — every dist op degrades to identity).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist import ops
from repro.dist.axes import AXES, axis_size_or_1
from repro.models import ssm as ssm_mod
from repro.models.attention import attention, attn_specs, cross_attn_specs
from repro.models.config import ModelConfig
from repro.models.layers import (embed_lookup, embed_specs, head_specs,
                                 lm_logits, mlp, mlp_specs, rms_norm,
                                 sharded_xent, sincos_positions)
from repro.models.moe import moe_block, moe_specs
from repro.models.params import ParamSpec, stacked, tree_map_specs


# ---------------------------------------------------------------------------
# stack plan: group the layer pattern into scannable units
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Group:
    name: str
    unit: tuple[str, ...]     # block kinds executed per scan step
    n_rep: int                # scan length


def stack_plan(cfg: ModelConfig) -> list[Group]:
    if not cfg.scan_layers:
        pat = list(cfg.pattern())
        if cfg.hybrid_period:
            out, cnt = [], 0
            for k in pat:
                out.append(k)
                cnt += 1
                if cnt % cfg.hybrid_period == 0:
                    out.append("shared_attn")
            pat = out
        return [Group(f"u{i}", (k,), 1) for i, k in enumerate(pat)]
    pat = list(cfg.pattern())
    if cfg.hybrid_period:
        # zamba2: insert a shared_attn marker after every k SSM layers
        out, cnt = [], 0
        for k in pat:
            out.append(k)
            cnt += 1
            if cnt % cfg.hybrid_period == 0:
                out.append("shared_attn")
        pat = out
    unit = list(cfg.layer_pattern)
    if cfg.hybrid_period:
        unit = list(cfg.layer_pattern) * cfg.hybrid_period + ["shared_attn"]
    # largest prefix of full units
    u = len(unit)
    n_rep = 0
    while (n_rep + 1) * u <= len(pat) and \
            pat[n_rep * u:(n_rep + 1) * u] == unit:
        n_rep += 1
    groups = []
    if n_rep:
        groups.append(Group("g0", tuple(unit), n_rep))
    rem = pat[n_rep * u:]
    if rem:
        groups.append(Group("g1", tuple(rem), 1))
    return groups


# ---------------------------------------------------------------------------
# per-block specs
# ---------------------------------------------------------------------------


def _block_specs(kind: str, cfg: ModelConfig, tp: int) -> dict:
    if kind in ("attn", "attn_local"):
        s = {
            "ln1": ParamSpec((cfg.d_model,), (None,), init="zeros",
                             dtype="float32"),
            "attn": attn_specs(cfg, tp),
            "ln2": ParamSpec((cfg.d_model,), (None,), init="zeros",
                             dtype="float32"),
        }
        s["ffn"] = (moe_specs(cfg) if cfg.moe is not None
                    else mlp_specs(cfg.d_model, cfg.d_ff, cfg.dtype))
        if cfg.encdec is not None:
            s["ln_x"] = ParamSpec((cfg.d_model,), (None,), init="zeros",
                                  dtype="float32")
            s["xattn"] = cross_attn_specs(cfg, tp)
        return s
    if kind == "rwkv":
        return ssm_mod.rwkv_specs(cfg, tp)
    if kind == "mamba":
        return ssm_mod.mamba_specs(cfg, tp)
    raise ValueError(kind)


def _enc_block_specs(cfg: ModelConfig, tp: int) -> dict:
    return {
        "ln1": ParamSpec((cfg.d_model,), (None,), init="zeros",
                         dtype="float32"),
        "attn": attn_specs(dataclasses.replace(cfg, mla=None), tp),
        "ln2": ParamSpec((cfg.d_model,), (None,), init="zeros",
                         dtype="float32"),
        "ffn": mlp_specs(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def model_specs(cfg: ModelConfig, tp: int) -> dict:
    """The full parameter tree (ParamSpec leaves)."""
    specs: dict[str, Any] = {"embed": embed_specs(
        cfg.vocab_padded, cfg.d_model, cfg.dtype)}
    if not cfg.tie_embeddings:
        specs["head"] = head_specs(cfg.d_model, cfg.vocab_padded, cfg.dtype)
    specs["final_norm"] = ParamSpec((cfg.d_model,), (None,), init="zeros",
                                    dtype="float32")
    stack: dict[str, Any] = {}
    for g in stack_plan(cfg):
        sub = {}
        for i, kind in enumerate(g.unit):
            if kind == "shared_attn":
                continue  # shared params live outside the scan
            sub[f"b{i}_{kind}"] = tree_map_specs(
                functools.partial(_stk, g.n_rep),
                _block_specs(kind, cfg, tp)) if g.n_rep > 1 else \
                _block_specs(kind, cfg, tp)
        stack[g.name] = sub
    specs["stack"] = stack
    if cfg.hybrid_period:
        shared_cfg = dataclasses.replace(cfg, moe=None, mla=None)
        specs["shared_attn"] = {
            "proj_in": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                 ("data", None), dtype=cfg.dtype),
            **_block_specs("attn", shared_cfg, tp),
        }
    if cfg.encdec is not None:
        specs["encoder"] = tree_map_specs(
            functools.partial(_stk, cfg.encdec.n_enc_layers),
            _enc_block_specs(cfg, tp))
        specs["enc_final_norm"] = ParamSpec((cfg.d_model,), (None,),
                                            init="zeros", dtype="float32")
    if cfg.vlm is not None:
        specs["img_proj"] = ParamSpec((cfg.vlm.patch_dim, cfg.d_model),
                                      ("data", None), dtype=cfg.dtype)
    return specs


def _stk(n, spec: ParamSpec) -> ParamSpec:
    return stacked(n, spec)


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, s_max: int, tp: int,
                *, seq_sharded: bool = False) -> dict:
    """ParamSpec tree for the KV/SSM cache (global shapes + shardings)."""
    hd = cfg.hd
    kv_sharded = cfg.n_kv_heads % tp == 0 if cfg.n_kv_heads else False
    n_kv = cfg.n_kv_heads
    kv_dim = "model" if kv_sharded else None
    bdim, sdim = ("data", None) if not seq_sharded else (None, "data")
    dt = cfg.dtype

    def attn_cache():
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "c_kv": ParamSpec((batch, s_max, m.kv_lora_rank),
                                  (bdim, sdim, None), dtype=dt),
                "k_rope": ParamSpec((batch, s_max, m.rope_head_dim),
                                    (bdim, sdim, None), dtype=dt),
                "len": ParamSpec((), (), init="zeros", dtype="int32"),
            }
        return {
            "k": ParamSpec((batch, s_max, n_kv, hd),
                           (bdim, sdim, kv_dim, None), dtype=dt),
            "v": ParamSpec((batch, s_max, n_kv, hd),
                           (bdim, sdim, kv_dim, None), dtype=dt),
            "len": ParamSpec((), (), init="zeros", dtype="int32"),
        }

    # SSM states have no sequence dim: when the cell seq-shards (batch=1,
    # long-context), the state is replicated over "data" instead.
    sb = None if seq_sharded else "data"

    def ssm_cache(kind):
        if kind == "rwkv":
            h = ssm_mod.rwkv_heads_padded(cfg, tp)
            sd = cfg.ssm.head_dim
            return {
                "last_tm": ParamSpec((batch, 1, cfg.d_model),
                                     (sb, None, None), dtype=dt),
                "last_cm": ParamSpec((batch, 1, cfg.d_model),
                                     (sb, None, None), dtype=dt),
                "s": ParamSpec((batch, h, sd, sd),
                               (sb, "model", None, None),
                               dtype="float32"),
            }
        di = cfg.ssm.expand * cfg.d_model
        nh = di // cfg.ssm.head_dim
        k = cfg.ssm.conv_kernel
        return {
            "conv_x": ParamSpec((batch, k - 1, di),
                                (sb, None, "model"), dtype=dt),
            "conv_bc": ParamSpec((batch, k - 1, 2 * cfg.ssm.state_dim),
                                 (sb, None, None), dtype=dt),
            "s": ParamSpec((batch, nh, cfg.ssm.state_dim, cfg.ssm.head_dim),
                           (sb, "model", None, None), dtype="float32"),
        }

    def block_cache(kind):
        if kind in ("attn", "attn_local"):
            c = {"self": attn_cache()}
            if cfg.encdec is not None:
                enc_len = s_max  # encoder length == s_max convention
                c["cross_k"] = ParamSpec(
                    (batch, enc_len, n_kv, hd),
                    (bdim, None, kv_dim, None), dtype=dt)
                c["cross_v"] = ParamSpec(
                    (batch, enc_len, n_kv, hd),
                    (bdim, None, kv_dim, None), dtype=dt)
            return c
        if kind == "shared_attn":
            return {"self": attn_cache()}
        return ssm_cache(kind)

    out: dict[str, Any] = {"stack": {}}
    for g in stack_plan(cfg):
        sub = {}
        for i, kind in enumerate(g.unit):
            bc = block_cache(kind)
            sub[f"b{i}_{kind}"] = (tree_map_specs(
                functools.partial(_stk, g.n_rep), bc)
                if g.n_rep > 1 else bc)
        out["stack"][g.name] = sub
    return out


# ---------------------------------------------------------------------------
# block execution
# ---------------------------------------------------------------------------


def _run_attn_block(p, cfg: ModelConfig, x, *, kind, pos, mode, cache,
                    n_prefix, enc_out, use_rope, seq_sharded=False):
    aux = jnp.float32(0.0)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    mask_kind = ("local" if kind == "attn_local" else
                 ("prefix" if n_prefix else "causal"))
    a = attention(p["attn"], cfg, h, pos=pos, kind=mask_kind,
                  n_prefix=n_prefix,
                  cache=None if cache is None else cache.get("self"),
                  mode=mode, use_rope=use_rope, seq_sharded=seq_sharded)
    x = x + a.y
    new_cache = {"self": a.cache} if a.cache is not None else None

    if cfg.encdec is not None:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if enc_out is not None:      # train/prefill: build cross kv now
            ck, cv = _cross_kv(p["xattn"], cfg, enc_out)
        else:                        # decode: cached
            ck, cv = cache["cross_k"], cache["cross_v"]
        ca = attention(p["xattn"], cfg, hx, pos=pos, cross_kv=(ck, cv),
                       mode="train", use_rope=False)
        x = x + ca.y
        if new_cache is not None:
            new_cache["cross_k"], new_cache["cross_v"] = ck, cv
        elif cache is not None:
            new_cache = {"self": cache.get("self"), "cross_k": ck,
                         "cross_v": cv}

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_block(p["ffn"], cfg, h2)
    else:
        y = mlp(p["ffn"], h2)
    x = x + y
    return x, new_cache, aux


def _cross_kv(p, cfg: ModelConfig, enc_out):
    tp = axis_size_or_1(AXES.model)
    hd = cfg.hd
    kv_sharded = cfg.n_kv_heads % tp == 0
    if kv_sharded:
        k = ops.col_matmul(enc_out, p["w_k"], fsdp_dim=0)
        v = ops.col_matmul(enc_out, p["w_v"], fsdp_dim=0)
    else:
        k = ops.matmul_accumulate(enc_out, ops.tp_psum_grad(p["w_k"]))
        v = ops.matmul_accumulate(enc_out, ops.tp_psum_grad(p["w_v"]))
    n_loc = (cfg.n_kv_heads // tp) if kv_sharded else cfg.n_kv_heads
    k = k.reshape(*enc_out.shape[:-1], n_loc, hd)
    v = v.reshape(*enc_out.shape[:-1], n_loc, hd)
    return k, v


def _run_block(kind, p, cfg, x, *, pos, mode, cache, n_prefix, enc_out,
               use_rope, shared_p=None, resid0=None, seq_sharded=False):
    if kind in ("attn", "attn_local"):
        return _run_attn_block(p, cfg, x, kind=kind, pos=pos, mode=mode,
                               cache=cache, n_prefix=n_prefix,
                               enc_out=enc_out, use_rope=use_rope,
                               seq_sharded=seq_sharded)
    if kind == "shared_attn":
        # zamba2: shared transformer block on concat(x, resid0), projected in
        h = ops.matmul_accumulate(jnp.concatenate([x, resid0], axis=-1),
                                  shared_p["proj_in"])
        shared_cfg = dataclasses.replace(cfg, moe=None, mla=None)
        y, c, aux = _run_attn_block(
            shared_p, shared_cfg, h, kind="attn", pos=pos, mode=mode,
            cache=cache, n_prefix=n_prefix, enc_out=None, use_rope=use_rope,
            seq_sharded=seq_sharded)
        return x + y, c, aux
    if kind == "rwkv":
        y, st = ssm_mod.rwkv_block(p, cfg, x, state=cache)
        return y, st, jnp.float32(0.0)
    if kind == "mamba":
        y, st = ssm_mod.mamba_block(p, cfg, x, state=cache)
        return y, st, jnp.float32(0.0)
    raise ValueError(kind)


def _run_stack(params, cfg: ModelConfig, x, *, pos, mode, caches,
               n_prefix, enc_out, use_rope, seq_sharded=False):
    """Execute all groups; returns (x, new_caches, aux_sum)."""
    aux_total = jnp.float32(0.0)
    new_caches: dict[str, Any] = {"stack": {}}
    resid0 = x
    shared_p = params.get("shared_attn")

    for g in stack_plan(cfg):
        gp = params["stack"][g.name]
        gc = None if caches is None else caches["stack"][g.name]

        if g.n_rep == 1:
            ncs = {}
            for i, kind in enumerate(g.unit):
                key = f"b{i}_{kind}"
                bc = None if gc is None else gc.get(key)
                x, nc, aux = _run_block(
                    kind, gp.get(key), cfg, x, pos=pos, mode=mode, cache=bc,
                    n_prefix=n_prefix, enc_out=enc_out, use_rope=use_rope,
                    shared_p=shared_p, resid0=resid0,
                    seq_sharded=seq_sharded)
                aux_total = aux_total + aux
                if nc is not None:
                    ncs[key] = nc
            new_caches["stack"][g.name] = ncs
            continue

        # scanned group: params (and caches) have leading dim n_rep
        def _unit(xc, auxc, lp, lc):
            ncs = {}
            for i, kind in enumerate(g.unit):
                key = f"b{i}_{kind}"
                bc = None if lc is None else lc.get(key)
                xc, nc, aux = _run_block(
                    kind, lp.get(key), cfg, xc, pos=pos, mode=mode,
                    cache=bc, n_prefix=n_prefix, enc_out=enc_out,
                    use_rope=use_rope, shared_p=shared_p, resid0=resid0,
                    seq_sharded=seq_sharded)
                auxc = auxc + aux
                if nc is not None:
                    ncs[key] = nc
            return xc, auxc, ncs

        if gc is None:
            def body(carry, lp):
                xc, auxc, _ = _unit(carry[0], carry[1], lp, None)
                return (xc, auxc), None
        else:
            def body(carry, layer_in):
                lp, lc = layer_in
                xc, auxc, ncs = _unit(carry[0], carry[1], lp, lc)
                return (xc, auxc), ncs

        if cfg.remat and mode == "train":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        (x, aux_total), ncs = lax.scan(
            body, (x, aux_total), gp if gc is None else (gp, gc))
        new_caches["stack"][g.name] = ncs if gc is not None else None

    return x, (new_caches if caches is not None else None), aux_total


# ---------------------------------------------------------------------------
# embedding front-ends per family
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch, *, pos0=0):
    """Returns (x, pos, n_prefix, labels_mask_extra)."""
    scale = (cfg.d_model ** 0.5) if cfg.scale_embed else None
    if cfg.vlm is not None and "patches" in batch:
        img = ops.matmul_accumulate(batch["patches"], params["img_proj"])
        img = img.astype(jnp.dtype(cfg.dtype))
        txt = embed_lookup(params["embed"], batch["tokens"], scale=scale)
        x = jnp.concatenate([img, txt], axis=1)
        n_prefix = img.shape[1]
        pos = pos0 + jnp.arange(x.shape[1])[None, :]
        return x, pos, n_prefix
    x = embed_lookup(params["embed"], batch["tokens"], scale=scale)
    pos = pos0 + jnp.arange(x.shape[1])[None, :]
    if cfg.encdec is not None:
        x = x + sincos_positions(pos, cfg.d_model).astype(x.dtype)
    return x, pos, 0


def _encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings [B, S_enc, D]."""
    pos = jnp.arange(frames.shape[1])[None, :]
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sincos_positions(pos, cfg.d_model).astype(x.dtype)

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        a = attention(lp["attn"], cfg, h, pos=pos, kind="full",
                      mode="train", use_rope=False)
        xc = carry + a.y
        h2 = rms_norm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + mlp(lp["ffn"], h2)
        return xc, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch, *, mode="train", caches=None,
            pos0=0, seq_sharded=False):
    """Full forward.  Returns (logits [B,S,V_t], new_caches, aux)."""
    use_rope = cfg.encdec is None
    enc_out = None
    if cfg.encdec is not None and "frames" in batch:
        enc_out = _encode(params, cfg, batch["frames"])
    x, pos, n_prefix = _embed_inputs(params, cfg, batch, pos0=pos0)
    x, new_caches, aux = _run_stack(
        params, cfg, x, pos=pos, mode=mode, caches=caches,
        n_prefix=n_prefix, enc_out=enc_out, use_rope=use_rope,
        seq_sharded=seq_sharded)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x,
                       params.get("head") if not cfg.tie_embeddings else None,
                       final_softcap=cfg.final_softcap)
    return logits, new_caches, aux


def loss_fn(params, cfg: ModelConfig, batch):
    """Next-token CE (text positions only for VLM).  Scalar local mean."""
    logits, _, aux = forward(params, cfg, batch, mode="train")
    labels = batch["labels"]
    if cfg.vlm is not None:
        n_img = cfg.vlm.n_patches
        logits = logits[:, n_img:]
    mask = batch.get("mask")
    loss = sharded_xent(logits[:, :-1], labels[:, 1:],
                        None if mask is None else mask[:, 1:])
    return loss + 0.01 * aux, {"nll": loss, "aux": aux}


def init_caches(cfg: ModelConfig, batch_size: int, s_max: int,
                *, seq_sharded: bool = False):
    """Zero caches with SHARD-LOCAL shapes (call inside shard_map/jit)."""
    from repro.dist.axes import axis_size_or_1 as _as
    tp = _as(AXES.model)
    sizes = {"model": tp, "data": _as(AXES.data)}
    specs = cache_specs(cfg, batch_size, s_max, tp, seq_sharded=seq_sharded)

    def mk(s: ParamSpec):
        return jnp.zeros(s.local_shape(sizes), jnp.dtype(s.dtype))

    return tree_map_specs(mk, specs)


def prefill(params, cfg: ModelConfig, batch, caches, *, seq_sharded=False):
    """Fill caches from a prompt; returns (last-token logits, caches)."""
    logits, new_caches, _ = forward(params, cfg, batch, mode="prefill",
                                    caches=caches, seq_sharded=seq_sharded)
    return logits[:, -1:], new_caches


def decode_step(params, cfg: ModelConfig, token, caches, t, *,
                seq_sharded=False):
    """One-token step.  token: [B,1] int32; t: current length (scalar)."""
    batch = {"tokens": token}
    logits, new_caches, _ = forward(params, cfg, batch, mode="decode",
                                    caches=caches, pos0=t,
                                    seq_sharded=seq_sharded)
    return logits, new_caches
