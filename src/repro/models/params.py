"""Parameter specs: one declarative tree drives init, sharding and dry-run.

Each leaf is a ``ParamSpec`` with a GLOBAL shape and per-dim mesh-axis
assignment ("model" = TP, "data" = FSDP/ZeRO-3, None = replicated; params
are never sharded over "pod" — the pod axis is pure DP).  From the tree we
derive:

* ``PartitionSpec`` per leaf                (jit in_shardings / dry-run)
* global ``ShapeDtypeStruct`` per leaf      (AOT lowering without allocation)
* shard-local init inside ``shard_map``     (keys folded by shard indices)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._compat import tree_flatten_with_path
from repro.dist.axes import axis_size_or_1

Tree = dict[str, Any]   # nested dict of ParamSpec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dims: tuple[str | None, ...]
    init: str = "normal"      # normal | zeros | ones | scaled(fan-in)
    scale: float | None = None
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)

    def pspec(self) -> P:
        return P(*self.dims)

    def global_sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))

    def local_shape(self, sizes: dict[str, int]) -> tuple[int, ...]:
        out = []
        for s, d in zip(self.shape, self.dims):
            div = sizes.get(d, 1) if d else 1
            assert s % div == 0, f"dim {s} not divisible by {d}={div}"
            out.append(s // div)
        return tuple(out)


def stacked(n: int, spec: ParamSpec) -> ParamSpec:
    """Prepend a scan-stack dimension (replicated)."""
    return ParamSpec((n,) + spec.shape, (None,) + spec.dims, spec.init,
                     spec.scale, spec.dtype)


def tree_map_specs(fn, tree: Tree):
    return jax.tree.map(fn, tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_pspecs(tree: Tree):
    return tree_map_specs(lambda s: s.pspec(), tree)


def tree_global_sds(tree: Tree):
    return tree_map_specs(lambda s: s.global_sds(), tree)


def tree_nbytes(tree: Tree) -> int:
    leaves = jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize
    return total


def _init_leaf(spec: ParamSpec, key, sizes: dict[str, int]):
    shape = spec.local_shape(sizes)
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(shape, dt)
    if spec.init == "ones":
        return jnp.ones(shape, dt)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale if spec.scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dt)


def init_tree(tree: Tree, key, *, fold: int = 0):
    """Initialize shard-local params.  Call INSIDE shard_map; ``fold`` is a
    per-shard fold (data_idx * tp + model_idx) so different shards hold
    different random slices, while pods replicate (fold excludes the pod
    index)."""
    sizes = {"model": axis_size_or_1("model"),
             "data": axis_size_or_1("data")}
    flat, treedef = tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    leaves = []
    for i, (path, spec) in enumerate(flat):
        k = jax.random.fold_in(jax.random.fold_in(key, i), fold)
        leaves.append(_init_leaf(spec, k, sizes))
    return jax.tree.unflatten(treedef, leaves)
