"""Mixture-of-Experts with expert parallelism over the TP axis.

GShard-style capacity dispatch, but position-in-expert is computed with
cumsum over flattened (token, slot) choices — no [T, E, C] one-hot tensor is
ever materialized (T·E·C would be terabytes at DeepSeek scale).  Tokens over
capacity are dropped (standard capacity-factor routing).  With
``cfg.moe.global_capacity`` the keep decision uses the token's position in
the GLOBAL per-expert order (one extra tunable ``api.allreduce`` of router
stats over the data axis), making data-sharded drops identical to a
single-device run.

The expert shuffle is TWO all-to-alls over the model axis through
``ops.ep_alltoall`` — i.e. GL8 territory for the tuner, and the single
largest collective payload in MoE training.

Shared experts (DeepSeek) run as a dense TP MLP on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api
from repro.core._axis import axis_index
from repro.dist import ops
from repro.dist.axes import AXES, axis_size_or_1, has_axis
from repro.models.config import ModelConfig
from repro.models.layers import mlp, mlp_specs
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.dtype
    specs = {
        "router": ParamSpec((d, m.n_experts), ("data", None),
                            dtype="float32"),
        # experts sharded over TP on the expert dim, FSDP on d_model
        "w_in": ParamSpec((m.n_experts, d, m.d_ff_expert),
                          ("model", "data", None), dtype=dt),
        "w_gate": ParamSpec((m.n_experts, d, m.d_ff_expert),
                            ("model", "data", None), dtype=dt),
        "w_out": ParamSpec((m.n_experts, m.d_ff_expert, d),
                           ("model", None, "data"), dtype=dt),
    }
    if m.n_shared:
        specs["shared"] = mlp_specs(d, m.n_shared * m.d_ff_expert, dt)
    return specs


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(4, -(-c // 4) * 4)


def moe_block(p: dict, cfg: ModelConfig, x) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss).  Experts sharded over the model axis."""
    m = cfg.moe
    tp = axis_size_or_1(AXES.model)
    e_loc = m.n_experts // tp
    b, s, d = x.shape
    t = b * s
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    # --- routing (fp32, replicated over TP) ---------------------------------
    logits = ops.matmul_accumulate(xt.astype(jnp.float32),
                                   p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    gate_vals, expert_ids = lax.top_k(probs, m.top_k)            # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((m.n_experts,), jnp.float32).at[
        expert_ids.reshape(-1)].add(1.0) / (t * m.top_k)
    aux = m.n_experts * jnp.sum(me * ce)

    # --- position-in-expert via cumsum over flattened (token, slot) ---------
    flat_e = expert_ids.reshape(-1)                              # [T*k]
    onehot = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                         # [T*k, E]
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                    # [T*k]
    if m.global_capacity and has_axis(AXES.data):
        # global-capacity mode: keep decisions use the token's position in
        # the GLOBAL per-expert order.  The batch is split contiguously
        # over the data axis, so global position = local cumsum + the
        # preceding shards' per-expert counts — one tiny tunable allreduce
        # of one-hot-placed router stats (dp x E int32).  Kept-token sets
        # then match the single-device run exactly.
        dp = axis_size_or_1(AXES.data)
        cap = _capacity(t * dp, cfg)
        counts = jnp.sum(onehot, axis=0, dtype=jnp.int32)        # [E] local
        placed = lax.dynamic_update_slice(
            jnp.zeros((dp, m.n_experts), jnp.int32), counts[None],
            (axis_index(AXES.data), 0))
        # one-hot-placed allreduce (the GL3 allgather-as-allreduce shape):
        # an allgather of the [E] counts would move dp x less, but the
        # ROADMAP item specifies the stats exchange as a tunable allreduce
        # and the payload is tiny (dp*E ints, latency-regime territory —
        # exactly where the tuner's doubling mock-up earns its keep)
        all_counts = api.allreduce(placed, AXES.data)            # [dp, E]
        before = jnp.arange(dp)[:, None] < axis_index(AXES.data)
        offset = jnp.sum(jnp.where(before, all_counts, 0), axis=0)
        pos_keep = pos_in_e + offset[flat_e]                     # global pos
        # local buffer only ever holds this shard's kept tokens
        cap_buf = min(cap, max(4, -(-(t * m.top_k) // 4) * 4))
    else:
        pos_keep = pos_in_e
        cap_buf = cap
    keep = pos_keep < cap
    slot = jnp.where(keep, flat_e * cap_buf + pos_in_e,
                     m.n_experts * cap_buf)

    # --- dispatch: scatter tokens into [E*cap_buf, D] ------------------------
    xk = jnp.repeat(xt, m.top_k, axis=0)                         # [T*k, D]
    buf = jnp.zeros((m.n_experts * cap_buf + 1, d), x.dtype)
    buf = buf.at[slot].add(xk * keep[:, None].astype(x.dtype))
    buf = buf[:-1]                                               # drop bin

    # --- EP all-to-all: expert-major buffer is already shard-tiled ----------
    buf = ops.ep_alltoall(buf)                                   # [tp*Eloc*cap, D]
    buf = buf.reshape(tp, e_loc, cap_buf, d).transpose(1, 0, 2, 3)
    buf = buf.reshape(e_loc, tp * cap_buf, d)

    # --- expert FFN ----------------------------------------------------------
    w_in = ops.fsdp_gather(p["w_in"], 1)                         # [Eloc, D, F]
    w_gate = ops.fsdp_gather(p["w_gate"], 1)
    w_out = ops.fsdp_gather(p["w_out"], 2)                       # [Eloc, F, D]
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)

    # --- reverse all-to-all + combine ---------------------------------------
    y = y.reshape(e_loc, tp, cap_buf, d).transpose(1, 0, 2, 3).reshape(
        tp * e_loc * cap_buf, d)
    y = ops.ep_alltoall(y)                                       # [E*cap, D]
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    gathered = y[slot]                                           # [T*k, D]
    gathered = gathered * (gate_vals.reshape(-1)[:, None].astype(y.dtype)
                           * keep[:, None].astype(y.dtype))
    out = jnp.sum(gathered.reshape(t, m.top_k, d), axis=1)

    if m.n_shared:
        out = out + mlp(p["shared"], xt)
    return out.reshape(b, s, d), aux
