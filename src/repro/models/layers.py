"""Shared layers: norms, RoPE, gated MLP, embedding + sharded-vocab loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api
from repro.dist import ops
from repro.dist.axes import AXES, axis_size_or_1, has_axis
from repro.models.params import ParamSpec


def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding; x: [..., S, H, hd], positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]                        # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sincos_positions(positions, d_model: int):
    """Whisper-style absolute sinusoidal embeddings; positions [..., S]."""
    half = d_model // 2
    freq = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# gated MLP (column -> row parallel)
# ---------------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int, dtype: str):
    return {
        "w_in": ParamSpec((d_model, d_ff), ("data", "model"), dtype=dtype),
        "w_gate": ParamSpec((d_model, d_ff), ("data", "model"), dtype=dtype),
        "w_out": ParamSpec((d_ff, d_model), ("model", "data"), dtype=dtype),
    }


def mlp(params, x, *, act=jax.nn.silu):
    # fsdp_dim=0: the data-axis K-dim weight gather is fused into the
    # matmul (matmul_accumulate — the contraction-dim ring)
    h = ops.col_matmul(x, params["w_in"], fsdp_dim=0)
    g = ops.col_matmul(x, params["w_gate"], fsdp_dim=0)
    # fsdp_dim=1: the data-axis w_out gather AND the model-axis
    # reduce-scatter both fuse around the matmul (matmul_reducescatter_2d
    # — tuner picks the nested ring vs unfused per 2-D cell)
    return ops.row_matmul(act(g) * h, params["w_out"], fsdp_dim=1)


# ---------------------------------------------------------------------------
# embedding (vocab sharded over TP, feature over FSDP) + sharded-vocab loss
# ---------------------------------------------------------------------------


def embed_specs(vocab_padded: int, d_model: int, dtype: str):
    return {"table": ParamSpec((vocab_padded, d_model), ("model", "data"),
                               scale=d_model ** -0.5, dtype=dtype)}


def embed_lookup(params, tokens, *, scale: float | None = None):
    """tokens: [B, S] global ids; table vocab-sharded over TP."""
    table = ops.fsdp_gather(params["table"], 1)       # [V_t, D]
    v_t = table.shape[0]
    t_idx = lax.axis_index(AXES.model) if has_axis(AXES.model) else 0
    local = tokens - t_idx * v_t
    ok = (local >= 0) & (local < v_t)
    emb = jnp.take(table, jnp.clip(local, 0, v_t - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, jnp.zeros_like(emb))
    emb = ops.tp_allreduce(emb)
    if scale is not None:
        emb = emb * jnp.asarray(scale, emb.dtype)
    return emb


def lm_logits(params, x, head_params=None, *, final_softcap=None):
    """x: [B, S, D] -> logits [B, S, V_t] (vocab-sharded, fp32)."""
    if head_params is not None:
        # w [D, V_t], K-sharded over data: fused accumulate-ring gather
        logits = ops.col_matmul(x, head_params["w"], fsdp_dim=0)
    else:
        # table [V_t, D/p_data]: transposed it is K-sharded on dim 0
        logits = ops.col_matmul(x, params["table"].T, fsdp_dim=0)
    logits = logits.astype(jnp.float32)
    if final_softcap:
        logits = jnp.tanh(logits / final_softcap) * final_softcap
    return logits


def head_specs(d_model: int, vocab_padded: int, dtype: str):
    return {"w": ParamSpec((d_model, vocab_padded), ("data", "model"),
                           dtype=dtype)}


def sharded_xent(logits, labels, mask=None):
    """Cross-entropy with the vocab dim sharded over TP.

    logits: [B, S, V_t] fp32; labels: [B, S] global ids; mask: [B, S].
    Returns mean NLL over unmasked tokens of the local batch shard (caller
    averages over data/pod axes).
    """
    v_t = logits.shape[-1]
    t_idx = lax.axis_index(AXES.model) if has_axis(AXES.model) else 0
    # stop-grad BEFORE pmax: logsumexp is m-invariant and pmax has no AD rule
    m_loc = lax.stop_gradient(jnp.max(logits, axis=-1))
    m = lax.pmax(m_loc, AXES.model) if has_axis(AXES.model) else m_loc
    se = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
    se = ops.tp_allreduce(se)
    logz = jnp.log(se) + m
    local = labels - t_idx * v_t
    ok = (local >= 0) & (local < v_t)
    tgt = jnp.take_along_axis(
        logits, jnp.clip(local, 0, v_t - 1)[..., None], axis=-1)[..., 0]
    tgt = jnp.where(ok, tgt, 0.0)
    tgt = ops.tp_allreduce(tgt)
    nll = logz - tgt
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = float(nll.size)
    return jnp.sum(nll) / denom
