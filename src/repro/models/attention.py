"""Attention: GQA (+windows/softcap/prefix), MLA, caches, seq-sharded decode.

Head sharding contract (TP degree ``t``):

* q heads padded up to a multiple of ``t``; each shard owns ``Hq_pad/t``.
* kv heads: if ``kv % t == 0`` the kv projections are model-sharded like q;
  otherwise (kv < t, e.g. gemma MQA) kv projections are REPLICATED, every
  shard computes all kv heads, and ``tp_psum_grad`` sums the partial weight
  grads.  The per-shard q-head block picks its kv group by index.

Cache modes:

* batch-sharded  — cache [B_loc, S_max, KVloc, hd]; standard decode.
* seq-sharded    — cache [B, S_max/d, KVloc, hd] over the data axis
  (long-context, batch < data size); decode uses flash-decoding partials
  combined with a tunable all-reduce over "data" (GL6/GL7 territory).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api
from repro.dist import ops
from repro.dist.axes import AXES, axis_size_or_1, has_axis
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm, rope
from repro.models.params import ParamSpec

NEG = -1e30


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig, tp: int) -> dict:
    d, hd, dt = cfg.d_model, cfg.hd, cfg.dtype
    hq = cfg.heads_padded(tp)
    if cfg.mla is not None:
        m = cfg.mla
        qk_hd = m.nope_head_dim + m.rope_head_dim
        return {
            "w_dq": ParamSpec((d, m.q_lora_rank), ("data", None), dtype=dt),
            "q_norm": ParamSpec((m.q_lora_rank,), (None,), init="zeros",
                                dtype="float32"),
            "w_uq": ParamSpec((m.q_lora_rank, hq * qk_hd), ("data", "model"),
                              dtype=dt),
            "w_dkv": ParamSpec((d, m.kv_lora_rank + m.rope_head_dim),
                               ("data", None), dtype=dt),
            "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), init="zeros",
                                 dtype="float32"),
            "w_ukv": ParamSpec(
                (m.kv_lora_rank, hq * (m.nope_head_dim + m.v_head_dim)),
                ("data", "model"), dtype=dt),
            "w_o": ParamSpec((hq * m.v_head_dim, d), ("model", "data"),
                             dtype=dt),
        }
    kv_sharded = cfg.n_kv_heads % tp == 0
    kv_dim = ("model" if kv_sharded else None)
    n_kv = cfg.n_kv_heads
    specs = {
        "w_q": ParamSpec((d, hq * hd), ("data", "model"), dtype=dt),
        "w_k": ParamSpec((d, n_kv * hd), ("data", kv_dim), dtype=dt),
        "w_v": ParamSpec((d, n_kv * hd), ("data", kv_dim), dtype=dt),
        "w_o": ParamSpec((hq * hd, d), ("model", "data"), dtype=dt),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), init="zeros",
                                    dtype="float32")
        specs["k_norm"] = ParamSpec((hd,), (None,), init="zeros",
                                    dtype="float32")
    return specs


def cross_attn_specs(cfg: ModelConfig, tp: int) -> dict:
    """Decoder cross-attention (whisper): q from decoder, kv from encoder."""
    return attn_specs(dataclasses.replace(cfg, mla=None), tp)


# ---------------------------------------------------------------------------
# mask construction
# ---------------------------------------------------------------------------


def make_mask(q_pos, kv_pos, *, kind: str, window: int = 0,
              n_prefix: int = 0, kv_len_valid=None):
    """Boolean [.., Sq, Skv] attend-mask.

    kind: "causal" | "local" (causal & window) | "prefix" (bidirectional
    for kv_pos < n_prefix, else causal) | "full" (encoder).
    ``kv_len_valid``: scalar — positions >= it are invalid (unfilled cache).
    """
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    if kind == "full":
        m = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    elif kind == "causal":
        m = k <= q
    elif kind == "local":
        m = (k <= q) & (k > q - window)
    elif kind == "prefix":
        m = (k <= q) | (k < n_prefix)
    else:
        raise ValueError(kind)
    if kv_len_valid is not None:
        m = m & (k < kv_len_valid)
    return m


# ---------------------------------------------------------------------------
# core attention math (jnp reference; kernels/ has the Pallas path)
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask, *, softcap=None, scale=None):
    """q:[B,Sq,H,dh] k/v:[B,Skv,H,dh(v)] mask:[B?,1?,Sq,Skv] -> [B,Sq,H,dv]"""
    dh = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s * (scale if scale is not None else 1.0 / math.sqrt(dh))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask[:, None, :, :] if mask.ndim == 3 else mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def _sdpa_partial(q, k, v, mask, *, softcap=None):
    """Flash-decoding local partial: returns (o_raw, l, m) over local kv."""
    dh = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask[:, None, :, :] if mask.ndim == 3 else mask, s, NEG)
    m = jnp.max(s, axis=-1)                              # [B,H,Sq]
    w = jnp.exp(s - m[..., None])
    l = jnp.sum(w, axis=-1)                              # [B,H,Sq]
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return o, l, m


def _chunk_mask(q_pos, kv_pos_chunk, *, kind, window, n_prefix, kv_valid):
    """Mask [B, Sq, C] for one KV chunk, built from positions (never a dense
    [Sq, Skv] tensor — that materialization is what the flash path removes).
    """
    q = q_pos[..., :, None]
    kp = kv_pos_chunk[None, None, :]
    if kind == "full":
        m = jnp.ones(jnp.broadcast_shapes(q.shape, kp.shape), bool)
    elif kind == "causal":
        m = kp <= q
    elif kind == "local":
        m = (kp <= q) & (kp > q - window)
    elif kind == "prefix":
        m = (kp <= q) | (kp < n_prefix)
    else:
        raise ValueError(kind)
    if kv_valid is not None:
        m = m & (kp < kv_valid)
    return m


def _flash_jnp(q, k, v, q_pos, kv_pos, *, kind, window=0, n_prefix=0,
               kv_valid=None, softcap=None, scale=None, chunk=1024):
    """Pure-JAX flash attention: online softmax over KV chunks, grouped GQA
    (no repeated-KV materialization).  Matches the Pallas kernel's schedule;
    used as the optimized attention path in §Perf.

    q: [B, Sq, HK, G, dh]; k, v: [B, Skv, HK, dh]; kv_pos: [Skv].
    Returns [B, Sq, HK, G, dh] in q's dtype.
    """
    b, sq, hk, g, dh = q.shape
    skv = k.shape[1]
    c = min(chunk, skv)
    while skv % c:
        c //= 2
    nc = skv // c
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    pc = kv_pos.reshape(nc, c)

    dv = v.shape[-1]
    m0 = jnp.full((b, hk, g, sq), NEG, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hk, g, sq, dv), jnp.float32)

    def body2(carry, ci):
        m, l, acc = carry
        # slice chunks in-body: no transposed copy of the whole cache
        kb = lax.dynamic_slice_in_dim(k, ci * c, c, axis=1)
        vb = lax.dynamic_slice_in_dim(v, ci * c, c, axis=1)
        pb = lax.dynamic_slice_in_dim(kv_pos, ci * c, c, axis=0)
        s = jnp.einsum("bqhgd,bchd->bhgqc", q, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = _chunk_mask(q_pos, pb, kind=kind, window=window,
                           n_prefix=n_prefix, kv_valid=kv_valid)
        s = jnp.where(mask[:, None, None, :, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        # cast p (scores) down, never the cache-sized v chunk up
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = lax.scan(body2, (m0, l0, a0), jnp.arange(nc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # [B,HK,G,Sq,dh] -> [B,Sq,HK,G,dh]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)
                            ).reshape(b, s, h * n_rep, d)


def _grouped_kv(k_loc, v_loc, cfg: ModelConfig, tp: int, hq_loc: int,
                kv_sharded: bool):
    """(k_sel, v_sel, group_size) for the no-repeat grouped flash path.

    kv_sharded: kv already local -> group = hq_loc / kv_loc.
    replicated kv (kv < tp): every arch here maps a shard's contiguous q
    block to exactly ONE kv head -> slice it (asserted)."""
    if kv_sharded:
        kv_loc = k_loc.shape[2]
        assert hq_loc % kv_loc == 0
        return k_loc, v_loc, hq_loc // kv_loc
    hq = cfg.heads_padded(tp)
    g_all = max(hq // cfg.n_kv_heads, 1)
    assert hq_loc <= g_all, (
        "local q block spans multiple kv heads; grouped flash path "
        "requires hq_loc <= hq/n_kv for replicated kv")
    t_idx = lax.axis_index(AXES.model) if has_axis(AXES.model) else 0
    kv_head = (t_idx * hq_loc) // g_all
    k_sel = lax.dynamic_slice_in_dim(k_loc, kv_head, 1, axis=2)
    v_sel = lax.dynamic_slice_in_dim(v_loc, kv_head, 1, axis=2)
    return k_sel, v_sel, hq_loc


def _local_kv_select(k_all, cfg: ModelConfig, tp: int):
    """From replicated all-kv-heads tensor, build per-local-q-head kv."""
    hq = cfg.heads_padded(tp)
    hq_loc = hq // tp
    n_kv = cfg.n_kv_heads
    rep = hq // n_kv if hq % n_kv == 0 else -1
    t_idx = lax.axis_index(AXES.model) if has_axis(AXES.model) else 0
    full = _repeat_kv(k_all, max(rep, 1))                # [B,S,hq,hd]
    if full.shape[2] < hq:                               # ragged: tile
        reps = -(-hq // full.shape[2])
        full = jnp.tile(full, (1, 1, reps, 1))[:, :, :hq]
    return lax.dynamic_slice_in_dim(full, t_idx * hq_loc, hq_loc, axis=2)


# ---------------------------------------------------------------------------
# the attention block
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AttnOut:
    y: jax.Array
    cache: dict | None = None


def attention(p: dict, cfg: ModelConfig, x, *, pos, kind: str = "causal",
              n_prefix: int = 0, cache: dict | None = None,
              mode: str = "train", cross_kv=None,
              use_rope: bool = True, seq_sharded: bool = False) -> AttnOut:
    """One attention sub-block (no residual/norm — the stack handles those).

    x: [B, S, D] replicated over TP.  pos: [B, S] absolute positions.
    mode: train | prefill | decode.  cache (prefill out / decode in-out):
      {"k","v": [B, S_max, KVloc, hd], "len": scalar int32}
      (seq-sharded variant: [B, S_max/d, KVloc, hd] + {"seq_sharded": 1}).
    cross_kv: (k, v) precomputed encoder kv for cross-attention.
    """
    if cfg.mla is not None and cross_kv is None:
        return _attention_mla(p, cfg, x, pos=pos, kind=kind, cache=cache,
                              mode=mode)
    tp = axis_size_or_1(AXES.model)
    hq = cfg.heads_padded(tp)
    hq_loc = hq // tp
    hd = cfg.hd
    kv_sharded = cfg.n_kv_heads % tp == 0

    q = ops.col_matmul(x, p["w_q"], fsdp_dim=0)
    q = q.reshape(*x.shape[:-1], hq_loc, hd)

    if cross_kv is not None:
        k_loc, v_loc = cross_kv
        kv_pos = jnp.arange(k_loc.shape[1])[None]
        kv_valid = None
    else:
        if kv_sharded:
            k = ops.col_matmul(x, p["w_k"], fsdp_dim=0)
            v = ops.col_matmul(x, p["w_v"], fsdp_dim=0)
        else:
            k = ops.matmul_accumulate(x, ops.tp_psum_grad(p["w_k"]))
            v = ops.matmul_accumulate(x, ops.tp_psum_grad(p["w_v"]))
        n_kv_loc = (cfg.n_kv_heads // tp) if kv_sharded else cfg.n_kv_heads
        k = k.reshape(*x.shape[:-1], n_kv_loc, hd)
        v = v.reshape(*x.shape[:-1], n_kv_loc, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        if use_rope:
            q = rope(q, pos, cfg.rope_theta)
            k = rope(k, pos, cfg.rope_theta)

    new_cache = None
    if cross_kv is not None:
        pass
    elif mode == "train":
        kv_pos = pos
        kv_valid = None
        k_loc, v_loc = k, v
    elif mode == "prefill":
        s_max = cache["k"].shape[1]
        kc = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), 0, axis=1)
        vc = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), 0, axis=1)
        new_cache = {"k": kc, "v": vc,
                     "len": jnp.int32(x.shape[1])}
        kv_pos = pos
        kv_valid = None
        k_loc, v_loc = k, v
    elif mode == "decode":
        if seq_sharded:
            o, new_cache = _decode_seq_sharded(cfg, q, k, v, cache, pos,
                                               kind=kind)
            return AttnOut(y=ops.row_matmul(o, p["w_o"], fsdp_dim=1),
                           cache=new_cache)
        t = cache["len"]
        kc = _cache_write(cache["k"], k, t)
        vc = _cache_write(cache["v"], v, t)
        new_cache = {"k": kc, "v": vc, "len": t + x.shape[1]}
        k_loc, v_loc = kc, vc
        kv_pos = jnp.arange(kc.shape[1])[None]
        kv_valid = t + x.shape[1]
    else:
        raise ValueError(mode)

    mask_kind = kind if cross_kv is None else "full"
    if (cfg.attn_impl == "flash" and mode == "decode" and cross_kv is None
            and mask_kind == "local" and cfg.window < k_loc.shape[1]):
        # decode only attends inside the window: slice the cache instead of
        # streaming all S_max slots (§Perf "windowed decode")
        t0 = cache["len"]
        start = jnp.clip(t0 + x.shape[1] - cfg.window, 0,
                         k_loc.shape[1] - cfg.window)
        k_loc = lax.dynamic_slice_in_dim(k_loc, start, cfg.window, axis=1)
        v_loc = lax.dynamic_slice_in_dim(v_loc, start, cfg.window, axis=1)
        kv_pos = start + jnp.arange(cfg.window)[None]
    if cfg.attn_impl == "flash":
        k_sel, v_sel, g = _grouped_kv(k_loc, v_loc, cfg, tp, hq_loc,
                                      kv_sharded or cross_kv is not None)
        qg = q.reshape(*q.shape[:2], k_sel.shape[2], g, hd)
        kvp = kv_pos.reshape(-1)
        o = _flash_jnp(qg, k_sel, v_sel, pos, kvp, kind=mask_kind,
                       window=cfg.window, n_prefix=n_prefix,
                       kv_valid=kv_valid, softcap=cfg.attn_softcap)
        o = o.reshape(*x.shape[:-1], hq_loc * hd)
    else:
        if kv_sharded:
            k_use = _repeat_kv(k_loc, hq_loc // k_loc.shape[2])
            v_use = _repeat_kv(v_loc, hq_loc // v_loc.shape[2])
        else:
            k_use = _local_kv_select(k_loc, cfg, tp)
            v_use = _local_kv_select(v_loc, cfg, tp)
        mask = make_mask(pos, kv_pos, kind=mask_kind,
                         window=cfg.window, n_prefix=n_prefix,
                         kv_len_valid=kv_valid)
        o = _sdpa(q, k_use, v_use, mask, softcap=cfg.attn_softcap)
        o = o.reshape(*x.shape[:-1], hq_loc * hd)
    # fsdp_dim=1 fuses the data-axis w_o gather AND the model-axis
    # reduce-scatter around the o-projection (the 2-D collective matmul)
    y = ops.row_matmul(o, p["w_o"], fsdp_dim=1)
    return AttnOut(y=y, cache=new_cache)


def _cache_write(buf, kv, t):
    """Write a [B,1,...] (or [B,s,...]) update at position t."""
    return lax.dynamic_update_slice_in_dim(buf, kv.astype(buf.dtype), t,
                                           axis=1)


def _decode_seq_sharded(cfg, q, k_new, v_new, cache, pos, *, kind):
    """Flash-decoding over a sequence-sharded cache (data axis).

    cache k/v: [B, S_loc, KV, hd]; this shard owns absolute positions
    [d_idx*S_loc, (d_idx+1)*S_loc).  The new token is written to its owner
    shard; partial softmax stats combine with tunable all-reduces.
    """
    d_idx = lax.axis_index(AXES.data) if has_axis(AXES.data) else 0
    s_loc = cache["k"].shape[1]
    t = cache["len"]                       # global length before this token
    local_t = t - d_idx * s_loc
    owner = (local_t >= 0) & (local_t < s_loc)
    wpos = jnp.clip(local_t, 0, s_loc - 1)
    kc = lax.dynamic_update_slice_in_dim(
        cache["k"],
        jnp.where(owner, k_new, lax.dynamic_slice_in_dim(
            cache["k"], wpos, k_new.shape[1], axis=1).astype(k_new.dtype)
        ).astype(cache["k"].dtype), wpos, axis=1)
    vc = lax.dynamic_update_slice_in_dim(
        cache["v"],
        jnp.where(owner, v_new, lax.dynamic_slice_in_dim(
            cache["v"], wpos, v_new.shape[1], axis=1).astype(v_new.dtype)
        ).astype(cache["v"].dtype), wpos, axis=1)
    new_cache = {"k": kc, "v": vc, "len": t + 1}

    tp = axis_size_or_1(AXES.model)
    hq_loc = cfg.heads_padded(tp) // tp
    kv_sharded = cfg.n_kv_heads % tp == 0
    if kv_sharded:
        k_use = _repeat_kv(kc, hq_loc // kc.shape[2])
        v_use = _repeat_kv(vc, hq_loc // vc.shape[2])
    else:
        k_use = _local_kv_select(kc, cfg, tp)
        v_use = _local_kv_select(vc, cfg, tp)

    kv_pos = d_idx * s_loc + jnp.arange(s_loc)[None]
    mask = make_mask(pos, kv_pos, kind=kind, window=cfg.window,
                     kv_len_valid=t + 1)
    o, l, m = _sdpa_partial(q, k_use, v_use, mask,
                            softcap=cfg.attn_softcap)
    # combine partials over the data axis (the tunable collective)
    if has_axis(AXES.data):
        g_m = lax.pmax(m, AXES.data)
        a = jnp.exp(m - g_m)
        num = api.allreduce(o * a[..., None].transpose(0, 2, 1, 3
                                                       ).astype(o.dtype),
                            AXES.data)
        den = api.allreduce(l * a, AXES.data)
    else:
        num, den = o, l
    o = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1)[..., None].astype(
        num.dtype)
    o = o.reshape(*q.shape[:2], hq_loc * cfg.hd)
    return o, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def _attention_mla(p, cfg: ModelConfig, x, *, pos, kind, cache, mode):
    m = cfg.mla
    tp = axis_size_or_1(AXES.model)
    hq = cfg.heads_padded(tp)
    hq_loc = hq // tp
    qk_hd = m.nope_head_dim + m.rope_head_dim

    c_q = rms_norm(ops.matmul_accumulate(x, p["w_dq"]), p["q_norm"],
                   cfg.norm_eps)
    q = ops.col_matmul(c_q, p["w_uq"], fsdp_dim=0).reshape(
        *x.shape[:-1], hq_loc, qk_hd)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)

    ckv_kr = ops.matmul_accumulate(
        x, ops.tp_psum_grad(p["w_dkv"]))                # [B,S,kvr+dr]
    c_kv = rms_norm(ckv_kr[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rope(ckv_kr[..., None, m.kv_lora_rank:], pos, cfg.rope_theta)

    new_cache = None
    if mode == "prefill":
        cc = lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, axis=1)
        kr = lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[..., 0, :].astype(cache["k_rope"].dtype),
            0, axis=1)
        new_cache = {"c_kv": cc, "k_rope": kr, "len": jnp.int32(x.shape[1])}
        kv_pos, kv_valid = pos, None
    elif mode == "decode":
        t = cache["len"]
        cc = lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), t, axis=1)
        kr = lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[..., 0, :].astype(cache["k_rope"].dtype),
            t, axis=1)
        new_cache = {"c_kv": cc, "k_rope": kr, "len": t + x.shape[1]}
        c_kv, k_rope = cc, kr[..., None, :]
        kv_pos = jnp.arange(cc.shape[1])[None]
        kv_valid = t + x.shape[1]
    else:
        kv_pos, kv_valid = pos, None

    if cfg.attn_impl == "flash":
        # the absorbed path reshapes the FULL up-projection weight into
        # per-head factors, so it keeps the unfused gather
        w_ukv = ops.fsdp_gather(p["w_ukv"], 0)
        # ABSORBED MLA (+ flash): fold W_uk into q and W_uv into the output
        # so the latent cache itself is the KV — no [B,S,H,dh] k/v ever
        # materializes (DeepSeek's own inference optimization, §Perf).
        w_ukv_h = w_ukv.reshape(m.kv_lora_rank, hq_loc,
                                m.nope_head_dim + m.v_head_dim)
        w_uk = w_ukv_h[..., :m.nope_head_dim]      # [kvr, H, dn]
        w_uv = w_ukv_h[..., m.nope_head_dim:]      # [kvr, H, dv]
        q_eff = jnp.einsum("bshd,khd->bshk", q_nope, w_uk)
        qf = jnp.concatenate([q_eff, q_rope.astype(q_eff.dtype)], axis=-1)
        keys = jnp.concatenate(
            [c_kv, (k_rope[..., 0, :] if k_rope.ndim == 4 else k_rope
                    ).astype(c_kv.dtype)], axis=-1)[:, :, None, :]
        vals = c_kv[:, :, None, :]
        o_lat = _flash_jnp(
            qf[:, :, None, :, :], keys, vals, pos, kv_pos.reshape(-1),
            kind=kind, window=cfg.window, kv_valid=kv_valid,
            softcap=cfg.attn_softcap, scale=1.0 / math.sqrt(qk_hd))
        o_lat = o_lat[:, :, 0]                     # [B,S,H,kvr]
        o = jnp.einsum("bshk,khd->bshd", o_lat, w_uv)
        o = o.reshape(*x.shape[:-1], hq_loc * m.v_head_dim)
    else:
        # naive MLA: up-project latent kv for local heads per use
        kv = ops.col_matmul(c_kv.astype(x.dtype), p["w_ukv"],
                            fsdp_dim=0).reshape(
            *c_kv.shape[:-1], hq_loc, m.nope_head_dim + m.v_head_dim)
        k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(
                k_rope.astype(k_nope.dtype),
                (*k_nope.shape[:-1], m.rope_head_dim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        mask = make_mask(pos, kv_pos, kind=kind, window=cfg.window,
                         kv_len_valid=kv_valid)
        o = _sdpa(qf, k, v, mask, softcap=cfg.attn_softcap,
                  scale=1.0 / math.sqrt(qk_hd))
        o = o.reshape(*x.shape[:-1], hq_loc * m.v_head_dim)
    y = ops.row_matmul(o, p["w_o"], fsdp_dim=1)
    return AttnOut(y=y, cache=new_cache)
