"""SSM blocks: RWKV6 ("Finch", data-dependent decay) and Mamba2 (SSD).

TP contract: SSM heads are sharded over the model axis (RWKV6 heads padded
up to a multiple of tp).  B/C (mamba) and the decay-LoRA down-projection
(rwkv) are replicated with ``tp_psum_grad`` markers.

Reference semantics here are pure JAX:
* mamba2 — chunked SSD (scalar per-head decay ⇒ the [L, L] pairwise decay
  matrix is stable and cheap);
* rwkv6  — ``lax.scan`` over time (channel-wise decay cannot be factored
  into one stable matmul; the chunked/blocked version is exactly what the
  Pallas kernel implements in VMEM).

Decode carries O(1) state: (conv tail / last token, S).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist import ops
from repro.dist.axes import AXES, axis_size_or_1
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.params import ParamSpec


# ===========================================================================
# RWKV6
# ===========================================================================


def rwkv_heads_padded(cfg: ModelConfig, tp: int) -> int:
    h = cfg.d_model // cfg.ssm.head_dim
    return -(-h // tp) * tp


def rwkv_specs(cfg: ModelConfig, tp: int) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    hd = cfg.ssm.head_dim
    da = rwkv_heads_padded(cfg, tp) * hd          # attention width (padded)
    r = cfg.ssm.decay_lora_rank
    return {
        "ln1": ParamSpec((d,), (None,), init="zeros", dtype="float32"),
        "ln2": ParamSpec((d,), (None,), init="zeros", dtype="float32"),
        # time-mix
        "mu_r": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "mu_k": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "mu_v": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "mu_w": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "mu_g": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "w_r": ParamSpec((d, da), ("data", "model"), dtype=dt),
        "w_k": ParamSpec((d, da), ("data", "model"), dtype=dt),
        "w_v": ParamSpec((d, da), ("data", "model"), dtype=dt),
        "w_g": ParamSpec((d, da), ("data", "model"), dtype=dt),
        "w0": ParamSpec((da,), ("model",), init="zeros", dtype="float32"),
        "wA": ParamSpec((d, r), ("data", None), dtype=dt),
        "wB": ParamSpec((r, da), (None, "model"), dtype=dt),
        "u": ParamSpec((da,), ("model",), init="zeros", dtype="float32"),
        "ln_x": ParamSpec((da,), ("model",), init="zeros", dtype="float32"),
        "w_o": ParamSpec((da, d), ("model", "data"), dtype=dt),
        # channel-mix
        "mu_ck": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "mu_cr": ParamSpec((d,), (None,), init="zeros", dtype=dt),
        "w_ck": ParamSpec((d, cfg.d_ff), ("data", "model"), dtype=dt),
        "w_cv": ParamSpec((cfg.d_ff, d), ("model", "data"), dtype=dt),
        "w_cr": ParamSpec((d, d), ("data", "model"), dtype=dt),
    }


def _token_shift(x, last):
    """x: [B,S,D]; last: [B,1,D] previous token (zeros at t=0 of sequence)."""
    prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    return prev


def _lerp(x, prev, mu):
    return x + (prev - x) * mu


def _wkv_scan(r, k, v, w, u, s0):
    """RWKV6 recurrence over time.

    r,k,v: [B,S,H,hd]; w: [B,S,H,hd] decay in (0,1); u: [H,hd] bonus.
    s0: [B,H,hd,hd].  Returns y [B,S,H,hd], s_final.
    """
    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                      # [B,H,hd]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = (jnp.einsum("bhk,bhkv->bhv", rt, s)
             + jnp.einsum("bhk,bhkv->bhv", rt * u[None], kv))
        s = wt[..., None] * s + kv
        return s, y

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    s_fin, ys = lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_fin


def rwkv_block(p: dict, cfg: ModelConfig, x, *, state=None):
    """Time-mix + channel-mix.  state (decode): {"last_tm","last_cm","s"}."""
    tp = axis_size_or_1(AXES.model)
    hd = cfg.ssm.head_dim
    h_loc = rwkv_heads_padded(cfg, tp) // tp
    b, s, d = x.shape
    f32 = jnp.float32

    # ---- time mix ----------------------------------------------------------
    xn = rms_norm(x, p["ln1"], cfg.norm_eps)
    last_tm = state["last_tm"] if state else jnp.zeros((b, 1, d), x.dtype)
    prev = _token_shift(xn, last_tm)
    xr = _lerp(xn, prev, p["mu_r"])
    xk = _lerp(xn, prev, p["mu_k"])
    xv = _lerp(xn, prev, p["mu_v"])
    xw = _lerp(xn, prev, p["mu_w"])
    xg = _lerp(xn, prev, p["mu_g"])

    r = ops.col_matmul(xr, p["w_r"], fsdp_dim=0)
    k = ops.col_matmul(xk, p["w_k"], fsdp_dim=0)
    v = ops.col_matmul(xv, p["w_v"], fsdp_dim=0)
    g = ops.col_matmul(xg, p["w_g"], fsdp_dim=0)
    # data-dependent decay (the Finch headline feature)
    low = jnp.tanh(ops.matmul_accumulate(xw, ops.tp_psum_grad(p["wA"])))
    dec_raw = p["w0"].astype(f32) + ops.col_matmul(
        low, p["wB"]).astype(f32)
    w = jnp.exp(-jnp.exp(dec_raw))                   # (0,1), per channel

    rh = r.reshape(b, s, h_loc, hd).astype(f32)
    kh = k.reshape(b, s, h_loc, hd).astype(f32)
    vh = v.reshape(b, s, h_loc, hd).astype(f32)
    wh = w.reshape(b, s, h_loc, hd)
    u = p["u"].astype(f32).reshape(h_loc, hd)
    s0 = (state["s"].astype(f32) if state
          else jnp.zeros((b, h_loc, hd, hd), f32))
    y, s_fin = _wkv_scan(rh, kh, vh, wh, u, s0)
    # per-head group norm (RWKV GroupNorm(n_heads)) — invariant under TP
    yh = y.astype(x.dtype)
    scale = p["ln_x"].reshape(h_loc, hd)
    yh = rms_norm(yh, scale, cfg.norm_eps)
    y = yh.reshape(b, s, h_loc * hd)
    y = y * jax.nn.silu(g)
    att = ops.row_matmul(y, p["w_o"], fsdp_dim=1)

    x_in_last = xn[:, -1:]         # time-mix shifts against the NORMED input
    x = x + att

    # ---- channel mix --------------------------------------------------------
    xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    last_cm = state["last_cm"] if state else jnp.zeros((b, 1, d), x.dtype)
    prevc = _token_shift(xn2, last_cm)
    xck = _lerp(xn2, prevc, p["mu_ck"])
    xcr = _lerp(xn2, prevc, p["mu_cr"])
    kk = ops.col_matmul(xck, p["w_ck"], fsdp_dim=0)
    kk = jnp.square(jax.nn.relu(kk))
    cv = ops.row_matmul(kk, p["w_cv"], fsdp_dim=1)
    r_loc = ops.col_matmul(xcr, p["w_cr"], fsdp_dim=0)
    r_full = ops.tp_allgather(r_loc, r_loc.ndim - 1)
    y = jax.nn.sigmoid(r_full) * cv
    out = x + y

    new_state = None
    if state is not None:
        # time-mix shifts against the block input; channel-mix against the
        # post-attention residual stream (its own input), per RWKV layout
        new_state = {"last_tm": x_in_last, "last_cm": xn2[:, -1:],
                     "s": s_fin}
    return out, new_state


# ===========================================================================
# Mamba2 (SSD, chunked)
# ===========================================================================


def mamba_specs(cfg: ModelConfig, tp: int) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    c = cfg.ssm
    di = c.expand * d                     # d_inner
    nh = di // c.head_dim                 # heads
    assert nh % tp == 0, f"mamba heads {nh} not divisible by tp {tp}"
    n = c.state_dim
    return {
        "ln": ParamSpec((d,), (None,), init="zeros", dtype="float32"),
        "w_in_z": ParamSpec((d, di), ("data", "model"), dtype=dt),
        "w_in_x": ParamSpec((d, di), ("data", "model"), dtype=dt),
        "w_bc": ParamSpec((d, 2 * n), ("data", None), dtype=dt),
        "w_dt": ParamSpec((d, nh), ("data", "model"), dtype=dt),
        "dt_bias": ParamSpec((nh,), ("model",), init="zeros",
                             dtype="float32"),
        "a_log": ParamSpec((nh,), ("model",), init="zeros", dtype="float32"),
        "d_skip": ParamSpec((nh,), ("model",), init="ones", dtype="float32"),
        "conv_x": ParamSpec((c.conv_kernel, di), (None, "model"),
                            scale=0.5, dtype=dt),
        "conv_bc": ParamSpec((c.conv_kernel, 2 * n), (None, None),
                             scale=0.5, dtype=dt),
        "gate_norm": ParamSpec((di,), ("model",), init="zeros",
                               dtype="float32"),
        "w_out": ParamSpec((di, d), ("model", "data"), dtype=dt),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv via K shifted adds.  x: [B,S,C], w: [K,C].
    ``tail``: [B,K-1,C] previous context (decode)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[k - 1 - i][None, None]
            for i in range(k))
    new_tail = xp[:, -(k - 1):] if k > 1 else tail
    return y, new_tail


def _ssd_chunked(xh, dt, a, B, C, s0, chunk: int):
    """Chunked SSD.  xh: [b,S,H,P]; dt: [b,S,H] (softplus'ed); a: [H] (>0);
    B, C: [b,S,N]; s0: [b,H,N,P].  Returns y [b,S,H,P], s_fin."""
    b, S, H, P = xh.shape
    N = B.shape[-1]
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    f32 = jnp.float32

    la_step = (-dt * a[None, None]).astype(f32)          # log a_t  [b,S,H]
    xbar = xh * dt[..., None]                            # dt-scaled input

    lac = la_step.reshape(b, nc, L, H)
    cum = jnp.cumsum(lac, axis=2)                        # within-chunk
    Bc = B.reshape(b, nc, L, N)
    Cc = C.reshape(b, nc, L, N)
    Xc = xbar.reshape(b, nc, L, H, P)

    # intra-chunk: M[t,s] = (C_t.B_s)·exp(cum_t - cum_s), s<=t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,L,L,H]
    tri = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc.astype(f32), Bc.astype(f32))
    m = cb[..., None] * dmat                              # [b,nc,L,L,H]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, Xc.astype(f32))

    # per-chunk aggregates for the inter-chunk scan
    # state in := sum_s exp(cum_L - cum_s) B_s xbar_s^T ; decay = exp(cum_L)
    wlast = cum[:, :, -1:, :]                             # [b,nc,1,H]
    kdec = jnp.exp(wlast - cum)                           # [b,nc,L,H]
    s_in = jnp.einsum("bcln,bclh,bclhp->bchnp",
                      Bc.astype(f32), kdec, Xc.astype(f32))
    chunk_decay = jnp.exp(wlast[:, :, 0, :])              # [b,nc,H]

    def step(s, inp):
        dec, sin, cdec, cq = inp
        # y_inter[t] = C_t · (exp(cum_t) ⊙ s)   (decay applied to carry)
        y = jnp.einsum("bln,blh,bhnp->blhp", cq, dec, s)
        s = cdec[..., None, None] * s + sin
        return s, y

    xs = (jnp.exp(cum).transpose(1, 0, 2, 3),             # [nc,b,L,H]
          s_in.transpose(1, 0, 2, 3, 4),                  # [nc,b,H,N,P]
          chunk_decay.transpose(1, 0, 2),                 # [nc,b,H]
          Cc.astype(f32).transpose(1, 0, 2, 3))           # [nc,b,L,N]
    s_fin, y_inter = lax.scan(step, s0.astype(f32), xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(b, S, H, P)
    y = y_intra.reshape(b, S, H, P) + y_inter
    return y, s_fin


def mamba_block(p: dict, cfg: ModelConfig, x, *, state=None):
    """Mamba2 mixer.  state (decode): {"conv_x","conv_bc","s"}."""
    c = cfg.ssm
    tp = axis_size_or_1(AXES.model)
    di_loc = c.expand * cfg.d_model // tp
    h_loc = di_loc // c.head_dim
    n = c.state_dim
    b, s, d = x.shape
    f32 = jnp.float32

    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    z = ops.col_matmul(xn, p["w_in_z"], fsdp_dim=0)
    xin = ops.col_matmul(xn, p["w_in_x"], fsdp_dim=0)
    bc = ops.matmul_accumulate(xn, ops.tp_psum_grad(p["w_bc"]))
    dt_raw = ops.col_matmul(xn, p["w_dt"], fsdp_dim=0)

    conv_x_w = p["conv_x"]
    conv_bc_w = ops.tp_psum_grad(p["conv_bc"])
    xin, tail_x = _causal_conv(xin, conv_x_w,
                               state["conv_x"] if state else None)
    bc, tail_bc = _causal_conv(bc, conv_bc_w,
                               state["conv_bc"] if state else None)
    xin = jax.nn.silu(xin)
    bc = jax.nn.silu(bc)
    B, C = bc[..., :n], bc[..., n:]

    dt = jax.nn.softplus(dt_raw.astype(f32) + p["dt_bias"][None, None])
    a = jnp.exp(p["a_log"].astype(f32))                  # per-head decay rate
    xh = xin.reshape(b, s, h_loc, c.head_dim)

    s0 = (state["s"].astype(f32) if state
          else jnp.zeros((b, h_loc, n, c.head_dim), f32))
    y, s_fin = _ssd_chunked(xh.astype(f32), dt, a, B, C, s0, c.chunk)
    y = y + xh.astype(f32) * p["d_skip"].astype(f32)[None, None, :, None]
    yh = y.astype(x.dtype)                      # [b,s,h_loc,P]
    scale = p["gate_norm"].reshape(h_loc, c.head_dim)
    yh = rms_norm(yh, scale, cfg.norm_eps)      # per-head (TP-invariant)
    y = yh.reshape(b, s, di_loc) * jax.nn.silu(z)
    out = x + ops.row_matmul(y, p["w_out"], fsdp_dim=1)

    new_state = None
    if state is not None:
        new_state = {"conv_x": tail_x, "conv_bc": tail_bc, "s": s_fin}
    return out, new_state
