"""repro.models — unified LM stack for all assigned architectures."""
from repro.models.config import ModelConfig  # noqa: F401
