"""Quantized wire format for the ring collectives (per-block symmetric scales).

The ``wire_q8`` / ``wire_fp8`` mock-up families (core/collectives.py) compress
the TRAVELLING operand of a ring schedule to an 8-bit wire dtype; this module
owns the wire format and the quantize/dequantize tiers:

Wire format
-----------
A payload ``[n, ...]`` is split into blocks of ``BLOCK_ROWS`` leading rows
(the last block may be short).  Each block carries one f32 symmetric scale::

    scale_b = max(|x_b|) / QMAX[wire_dtype]        (>= a tiny floor)
    q_b     = round(x_b / scale_b)   as int8       (wire_q8)
            = (x_b / scale_b)        as e4m3 fp8   (wire_fp8)

Dequantization is ``q.astype(f32) * scale``; REDUCTIONS ALWAYS ACCUMULATE IN
f32 AFTER DEQUANT (the rule the selfcheck tolerance gate assumes — see
DESIGN_KERNELS.md "Quantized wire").  The per-element error of one
quantize/dequantize round trip is bounded by half a quantization step::

    |x - deq(q)| <= scale_b / 2 = max(|x_b|) / (2 * QMAX)   (int8)
    |x - deq(q)| <= |x| * 2**-4                             (e4m3 fp8)

so a gather-style wire (one quantization at the origin, the pair travels
as-is) has max-norm relative error ~``1/(2*QMAX)``, while a travelling
ACCUMULATOR (reduce-scatter/allreduce) requantizes per hop and the bound
scales with the hop count — ``wire_tol`` encodes both regimes.

Execution tiers (same split as kernels/collective_matmul.py):

1. ``quantize``/``dequantize`` — pure jnp, usable inside shard_map / vmap
   ring steps on any backend (CPU CI included); XLA fuses them into the
   surrounding ring step.
2. ``quant_pack``/``dequant_unpack`` — the per-block Pallas kernels in the
   kernels/pack.py style (grid over blocks, one scale per grid step), the
   TPU tier; exercised on CPU via ``interpret=True``.  On TPU the natural
   tile floor for 8-bit lanes is (32, 128); the kernels pad short blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["WIRE_DTYPES", "WIRE_ITEMSIZE", "QMAX", "BLOCK_ROWS", "BASE_TOL",
           "wire_tol", "quantize", "dequantize", "wire_roundtrip",
           "quant_pack", "dequant_unpack"]

#: wire dtypes of the quantized mock-up families (impl name -> dtype lives in
#: collectives.REGISTRY[op][name].wire_dtype)
WIRE_DTYPES = ("int8", "float8_e4m3fn")

#: bytes per wire element — the costmodel's wire_width term
WIRE_ITEMSIZE = {"int8": 1, "float8_e4m3fn": 1}

#: largest representable magnitude per wire dtype (e4m3 max finite = 448)
QMAX = {"int8": 127.0, "float8_e4m3fn": 448.0}

#: rows per scale block (one f32 scale per BLOCK_ROWS leading rows)
BLOCK_ROWS = 8

#: single-roundtrip max-norm relative error bound per wire dtype, with ~4x
#: headroom over the analytic half-step bound (1/254 for int8; 2**-4 for the
#: 3-bit e4m3 mantissa) so benign rounding never trips the gate while a
#: payload the format cannot represent (cancellation, huge in-block dynamic
#: range) still does.
BASE_TOL = {"int8": 4.0 / 254.0, "float8_e4m3fn": 4.0 * 2.0 ** -4}

_SCALE_FLOOR = 1e-30


def wire_tol(wire_dtype: str, hops: int = 1) -> float:
    """Max-norm relative error bound for a wire impl whose travelling data
    is (re)quantized ``hops`` times: gather-style rings quantize once at the
    origin (hops=1); travelling accumulators requantize per hop (hops=p-1)
    and worst-case errors add."""
    return BASE_TOL[wire_dtype] * max(int(hops), 1)


def _nblocks(n: int, block_rows: int) -> int:
    return -(-n // block_rows)


def _row_scales(scales, n: int, block_rows: int, ndim: int):
    """Per-row scale vector [n, 1, ..] from the per-block scales [nb, 1]."""
    per_row = scales.reshape(-1)[jnp.arange(n) // block_rows]
    return per_row.reshape((n,) + (1,) * (ndim - 1))


# ---------------------------------------------------------------------------
# tier 1: pure-jnp quantize/dequantize (any backend, inside ring steps)
# ---------------------------------------------------------------------------


def quantize(x, wire_dtype: str = "int8", *, block_rows: int = BLOCK_ROWS):
    """Per-block symmetric quantization of ``x`` ``[n, ...]``.

    Returns ``(q, scales)``: ``q`` has x's shape in the wire dtype, and
    ``scales`` is ``[nblocks, 1]`` f32 (one scale per BLOCK_ROWS leading
    rows) — the pair IS the wire format a ring step ppermutes.
    """
    qmax = QMAX[wire_dtype]
    n = x.shape[0]
    nb = _nblocks(n, block_rows)
    xf = x.astype(jnp.float32)
    pad = nb * block_rows - n
    xb = jnp.pad(xf, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else xf
    amax = jnp.max(jnp.abs(xb).reshape(nb, -1), axis=1)
    scales = (jnp.maximum(amax, _SCALE_FLOOR) / qmax).reshape(nb, 1)
    s = _row_scales(scales, n, block_rows, x.ndim)
    if wire_dtype == "int8":
        q = jnp.clip(jnp.round(xf / s), -qmax, qmax).astype(jnp.int8)
    else:
        q = (xf / s).astype(jnp.dtype(wire_dtype))
    return q, scales


def dequantize(q, scales, out_dtype=jnp.float32, *,
               block_rows: int = BLOCK_ROWS):
    """Inverse of :func:`quantize`: ``q.astype(f32) * scale`` per block.
    Reductions must add the f32 result BEFORE any cast to ``out_dtype``."""
    n = q.shape[0]
    s = _row_scales(scales, n, block_rows, q.ndim)
    return (q.astype(jnp.float32) * s).astype(out_dtype)


def wire_roundtrip(x, wire_dtype: str = "int8", *,
                   block_rows: int = BLOCK_ROWS):
    """One quantize/dequantize round trip (what a single wire hop does to
    the payload values) — the reference for error-bound tests."""
    q, scales = quantize(x, wire_dtype, block_rows=block_rows)
    return dequantize(q, scales, x.dtype, block_rows=block_rows)


# ---------------------------------------------------------------------------
# tier 2: per-block Pallas kernels (kernels/pack.py style; TPU, interpret on
# CPU) — grid over scale blocks, one scale computed per grid step
# ---------------------------------------------------------------------------


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: float, wire_dtype: str):
    xb = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xb)), _SCALE_FLOOR) / qmax
    s_ref[0, 0] = scale
    if wire_dtype == "int8":
        q_ref[...] = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(
            jnp.int8)
    else:
        q_ref[...] = (xb / scale).astype(jnp.dtype(wire_dtype))


@functools.partial(jax.jit,
                   static_argnames=("wire_dtype", "block_rows", "interpret"))
def quant_pack(x, *, wire_dtype: str = "int8",
               block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """Pallas quantize-on-send: ``[n, d]`` -> ``([n, d] wire dtype,
    [nblocks, 1] f32 scales)``.  Non-divisible ``n`` is zero-padded up to
    the block grid (pad rows never raise a block's abs-max) and sliced
    back, mirroring pallas_matmul's pad behaviour."""
    n, d = x.shape
    nb = _nblocks(n, block_rows)
    pad = nb * block_rows - n
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    q, scales = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=QMAX[wire_dtype],
                          wire_dtype=wire_dtype),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda j: (j, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda j: (j, 0)),
                   pl.BlockSpec((1, 1), lambda j: (j, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb * block_rows, d),
                                        jnp.dtype(wire_dtype)),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(xp)
    return q[:n], scales


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(
        o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "block_rows", "interpret"))
def dequant_unpack(q, scales, *, out_dtype=jnp.float32,
                   block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """Pallas dequantize-on-receive: inverse of :func:`quant_pack`."""
    n, d = q.shape
    nb = _nblocks(n, block_rows)
    pad = nb * block_rows - n
    qp = jnp.pad(q, ((0, pad), (0, 0))) if pad else q
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, d),
                                       jnp.dtype(out_dtype)),
        interpret=interpret,
    )(qp, scales)
    return out[:n]
