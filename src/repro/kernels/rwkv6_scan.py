"""Chunked RWKV6 WKV kernel (Pallas TPU).

Channel-wise data-dependent decay cannot be factored through one stable
matmul (exp(-cumsum) overflows), so the kernel keeps the chunk-local decay
differences in VMEM where they are formed pairwise (always ≤ 0 ⇒ exp ≤ 1,
underflow-safe) and does:

  inter-chunk:  y_t += (r_t ⊙ e^{cum_{t-1}}) @ S_prev           [L,hd]@[hd,hd]
  intra-chunk:  per-row matvec over the masked pairwise tensor
  state update: S ← e^{cum_L} ⊙ S + (k ⊙ e^{cum_L - cum})ᵀ @ v  [hd,L]@[L,hd]

Grid (BH, S/L): the chunk index is innermost (sequential on TPU), carrying
S in an f32 VMEM scratch; BH changes reset it (@pl.when chunk==0).

Inputs: r,k,v,w [BH, S, hd] (w = decay in (0,1)); u [BH, hd].
Outputs: y [BH, S, hd] f32, s_fin [BH, hd, hd] f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_scr, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)                 # [L, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                 # [hd]
    L, hd = r.shape

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)                   # [L, hd], decreasing
    cum_prev = cum - logw                            # cum_{t-1}

    s_prev = s_scr[...]                              # [hd, hd]

    # inter-chunk
    r_dec = r * jnp.exp(cum_prev)                    # safe: cum_prev <= 0
    y = jax.lax.dot_general(r_dec, s_prev, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: pairwise decay differences, always <= 0 for s < t
    # A[t,s] = sum_c r[t,c] k[s,c] exp(cum_prev[t,c] - cum[s,c])
    diff = cum_prev[:, None, :] - cum[None, :, :]    # [L, L, hd]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    e = jnp.exp(jnp.minimum(diff, 0.0)) * tri[..., None]
    a = jnp.einsum("tc,sc,tsc->ts", r, k, e,
                   preferred_element_type=jnp.float32)
    y = y + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # bonus diagonal: (r_t . (u*k_t)) v_t
    bonus = jnp.sum(r * (u[None, :] * k), axis=-1, keepdims=True)
    y = y + bonus * v
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    kdec = k * jnp.exp(cum[-1][None, :] - cum)       # <= 1, safe
    s_new = jnp.exp(cum[-1])[:, None] * s_prev + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _out():
        s_out_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = 32, interpret: bool = False):
    bh, s, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y, s_fin = pl.pallas_call(
        kern,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_fin
