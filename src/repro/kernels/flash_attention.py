"""Blockwise flash attention for TPU (Pallas) — online softmax, causal /
sliding-window masks, logit softcap, GQA via head-group index maps.

Tiling: grid (B, Hq, Sq/BQ, Skv/BKV); the KV block index is the innermost
(sequential) grid dim, so the running (m, l, acc) state lives in VMEM
scratch across KV steps — the canonical TPU flash schedule.  Block shapes
are MXU-aligned (BQ, BKV multiples of 128 on hardware; tests use smaller
interpret-mode blocks).

q: [B, Hq, Sq, dh]; k, v: [B, Hkv, Skv, dh]; out: [B, Hq, Sq, dh].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, softcap: float, bq: int, bkv: int,
            n_kv_blocks: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bkv

    # block-level skip: fully-masked KV blocks contribute nothing
    def relevant():
        if causal:
            c = k_start <= q_start + bq - 1
        else:
            c = True
        if window:
            c = jnp.logical_and(c, k_start + bkv - 1 > q_start - window)
        return c

    @pl.when(jnp.asarray(relevant()))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)          # [bkv, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask = kpos <= qpos
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bkv: int = 128,
                    interpret: bool = False):
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    nq, nk = sq // bq, skv // bkv
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap, bq=bq,
        bkv=bkv, n_kv_blocks=nk, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h, q_, k_: (b_, h, q_, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b_, h, q_, k_, g=g: (b_, h // g, k_, 0)),
            pl.BlockSpec((1, 1, bkv, dh),
                         lambda b_, h, q_, k_, g=g: (b_, h // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b_, h, q_, k_: (b_, h, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
