"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) so the same call
sites run the kernel bodies in interpret mode for CI and compile to Mosaic
on real hardware.  The model stack keeps pure-jnp paths as its default; the
kernels are the TPU hot-spot implementations validated against
``kernels/ref.py`` and swapped in via ``use_kernels`` launch flags.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pack import guideline_pack as _pack
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv
from repro.kernels.ssd_mamba2 import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    bq=128, bkv=128, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  bq=bq, bkv=bkv, interpret=interpret)


def rwkv6_scan(r, k, v, w, u, *, chunk=32, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _rwkv(r, k, v, w, u, chunk=chunk, interpret=interpret)


def ssd_scan(x, dt, a, B, C, *, chunk=64, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _ssd(x, dt, a, B, C, chunk=chunk, interpret=interpret)


def guideline_pack(x, idx, p, *, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _pack(x, idx, p, interpret=interpret)
