"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: [B,Hq,Sq,dh]; k,v: [B,Hkv,Skv,dh]; GQA by head grouping.
    window>0: sliding-window causal.  Returns [B,Hq,Sq,dh] (q dtype)."""
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask = kpos <= qpos
    if window:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(
        q.dtype)


def rwkv6_ref(r, k, v, w, u, s0=None):
    """Sequential WKV6.  r,k,v,w: [BH, S, hd] (w = decay in (0,1));
    u: [BH, hd]; s0: [BH, hd, hd].  Returns (y [BH,S,hd] f32, s_fin)."""
    bh, s, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((bh, hd, hd), jnp.float32)

    def step(S, t):
        rt, kt, vt, wt = (r[:, t].astype(jnp.float32),
                          k[:, t].astype(jnp.float32),
                          v[:, t].astype(jnp.float32),
                          w[:, t].astype(jnp.float32))
        kv = jnp.einsum("bk,bv->bkv", kt, vt)
        y = (jnp.einsum("bk,bkv->bv", rt, S)
             + jnp.einsum("bk,bkv->bv", rt * u.astype(jnp.float32), kv))
        S = wt[..., None] * S + kv
        return S, y

    S, ys = jax.lax.scan(step, s0.astype(jnp.float32), jnp.arange(s))
    return ys.transpose(1, 0, 2), S


def ssd_ref(x, dt, a, B, C, s0=None):
    """Sequential Mamba2/SSD.  x: [BH,S,P]; dt: [BH,S]; a: [BH];
    B,C: [BH,S,N]; s0: [BH,N,P].  S_t = exp(-dt_t a) S + B_t (dt_t x_t)^T;
    y_t = C_t^T S_t.  Returns (y [BH,S,P] f32, s_fin)."""
    bh, s, p = x.shape
    n = B.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((bh, n, p), jnp.float32)

    def step(S, t):
        dec = jnp.exp(-dt[:, t] * a).astype(jnp.float32)       # [BH]
        xb = (x[:, t] * dt[:, t][:, None]).astype(jnp.float32)  # [BH,P]
        S = dec[:, None, None] * S + jnp.einsum(
            "bn,bp->bnp", B[:, t].astype(jnp.float32), xb)
        y = jnp.einsum("bn,bnp->bp", C[:, t].astype(jnp.float32), S)
        return S, y

    S, ys = jax.lax.scan(step, s0.astype(jnp.float32), jnp.arange(s))
    return ys.transpose(1, 0, 2), S


def pack_ref(x, idx, p):
    """GL3/GL13 one-hot placement: [n,d] -> [p*n,d] zeros except block idx."""
    n, d = x.shape
    buf = jnp.zeros((p * n, d), x.dtype)
    return jax.lax.dynamic_update_slice(buf, x, (idx * n, 0))


def quant_roundtrip_ref(x, qmax, block_rows=8):
    """Per-block symmetric int quantize/dequantize (kernels/quant.py wire
    format), as an explicit loop over scale blocks: scale = max(|block|)/qmax,
    q = clip(round(x/scale)), roundtrip = q*scale.  Returns (roundtrip
    [n,d] f32, scales [nblocks] f32)."""
    import numpy as np
    xn = np.asarray(x, np.float32)
    out = np.empty_like(xn)
    scales = []
    for b in range(0, xn.shape[0], block_rows):
        blk = xn[b:b + block_rows]
        s = max(float(np.max(np.abs(blk))), 1e-30) / qmax
        scales.append(s)
        out[b:b + block_rows] = np.clip(np.round(blk / s), -qmax, qmax) * s
    return out, np.asarray(scales, np.float32)
