"""Tier-3 collective matmul: the single-kernel RDMA ring (TPU only).

A Pallas kernel that drives ``make_async_remote_copy`` sends itself
(double-buffered comm scratch, per-slot DMA semaphores, neighbour barrier) —
the full latency-hiding schedule with no XLA scheduling dependence.

This module is TPU-only and imported LAZILY: the ``fused_ring`` dispatcher
impl (core/collectives.py) performs the backend check and only imports it
when ``jax.default_backend() == "tpu"``, so CPU CI never loads this path
(``make_async_remote_copy`` has no host interpret path across shard_map
devices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core._axis import axis_size

__all__ = ["ring_allgather_matmul_rdma"]

# jax 0.4.x names this TPUCompilerParams; new jax uses CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _agmm_rdma_kernel(x_ref, w_ref, o_ref, gath_ref, comm_buf, send_sem,
                      recv_sem, credit_sem, acc_scr, *, p: int, axis: str):
    """One grid step per ring hop: RDMA-send the resident chunk to the right
    neighbour, matmul it into its output rows, then wait on the transfers —
    compute and ICI traffic overlap inside a single kernel invocation.

    Buffer-reuse flow control: the send at step s lands in the right
    neighbour's slot ``(s+1) % 2`` — the buffer that neighbour last read at
    its step s-1.  Each device therefore grants one CREDIT to its left
    neighbour when it finishes consuming a slot, and a sender must burn one
    credit (from the right neighbour) before re-targeting that slot; the
    step-0 send needs none (both slots start free)."""
    s = pl.program_id(0)
    my = lax.axis_index(axis)
    right = lax.rem(my + 1, p)
    left = lax.rem(my + p - 1, p)

    @pl.when(s == 0)
    def _seed():
        # neighbour barrier so nobody RDMAs into a peer still setting up
        bar = pltpu.get_barrier_semaphore()
        pltpu.semaphore_signal(bar, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_signal(bar, inc=1, device_id=(right,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(bar, 2)
        comm_buf[0] = x_ref[...]

    slot = lax.rem(s, 2)
    nxt = lax.rem(s + 1, 2)

    @pl.when(jnp.logical_and(s >= 1, s < p - 1))
    def _flow_control():
        # right neighbour finished reading its slot `nxt` at its step s-1
        pltpu.semaphore_wait(credit_sem, 1)

    @pl.when(s < p - 1)
    def _send():
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[slot],
            dst_ref=comm_buf.at[nxt],
            send_sem=send_sem.at[slot],
            recv_sem=recv_sem.at[nxt],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()

    # matmul the chunk we hold while the RDMA is in flight
    src = lax.rem(my - s + p, p)
    n = x_ref.shape[0]
    blk = comm_buf[slot]
    acc_scr[...] = jax.lax.dot_general(
        blk, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[pl.ds(src * n, n), :] = acc_scr[...].astype(o_ref.dtype)
    gath_ref[pl.ds(src * n, n), :] = blk

    @pl.when(s < p - 1)
    def _wait():
        pltpu.semaphore_wait(send_sem.at[slot], 1)
        pltpu.semaphore_wait(recv_sem.at[nxt], 1)

    @pl.when(s < p - 2)
    def _grant():
        # slot `slot` is fully consumed (matmul done AND our outgoing DMA
        # from it delivered): the left neighbour may target it again with
        # its step-s+1 send.  Credits exactly balance the waits above, so
        # the semaphore drains to zero by kernel exit.
        pltpu.semaphore_signal(credit_sem, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)


def ring_allgather_matmul_rdma(x, w, axis: str, *,
                               return_gathered: bool = False,
                               collective_id: int = 7):
    """The tier-3 Pallas kernel: ring allgather-matmul with in-kernel RDMA."""
    p = axis_size(axis)
    n, k = x.shape
    m = w.shape[-1]
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = jnp.matmul(x, w)
        return (out, x) if return_gathered else out
    out, gath = pl.pallas_call(
        functools.partial(_agmm_rdma_kernel, p=p, axis=axis),
        grid=(p,),
        in_specs=[pl.BlockSpec((n, k), lambda s: (0, 0),
                               memory_space=pltpu.VMEM),
                  pl.BlockSpec((k, m), lambda s: (0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=(pl.BlockSpec((p * n, m), lambda s: (0, 0),
                                memory_space=pltpu.VMEM),
                   pl.BlockSpec((p * n, k), lambda s: (0, 0),
                                memory_space=pltpu.VMEM)),
        out_shape=(jax.ShapeDtypeStruct((p * n, m), out_dtype),
                   jax.ShapeDtypeStruct((p * n, k), x.dtype)),
        scratch_shapes=[
            pltpu.VMEM((2, n, k), x.dtype),        # double-buffered chunks
            pltpu.SemaphoreType.DMA((2,)),         # send slots
            pltpu.SemaphoreType.DMA((2,)),         # recv slots
            pltpu.SemaphoreType.REGULAR,           # buffer-reuse credits
            pltpu.VMEM((n, m), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            has_side_effects=True, collective_id=collective_id),
    )(x, w)
    return (out, gath) if return_gathered else out
