"""Tier-3 collective matmul: the single-kernel RDMA ring (TPU only) + its
interpret-mode CPU test tier.

A Pallas kernel that drives ``make_async_remote_copy`` sends itself
(double-buffered comm scratch, per-slot DMA semaphores, neighbour barrier) —
the full latency-hiding schedule with no XLA scheduling dependence.

The REAL kernel (``ring_allgather_matmul_rdma``) stays TPU-only: the
``fused_ring`` dispatcher impl (core/collectives.py) performs the backend
check (``on_tpu``) and only calls it on TPU — ``make_async_remote_copy``
has no host interpret path across shard_map devices.  The module itself is
now importable anywhere so CPU CI can exercise the ring's BLOCK logic:

* ``ring_step_src`` / ``ring_step_slots`` — the per-step rank/double-buffer
  indexing, shared verbatim by the RDMA kernel, the interpret tier, and
  the protocol simulation (works on traced ints and Python ints alike).
* ``ring_schedule`` — the flow-control protocol (sends, DMA waits, credit
  waits/grants per step) as plain data, mirroring the kernel's ``pl.when``
  predicates; the CPU test simulates it and checks credits balance and no
  slot is overwritten before its reader consumed it.
* ``ring_allgather_matmul_blocks`` — one rank's grid schedule as a
  single-device Pallas kernel with the DMA arrivals emulated from the full
  chunk array (``interpret=True`` on CPU): same src/slot/output-row
  indexing, no semaphores or remote copies — grid/indexing equivalence vs
  the ppermute reference without TPU hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core._axis import axis_size

__all__ = ["ring_allgather_matmul_rdma", "ring_allgather_matmul_blocks",
           "ring_step_src", "ring_step_slots", "ring_schedule"]

# jax 0.4.x names this TPUCompilerParams; new jax uses CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def ring_step_src(my, s, p: int):
    """Originating rank of the chunk resident at ring step ``s`` on rank
    ``my`` — the output-row placement index.  Works traced (``my``/``s``
    jax ints inside a kernel) and as plain Python ints (simulation)."""
    return (my - s + p) % p


def ring_step_slots(s):
    """(consume, send-target) double-buffer slots of ring step ``s``."""
    return s % 2, (s + 1) % 2


def ring_schedule(p: int) -> list[dict]:
    """The RDMA ring's per-step flow-control protocol as data — one dict
    per grid step, mirroring the kernel's ``pl.when`` predicates:

    ``slot``/``nxt``   consume / send-target buffer slots,
    ``send``           issue an RDMA to the right neighbour (s < p-1),
    ``wait_credit``    burn a credit from the right neighbour before the
                       send may re-target its slot (1 <= s < p-1),
    ``wait_dma``       block on the send+recv semaphores (s < p-1),
    ``grant_credit``   tell the left neighbour our slot is consumed
                       (s < p-2 — the final slots are never reused).

    The CPU protocol simulation replays this against a p-device model and
    asserts safety (no overwrite of an unconsumed slot) and liveness
    (credits balance to zero, every chunk delivered)."""
    steps = []
    for s in range(p):
        slot, nxt = ring_step_slots(s)
        steps.append({"s": s, "slot": slot, "nxt": nxt,
                      "send": s < p - 1,
                      "wait_credit": 1 <= s < p - 1,
                      "wait_dma": s < p - 1,
                      "grant_credit": s < p - 2})
    return steps


def _agmm_rdma_kernel(x_ref, w_ref, o_ref, gath_ref, comm_buf, send_sem,
                      recv_sem, credit_sem, acc_scr, *, p: int, axis: str):
    """One grid step per ring hop: RDMA-send the resident chunk to the right
    neighbour, matmul it into its output rows, then wait on the transfers —
    compute and ICI traffic overlap inside a single kernel invocation.

    Buffer-reuse flow control: the send at step s lands in the right
    neighbour's slot ``(s+1) % 2`` — the buffer that neighbour last read at
    its step s-1.  Each device therefore grants one CREDIT to its left
    neighbour when it finishes consuming a slot, and a sender must burn one
    credit (from the right neighbour) before re-targeting that slot; the
    step-0 send needs none (both slots start free)."""
    s = pl.program_id(0)
    my = lax.axis_index(axis)
    right = lax.rem(my + 1, p)
    left = lax.rem(my + p - 1, p)

    @pl.when(s == 0)
    def _seed():
        # neighbour barrier so nobody RDMAs into a peer still setting up
        bar = pltpu.get_barrier_semaphore()
        pltpu.semaphore_signal(bar, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_signal(bar, inc=1, device_id=(right,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(bar, 2)
        comm_buf[0] = x_ref[...]

    slot, nxt = ring_step_slots(s)

    @pl.when(jnp.logical_and(s >= 1, s < p - 1))
    def _flow_control():
        # right neighbour finished reading its slot `nxt` at its step s-1
        pltpu.semaphore_wait(credit_sem, 1)

    @pl.when(s < p - 1)
    def _send():
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[slot],
            dst_ref=comm_buf.at[nxt],
            send_sem=send_sem.at[slot],
            recv_sem=recv_sem.at[nxt],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()

    # matmul the chunk we hold while the RDMA is in flight
    src = ring_step_src(my, s, p)
    n = x_ref.shape[0]
    blk = comm_buf[slot]
    acc_scr[...] = jax.lax.dot_general(
        blk, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[pl.ds(src * n, n), :] = acc_scr[...].astype(o_ref.dtype)
    gath_ref[pl.ds(src * n, n), :] = blk

    @pl.when(s < p - 1)
    def _wait():
        pltpu.semaphore_wait(send_sem.at[slot], 1)
        pltpu.semaphore_wait(recv_sem.at[nxt], 1)

    @pl.when(s < p - 2)
    def _grant():
        # slot `slot` is fully consumed (matmul done AND our outgoing DMA
        # from it delivered): the left neighbour may target it again with
        # its step-s+1 send.  Credits exactly balance the waits above, so
        # the semaphore drains to zero by kernel exit.
        pltpu.semaphore_signal(credit_sem, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)


def ring_allgather_matmul_rdma(x, w, axis: str, *,
                               return_gathered: bool = False,
                               collective_id: int = 7):
    """The tier-3 Pallas kernel: ring allgather-matmul with in-kernel RDMA."""
    p = axis_size(axis)
    n, k = x.shape
    m = w.shape[-1]
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = jnp.matmul(x, w)
        return (out, x) if return_gathered else out
    out, gath = pl.pallas_call(
        functools.partial(_agmm_rdma_kernel, p=p, axis=axis),
        grid=(p,),
        in_specs=[pl.BlockSpec((n, k), lambda s: (0, 0),
                               memory_space=pltpu.VMEM),
                  pl.BlockSpec((k, m), lambda s: (0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=(pl.BlockSpec((p * n, m), lambda s: (0, 0),
                                memory_space=pltpu.VMEM),
                   pl.BlockSpec((p * n, k), lambda s: (0, 0),
                                memory_space=pltpu.VMEM)),
        out_shape=(jax.ShapeDtypeStruct((p * n, m), out_dtype),
                   jax.ShapeDtypeStruct((p * n, k), x.dtype)),
        scratch_shapes=[
            pltpu.VMEM((2, n, k), x.dtype),        # double-buffered chunks
            pltpu.SemaphoreType.DMA((2,)),         # send slots
            pltpu.SemaphoreType.DMA((2,)),         # recv slots
            pltpu.SemaphoreType.REGULAR,           # buffer-reuse credits
            pltpu.VMEM((n, m), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            has_side_effects=True, collective_id=collective_id),
    )(x, w)
    return (out, gath) if return_gathered else out


# ---------------------------------------------------------------------------
# interpret-mode CPU tier: the same grid schedule, DMA arrivals emulated
# ---------------------------------------------------------------------------


def _agmm_block_kernel(xall_ref, w_ref, o_ref, gath_ref, comm_buf, acc_scr,
                       *, p: int, my: int):
    """One rank's view of the RDMA grid: identical slot/src/output-row
    indexing (shared helpers), with the remote copy replaced by reading
    the chunk the DMA WOULD deliver from the full chunk array — so a wrong
    slot rotation or src formula scrambles the output vs the reference."""
    s = pl.program_id(0)
    slot, nxt = ring_step_slots(s)

    @pl.when(s == 0)
    def _seed():
        comm_buf[0] = pl.load(
            xall_ref, (pl.ds(my, 1), slice(None), slice(None)))[0]

    @pl.when(s < p - 1)
    def _send():
        # the step-s RDMA targets slot `nxt` with the chunk this rank will
        # consume at step s+1 (originated by ring_step_src(my, s+1, p))
        arriving = pl.load(
            xall_ref, (pl.ds(ring_step_src(my, s + 1, p), 1),
                       slice(None), slice(None)))[0]
        comm_buf[nxt] = arriving

    src = ring_step_src(my, s, p)
    n = xall_ref.shape[1]
    blk = comm_buf[slot]
    acc_scr[...] = jax.lax.dot_general(
        blk, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[pl.ds(src * n, n), :] = acc_scr[...].astype(o_ref.dtype)
    gath_ref[pl.ds(src * n, n), :] = blk


def ring_allgather_matmul_blocks(x_all, w, my: int, *,
                                 interpret: bool = True):
    """CPU tier of the RDMA ring: rank ``my``'s (p,)-grid block schedule
    over the full chunk array ``x_all [p, n, K]`` — exercised with
    ``interpret=True`` in CI so the block logic is covered without TPU
    hardware.  Returns ``(out [p·n, M], gathered [p·n, K])`` exactly like
    ``ring_allgather_matmul_rdma(..., return_gathered=True)``."""
    p, n, k = x_all.shape
    m = w.shape[-1]
    out_dtype = jnp.result_type(x_all.dtype, w.dtype)
    return pl.pallas_call(
        functools.partial(_agmm_block_kernel, p=p, my=my),
        grid=(p,),
        in_specs=[pl.BlockSpec((p, n, k), lambda s: (0, 0, 0),
                               memory_space=pltpu.VMEM),
                  pl.BlockSpec((k, m), lambda s: (0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=(pl.BlockSpec((p * n, m), lambda s: (0, 0),
                                memory_space=pltpu.VMEM),
                   pl.BlockSpec((p * n, k), lambda s: (0, 0),
                                memory_space=pltpu.VMEM)),
        out_shape=(jax.ShapeDtypeStruct((p * n, m), out_dtype),
                   jax.ShapeDtypeStruct((p * n, k), x_all.dtype)),
        scratch_shapes=[
            pltpu.VMEM((2, n, k), x_all.dtype),    # double-buffered chunks
            pltpu.VMEM((n, m), jnp.float32),
        ],
        interpret=interpret,
    )(x_all, w)
