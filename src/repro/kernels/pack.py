"""Guideline pack kernel: the mock-ups' local data movement (Pallas).

GL3/GL13 place the payload into a p-times-larger zero buffer at offset
idx*n before the collective; GL6/GL7/GL15/GL16 pad to a multiple of p.
On TPU this memcpy runs at HBM bandwidth — one fused kernel instead of
XLA's broadcast(0) + dynamic-update-slice pair (which reads+writes the big
buffer twice).

Grid (p,): block j writes x when j == idx else zeros — single pass over
the output, no zero-materialization of the full buffer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, x_ref, o_ref):
    j = pl.program_id(0)
    idx = idx_ref[0]
    x = x_ref[...]
    o_ref[...] = jnp.where(j == idx, x, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def guideline_pack(x, idx, p: int, *, interpret: bool = False):
    """x: [n, d]; idx: scalar int32 shard index -> [p*n, d] one-hot-placed."""
    n, d = x.shape
    idx = jnp.asarray(idx, jnp.int32).reshape(1)
    return pl.pallas_call(
        _kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((n, d), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, d), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((p * n, d), x.dtype),
        interpret=interpret,
    )(idx, x)
