"""Latency-hiding collective-matmul kernels (ring schedules, Pallas + ref).

Two fused primitives, each semantically equal to an unfused collective
followed (or preceded) by a dense matmul:

* ``ring_allgather_matmul``      out = all_gather(x, rows) @ w
* ``ring_matmul_reducescatter``  out = reduce_scatter(x @ w, rows)

Both run the classic (p-1)-step neighbour ring, but matmul the chunk they
already hold while the next chunk is in flight — the "collective matmul" of
Wang et al. (overlap of ICI transfers with MXU work), applied here as a
tunable mock-up: the dispatcher's ``fused_ring`` impl of the
``allgather_matmul`` / ``matmul_reducescatter`` ops (core/collectives.py)
calls these, and the tuner arbitrates fused vs unfused per (op, p, nbytes)
exactly like any other guideline.

Three execution tiers:

1. **Reference ring** (any backend, incl. CPU CI): ``lax.ppermute`` steps
   with a per-chunk local matmul.  The permute for chunk s+1 is issued
   *before* chunk s is consumed, so the dataflow graph exposes the overlap
   to XLA's latency-hiding scheduler; per-row contraction order matches the
   unfused composition, so the all-gather direction is bit-exact.
2. **Pallas block matmul** (``pallas_matmul``): the per-chunk matmul as a
   tiled MXU kernel with an fp32 VMEM accumulator; used inside the ring on
   TPU and exercised on CPU via ``interpret=True``.
3. **RDMA ring kernel** (``ring_allgather_matmul_rdma``): a single Pallas
   kernel that drives ``make_async_remote_copy`` sends itself (double-
   buffered comm scratch, per-slot DMA semaphores, neighbour barrier) —
   the full latency-hiding schedule with no XLA scheduling dependence.
   TPU-only; the public entry points fall back to tier 1/2 elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core._axis import axis_index, axis_size, ring_perm

__all__ = ["pallas_matmul", "ring_allgather_matmul",
           "ring_matmul_reducescatter", "ring_allgather_matmul_rdma"]

# jax 0.4.x names this TPUCompilerParams; new jax uses CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - backend probing never fatal
        return False


# ---------------------------------------------------------------------------
# tier 2: tiled local matmul (the per-chunk compute of the ring)
# ---------------------------------------------------------------------------


def _mm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pallas_matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False):
    """``x @ w`` as a tiled Pallas kernel (fp32 accumulation).

    Non-divisible shapes are zero-padded up to the block grid and the
    result sliced back — rows/cols of the pad contribute nothing.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def _local_mm(x, w, mm: str):
    """The per-chunk matmul: 'jnp' reference, 'pallas' MXU kernel, or
    'auto' (pallas on TPU, jnp elsewhere — CPU CI stays on the exact
    jnp contraction so the fused ring is bit-comparable to unfused)."""
    if mm == "auto":
        mm = "pallas" if _on_tpu() else "jnp"
    if mm == "pallas":
        return pallas_matmul(x, w, interpret=not _on_tpu())
    return jnp.matmul(x, w)


# ---------------------------------------------------------------------------
# tier 1: reference rings (ppermute chunks + per-chunk matmul)
# ---------------------------------------------------------------------------


def ring_allgather_matmul(x, w, axis: str, *, return_gathered: bool = False,
                          mm: str = "auto"):
    """``all_gather(x, rows) @ w`` with per-chunk overlap.

    x: per-shard ``[n, K]`` (rows gathered over ``axis``), w: ``[K, M]``
    (shard-local) -> ``[p*n, M]``.  Step s matmuls the chunk originated by
    rank ``idx - s`` while the ppermute moving chunk s+1 is already in
    flight.  Row results use the exact same K-contraction as the unfused
    ``matmul(all_gather(x), w)`` — bit-identical per row for ``mm='jnp'``.

    ``return_gathered=True`` additionally returns the assembled
    ``all_gather(x)`` — the ring materializes it for free, and custom VJPs
    reuse it instead of re-gathering.
    """
    p = axis_size(axis)
    n = x.shape[0]
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = _local_mm(x, w, mm).astype(out_dtype)
        return (out, x) if return_gathered else out
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    out = jnp.zeros((p * n, w.shape[-1]), out_dtype)
    gath = jnp.zeros((p * n,) + x.shape[1:], x.dtype) if return_gathered \
        else None
    cur = x
    for s in range(p):
        # issue the transfer of the NEXT chunk before consuming this one:
        # the matmul below has no data dependence on it, so the scheduler
        # (or the RDMA kernel on TPU) can run both concurrently.
        nxt = lax.ppermute(cur, axis, ring_perm(p, 1)) if s < p - 1 else None
        src = (idx - s) % p                # originating rank of `cur`
        blk = _local_mm(cur, w, mm).astype(out_dtype)
        out = lax.dynamic_update_slice(out, blk, (src * n, 0))
        if return_gathered:
            gath = lax.dynamic_update_slice(gath, cur, (src * n,) + zeros)
        cur = nxt
    return (out, gath) if return_gathered else out


def ring_matmul_reducescatter(x, w, axis: str, *, mm: str = "auto"):
    """``reduce_scatter(x @ w, rows)`` with per-chunk overlap.

    x: per-shard ``[p*n, K]`` (partial contraction — different shards hold
    different K-slices of the logical operand), w: ``[K, M]`` ->
    ``[n, M]`` summed over ``axis``.  The travelling accumulator picks up
    rank j's contribution to row-block b at step ``b = (j + p-1-s) % p``;
    while it is in flight the next step's local contribution (a pure
    function of resident x, w) can already be computed.
    """
    p = axis_size(axis)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        return _local_mm(x, w, mm).astype(out_dtype)
    rows = x.shape[0]
    assert rows % p == 0, f"rows {rows} not divisible by axis size {p}"
    n = rows // p
    idx = axis_index(axis)
    acc = None
    for s in range(p):
        blk_id = (idx + (p - 1 - s)) % p
        blk = lax.dynamic_slice(x, (blk_id * n,) + (0,) * (x.ndim - 1),
                                (n,) + x.shape[1:])
        contrib = _local_mm(blk, w, mm).astype(out_dtype)
        acc = contrib if acc is None else acc + contrib
        if s < p - 1:
            acc = lax.ppermute(acc, axis, ring_perm(p, 1))
    return acc


# ---------------------------------------------------------------------------
# tier 3: single-kernel RDMA ring (TPU only — drives its own transfers)
# ---------------------------------------------------------------------------


def _agmm_rdma_kernel(x_ref, w_ref, o_ref, gath_ref, comm_buf, send_sem,
                      recv_sem, credit_sem, acc_scr, *, p: int, axis: str):
    """One grid step per ring hop: RDMA-send the resident chunk to the right
    neighbour, matmul it into its output rows, then wait on the transfers —
    compute and ICI traffic overlap inside a single kernel invocation.

    Buffer-reuse flow control: the send at step s lands in the right
    neighbour's slot ``(s+1) % 2`` — the buffer that neighbour last read at
    its step s-1.  Each device therefore grants one CREDIT to its left
    neighbour when it finishes consuming a slot, and a sender must burn one
    credit (from the right neighbour) before re-targeting that slot; the
    step-0 send needs none (both slots start free)."""
    s = pl.program_id(0)
    my = lax.axis_index(axis)
    right = lax.rem(my + 1, p)
    left = lax.rem(my + p - 1, p)

    @pl.when(s == 0)
    def _seed():
        # neighbour barrier so nobody RDMAs into a peer still setting up
        bar = pltpu.get_barrier_semaphore()
        pltpu.semaphore_signal(bar, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_signal(bar, inc=1, device_id=(right,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(bar, 2)
        comm_buf[0] = x_ref[...]

    slot = lax.rem(s, 2)
    nxt = lax.rem(s + 1, 2)

    @pl.when(jnp.logical_and(s >= 1, s < p - 1))
    def _flow_control():
        # right neighbour finished reading its slot `nxt` at its step s-1
        pltpu.semaphore_wait(credit_sem, 1)

    @pl.when(s < p - 1)
    def _send():
        rdma = pltpu.make_async_remote_copy(
            src_ref=comm_buf.at[slot],
            dst_ref=comm_buf.at[nxt],
            send_sem=send_sem.at[slot],
            recv_sem=recv_sem.at[nxt],
            device_id=(right,),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        rdma.start()

    # matmul the chunk we hold while the RDMA is in flight
    src = lax.rem(my - s + p, p)
    n = x_ref.shape[0]
    blk = comm_buf[slot]
    acc_scr[...] = jax.lax.dot_general(
        blk, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[pl.ds(src * n, n), :] = acc_scr[...].astype(o_ref.dtype)
    gath_ref[pl.ds(src * n, n), :] = blk

    @pl.when(s < p - 1)
    def _wait():
        pltpu.semaphore_wait(send_sem.at[slot], 1)
        pltpu.semaphore_wait(recv_sem.at[nxt], 1)

    @pl.when(s < p - 2)
    def _grant():
        # slot `slot` is fully consumed (matmul done AND our outgoing DMA
        # from it delivered): the left neighbour may target it again with
        # its step-s+1 send.  Credits exactly balance the waits above, so
        # the semaphore drains to zero by kernel exit.
        pltpu.semaphore_signal(credit_sem, inc=1, device_id=(left,),
                               device_id_type=pltpu.DeviceIdType.LOGICAL)


def ring_allgather_matmul_rdma(x, w, axis: str, *,
                               return_gathered: bool = False,
                               collective_id: int = 7):
    """The tier-3 Pallas kernel: ring allgather-matmul with in-kernel RDMA.

    TPU-only (``make_async_remote_copy`` has no host interpret path across
    shard_map devices); callers gate on backend and fall back to
    ``ring_allgather_matmul`` elsewhere.
    """
    p = axis_size(axis)
    n, k = x.shape
    m = w.shape[-1]
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = jnp.matmul(x, w)
        return (out, x) if return_gathered else out
    out, gath = pl.pallas_call(
        functools.partial(_agmm_rdma_kernel, p=p, axis=axis),
        grid=(p,),
        in_specs=[pl.BlockSpec((n, k), lambda s: (0, 0),
                               memory_space=pltpu.VMEM),
                  pl.BlockSpec((k, m), lambda s: (0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=(pl.BlockSpec((p * n, m), lambda s: (0, 0),
                                memory_space=pltpu.VMEM),
                   pl.BlockSpec((p * n, k), lambda s: (0, 0),
                                memory_space=pltpu.VMEM)),
        out_shape=(jax.ShapeDtypeStruct((p * n, m), out_dtype),
                   jax.ShapeDtypeStruct((p * n, k), x.dtype)),
        scratch_shapes=[
            pltpu.VMEM((2, n, k), x.dtype),        # double-buffered chunks
            pltpu.SemaphoreType.DMA((2,)),         # send slots
            pltpu.SemaphoreType.DMA((2,)),         # recv slots
            pltpu.SemaphoreType.REGULAR,           # buffer-reuse credits
            pltpu.VMEM((n, m), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            has_side_effects=True, collective_id=collective_id),
    )(x, w)
    return (out, gath) if return_gathered else out
