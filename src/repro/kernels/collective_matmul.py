"""Latency-hiding collective-matmul kernels (ring schedules, Pallas + ref).

Four fused primitives, each semantically equal to an unfused collective
composition around a dense matmul:

* ``ring_allgather_matmul``          out = all_gather(x, rows) @ w
* ``ring_matmul_reducescatter``      out = reduce_scatter(x @ w, rows)
* ``ring_matmul_accumulate``         out = x @ all_gather(w, rows)
* ``ring_matmul_reducescatter_2d``   out = reduce_scatter(
                                         x @ all_gather(w, cols, ag_axis),
                                         rows, rs_axis)   — TWO mesh axes

All run the classic (p-1)-step neighbour ring, but matmul the chunk they
already hold while the next chunk is in flight — the "collective matmul" of
Wang et al. (overlap of ICI transfers with MXU work), applied here as a
tunable mock-up: the dispatcher's ``fused_ring`` / ``fused_ring2d`` impls
of the ``allgather_matmul`` / ``matmul_reducescatter`` /
``matmul_accumulate`` / ``matmul_reducescatter_2d`` ops
(core/collectives.py) call these, and the tuner arbitrates fused vs unfused
per tuning cell exactly like any other guideline.

The ring schedules differ in WHAT travels and WHAT stays resident:

=========================  ==================  ===========================
schedule                   travelling operand  per-step local work
=========================  ==================  ===========================
allgather-matmul           activation chunk    chunk row-block @ resident w
                           (gather role)       -> disjoint output rows
matmul-reducescatter       output accumulator  resident x row-block @ w,
                           (scatter role)      added into the accumulator
matmul-accumulate          weight block        x K-slice @ weight block,
                           (contract role)     accumulated into [T, M] out
matmul-reducescatter-2d    outer: weight       inner matmul-reducescatter
                           column block over   ring over ``rs_axis`` of the
                           ``ag_axis``; inner: resident x against the
                           output accumulator  resident weight block —
                           over ``rs_axis``    nested rings, issue-before-
                                               consume on BOTH axes
=========================  ==================  ===========================

The 2-D schedule is weight-stationary in the serving sense: each rank's
FSDP weight shard never leaves its ring slot's rotation — one column block
is in flight on the outer (data) ring while the previous block's partial
products are being reduce-scattered over the inner (model) ring.
``ring_matmul_reducescatter_2d_t`` is its transpose (the dw schedule of
the paired VJP): the gathered operand's dim is CONTRACTED away (outer
travelling accumulator over the scatter axis, inner contract-stream of the
cotangent's column slice over the gather axis).

Three execution tiers:

1. **Reference ring** (any backend, incl. CPU CI): ``lax.ppermute`` steps
   with a per-chunk local matmul.  The permute for chunk s+1 is issued
   *before* chunk s is consumed, so the dataflow graph exposes the overlap
   to XLA's latency-hiding scheduler; per-row contraction order matches the
   unfused composition, so the all-gather direction is bit-exact.
2. **Pallas block matmul** (``pallas_matmul``): the per-chunk matmul as a
   tiled MXU kernel with an fp32 VMEM accumulator; used inside the ring on
   TPU and exercised on CPU via ``interpret=True``.
3. **RDMA ring kernel** (``collective_matmul_rdma.ring_allgather_matmul_
   rdma``): a single Pallas kernel that drives ``make_async_remote_copy``
   sends itself — the full latency-hiding schedule with no XLA scheduling
   dependence.  TPU-only and kept in its own module; the ``fused_ring``
   dispatcher impl performs the backend check (``on_tpu``) and only
   imports the RDMA module on TPU, so CPU CI never loads that path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core._axis import axis_index, axis_size, ring_perm

__all__ = ["pallas_matmul", "ring_allgather_matmul",
           "ring_matmul_reducescatter", "ring_matmul_accumulate",
           "ring_matmul_reducescatter_2d", "ring_matmul_reducescatter_2d_t",
           "ring_allgather_matmul_wire", "ring_matmul_reducescatter_wire",
           "ring_matmul_accumulate_wire", "on_tpu"]


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def on_tpu() -> bool:
    """Backend check gating the TPU-only execution tiers (RDMA ring,
    non-interpret Pallas)."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - backend probing never fatal
        return False


_on_tpu = on_tpu  # internal alias


# ---------------------------------------------------------------------------
# tier 2: tiled local matmul (the per-chunk compute of the ring)
# ---------------------------------------------------------------------------


def _mm_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def pallas_matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False):
    """``x @ w`` as a tiled Pallas kernel (fp32 accumulation).

    Non-divisible shapes are zero-padded up to the block grid and the
    result sliced back — rows/cols of the pad contribute nothing.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                  pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def _local_mm(x, w, mm: str):
    """The per-chunk matmul: 'jnp' reference, 'pallas' MXU kernel, or
    'auto' (pallas on TPU, jnp elsewhere — CPU CI stays on the exact
    jnp contraction so the fused ring is bit-comparable to unfused)."""
    if mm == "auto":
        mm = "pallas" if _on_tpu() else "jnp"
    if mm == "pallas":
        return pallas_matmul(x, w, interpret=not _on_tpu())
    return jnp.matmul(x, w)


# ---------------------------------------------------------------------------
# tier 1: reference rings (ppermute chunks + per-chunk matmul)
# ---------------------------------------------------------------------------


def ring_allgather_matmul(x, w, axis: str, *, return_gathered: bool = False,
                          mm: str = "auto"):
    """``all_gather(x, rows) @ w`` with per-chunk overlap.

    x: per-shard ``[n, K]`` (rows gathered over ``axis``), w: ``[K, M]``
    (shard-local) -> ``[p*n, M]``.  Step s matmuls the chunk originated by
    rank ``idx - s`` while the ppermute moving chunk s+1 is already in
    flight.  Row results use the exact same K-contraction as the unfused
    ``matmul(all_gather(x), w)`` — bit-identical per row for ``mm='jnp'``.

    ``return_gathered=True`` additionally returns the assembled
    ``all_gather(x)`` — the ring materializes it for free, and custom VJPs
    reuse it instead of re-gathering.
    """
    p = axis_size(axis)
    n = x.shape[0]
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = _local_mm(x, w, mm).astype(out_dtype)
        return (out, x) if return_gathered else out
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    out = jnp.zeros((p * n, w.shape[-1]), out_dtype)
    gath = jnp.zeros((p * n,) + x.shape[1:], x.dtype) if return_gathered \
        else None
    cur = x
    for s in range(p):
        # issue the transfer of the NEXT chunk before consuming this one:
        # the matmul below has no data dependence on it, so the scheduler
        # (or the RDMA kernel on TPU) can run both concurrently.
        nxt = lax.ppermute(cur, axis, ring_perm(p, 1)) if s < p - 1 else None
        src = (idx - s) % p                # originating rank of `cur`
        blk = _local_mm(cur, w, mm).astype(out_dtype)
        out = lax.dynamic_update_slice(out, blk, (src * n, 0))
        if return_gathered:
            gath = lax.dynamic_update_slice(gath, cur, (src * n,) + zeros)
        cur = nxt
    return (out, gath) if return_gathered else out


def ring_matmul_reducescatter(x, w, axis: str, *, mm: str = "auto"):
    """``reduce_scatter(x @ w, rows)`` with per-chunk overlap.

    x: per-shard ``[p*n, K]`` (partial contraction — different shards hold
    different K-slices of the logical operand), w: ``[K, M]`` ->
    ``[n, M]`` summed over ``axis``.  The travelling accumulator picks up
    rank j's contribution to row-block b at step ``b = (j + p-1-s) % p``;
    while it is in flight the next step's local contribution (a pure
    function of resident x, w) can already be computed.
    """
    p = axis_size(axis)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        return _local_mm(x, w, mm).astype(out_dtype)
    rows = x.shape[0]
    assert rows % p == 0, f"rows {rows} not divisible by axis size {p}"
    n = rows // p
    idx = axis_index(axis)
    acc = None
    for s in range(p):
        blk_id = (idx + (p - 1 - s)) % p
        blk = lax.dynamic_slice(x, (blk_id * n,) + (0,) * (x.ndim - 1),
                                (n,) + x.shape[1:])
        contrib = _local_mm(blk, w, mm).astype(out_dtype)
        acc = contrib if acc is None else acc + contrib
        if s < p - 1:
            acc = lax.ppermute(acc, axis, ring_perm(p, 1))
    return acc


def ring_matmul_accumulate(x, w, axis: str, *, return_gathered: bool = False,
                           mm: str = "auto"):
    """``x @ all_gather(w, rows)`` with per-block overlap — the contraction-
    dim ring.

    x: ``[T, K]`` shard-local (K = p·k_loc, the full contraction), w:
    per-shard ``[k_loc, M]`` (rows gathered over ``axis``) -> ``[T, M]``.
    The gathered dim is contracted away, so neither row-block schedule
    applies; instead the WEIGHT blocks travel: step s matmuls the K-slice of
    ``x`` matching the block originated by rank ``idx - s`` into a local
    accumulator while the ppermute moving block s+1 is already in flight
    (issue-before-consume, same overlap law as the other rings).

    ``return_gathered=True`` additionally returns the assembled
    ``all_gather(w)`` — the ring materializes it for free, and custom VJPs
    reuse it for the input gradient instead of re-gathering.
    """
    p = axis_size(axis)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = _local_mm(x, w, mm).astype(out_dtype)
        return (out, w) if return_gathered else out
    k_loc = w.shape[0]
    assert x.shape[-1] == p * k_loc, (x.shape, w.shape, p)
    idx = axis_index(axis)
    zeros = (0,) * (w.ndim - 1)
    gath = jnp.zeros((p * k_loc,) + w.shape[1:], w.dtype) if return_gathered \
        else None
    acc = None
    cur = w
    for s in range(p):
        # issue the transfer of the NEXT weight block before consuming this
        # one — the accumulate below has no data dependence on it
        nxt = lax.ppermute(cur, axis, ring_perm(p, 1)) if s < p - 1 else None
        src = (idx - s) % p                # originating rank of `cur`
        xblk = lax.dynamic_slice_in_dim(x, src * k_loc, k_loc, axis=-1)
        contrib = _local_mm(xblk, cur, mm).astype(out_dtype)
        acc = contrib if acc is None else acc + contrib
        if return_gathered:
            gath = lax.dynamic_update_slice(gath, cur, (src * k_loc,) + zeros)
        cur = nxt
    return (acc, gath) if return_gathered else acc


# ---------------------------------------------------------------------------
# tier 1b: the weight-stationary 2-D nested ring (data × model)
# ---------------------------------------------------------------------------


def ring_matmul_reducescatter_2d(x, w, rs_axis: str, ag_axis: str, *,
                                 return_gathered: bool = False,
                                 mm: str = "auto"):
    """``reduce_scatter(x @ all_gather(w, cols over ag_axis), rows over
    rs_axis)`` with nested overlap — the weight-stationary 2-D collective
    matmul.

    x: ``[T, K]`` shard-local (T divisible by ``q = size(rs_axis)``), w:
    per-shard ``[K, m_loc]`` (column block of the logical ``[K, d·m_loc]``
    weight, gathered over ``d = size(ag_axis)``) -> ``[T/q, d·m_loc]``
    summed over ``rs_axis`` with row-block i landing on inner-rank i.

    Nested rings: the OUTER ring streams weight column blocks over
    ``ag_axis`` (d steps, issue-before-consume — the ppermute moving block
    s+1 is issued before block s is consumed); each outer step runs a full
    INNER ``ring_matmul_reducescatter`` over ``rs_axis`` (itself
    issue-before-consume), whose ``[T/q, m_loc]`` result fills the outer
    block's output columns.  Both transfers overlap MXU work, so the
    modeled cost is ``max(outer_comm, per-step max(inner_comm, compute))``
    per outer step (costmodel.t_overlapped_ring2d).

    ``return_gathered=True`` additionally returns the assembled
    ``all_gather(w, cols)`` ``[K, d·m_loc]`` — the outer ring materializes
    it for free, and the paired VJP reuses it for dx instead of
    re-gathering.
    """
    d = axis_size(ag_axis)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if d == 1:
        out = ring_matmul_reducescatter(x, w, rs_axis, mm=mm)
        return (out, w) if return_gathered else out
    m_loc = w.shape[-1]
    idx = axis_index(ag_axis)
    q = axis_size(rs_axis)
    rows = x.shape[0]
    assert rows % q == 0, f"rows {rows} not divisible by rs axis size {q}"
    out = jnp.zeros((rows // q, d * m_loc), out_dtype)
    gath = jnp.zeros((w.shape[0], d * m_loc), w.dtype) if return_gathered \
        else None
    cur = w
    for s in range(d):
        # issue the transfer of the NEXT weight block before consuming this
        # one — the inner ring below has no data dependence on it
        nxt = lax.ppermute(cur, ag_axis, ring_perm(d, 1)) if s < d - 1 \
            else None
        src = (idx - s) % d                # originating rank of `cur`
        blk = ring_matmul_reducescatter(x, cur, rs_axis, mm=mm)
        out = lax.dynamic_update_slice(out, blk.astype(out_dtype),
                                       (0, src * m_loc))
        if return_gathered:
            gath = lax.dynamic_update_slice(gath, cur, (0, src * m_loc))
        cur = nxt
    return (out, gath) if return_gathered else out


def ring_matmul_reducescatter_2d_t(g, x, rs_axis: str, ag_axis: str, *,
                                   mm: str = "auto"):
    """``reduce_scatter(all_gather(g, rows over ag_axis)ᵀ @ x, rows over
    rs_axis)`` — the TRANSPOSE 2-D schedule (the dw of the paired VJP).

    g: per-shard ``[t_loc, M]`` (row block of the logical ``[q·t_loc, M]``
    cotangent, gathered over ``q = size(ag_axis)``), x: ``[q·t_loc, K]``
    shard-local -> ``[M/d, K]`` summed over ``rs_axis``
    (``d = size(rs_axis)``; M divisible by d).

    Relative to the forward 2-D schedule both axes swap roles AND the
    gathered dim is CONTRACTED away (like ``matmul_accumulate`` vs the
    row-block rings): the OUTER ring is the travelling output accumulator
    over ``rs_axis``; per outer step the needed ``[t_loc, M/d]`` COLUMN
    SLICE of the cotangent streams around ``ag_axis`` (inner ring,
    issue-before-consume), so the full cotangent crosses the gather axis
    exactly once in total.
    """
    d = axis_size(rs_axis)
    out_dtype = jnp.result_type(g.dtype, x.dtype)
    q = axis_size(ag_axis)
    M = g.shape[-1]
    assert M % d == 0, f"cols {M} not divisible by rs axis size {d}"
    m_loc = M // d
    t_loc = g.shape[0]
    assert x.shape[0] == q * t_loc, (g.shape, x.shape, q)
    idx_rs = axis_index(rs_axis)
    idx_ag = axis_index(ag_axis)
    acc = None
    for s in range(d):
        # travelling-accumulator target of this outer step (same block
        # order as ring_matmul_reducescatter)
        blk_id = (idx_rs + (d - 1 - s)) % d
        cur = lax.dynamic_slice(g, (0, blk_id * m_loc), (t_loc, m_loc))
        contrib = None
        for t in range(q):
            # inner contract-stream: cotangent slice t+1 in flight while
            # slice t multiplies its matching x row block
            nxt = lax.ppermute(cur, ag_axis, ring_perm(q, 1)) \
                if t < q - 1 else None
            src = (idx_ag - t) % q         # originating rank of `cur`
            xblk = lax.dynamic_slice_in_dim(x, src * t_loc, t_loc, axis=0)
            c = _local_mm(jnp.swapaxes(cur, 0, 1), xblk, mm).astype(out_dtype)
            contrib = c if contrib is None else contrib + c
            cur = nxt
        acc = contrib if acc is None else acc + contrib
        if s < d - 1:
            acc = lax.ppermute(acc, rs_axis, ring_perm(d, 1))
    return acc


# ---------------------------------------------------------------------------
# tier 1c: quantized-wire rings (wire_q8 / wire_fp8 mock-up families)
#
# Same (p-1)-step issue-before-consume schedules as the f32 rings above, but
# the TRAVELLING operand crosses the wire in an 8-bit format with per-block
# scales (kernels/quant.py).  Two regimes:
#
# * gather-style (allgather-matmul, accumulate): the payload is quantized
#   ONCE at its origin and the (values, scales) pair travels unchanged —
#   every receiver dequantizes the same single-roundtrip approximation, and
#   the resident chunk (which never crossed the wire) stays exact.
# * travelling accumulator (matmul-reducescatter): the accumulator must be
#   requantized before every hop; dequantized contributions are ALWAYS
#   summed in f32 (the accumulate-in-f32 rule the selfcheck tolerance gate
#   assumes), so errors add per hop but never compound multiplicatively.
# ---------------------------------------------------------------------------


def ring_allgather_matmul_wire(x, w, axis: str, *, wire_dtype: str = "int8",
                               return_gathered: bool = False,
                               mm: str = "auto"):
    """``ring_allgather_matmul`` with the travelling activation chunk sent
    as (8-bit values, per-block scales); dequantize-on-receive feeds the
    per-chunk matmul.  ``return_gathered`` returns the wire-approximate
    gathered operand (own chunk exact)."""
    from repro.kernels import quant as Qz
    p = axis_size(axis)
    n = x.shape[0]
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = _local_mm(x, w, mm).astype(out_dtype)
        return (out, x) if return_gathered else out
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    out = jnp.zeros((p * n, w.shape[-1]), out_dtype)
    gath = jnp.zeros((p * n,) + x.shape[1:], x.dtype) if return_gathered \
        else None
    q, sc = Qz.quantize(x, wire_dtype)
    cur = x                                 # resident chunk: never on the wire
    for s in range(p):
        # issue the transfer of the NEXT chunk's wire pair before consuming
        # this one (same overlap exposure as the f32 ring)
        nxt = (lax.ppermute(q, axis, ring_perm(p, 1)),
               lax.ppermute(sc, axis, ring_perm(p, 1))) if s < p - 1 else None
        src = (idx - s) % p                 # originating rank of `cur`
        blk = _local_mm(cur, w, mm).astype(out_dtype)
        out = lax.dynamic_update_slice(out, blk, (src * n, 0))
        if return_gathered:
            gath = lax.dynamic_update_slice(gath, cur.astype(x.dtype),
                                            (src * n,) + zeros)
        if nxt is not None:
            q, sc = nxt
            cur = Qz.dequantize(q, sc, x.dtype)
    return (out, gath) if return_gathered else out


def ring_matmul_reducescatter_wire(x, w, axis: str, *,
                                   wire_dtype: str = "int8",
                                   mm: str = "auto"):
    """``ring_matmul_reducescatter`` with the travelling accumulator
    requantized per hop; contributions accumulate in f32 after dequant."""
    from repro.kernels import quant as Qz
    p = axis_size(axis)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        return _local_mm(x, w, mm).astype(out_dtype)
    rows = x.shape[0]
    assert rows % p == 0, f"rows {rows} not divisible by axis size {p}"
    n = rows // p
    idx = axis_index(axis)
    acc = None
    for s in range(p):
        blk_id = (idx + (p - 1 - s)) % p
        blk = lax.dynamic_slice(x, (blk_id * n,) + (0,) * (x.ndim - 1),
                                (n,) + x.shape[1:])
        contrib = _local_mm(blk, w, mm).astype(jnp.float32)
        acc = contrib if acc is None else acc + contrib
        if s < p - 1:
            q, sc = Qz.quantize(acc, wire_dtype)
            q = lax.ppermute(q, axis, ring_perm(p, 1))
            sc = lax.ppermute(sc, axis, ring_perm(p, 1))
            acc = Qz.dequantize(q, sc, jnp.float32)
    return acc.astype(out_dtype)


def ring_matmul_accumulate_wire(x, w, axis: str, *, wire_dtype: str = "int8",
                                return_gathered: bool = False,
                                mm: str = "auto"):
    """``ring_matmul_accumulate`` with the travelling weight block sent as
    a wire pair quantized once at its origin; partial products accumulate
    in f32 after dequant."""
    from repro.kernels import quant as Qz
    p = axis_size(axis)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if p == 1:
        out = _local_mm(x, w, mm).astype(out_dtype)
        return (out, w) if return_gathered else out
    k_loc = w.shape[0]
    assert x.shape[-1] == p * k_loc, (x.shape, w.shape, p)
    idx = axis_index(axis)
    zeros = (0,) * (w.ndim - 1)
    gath = jnp.zeros((p * k_loc,) + w.shape[1:], w.dtype) if return_gathered \
        else None
    q, sc = Qz.quantize(w, wire_dtype)
    cur = w                                 # resident block: never on the wire
    acc = None
    for s in range(p):
        nxt = (lax.ppermute(q, axis, ring_perm(p, 1)),
               lax.ppermute(sc, axis, ring_perm(p, 1))) if s < p - 1 else None
        src = (idx - s) % p                 # originating rank of `cur`
        xblk = lax.dynamic_slice_in_dim(x, src * k_loc, k_loc, axis=-1)
        contrib = _local_mm(xblk, cur, mm).astype(jnp.float32)
        acc = contrib if acc is None else acc + contrib
        if return_gathered:
            gath = lax.dynamic_update_slice(gath, cur.astype(w.dtype),
                                            (src * k_loc,) + zeros)
        if nxt is not None:
            q, sc = nxt
            cur = Qz.dequantize(q, sc, w.dtype)
    out = acc.astype(out_dtype)
    return (out, gath) if return_gathered else out


# ---------------------------------------------------------------------------
# tier 3 lives in kernels/collective_matmul_rdma.py (TPU-only module); keep
# the historical import path working without loading it on CPU.
# ---------------------------------------------------------------------------


def __getattr__(name: str):
    if name == "ring_allgather_matmul_rdma":
        from repro.kernels.collective_matmul_rdma import \
            ring_allgather_matmul_rdma
        return ring_allgather_matmul_rdma
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
