"""Chunked Mamba2 SSD kernel (Pallas TPU).

Scalar per-head decay makes the [L, L] pairwise decay matrix cheap and
stable (differences of a monotone cumsum, always ≤ 0).  Per chunk:

  cb      = C @ Bᵀ                       [L,N]@[N,L] (MXU)
  y_intra = (cb ⊙ decay ⊙ tril) @ X      [L,L]@[L,P] (MXU)
  y_inter = (C ⊙ e^{cum}) @ S            [L,N]@[N,P] (MXU)
  S       ← e^{cum_L} S + (B ⊙ e^{cum_L-cum})ᵀ @ X

Grid (BH, S/L), chunk innermost; state in VMEM scratch.

x: [BH,S,P]; dt: [BH,S] (softplus'ed); a: [BH] (>0); B,C: [BH,S,N].
Returns y [BH,S,P] f32, s_fin [BH,N,P] f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_out_ref, s_scr, *,
            n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)                 # [L, P]
    dt = dt_ref[0].astype(jnp.float32)               # [L]
    a = a_ref[0].astype(jnp.float32)                 # scalar
    B = b_ref[0].astype(jnp.float32)                 # [L, N]
    C = c_ref[0].astype(jnp.float32)                 # [L, N]
    L, P = x.shape

    la = -dt * a                                     # log-decay per step
    cum = jnp.cumsum(la)                             # [L], decreasing
    xb = x * dt[:, None]

    s_prev = s_scr[...]                              # [N, P]

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L,L]
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    m = cb * jnp.exp(jnp.minimum(diff, 0.0)) * tri
    y = jax.lax.dot_general(m, xb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    c_dec = C * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(c_dec, s_prev, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    b_dec = B * jnp.exp(cum[-1] - cum)[:, None]
    s_new = jnp.exp(cum[-1]) * s_prev + jax.lax.dot_general(
        b_dec, xb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ci == n_chunks - 1)
    def _out():
        s_out_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, B, C, *, chunk: int = 64, interpret: bool = False):
    bh, s, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    kern = functools.partial(_kernel, n_chunks=nc)
    y, s_fin = pl.pallas_call(
        kern,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, p), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, B, C)
    return y, s_fin
