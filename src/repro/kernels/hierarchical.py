"""Composed hierarchical ring schedules for two-tier meshes.

A (pod, data, model) mesh crosses interconnect tiers with ~order-of-
magnitude link gaps (ICI vs DCN).  A single flat collective over the
joint group runs every synchronous ring step at the SLOW tier's rate;
the hierarchical decompositions below keep the bulk of the bytes on the
fast intra tier and move only a ``1/q`` share across the slow inter tier
(survey arXiv:1611.06334; the composition-of-guidelines idea of PGMPI
arXiv:1606.00215):

* ``hier_allreduce``      RS-intra → AR-inter → AG-intra
* ``hier_allgather``      AG-intra → AG-inter
* ``hier_reduce_scatter`` RS-inter → RS-intra   (the all-gather dual)

Everything is built from the two exact (full-precision wire) ring
primitives ``ring_reduce_scatter`` / ``ring_allgather`` — per-axis
neighbour ``ppermute`` loops in the style of the pallas-guide ring-
collective pattern, expressed at the jnp tier so the same code runs
under shard_map, vmap semantic tests, and the subprocess SPMD harness.
Axis-pair convention everywhere: ``inter_axis`` is the OUTER (slow)
axis, ``intra_axis`` the INNER (fast) one; gathered/scattered block
order is outer-major, matching a flat collective over
``(inter, intra)``.

Mock-ups call these directly (never the dispatcher — no recursive
re-tuning); ``core.collectives`` registers them as the ``MPIX_*``
EXT-guideline impls.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core._axis import axis_index, axis_size, pshift, ring_perm


def _n_rows(x) -> int:
    return int(x.shape[0])


def _pad_rows(x, n_pad: int):
    """Zero-pad dim 0 up to ``n_pad`` rows (reduction identity)."""
    n = _n_rows(x)
    if n_pad == n:
        return x
    pad = [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def ring_reduce_scatter(x, axis: str):
    """Exact (p-1)-hop travelling-accumulator ring reduce-scatter.

    Per-shard ``[p·n, ...]`` → ``[n, ...]``: rank ``i`` ends with the sum
    of block ``i`` over the axis (``lax.psum_scatter`` tiled semantics).
    Rows must divide ``p`` — callers pad (the hierarchical wrappers do).
    """
    p = axis_size(axis)
    if p == 1:
        return x
    n = _n_rows(x) // p
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    acc = None
    for s in range(p):
        blk_id = (idx + (p - 1 - s)) % p
        blk = lax.dynamic_slice(x, (blk_id * n,) + zeros, (n,) + x.shape[1:])
        acc = blk if acc is None else acc + blk
        if s < p - 1:
            acc = pshift(acc, axis, ring_perm(p, 1))
    return acc


def ring_allgather(x, axis: str):
    """Exact (p-1)-hop neighbour-ring all-gather: ``[n, ...]`` →
    ``[p·n, ...]`` in rank order."""
    p = axis_size(axis)
    if p == 1:
        return x
    n = _n_rows(x)
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    out = jnp.zeros((p * n,) + x.shape[1:], x.dtype)
    out = lax.dynamic_update_slice(out, x, (idx * n,) + zeros)
    cur = x
    for s in range(1, p):
        cur = pshift(cur, axis, ring_perm(p, 1))
        src = (idx - s) % p
        out = lax.dynamic_update_slice(out, cur, (src * n,) + zeros)
    return out


def ring_allreduce(x, axis: str):
    """Exact ring allreduce = padded ring RS + ring AG (Rabenseifner /
    GL6 shape) — the inter-tier stage of ``hier_allreduce``."""
    p = axis_size(axis)
    if p == 1:
        return x
    n = _n_rows(x)
    k = -(-n // p)
    red = ring_reduce_scatter(_pad_rows(x, k * p), axis)
    out = ring_allgather(red, axis)
    return out[:n] if out.shape[0] != n else out


def hier_allreduce(x, inter_axis: str, intra_axis: str):
    """RS-intra → AR-inter → AG-intra.

    The full buffer only ever moves on the intra tier; the inter tier
    reduces ``1/q`` of it per rank.  Result = ``psum`` over BOTH axes.
    """
    q = axis_size(intra_axis)
    n = _n_rows(x)
    k = -(-n // q)
    red = ring_reduce_scatter(_pad_rows(x, k * q), intra_axis)
    mid = ring_allreduce(red, inter_axis)
    out = ring_allgather(mid, intra_axis)
    return out[:n] if out.shape[0] != n else out


def hier_allgather(x, inter_axis: str, intra_axis: str):
    """AG-intra → AG-inter: gather the fast tier first, then stream the
    already-assembled ``q·n`` node block across the slow tier once.
    Block order is outer-major — identical to a flat all-gather over
    ``(inter, intra)``."""
    return ring_allgather(ring_allgather(x, intra_axis), inter_axis)


def hier_reduce_scatter(x, inter_axis: str, intra_axis: str):
    """RS-inter → RS-intra (the ``hier_allgather`` dual): the slow tier
    reduces ``q·n``-row node blocks down to one per outer rank, the fast
    tier finishes at full speed.  Rank ``(i, j)`` ends with the joint
    sum of block ``i·q + j`` — ``psum_scatter`` over ``(inter, intra)``.
    Rows must divide ``p·q`` (the dispatcher op's contract)."""
    return ring_reduce_scatter(ring_reduce_scatter(x, inter_axis),
                               intra_axis)
