"""Distributed training CLI — the production entry point.

Composes the tested pieces: mesh construction, tuned-profile loading
(PGMPITuneD), the manual-SPMD Trainer, deterministic sharded data,
async checkpointing, straggler watchdog, and crash-resume.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --smoke --steps 50 --profile-dir results/profiles_v5e

On a real pod, drop --smoke and pass --mesh 16x16 / --mesh 2x16x16 (this
container has one CPU device, so full-size runs are for TPU hosts; the
same code path is exercised at 1-device and 8-device scale by the tests).
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--mesh", default="",
                    help="'16x16' | '2x16x16' | 'dxt' over host devices;"
                         " empty = single device")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--compress", choices=("none", "bf16"), default="none")
    ap.add_argument("--profile-dir", default="",
                    help="tuned-profile directory (flat files = base store,"
                         " per-phase subdirs from tuner.tune_trace);"
                         " default: $PGTUNE_PROFILE_DIR")
    ap.add_argument("--force", default="", help="op:alg=...;... override")
    ap.add_argument("--ckpt-dir", default="results/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import jax

    from repro.ckpt import AsyncCheckpointer, checkpoint as ck
    from repro.configs import get_config
    from repro.core.api import parse_module_spec
    from repro.core.profiles import resolve_stores
    from repro.data import make_batch
    from repro.ft import StepWatchdog
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.train import Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    mesh = None
    if args.mesh == "16x16":
        mesh = make_production_mesh()
    elif args.mesh == "2x16x16":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh:
        d, t = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh((d, t), ("data", "model"))

    # precedence: --profile-dir > $PGTUNE_PROFILE_DIR > none
    profiles, phase_stores = resolve_stores(args.profile_dir or None)
    if profiles is not None or phase_stores:
        print(f"profiles: base={len(profiles) if profiles else 0} "
              f"phases={sorted(phase_stores)}")
    force = parse_module_spec(args.force) if args.force else None

    tr = Trainer(cfg, mesh=mesh, n_micro=args.n_micro,
                 compress=args.compress, profiles=profiles,
                 phase_profiles=phase_stores or None, force=force,
                 base_lr=args.lr, warmup=args.warmup)
    params, opt = tr.init(0)
    start = ck.latest_step(args.ckpt_dir) or 0
    if start:
        state = ck.restore(args.ckpt_dir, start,
                           {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    acp = AsyncCheckpointer(args.ckpt_dir)
    wd = StepWatchdog(ratio=4.0)
    t0 = time.time()
    for i in range(start, args.steps):
        wd.start_step()
        batch = tr.put_batch(make_batch(cfg, args.global_batch, args.seq, i))
        params, opt, m = tr.step(params, opt, batch, i)
        straggler = wd.end_step()
        if i % args.log_every == 0 or straggler:
            note = "  [STRAGGLER]" if straggler else ""
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{wd.median*1e3:.0f} ms/step{note}", flush=True)
        if (i + 1) % args.ckpt_every == 0:
            acp.save(i + 1, {"params": params, "opt": opt})
    acp.wait()
    ck.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt})
    dt = time.time() - t0
    tok = (args.steps - start) * args.global_batch * args.seq
    print(f"done: {args.steps - start} steps, {tok/dt:.0f} tok/s, "
          f"stragglers={len(wd.straggler_steps)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
