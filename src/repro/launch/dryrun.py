import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
backend initialization (see the module-level guard below).

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod both \
        --out results/dryrun
    python -m repro.launch.dryrun ... --force allreduce=allreduce_as_rsb_allgather

Per cell: jit(...).lower(*input_specs).compile() on the production mesh,
then print/record memory_analysis(), cost_analysis() and the HLO collective
schedule (payload bytes per collective class) for §Roofline.
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback


def _check_device_count():
    import jax
    n = len(jax.devices())
    if n < 512:
        raise RuntimeError(
            f"dry-run needs 512 host devices, got {n}; something imported "
            "jax before the XLA_FLAGS lines at the top of this module")


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             force: dict | None = None, profiles=None,
             hlo_dir: str | None = None, attn_impl: str | None = None,
             n_micro: int | None = None, capacity_factor: float | None = None,
             donate: bool = False, unroll: bool = False,
             tag: str = "") -> dict:
    import jax
    from repro._compat import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.analysis.hlo import collective_bytes, program_costs
    from repro.analysis.roofline import roofline_terms
    from repro.configs import get_config
    from repro.core import api
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, applicable, input_specs
    from repro.models import lm
    from repro.train.trainer import make_step_fns

    cfg = get_config(arch)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if capacity_factor and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=capacity_factor))
    cell = SHAPES[shape]
    if n_micro:
        cell = dataclasses.replace(cell, n_micro=n_micro)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skip", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    with mesh, api.tuned(profiles=profiles, force=force) as tune_ctx:
        args_sds, in_ps = input_specs(cfg, cell, mesh)
        if cell.kind == "train":
            _, train_fn = make_step_fns(cfg, n_micro=cell.n_micro)
            out_ps = (in_ps[0], in_ps[1],
                      {"loss": P(), "grad_norm": P(), "lr": P()})
            fn = shard_map(train_fn, mesh=mesh, in_specs=in_ps,
                           out_specs=out_ps, check_vma=False)
        elif cell.kind == "prefill":
            def pf(params, batch, caches):
                return lm.prefill(params, cfg, batch, caches,
                                  seq_sharded=cell.seq_sharded)
            from repro.launch.shapes import dp_axes
            out_ps = (P(dp_axes(mesh)), in_ps[2])
            fn = shard_map(pf, mesh=mesh, in_specs=in_ps, out_specs=out_ps,
                           check_vma=False)
        else:
            def dc(params, token, caches, t):
                return lm.decode_step(params, cfg, token, caches, t,
                                      seq_sharded=cell.seq_sharded)
            out_ps = (in_ps[1], in_ps[2])
            fn = shard_map(dc, mesh=mesh, in_specs=in_ps, out_specs=out_ps,
                           check_vma=False)

        if donate and cell.kind == "train":
            jfn = jax.jit(fn, donate_argnums=(0, 1))
        elif donate and cell.kind == "decode":
            jfn = jax.jit(fn, donate_argnums=(2,))
        else:
            jfn = jax.jit(fn)
        lowered = jfn.lower(*args_sds)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # trip-count-aware program costs (XLA cost_analysis counts scan bodies
    # once; see analysis/hlo.py docstring)
    pc = program_costs(hlo)
    if hlo_dir:
        d = pathlib.Path(hlo_dir)
        d.mkdir(parents=True, exist_ok=True)
        (d / f"{arch}_{shape}_{mesh_name}.hlo.txt").write_text(hlo)

    rl = roofline_terms(arch, shape, mesh_name, cost=cost, coll=coll,
                        cfg=cfg, cell=cell, n_devices=n_dev,
                        flops_override=pc["dot_flops"],
                        bytes_override=pc["bytes"])
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    res = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "variant": tag or "baseline",
        "pgmpi_footer": api.format_footer(tune_ctx),
        "modeled_collective_latency_us": _modeled_latency(tune_ctx),
        "devices": int(n_dev),
        "compile_s": round(time.time() - t0, 1),
        "memory": mem_d,
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")
                 if k in cost},
        "program_costs": pc,
        "collectives": coll,
        "roofline": rl.row(),
    }
    return res


def _modeled_latency(ctx) -> dict:
    """Cost-model latency of the dispatched collective schedule vs the
    all-default schedule (v5e ICI; the paper's tuned-vs-default panel)."""
    from repro.core import costmodel as cm
    t_sel = 0.0
    t_def = 0.0
    for rec in ctx.record:
        try:
            t_sel += cm.latency_cell(rec.cell, rec.impl, cm.V5E_ICI)
            t_def += cm.latency_cell(rec.cell, "default", cm.V5E_ICI)
        except KeyError:
            pass
    return {"selected": round(t_sel * 1e6, 2), "default": round(t_def * 1e6, 2)}


def main(argv=None) -> int:
    _check_device_count()
    from repro.configs import ARCHS
    from repro.core.api import parse_module_spec
    from repro.launch.shapes import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=("on", "off", "both"),
                    default="off")
    ap.add_argument("--force", default="",
                    help="op:alg=...;op:alg=... (PGMPITuneCLI syntax)")
    ap.add_argument("--profile-dir", default="",
                    help="load tuned profiles (PGMPITuneD mode)")
    ap.add_argument("--out", default="", help="write one JSON per cell here")
    ap.add_argument("--hlo-dir", default="", help="dump compiled HLO text")
    ap.add_argument("--attn-impl", default="", choices=("", "ref", "flash"))
    ap.add_argument("--n-micro", type=int, default=0)
    ap.add_argument("--cf", type=float, default=0.0,
                    help="MoE capacity factor override")
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer stacks (serving: in-place caches)")
    ap.add_argument("--tag", default="", help="variant tag for the JSON")
    args = ap.parse_args(argv)

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"on": [True], "off": [False], "both": [False, True]}[
        args.multi_pod]
    force = parse_module_spec(args.force.replace(";", ";")) if args.force \
        else None
    profiles = None
    if args.profile_dir:
        from repro.core.profiles import ProfileStore
        profiles = ProfileStore.load(args.profile_dir)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    res = run_cell(arch, shape, multi_pod=mp, force=force,
                                   profiles=profiles,
                                   hlo_dir=args.hlo_dir or None,
                                   attn_impl=args.attn_impl or None,
                                   n_micro=args.n_micro or None,
                                   capacity_factor=args.cf or None,
                                   donate=args.donate, unroll=args.unroll,
                                   tag=args.tag)
                except Exception as e:
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": f"{type(e).__name__}:"
                           f" {str(e)[:500]}"}
                    failures += 1
                line = json.dumps(res)
                print(line, flush=True)
                if args.out:
                    d = pathlib.Path(args.out)
                    d.mkdir(parents=True, exist_ok=True)
                    sfx = f"_{args.tag}" if args.tag else ""
                    (d / (f"{res['arch']}_{res['shape']}_"
                          f"{res['mesh']}{sfx}.json")
                     ).write_text(json.dumps(res, indent=1))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
