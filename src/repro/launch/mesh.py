"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state.  Single-pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model); the pod axis is
pure DP over DCN (hierarchical gradient sync — see train/trainer.py).
"""
from __future__ import annotations

import jax

from repro._compat import auto_axis_types, make_mesh, mesh_with_axis_types


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    devs = jax.devices()
    n = 1
    for s in shape:
        n *= s
    return mesh_with_axis_types(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small mesh over host devices (tests / measured tuning)."""
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))
