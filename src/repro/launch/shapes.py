"""Assigned input-shape cells and their jit signatures (``input_specs``).

Four cells per architecture (40 total):

=============  ==========  ============  =========================
cell           seq_len     global_batch  lowered program
=============  ==========  ============  =========================
train_4k       4,096       256           train_step (fwd+bwd+opt)
prefill_32k    32,768      32            serve prefill
decode_32k     32,768      128           serve decode (1 new token)
long_500k      524,288     1             serve decode, seq-sharded KV
=============  ==========  ============  =========================

``long_500k`` is lowered only for sub-quadratic-capable archs
(cfg.subquadratic); pure full-attention archs record a ``skip`` (DESIGN.md
§Arch-applicability).  Encoder-decoder decode cells drive the DECODER with
a cached encoder context of the same length.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.data.synthetic import batch_specs
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.params import tree_global_sds, tree_map_specs, tree_pspecs


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"
    seq_sharded: bool = False
    n_micro: int = 8


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill",
                             n_micro=1),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode", n_micro=1),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode",
                           seq_sharded=True, n_micro=1),
}


def applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k KV decode skipped"
    return True, ""


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_pspec(mesh) -> P:
    return P(dp_axes(mesh))


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """(args_sds, in_pspecs) for the cell's program, params included.

    train:   (params, opt_state, batch, step)
    prefill: (params, batch, caches)
    decode:  (params, token, caches, t)
    """
    from repro.train.trainer import opt_state_pspecs
    tp = mesh.shape.get("model", 1)
    spec_tree = lm.model_specs(cfg, tp)
    params_sds = tree_global_sds(spec_tree)
    params_ps = tree_pspecs(spec_tree)
    bp = batch_pspec(mesh)

    if cell.kind == "train":
        opt_name = cfg.optimizer
        opt_ps = opt_state_pspecs(opt_name, spec_tree)
        opt_sds = _opt_sds(opt_name, spec_tree)
        bs = batch_specs(cfg, cell.global_batch, cell.seq_len)
        bs_ps = jax.tree.map(lambda _: bp, bs)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        return ((params_sds, opt_sds, bs, step_sds),
                (params_ps, opt_ps, bs_ps, P()))

    cspec = lm.cache_specs(cfg, cell.global_batch, cell.seq_len, tp,
                           seq_sharded=cell.seq_sharded)
    caches_sds = tree_global_sds(cspec)
    caches_ps = _cache_pspecs(cspec, mesh, cell)
    if cell.kind == "prefill":
        bs = batch_specs(cfg, cell.global_batch, cell.seq_len)
        bs.pop("labels", None)
        bs_ps = jax.tree.map(lambda _: bp, bs)
        return ((params_sds, bs, caches_sds),
                (params_ps, bs_ps, caches_ps))

    # decode: one new token with a KV cache of seq_len
    tok_b = cell.global_batch
    tok = jax.ShapeDtypeStruct((tok_b, 1), jnp.int32)
    tok_ps = bp if not cell.seq_sharded else P()
    t_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return ((params_sds, tok, caches_sds, t_sds),
            (params_ps, tok_ps, caches_ps, P()))


def _cache_pspecs(cspec, mesh, cell):
    """Cache PartitionSpecs; the batch dim additionally shards over 'pod'
    when present (except seq-sharded cells, where pod replicates)."""
    pod = "pod" in mesh.shape and not cell.seq_sharded

    def ps(s):
        dims = list(s.dims)
        if pod:
            # the first "data" dim is the batch dim (stacked specs carry a
            # leading None scan dim); batch additionally shards over "pod"
            for i, d in enumerate(dims):
                if d == "data":
                    dims[i] = ("pod", "data")
                    break
        return P(*dims)

    return tree_map_specs(ps, cspec)


def _opt_sds(opt_name: str, spec_tree):
    from repro.models.params import ParamSpec

    if opt_name == "adamw":
        ms = tree_map_specs(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), spec_tree)
        return {"m": ms, "v": ms,
                "count": jax.ShapeDtypeStruct((), jnp.int32)}
    if opt_name == "adafactor":
        def fac(s: ParamSpec):
            if len(s.shape) >= 2:
                return {"vr": jax.ShapeDtypeStruct(s.shape[:-1], jnp.float32),
                        "vc": jax.ShapeDtypeStruct(
                            s.shape[:-2] + s.shape[-1:], jnp.float32)}
            return {"v": jax.ShapeDtypeStruct(s.shape, jnp.float32)}
        return {"f": tree_map_specs(fac, spec_tree),
                "count": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(opt_name)
