"""Serving step builders (prefill / decode) as shard_map'd jits."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro._compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import api
from repro.models import lm
from repro.models.config import ModelConfig


def build_prefill(cfg: ModelConfig, mesh, cell, *, profiles=None,
                  force=None):
    from repro.launch.shapes import input_specs

    (p_sds, b_sds, c_sds), (p_ps, b_ps, c_ps) = input_specs(cfg, cell, mesh)

    def fn(params, batch, caches):
        logits, new_caches = lm.prefill(params, cfg, batch, caches,
                                        seq_sharded=cell.seq_sharded)
        return logits, new_caches

    with api.tuned(profiles=profiles, force=force):
        sm = shard_map(fn, mesh=mesh, in_specs=(p_ps, b_ps, c_ps),
                       out_specs=(P(_dp(mesh, cell)), c_ps),
                       check_vma=False)
        return jax.jit(sm), (p_sds, b_sds, c_sds)


def build_decode(cfg: ModelConfig, mesh, cell, *, profiles=None, force=None):
    from repro.launch.shapes import input_specs

    (p_sds, t_sds, c_sds, i_sds), (p_ps, t_ps, c_ps, i_ps) = \
        input_specs(cfg, cell, mesh)

    def fn(params, token, caches, t):
        return lm.decode_step(params, cfg, token, caches, t,
                              seq_sharded=cell.seq_sharded)

    with api.tuned(profiles=profiles, force=force):
        sm = shard_map(fn, mesh=mesh,
                       in_specs=(p_ps, t_ps, c_ps, i_ps),
                       out_specs=(t_ps if cell.seq_sharded
                                  else P(_dp(mesh, cell)), c_ps),
                       check_vma=False)
        return jax.jit(sm, donate_argnums=(2,)), (p_sds, t_sds, c_sds, i_sds)


def _dp(mesh, cell):
    if cell.seq_sharded:
        return None
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
