"""Serving step builders (prefill / decode) as shard_map'd jits.

Profile-driven serving: each builder resolves a tuned ``ProfileStore`` with
precedence  explicit ``profiles=``/``phase_profiles=`` args  >
``profile_dir=`` (or ``$PGTUNE_PROFILE_DIR``)  >  none — and activates it
*inside* the step function, so the PGMPITuneD redirection happens when jit
actually traces (first call), not at builder time.  Dispatches are tagged
``api.phase("prefill")`` / ``api.phase("decode")``, which (a) records a
phase-split workload trace into ``record=`` and (b) lets per-phase stores
from ``tuner.tune_trace`` pick different mock-ups for prefill vs decode.

When no tuning inputs are given the step functions run under whatever
``api.tuned`` context is ambient at call time (e.g. launch/dryrun's), so
callers that manage their own context keep full control.

Fleet mode: pass ``store_ref=`` (a ``profiles.StoreRef``, e.g. from
``resolve_stores(watch=True)``) and ``plan=`` (an ``api.Plan``).  The step
then takes one TRAILING replicated argument — the plan vector — and every
multi-impl dispatch site compiles to a runtime switch read from it.  A new
profile epoch is adopted by feeding ``plan.vector(store_ref)`` on the next
step call: contents change, shape doesn't, so the jit cache stays warm
(zero re-trace — the hot-swap demo in bench_fleet_retune.py counts).
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from repro._compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import api
from repro.core.profiles import resolve_stores
from repro.models import lm
from repro.models.config import ModelConfig


def _resolve(profiles, phase_profiles, profile_dir):
    """Explicit stores win; otherwise load from profile_dir / env."""
    if profiles is None and phase_profiles is None:
        base, phases = resolve_stores(profile_dir)
        return base, (phases or None)
    return profiles, phase_profiles


@contextlib.contextmanager
def _serving_ctx(tag, profiles, phase_profiles, force, record,
                 store_ref=None, plan=None):
    """Phase-tag the step; open a tuned context only when the builder was
    given tuning inputs (else the caller's ambient context applies)."""
    if (profiles, phase_profiles, force, store_ref,
            plan) == (None, None, None, None, None):
        if record is None:
            with api.phase(tag):
                yield
            return
        # record-only: a fresh context would silently shadow a caller-
        # managed api.tuned — inherit its tuning inputs, swap the sink
        amb = api._ctx()
        if amb is not None:
            with api.tuned(profiles=amb.profiles,
                           phase_profiles=amb.phase_profiles,
                           force=amb.force or None,
                           scratch_budget_bytes=amb.scratch_budget_bytes,
                           chunk_bytes=amb.chunk_bytes,
                           store_ref=amb.store_ref, plan=amb.plan,
                           record=record), api.phase(tag):
                yield
            return
    with api.tuned(profiles=profiles, phase_profiles=phase_profiles,
                   force=force, record=record, store_ref=store_ref,
                   plan=plan), api.phase(tag):
        yield


def build_prefill(cfg: ModelConfig, mesh, cell, *, profiles=None,
                  force=None, phase_profiles=None, profile_dir=None,
                  record=None, store_ref=None, plan=None):
    from repro.launch.shapes import input_specs

    profiles, phase_profiles = _resolve(profiles, phase_profiles,
                                        profile_dir)
    (p_sds, b_sds, c_sds), (p_ps, b_ps, c_ps) = input_specs(cfg, cell, mesh)

    if plan is None:
        def fn(params, batch, caches):
            with _serving_ctx("prefill", profiles, phase_profiles, force,
                              record, store_ref):
                logits, new_caches = lm.prefill(params, cfg, batch, caches,
                                                seq_sharded=cell.seq_sharded)
            return logits, new_caches

        in_specs, extra_sds = (p_ps, b_ps, c_ps), ()
    else:
        def fn(params, batch, caches, plan_vec):
            with _serving_ctx("prefill", profiles, phase_profiles, force,
                              record, store_ref, plan), \
                    api.plan_input(plan_vec):
                logits, new_caches = lm.prefill(params, cfg, batch, caches,
                                                seq_sharded=cell.seq_sharded)
            return logits, new_caches

        in_specs = (p_ps, b_ps, c_ps, P())
        extra_sds = (jax.ShapeDtypeStruct((plan.capacity,), jnp.int32),)

    sm = shard_map(fn, mesh=mesh, in_specs=in_specs,
                   out_specs=(P(_dp(mesh, cell)), c_ps),
                   check_vma=False)
    return jax.jit(sm), (p_sds, b_sds, c_sds, *extra_sds)


def build_decode(cfg: ModelConfig, mesh, cell, *, profiles=None, force=None,
                 phase_profiles=None, profile_dir=None, record=None,
                 store_ref=None, plan=None):
    from repro.launch.shapes import input_specs

    profiles, phase_profiles = _resolve(profiles, phase_profiles,
                                        profile_dir)
    (p_sds, t_sds, c_sds, i_sds), (p_ps, t_ps, c_ps, i_ps) = \
        input_specs(cfg, cell, mesh)

    if plan is None:
        def fn(params, token, caches, t):
            with _serving_ctx("decode", profiles, phase_profiles, force,
                              record, store_ref):
                return lm.decode_step(params, cfg, token, caches, t,
                                      seq_sharded=cell.seq_sharded)

        in_specs, extra_sds = (p_ps, t_ps, c_ps, i_ps), ()
    else:
        def fn(params, token, caches, t, plan_vec):
            with _serving_ctx("decode", profiles, phase_profiles, force,
                              record, store_ref, plan), \
                    api.plan_input(plan_vec):
                return lm.decode_step(params, cfg, token, caches, t,
                                      seq_sharded=cell.seq_sharded)

        in_specs = (p_ps, t_ps, c_ps, i_ps, P())
        extra_sds = (jax.ShapeDtypeStruct((plan.capacity,), jnp.int32),)

    sm = shard_map(fn, mesh=mesh, in_specs=in_specs,
                   out_specs=(t_ps if cell.seq_sharded
                              else P(_dp(mesh, cell)), c_ps),
                   check_vma=False)
    return (jax.jit(sm, donate_argnums=(2,)),
            (p_sds, t_sds, c_sds, i_sds, *extra_sds))


def _dp(mesh, cell):
    if cell.seq_sharded:
        return None
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
