"""repro.core — guideline-based collective autotuning (the paper's library).

Public surface:

* ``repro.core.api``         — dispatching collective entry points
* ``repro.core.collectives`` — default + mock-up implementations (GL1-22)
* ``repro.core.guidelines``  — guideline registry / Table-1 memory model
* ``repro.core.costmodel``   — α-β-γ fabric model (v5e ICI / DCN presets)
* ``repro.core.profiles``    — performance profiles (Listing-1 format)
* ``repro.core.tuner``       — offline tuning pass + trace replay
* ``repro.core.nrep``        — NREP estimation (Alg. 1 / Eq. 1)
* ``repro.core.trace``       — workload traces (phase-tagged dispatch mix)
"""
from repro.core import api  # noqa: F401
from repro.core.api import tuned  # noqa: F401
from repro.core.profiles import Profile, ProfileStore, Range  # noqa: F401
from repro.core.trace import Trace, TraceEntry  # noqa: F401
