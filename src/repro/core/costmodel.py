"""α-β-γ latency model for collective algorithms on TPU interconnects.

The paper benchmarks mock-ups on real clusters; this container is CPU-only,
so production-scale tuning decisions come from an analytic latency model of
the target fabric (TPU v5e ICI 2-D torus per pod; DCN across pods), with the
measured-latency backend (``core.measure``) validating *orderings* on host
devices.

Model: a mesh axis is a 1-D bidirectional ring (an ICI torus dimension) or a
DCN star.  Per-message cost α + B·β per hop, reduction γ per byte.  Formulas
are the textbook schedules (Chan et al. 2007, the paper's [3]):

  ring all-gather      (p-1)·α + (p-1)·B·β                  (B = per-shard bytes)
  recursive doubling   log2(p)·α + (p-1)·B·β
  ring reduce-scatter  (p-1)·α + (p-1)/p·Bt·(β+γ)           (Bt = total bytes)
  ring all-reduce      2(p-1)·α + 2(p-1)/p·Bt·β + (p-1)/p·Bt·γ
  binomial tree        ceil(log2 p)·(α + B·β) (+γ for reduce)
  ring all-to-all      (p-1)·α + p·Bt·β/8      (bisection-limited, bidir ring)

``default_pricing`` selects what the *untuned* library is assumed to emit:

* ``"optimal"`` — XLA-like: defaults already use the best ring schedules.
  Used for roofline/§Perf work (honest baseline).
* ``"naive"``   — a mediocre vendor library: tree-based defaults sized for
  latency, no bandwidth-optimal paths.  Used to reproduce the paper's
  violation studies (the JUQUEEN/IBM-MPI situation).

``hw_bcast`` models platform broadcast acceleration (BlueGene/Q's HW bcast,
the reason GL1/GL21 violations dominate Fig. 5): tree bcast latency term is
divided by ``hw_bcast_speedup``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

from repro.core.collectives import REGISTRY

# ---------------------------------------------------------------------------
# fabric presets
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Topo:
    """One mesh-axis fabric."""
    name: str
    alpha: float            # per-message latency (s)
    link_bw: float          # per-link bandwidth (B/s), one direction
    gamma: float            # reduction cost (s/B) — HBM-bound vector add
    bidir: bool = True      # ring usable in both directions
    default_pricing: str = "optimal"   # "optimal" | "naive"
    hw_bcast: bool = False
    hw_bcast_speedup: float = 5.0
    # fused collective-matmul terms (allgather_matmul / matmul_reducescatter):
    # peak matmul throughput, the assumed output width M of the fused matmul
    # (the dispatch key only carries the collective payload, so the model
    # prices a canonical TP-width matmul), and the per-ring-step overhead of
    # the fused kernel (RDMA issue + semaphore wait + small-tile MXU
    # inefficiency) — the term that makes fusion LOSE on small messages.
    matmul_flops: float = 2.0e14
    fused_mm_cols: int = 8192
    fused_step_overhead: float = 1.5e-6
    # quantize/dequantize bandwidth of the wire_q8/wire_fp8 mock-ups: the
    # per-block scale kernels are HBM-bound streaming passes, so they run at
    # HBM speed (v5e ≈ 819 GB/s).  One pass reads + writes the payload.
    quant_bw: float = 819e9

    @property
    def beta(self) -> float:
        return 1.0 / self.link_bw

    def scaled(self, *, name: str | None = None, alpha_mult: float = 1.0,
               bw_mult: float = 1.0, gamma_mult: float = 1.0) -> "Topo":
        """A derived tier: the same fabric with its link parameters scaled
        (how a DCN tier is anchored to a FITTED base tier — published
        relative gaps applied to measured absolutes, see ``fit_topo``)."""
        return dataclasses.replace(
            self, name=name or f"{self.name}-scaled",
            alpha=self.alpha * alpha_mult, link_bw=self.link_bw * bw_mult,
            gamma=self.gamma * gamma_mult)


# v5e: ~50 GB/s per ICI link/direction, ~1 µs collective start, reductions
# run at HBM speed (819 GB/s read+write ≈ 2.4e-12 s/B effective).
V5E_ICI = Topo("v5e-ici", alpha=1.0e-6, link_bw=50e9, gamma=2.5e-12)
# cross-pod DCN: ~10x latency, ~4x less bandwidth per host link.
V5E_DCN = Topo("v5e-dcn", alpha=10.0e-6, link_bw=12.5e9, gamma=2.5e-12)
# "mediocre vendor library on a machine with HW broadcast" — the JUQUEEN-like
# setting for reproducing the paper's violation tables.
BGQ_LIKE = Topo("bgq-like", alpha=2.0e-6, link_bw=2e9, gamma=4e-12,
                default_pricing="naive", hw_bcast=True)

PRESETS = {t.name: t for t in (V5E_ICI, V5E_DCN, BGQ_LIKE)}

#: published v5e DCN-vs-ICI link gaps (the RATIOS are the assumed part;
#: ``MeshTopo.fit``/``fit_topo`` anchor the absolutes in measured sweeps)
DCN_ALPHA_MULT = 10.0
DCN_BW_MULT = 12.5e9 / 50e9


# ---------------------------------------------------------------------------
# hierarchical topology: one Topo per mesh axis
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshTopo:
    """Per-axis fabric map of a hierarchical mesh: axis name -> ``Topo``.

    A ``(pod, data, model)`` mesh crosses interconnect tiers with
    order-of-magnitude link gaps; pricing every axis with one flat ``Topo``
    is the bug this type fixes.  ``latency_cell``/``sweep_cell`` accept a
    ``MeshTopo`` wherever they accept a ``Topo`` and resolve each cell's
    ``tier`` token (``""``, ``"<tier>"`` or ``"<outer>/<inner>"``) to the
    per-axis parameters; plain-``Topo`` callers keep the flat behaviour
    bit-for-bit.
    """
    axes: tuple[tuple[str, "Topo"], ...]

    @classmethod
    def of(cls, **axes: "Topo") -> "MeshTopo":
        """``MeshTopo.of(pod=V5E_DCN, data=V5E_ICI, model=V5E_ICI)``."""
        return cls(tuple(axes.items()))

    @classmethod
    def fit(cls, axis_points: "dict[str, tuple[int, list, list | None]]",
            *, base: "Topo" = V5E_ICI) -> "MeshTopo":
        """Build a MeshTopo whose per-tier parameters are FIT from measured
        sweeps: ``axis_points[name] = (p, allgather_points,
        allreduce_points?)`` with points as ``(payload_bytes, seconds)``
        (see ``measure.sweep_axis``).  Each axis gets ``fit_topo`` applied
        to its own sweep — no assumed constants."""
        fitted = {name: fit_topo(p, ag, ar, name=name, base=base)
                  for name, (p, ag, ar) in axis_points.items()}
        return cls(tuple(fitted.items()))

    # -- resolution ----------------------------------------------------------
    def topo(self, axis: str) -> "Topo":
        """The fabric of one mesh axis (KeyError for unknown axes)."""
        for name, t in self.axes:
            if name == axis:
                return t
        raise KeyError(f"MeshTopo has no axis {axis!r} "
                       f"(axes: {[n for n, _ in self.axes]})")

    def by_tier(self, token: str) -> "Topo | None":
        """A tier by its ``Topo.name`` token (None when unknown)."""
        for _, t in self.axes:
            if t.name == token:
                return t
        return None

    @property
    def flat(self) -> "Topo":
        """The tier untiered (``tier == ""``) cells price on: the FASTEST
        axis (min β) — matches the pre-hierarchy flat model, which assumed
        every link was the good one."""
        return min((t for _, t in self.axes), key=lambda t: (t.beta, t.alpha))

    @property
    def slowest(self) -> "Topo":
        return max((t for _, t in self.axes), key=lambda t: (t.beta, t.alpha))

    def tier_token(self, axis: str, inner_axis: str | None = None) -> str:
        """The ``OpCell.tier`` token of a dispatch over ``axis`` (and, for
        two-axis cells, ``inner_axis``).  Unknown axes map to ``""`` (the
        untiered flat behaviour) rather than raising: an uninstrumented
        mesh must keep dispatching."""
        try:
            tok = self.topo(axis).name
        except KeyError:
            return ""
        if inner_axis is None:
            return tok
        try:
            return f"{tok}/{self.topo(inner_axis).name}"
        except KeyError:
            return ""

    def resolve(self, tier: str) -> "tuple[Topo, Topo]":
        """``(outer, inner)`` fabrics of a cell's tier token.  ``""`` and
        unknown tokens price flat; a single token prices both slots on
        that tier (1-D cells only read the first slot)."""
        if not tier:
            return self.flat, self.flat
        out_tok, _, in_tok = tier.partition("/")
        t_out = self.by_tier(out_tok) or self.flat
        t_in = (self.by_tier(in_tok) or self.flat) if in_tok else t_out
        return t_out, t_in


def _lstsq_line(points) -> tuple[float, float]:
    """Closed-form least squares of ``t = intercept + slope·B`` over
    ``[(B, t), ...]`` (>= 2 distinct sizes required)."""
    pts = [(float(b), float(t)) for b, t in points]
    n = len(pts)
    if n < 2 or len({b for b, _ in pts}) < 2:
        raise ValueError("fit_topo needs >= 2 distinct payload sizes")
    mx = sum(b for b, _ in pts) / n
    my = sum(t for _, t in pts) / n
    sxx = sum((b - mx) ** 2 for b, _ in pts)
    sxy = sum((b - mx) * (t - my) for b, t in pts)
    slope = sxy / sxx
    return slope, my - slope * mx


def fit_topo(p: int, allgather_points, allreduce_points=None, *,
             name: str = "fit", base: "Topo" = V5E_ICI) -> "Topo":
    """α-β(-γ) of ONE tier from measured ring sweeps, not assumed constants.

    ``allgather_points``: ``(per-shard payload bytes B, seconds)`` samples
    of a ring all-gather on a ``p``-rank axis — the model is linear,
    ``t = (p-1)·α + (p-1)·β·B``, so a least-squares line gives
    ``α = intercept/(p-1)`` and ``β = slope/(p-1)``.  With
    ``allreduce_points`` (total-buffer bytes Bt vs seconds;
    ``t = 2(p-1)·α + (2(p-1)/p)·β·Bt + ((p-1)/p)·γ·Bt``) the reduction
    cost γ is fit from the slope surplus over the already-fit β.  Non-link
    fields (overheads, matmul rates) carry over from ``base``.
    """
    if p < 2:
        raise ValueError("fit_topo needs an axis of size >= 2")
    slope, icept = _lstsq_line(allgather_points)
    alpha = max(icept / (p - 1), 1e-12)
    beta = max(slope / (p - 1), 1e-16)
    gamma = base.gamma
    if allreduce_points is not None:
        s2, _ = _lstsq_line(allreduce_points)
        gamma = max((s2 - 2.0 * (p - 1) / p * beta) * p / (p - 1), 0.0)
    return dataclasses.replace(base, name=name, alpha=alpha,
                               link_bw=1.0 / beta, gamma=gamma)


def _tiers_for(cell, topo) -> "tuple[Topo, Topo]":
    """``(outer, inner)`` fabrics for one cell under either topology kind."""
    if isinstance(topo, MeshTopo):
        return topo.resolve(getattr(cell, "tier", ""))
    return topo, topo


def _log2c(p: int) -> int:
    return max(1, math.ceil(math.log2(max(p, 2))))


def _is_pow2(p: int) -> bool:
    return p & (p - 1) == 0


# ---------------------------------------------------------------------------
# primitive schedule costs.  B = bytes "per shard sent" in the op's natural
# convention (documented per formula).
# ---------------------------------------------------------------------------


def t_ring_allgather(p, B, t: Topo):
    """B = per-shard contribution bytes; output p·B."""
    return (p - 1) * t.alpha + (p - 1) * B * t.beta


def t_doubling_allgather(p, B, t: Topo):
    return _log2c(p) * t.alpha + (p - 1) * B * t.beta


def t_ring_reduce_scatter(p, Bt, t: Topo):
    """Bt = total buffer bytes (p·chunk)."""
    return (p - 1) * t.alpha + (p - 1) / p * Bt * (t.beta + t.gamma)


def t_ring_allreduce(p, Bt, t: Topo):
    return (2 * (p - 1) * t.alpha
            + 2 * (p - 1) / p * Bt * t.beta
            + (p - 1) / p * Bt * t.gamma)


def t_doubling_allreduce(p, Bt, t: Topo):
    return _log2c(p) * (t.alpha + Bt * t.beta + Bt * t.gamma)


def t_tree(p, B, t: Topo, *, reduce: bool = False, bcast: bool = False):
    """Binomial tree; B bytes move each round."""
    a = t.alpha
    if bcast and t.hw_bcast:
        a = a / t.hw_bcast_speedup
    per = a + B * t.beta + (B * t.gamma if reduce else 0.0)
    return _log2c(p) * per


def t_tree_scatter_gather(p, Bt, t: Topo):
    """Binomial scatter/gather: log p rounds, halving/doubling payload;
    total bytes ≈ Bt·(p-1)/p."""
    return _log2c(p) * t.alpha + (p - 1) / p * Bt * t.beta


def t_ring_alltoall(p, Bt, t: Topo):
    """Bt = per-shard buffer (p chunks).  Bisection-limited on a bidirectional
    ring: byte-hops ≈ Bt·p/4, 2 links per node ⇒ Bt·p·β/8."""
    div = 8.0 if t.bidir else 4.0
    return (p - 1) * t.alpha + p * Bt * t.beta / div


def t_fused_matmul(elems: float, t: Topo):
    """MXU time of a fused matmul whose gathered/reduced operand has
    ``elems`` elements: 2 MACs per element per output column."""
    return 2.0 * elems * t.fused_mm_cols / t.matmul_flops


def t_overlapped_ring(p, step_comm: float, mm_total: float, t: Topo):
    """The overlap law of the fused collective-matmul rings: the first
    chunk's matmul is exposed, every later step costs max(transfer,
    chunk-matmul) instead of their sum.  ``fused_step_overhead`` is paid
    per step (serial kernel issue), so fusion loses in the latency regime
    and wins when both terms are material — the guideline the tuner
    verifies per shape."""
    chunk = mm_total / p + t.fused_step_overhead
    return chunk + (p - 1) * max(chunk, step_comm)


def t_overlapped_ring2d(p_out: int, q_in: int, outer_step_comm: float,
                        inner_step_comm: float, mm_total: float, t: Topo,
                        t_inner: "Topo | None" = None):
    """The nested overlap law of the 2-D ring:
    ``max(outer_comm, per-step max(inner_comm, compute))``.

    Each of the ``p_out`` outer steps runs a full inner ring
    (``t_overlapped_ring`` over ``q_in`` steps) on ``1/p_out`` of the total
    compute; the outer transfer is issued before the inner ring consumes
    the resident block, so it hides behind the whole inner ring.  The
    first outer block's inner ring is exposed, and the outer kernel issue
    pays ``fused_step_overhead`` per outer step — so the 2-D schedule
    loses in the latency regime on BOTH axes at once.

    The two axes are independent fabrics: ``t`` prices the OUTER stream
    (its ``fused_step_overhead`` is the outer kernel-issue cost) and
    ``t_inner`` the inner ring.  A data(DCN)×model(ICI) mesh priced with
    one flat ``t`` — the pre-``MeshTopo`` behaviour, kept when ``t_inner``
    is omitted — underestimates the outer stream by the full ICI/DCN
    bandwidth gap (~4x at v5e numbers).  Callers must also build
    ``outer_step_comm``/``inner_step_comm`` from the matching per-axis
    α/β (see ``latency_cell``).
    """
    ti = t if t_inner is None else t_inner
    inner = t_overlapped_ring(q_in, inner_step_comm, mm_total / p_out, ti)
    return inner + (p_out - 1) * max(
        inner, outer_step_comm + t.fused_step_overhead)


def t_meta(p, t: Topo):
    """The 2p·I count/displacement exchange of the 'v' emulations."""
    return t_ring_allgather(p, 8, t)


def t_linear_rooted(p, B, t: Topo, *, reduce: bool = False):
    """Naive rooted gather/scatter/reduce: root talks to p-1 peers serially."""
    per = t.alpha + B * t.beta + (B * t.gamma if reduce else 0.0)
    return (p - 1) * per


# ---------------------------------------------------------------------------
# quantized-wire pricing (wire_q8 / wire_fp8 mock-ups, kernels/quant.py)
# ---------------------------------------------------------------------------

#: bytes per wire element (mirrors kernels.quant.WIRE_ITEMSIZE without
#: importing jax at costmodel-import time)
WIRE_ITEMSIZE = {"int8": 1, "float8_e4m3fn": 1}

#: on-wire overhead of the per-block scales: one f32 scale per BLOCK_ROWS=8
#: rows.  A wire row is >= 32 B for any realistic width, so the fraction is
#: bounded by 4/(8*32) * 8 = 1/16 — priced at that conservative bound.
SCALE_FRAC = 1.0 / 16.0


def wire_factor(wire_dtype: str, itemsize: int) -> float:
    """Bytes-on-wire ratio vs the compute dtype (never > 1: quantizing an
    already-8-bit payload does not shrink it)."""
    return min(1.0, WIRE_ITEMSIZE[wire_dtype] / float(max(itemsize, 1)))


def wire_bytes(B: float, itemsize: int, wire_dtype: str) -> float:
    """Bytes a ``B``-byte compute-dtype payload occupies on the wire:
    payload x wire_width/compute_width plus the per-block scale stream."""
    return B * wire_factor(wire_dtype, itemsize) * (1.0 + SCALE_FRAC)


def t_quant(B: float, t: Topo) -> float:
    """One quantize (or dequantize) pass over ``B`` payload bytes: an
    HBM-bound read+write stream at ``quant_bw``."""
    return 2.0 * B / t.quant_bw


# ---------------------------------------------------------------------------
# per-impl latency.  ``nbytes`` is the byte size of the op's *input* per-shard
# array (dim-0 rows × row bytes) — the same key the dispatcher uses.
# ---------------------------------------------------------------------------


def latency(op: str, impl: str, p: int, nbytes: int, topo: Topo,
            *, chunk_bytes: int = 0, tier: str = "") -> float:
    """Modeled latency (seconds) of one ``impl`` of ``op`` on an axis of size
    ``p``.  Compositions are priced as the sum of the sub-implementations
    they actually lower to (see collectives.py).  A ``MeshTopo`` is
    resolved through ``tier`` (one axis — the first slot of the token)."""
    if isinstance(topo, MeshTopo):
        topo = topo.resolve(tier)[0]
    if p <= 1:
        return 0.0
    if op == "collective_permute":
        # not a dispatcher op (no mock-ups): one neighbour hop of the whole
        # payload — priced so HLO-level scans (analysis/interpose) can map
        # every collective instruction, permutes included.
        if impl != "default":
            raise KeyError(f"no cost model for {(op, impl)}")
        return topo.alpha + float(max(nbytes, 1)) * topo.beta
    B = float(max(nbytes, 1))
    naive = topo.default_pricing == "naive"

    def dflt_allgather(Bv):
        if naive:
            # linear gather + tree bcast of the full buffer
            return (t_linear_rooted(p, Bv, topo)
                    + t_tree(p, p * Bv, topo, bcast=True))
        return t_ring_allgather(p, Bv, topo)

    def dflt_allreduce(Bv):
        if naive:
            return (t_tree(p, Bv, topo, reduce=True)
                    + t_tree(p, Bv, topo, bcast=True))
        return t_ring_allreduce(p, Bv, topo)

    def dflt_reducescatter(Bt):
        if naive:
            return (t_tree(p, Bt, topo, reduce=True)
                    + t_linear_rooted(p, Bt / p, topo))
        return t_ring_reduce_scatter(p, Bt, topo)

    def dflt_alltoall(Bt):
        if naive:
            return t_linear_rooted(p, Bt / p, topo) * 2
        return t_ring_alltoall(p, Bt, topo)

    def dflt_bcast(Bv):
        # default bcast is select+psum (XLA canonical)
        return dflt_allreduce(Bv)

    def dflt_gather(Bv):
        if naive:                          # mediocre vendor: linear rooted
            return t_linear_rooted(p, Bv, topo)
        return dflt_allgather(Bv)          # gather served by all-gather

    def dflt_scatter(Bt):
        if naive:
            return t_linear_rooted(p, Bt / p, topo)
        return dflt_alltoall(Bt)           # scatter served by all-to-all

    def dflt_reduce(Bv):
        if naive:
            return t_linear_rooted(p, Bv, topo, reduce=True)
        return dflt_allreduce(Bv)          # reduce served by psum

    def scan_cost(Bv):
        return _log2c(p) * (topo.alpha + Bv * topo.beta + Bv * topo.gamma)

    ag, ar, rs, a2a = (dflt_allgather, dflt_allreduce, dflt_reducescatter,
                       dflt_alltoall)

    table = {
        # ---- allgather (B = per-shard contribution) ----
        ("allgather", "default"): lambda: ag(B),
        ("allgather", "allgather_as_gather_bcast"):
            lambda: dflt_gather(B) + dflt_bcast(p * B),
        ("allgather", "allgather_as_alltoall"): lambda: a2a(p * B),
        ("allgather", "allgather_as_allreduce"): lambda: ar(p * B),
        ("allgather", "allgather_as_allgatherv"):
            lambda: ag(B) + t_meta(p, topo),
        ("allgather", "allgather_as_ring"):
            lambda: t_ring_allgather(p, B, topo),
        ("allgather", "allgather_as_doubling"):
            lambda: t_doubling_allgather(p, B, topo),
        # ---- allreduce (B = buffer bytes) ----
        ("allreduce", "default"): lambda: ar(B),
        ("allreduce", "allreduce_as_reduce_bcast"):
            lambda: dflt_reduce(B) + dflt_bcast(B),
        ("allreduce", "allreduce_as_tree_reduce_bcast"):
            lambda: (t_tree(p, B, topo, reduce=True)
                     + t_tree(p, B, topo, bcast=True)),
        ("allreduce", "allreduce_as_rsb_allgather"):
            lambda: (t_ring_reduce_scatter(p, B, topo)
                     + t_ring_allgather(p, B / p, topo)),
        ("allreduce", "allreduce_as_rs_allgatherv"):
            lambda: (t_ring_reduce_scatter(p, _pad(B, p, chunk_bytes), topo)
                     + t_ring_allgather(p, _pad(B, p, chunk_bytes) / p, topo)
                     + t_meta(p, topo)),
        ("allreduce", "allreduce_as_doubling"):
            lambda: t_doubling_allreduce(p, B, topo),
        # ---- alltoall (B = per-shard buffer, p chunks) ----
        ("alltoall", "default"): lambda: a2a(B),
        ("alltoall", "alltoall_as_alltoallv"):
            lambda: a2a(B) + t_meta(p, topo),
        ("alltoall", "alltoall_as_ppermute"):
            lambda: (p - 1) * topo.alpha + p * B * topo.beta / (
                8.0 if topo.bidir else 4.0),
        # ---- bcast (B = payload) ----
        ("bcast", "default"): lambda: dflt_bcast(B),
        ("bcast", "bcast_as_allgatherv"):
            lambda: ag(B) + t_meta(p, topo),
        ("bcast", "bcast_as_scatter_allgather"):
            lambda: (t_tree_scatter_gather(p, B, topo)
                     + t_ring_allgather(p, B / p, topo)),
        ("bcast", "bcast_as_tree"):
            lambda: t_tree(p, B, topo, bcast=True),
        # ---- gather (B = per-shard contribution) ----
        ("gather", "default"): lambda: dflt_gather(B),
        ("gather", "gather_as_allgather"): lambda: t_ring_allgather(p, B, topo),
        ("gather", "gather_as_gatherv"):
            lambda: dflt_gather(B) + t_meta(p, topo),
        ("gather", "gather_as_reduce"): lambda: dflt_reduce(p * B),
        ("gather", "gather_as_tree"):
            lambda: t_tree_scatter_gather(p, p * B, topo),
        # ---- reduce (B = buffer bytes) ----
        ("reduce", "default"): lambda: dflt_reduce(B),
        ("reduce", "reduce_as_allreduce"): lambda: t_ring_allreduce(p, B, topo),
        ("reduce", "reduce_as_rsb_gather"):
            lambda: (t_ring_reduce_scatter(p, B, topo)
                     + t_ring_allgather(p, B / p, topo)),
        ("reduce", "reduce_as_rs_gatherv"):
            lambda: (t_ring_reduce_scatter(p, _pad(B, p, chunk_bytes), topo)
                     + t_ring_allgather(p, _pad(B, p, chunk_bytes) / p, topo)
                     + t_meta(p, topo)),
        ("reduce", "reduce_as_tree"):
            lambda: t_tree(p, B, topo, reduce=True),
        # ---- reducescatter (B = total buffer bytes, p chunks) ----
        ("reducescatter", "default"): lambda: rs(B),
        ("reducescatter", "rsb_as_reduce_scatter"):
            lambda: dflt_reduce(B) + dflt_scatter(B),
        ("reducescatter", "rsb_as_reduce_scatter_irr"):
            lambda: t_ring_reduce_scatter(p, B, topo) + t_meta(p, topo),
        ("reducescatter", "rsb_as_allreduce"): lambda: dflt_reduce(B),
        # ---- scan ----
        ("scan", "default"): lambda: scan_cost(B),
        ("scan", "scan_as_exscan_reducelocal"):
            lambda: scan_cost(B) + topo.alpha + B * (topo.beta + topo.gamma),
        ("exscan", "default"): lambda: scan_cost(B) + topo.alpha + B * topo.beta,
        # ---- fused collective-matmul ops ----
        # allgather_matmul: B = per-shard contribution bytes of x; the
        # matmul touches p·B/4 gathered elements.  Unfused = collective
        # PLUS matmul; fused = per-step max (see t_overlapped_ring).
        ("allgather_matmul", "default"):
            lambda: ag(B) + t_fused_matmul(p * B / 4.0, topo),
        ("allgather_matmul", "fused_ring"):
            lambda: t_overlapped_ring(
                p, topo.alpha + B * topo.beta,
                t_fused_matmul(p * B / 4.0, topo), topo),
        # matmul_reducescatter: B = total input-buffer bytes of x (p row
        # blocks); each ring step moves one reduced output block (~B/p with
        # the canonical square-ish K≈M assumption) and reduces it (γ).
        ("matmul_reducescatter", "default"):
            lambda: t_fused_matmul(B / 4.0, topo) + rs(B),
        ("matmul_reducescatter", "fused_ring"):
            lambda: t_overlapped_ring(
                p, topo.alpha + (B / p) * (topo.beta + topo.gamma),
                t_fused_matmul(B / 4.0, topo), topo),
        # matmul_accumulate: B = per-shard K-dim weight-shard bytes (the
        # streamed operand); the contraction touches p·B/4 gathered weight
        # elements, each feeding a canonical-width row batch.  Unfused =
        # weight all-gather PLUS the matmul; fused = weight block in flight
        # while the previous block's partial products accumulate.
        ("matmul_accumulate", "default"):
            lambda: ag(B) + t_fused_matmul(p * B / 4.0, topo),
        ("matmul_accumulate", "fused_ring"):
            lambda: t_overlapped_ring(
                p, topo.alpha + B * topo.beta,
                t_fused_matmul(p * B / 4.0, topo), topo),
        # matmul_reducescatter_2d: B = streamed weight-block bytes over the
        # OUTER axis.  The geometry-less canonical assumption: the inner
        # axis equals the outer (square data x model mesh), the matmul
        # touches p·B/4 gathered weight elements, the output buffer is the
        # gathered weight's size p·B, and the inner ring's travelling
        # accumulator block is its per-(outer-step, inner-rank) share
        # p·B/(p·p) = B/p.  Unfused = weight all-gather PLUS matmul PLUS
        # output reduce-scatter; fused = the nested overlap law.
        ("matmul_reducescatter_2d", "default"):
            lambda: (ag(B) + t_fused_matmul(p * B / 4.0, topo)
                     + rs(p * B)),
        ("matmul_reducescatter_2d", "fused_ring2d"):
            lambda: t_overlapped_ring2d(
                p, p, topo.alpha + B * topo.beta,
                topo.alpha + (B / p) * (topo.beta + topo.gamma),
                t_fused_matmul(p * B / 4.0, topo), topo),
        # ---- scatter (B = total buffer bytes, p chunks) ----
        ("scatter", "default"): lambda: dflt_scatter(B),
        ("scatter", "scatter_as_bcast"): lambda: dflt_bcast(B),
        ("scatter", "scatter_as_scatterv"):
            lambda: dflt_scatter(B) + t_meta(p, topo),
        ("scatter", "scatter_as_tree"):
            lambda: t_tree_scatter_gather(p, B, topo),
    }
    # ---- quantized-wire mock-ups (wire_q8 / wire_fp8) ----
    # Same ring schedules with the travelling operand at wire width (+ scale
    # overhead) plus quant/dequant HBM passes at quant_bw.  The canonical
    # table carries no dtype (latency_cell does), so the compute dtype is
    # assumed f32 (itemsize 4) — consistent with the /4.0 element counts of
    # the fused-matmul entries above.  Gather-style wires quantize once and
    # dequantize p-1 received chunks (p passes of B); travelling
    # accumulators requantize + dequantize each hop (2(p-1) passes of B/p).
    _it = 4
    for _nm, _wd in (("wire_q8", "int8"), ("wire_fp8", "float8_e4m3fn")):
        Bw = wire_bytes(B, _it, _wd)
        Bwp = wire_bytes(B / p, _it, _wd)

        def rs_wire(Bt, Btw, _p=p, _t=topo):
            # ring reduce-scatter on the wire: bytes move at wire width,
            # the f32 accumulate (γ) is full-width, 2 quant passes per hop.
            return ((_p - 1) * _t.alpha
                    + (_p - 1) / _p * Btw * _t.beta
                    + (_p - 1) / _p * Bt * _t.gamma
                    + 2 * (_p - 1) / _p * t_quant(Bt, _t))

        def ag_wire(Bc, Bcw, _p=p, _t=topo):
            # ring allgather on the wire: 1 quant + (p-1) dequant passes.
            return t_ring_allgather(_p, Bcw, _t) + _p * t_quant(Bc, _t)

        table.update({
            ("allgather", _nm): partial(ag_wire, B, Bw),
            ("reducescatter", _nm): partial(rs_wire, B, Bw),
            ("allreduce", _nm):
                lambda rs=partial(rs_wire, B, Bw),
                       ag=partial(ag_wire, B / p, Bwp): rs() + ag(),
            ("allgather_matmul", _nm):
                lambda Bw=Bw: t_overlapped_ring(
                    p, topo.alpha + Bw * topo.beta,
                    t_fused_matmul(p * B / 4.0, topo)
                    + p * t_quant(B, topo), topo),
            ("matmul_accumulate", _nm):
                lambda Bw=Bw: t_overlapped_ring(
                    p, topo.alpha + Bw * topo.beta,
                    t_fused_matmul(p * B / 4.0, topo)
                    + p * t_quant(B, topo), topo),
            ("matmul_reducescatter", _nm):
                lambda Bwp=Bwp: t_overlapped_ring(
                    p, topo.alpha + Bwp * topo.beta + (B / p) * topo.gamma,
                    t_fused_matmul(B / 4.0, topo)
                    + 2 * p * t_quant(B / p, topo), topo),
        })
    key = (op, impl)
    if key not in table:
        imp = REGISTRY.get(op, {}).get(impl)
        if imp is not None and getattr(imp, "hier", False):
            # two-axis mock-ups are inadmissible on a one-axis problem
            return math.inf
        raise KeyError(f"no cost model for {key}")
    imp = REGISTRY[op][impl]
    if imp.requires_pow2 and not _is_pow2(p):
        return math.inf
    return float(table[key]())


def latency_hier(cell, impl: str, t_out: Topo, t_in: Topo) -> float:
    """Modeled latency of a HIERARCHICAL plain cell: ``cell.p`` outer
    (inter-tier) ranks × ``cell.p2`` inner (intra-tier) ranks.

    ``default`` is the untuned library's single collective over the joint
    ``p·p2`` group: a ring through all ranks crosses the outer tier, and
    ring steps are synchronous, so EVERY step is gated by the slowest
    link the ring traverses.  The ``MPIX_*`` mock-ups are the composed
    tier-aware schedules (survey arXiv:1611.06334): the bulk of the bytes
    move on the fast intra tier, only a ``1/p2`` share crosses the slow
    tier.  Flat (one-axis) mock-ups are inadmissible here — they would
    reduce/gather over the outer axis only — and price to ``inf``.
    """
    p, q = cell.p, cell.p2
    B = float(max(cell.nbytes, 1))
    imp = REGISTRY[cell.op][impl]
    if imp.requires_pow2 and not (_is_pow2(p) and _is_pow2(q)):
        return math.inf
    if p * q <= 1:
        return 0.0
    slow = t_out if (t_out.beta, t_out.alpha) >= (t_in.beta, t_in.alpha) \
        else t_in
    if impl == "default":
        if cell.op == "allreduce":
            return t_ring_allreduce(p * q, B, slow)
        if cell.op == "allgather":
            return t_ring_allgather(p * q, B, slow)
        if cell.op == "reducescatter":
            return t_ring_reduce_scatter(p * q, B, slow)
    if cell.op == "allreduce" and impl == "MPIX_rs_ar_ag":
        # RS-intra -> AR-inter -> AG-intra (B = buffer bytes)
        return (t_ring_reduce_scatter(q, B, t_in)
                + t_ring_allreduce(p, B / q, t_out)
                + t_ring_allgather(q, B / q, t_in))
    if cell.op == "allgather" and impl == "MPIX_ag_ag":
        # AG-intra -> AG-inter (B = per-shard contribution; the inter
        # stage moves the q·B intra-gathered block)
        return (t_ring_allgather(q, B, t_in)
                + t_ring_allgather(p, q * B, t_out))
    if cell.op == "reducescatter" and impl == "MPIX_rs_rs":
        # RS-inter -> RS-intra (B = total buffer, p·q chunks): the dual
        # of MPIX_ag_ag — outer tier reduces to a B/p block per outer
        # rank, the intra tier finishes at full speed
        return (t_ring_reduce_scatter(p, B, t_out)
                + t_ring_reduce_scatter(q, B / p, t_in))
    return math.inf


def latency_cell(cell, impl: str, topo: "Topo | MeshTopo", *,
                 chunk_bytes: int = 0) -> float:
    """Modeled latency of one ``OpCell`` — the geometry-aware entry point.

    Plain cells (and fused cells with unknown geometry, e.g. from v1
    traces) fall back to the canonical ``latency`` table; cells carrying a
    recorded GEMM are priced from the TRUE flop count ``2·K·M·N`` instead
    of the canonical ``fused_mm_cols``-width assumption, and the
    matmul-reducescatter ring moves its true output-block bytes.

    ``topo`` may be a flat ``Topo`` (both axes of a two-axis cell price on
    it — the pre-hierarchy behaviour) or a ``MeshTopo``, resolved through
    ``cell.tier``: the OUTER fabric prices the ``p`` axis (the stream /
    inter tier), the INNER fabric the ``p2`` axis.
    """
    t_out, t_in = _tiers_for(cell, topo)
    if getattr(cell, "hier", False):
        return latency_hier(cell, impl, t_out, t_in)
    if not getattr(cell, "fused", False):
        return latency(cell.op, impl, cell.p, cell.nbytes, t_out,
                       chunk_bytes=chunk_bytes)
    p = cell.p
    if p <= 1 and getattr(cell, "p2", 0) <= 1:
        return 0.0
    imp = REGISTRY[cell.op][impl]
    if getattr(imp, "hier", False):
        return math.inf          # two-axis plain mock-up on a fused cell
    if imp.requires_pow2 and not _is_pow2(p):
        return math.inf
    mm = 2.0 * cell.mm_k * cell.mm_m * cell.mm_n / t_out.matmul_flops
    B = float(max(cell.nbytes, 1))
    if cell.op == "matmul_reducescatter_2d":
        # nested 2-D cells: p = outer stream axis (t_out), p2 = inner rs
        # axis (t_in); the recorded dims are the PER-RANK GEMM, so ``mm``
        # above is already one rank's compute and the output product is
        # mm_m x mm_n.
        q = max(cell.p2, 1)
        it = cell.itemsize
        bt_out = float(cell.mm_m * cell.mm_n * it)
        if cell.mm_role == "2dT":
            # outer loop = travelling accumulator over the rs axis (q
            # steps, [mm_m/q, mm_n] blocks, t_in fabric); inner =
            # cotangent column-slice stream over the gather axis (p
            # steps, t_out fabric)
            acc_blk = bt_out / q
            slice_blk = (float(cell.mm_k) / p) * (float(cell.mm_m) / q) * it
            if impl == "default":
                return (latency("allgather", "default", p, cell.nbytes,
                                t_out)
                        + mm
                        + t_ring_reduce_scatter(q, bt_out, t_in))
            return t_overlapped_ring2d(
                q, p,
                t_in.alpha + acc_blk * (t_in.beta + t_in.gamma),
                t_out.alpha + slice_blk * t_out.beta,
                mm, t_in, t_out)
        # forward "2d": outer = weight column-block stream over the gather
        # axis (p steps, B bytes each, t_out fabric); inner =
        # matmul-reducescatter ring over the rs axis (q steps,
        # [mm_m/q, mm_n/p] accumulator blocks, t_in fabric)
        inner_blk = (float(cell.mm_m) / q) * (float(cell.mm_n) / p) * it
        if impl == "default":
            return (latency("allgather", "default", p, cell.nbytes, t_out)
                    + mm
                    + t_ring_reduce_scatter(q, bt_out, t_in))
        return t_overlapped_ring2d(
            p, q, t_out.alpha + B * t_out.beta,
            t_in.alpha + inner_blk * (t_in.beta + t_in.gamma),
            mm, t_out, t_in)
    topo = t_out
    if cell.op in ("allgather_matmul", "matmul_accumulate"):
        # streamed operand all-gathered over the axis; steps move B bytes
        if impl == "default":
            return latency("allgather", "default", p, cell.nbytes, topo) + mm
        step_b = B
        if imp.wire_dtype:
            # gather-style wire: steps move wire bytes; 1 quant + (p-1)
            # dequant HBM passes fold into the overlappable compute.
            step_b = wire_bytes(B, cell.itemsize, imp.wire_dtype)
            mm = mm + p * t_quant(B, topo)
        return t_overlapped_ring(p, topo.alpha + step_b * topo.beta, mm, topo)
    if cell.op == "matmul_reducescatter":
        bt_out = float(cell.mm_m * cell.mm_n * cell.itemsize)
        if impl == "default":
            return mm + latency("reducescatter", "default", p,
                                int(bt_out), topo)
        blk = bt_out / p
        step = topo.alpha + blk * (topo.beta + topo.gamma)
        if imp.wire_dtype:
            # travelling accumulator on the wire: block bytes shrink, the
            # f32 accumulate (γ) stays full-width, requantize+dequantize
            # per hop folds into the overlappable compute.
            step = (topo.alpha
                    + wire_bytes(blk, cell.itemsize, imp.wire_dtype)
                    * topo.beta + blk * topo.gamma)
            mm = mm + 2 * p * t_quant(blk, topo)
        return t_overlapped_ring(p, step, mm, topo)
    raise KeyError(f"no geometry cost model for {cell.op!r}")


def _pad(B: float, p: int, chunk_bytes: int) -> float:
    """GL7/GL16 chunk-aligned padding of the buffer."""
    c = max(float(chunk_bytes), 1.0)
    k = math.ceil(math.ceil(B / c) / p)
    return p * k * c


def sweep(op: str, p: int, nbytes: int, topo: Topo, *,
          chunk_bytes: int = 0) -> dict[str, float]:
    """Latency of every registered impl of ``op`` at one (p, nbytes)."""
    return {name: latency(op, name, p, nbytes, topo, chunk_bytes=chunk_bytes)
            for name in REGISTRY[op]}


def sweep_cell(cell, topo: Topo, *, chunk_bytes: int = 0) -> dict[str, float]:
    """Latency of every priceable impl for one ``OpCell`` — the
    geometry-aware ``sweep``.  Ops outside the dispatcher registry
    (``collective_permute``) price their default only, so HLO-level scans
    always get at least one number per mapped cell."""
    impls = REGISTRY.get(cell.op)
    if impls is None:
        return {"default": latency(cell.op, "default", cell.p, cell.nbytes,
                                   topo, chunk_bytes=chunk_bytes,
                                   tier=getattr(cell, "tier", ""))}
    return {name: latency_cell(cell, name, topo, chunk_bytes=chunk_bytes)
            for name in impls}


def best_impl_cell(cell, topo: Topo, *,
                   chunk_bytes: int = 0) -> tuple[str, float]:
    """``(impl, latency)`` of the fastest modeled implementation for one
    cell — the 'best mock-up' side of the tuning-potential report."""
    sw = sweep_cell(cell, topo, chunk_bytes=chunk_bytes)
    name = min(sw, key=sw.get)
    return name, sw[name]
