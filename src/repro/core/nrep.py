"""The paper's NREP estimation (§4.2, Eq. 1) and RSE stopping rule.

"The idea is to estimate the number of repetitions for each case by measuring
the latency of MPI functions with a 1 Byte message … batches grow
exponentially … for larger sizes take b1 (+ b2) samples, use the minimum, and
set  nrep_m = max(ceil(t1_nrep / t_m_min), K)."
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

Sampler = Callable[[int, int], Sequence[float]]   # (msize_bytes, count) -> latencies


def rse(samples: Sequence[float]) -> float:
    """Relative standard error of the mean."""
    n = len(samples)
    if n < 2:
        return math.inf
    mean = sum(samples) / n
    if mean == 0:
        return math.inf
    var = sum((s - mean) ** 2 for s in samples) / (n - 1)
    return math.sqrt(var / n) / mean


@dataclasses.dataclass
class OneByteEstimate:
    nrep: int            # samples taken until RSE < threshold
    total_time: float    # the paper's t1^nrep (sum of all 1-byte latencies)
    final_rse: float
    batches: int


def estimate_1byte(sampler: Sampler, *, rse_threshold: float = 0.01,
                   batch0: int = 10, growth: float = 2.0,
                   max_samples: int = 100_000) -> OneByteEstimate:
    """Exponentially growing batches of 1-byte measurements until the RSE of
    the accumulated sample set drops below ``rse_threshold`` (paper: 1%)."""
    samples: list[float] = []
    batch = batch0
    batches = 0
    while True:
        samples.extend(sampler(1, int(batch)))
        batches += 1
        r = rse(samples)
        if r < rse_threshold or len(samples) >= max_samples:
            return OneByteEstimate(nrep=len(samples),
                                   total_time=sum(samples),
                                   final_rse=r, batches=batches)
        batch = math.ceil(batch * growth)


def estimate_nrep(sampler: Sampler, msize: int, one_byte: OneByteEstimate, *,
                  b1: int = 5, b2: int = 5, rse_threshold: float = 0.05,
                  K: int = 10) -> int:
    """Eq. (1): nrep_m = max(ceil(t1_nrep / t_m_min), K).

    Takes b1 samples; if their RSE exceeds ``rse_threshold`` (a *different*
    threshold than the 1-byte one, per the paper) takes another b2.
    ``t_m_min`` is the minimum of the b1(+b2) latencies.
    """
    samples = list(sampler(msize, b1))
    if rse(samples) > rse_threshold:
        samples += list(sampler(msize, b2))
    t_min = min(samples)
    if t_min <= 0:
        return K
    return max(math.ceil(one_byte.total_time / t_min), K)
