"""Performance profiles (paper §3.2.2, Listing 1) + O(log M) lookup.

A profile is valid for ONE collective and ONE axis size (the paper: "profiles
are only valid for the same number of processes") — and, for the fused
collective-matmul ops, ONE matmul geometry (``cell.Geom``: dtype + the GEMM
dims + the gather/scatter/contract role).  It maps message-size ranges
(bytes) to a replacement mock-up.  The on-disk text format round-trips the
paper's Listing 1 (MPI op names, numbered algorithm table, ``lo hi alg``
range lines) with geometry carried on a ``#@geom`` header line that v1
parsers ignore — so v1 profile files load unchanged (geometry-less); a JSON
form carries extra provenance (topo, backend, chunk).

Lookup is ``O(1)`` to find the (op, p, geom) profile + ``O(log M)`` bisect
over the sorted ranges — the paper's "combination of hash functions and
binary searches".  ``lookup_cell`` adds the geometry resolution order:
exact geometry > nearest tuned geometry (same role + dtype, log-space shape
distance) > the geometry-less (op, p) profile.

Fleet retuning adds an EPOCH to a saved profile directory: ``save(epoch=)``
writes a ``MANIFEST.json`` (generation number, source-shard digest,
geometry census) LAST, so a watcher that sees a new manifest sees complete
profiles.  ``resolve_stores(watch=True)`` returns a ``StoreRef`` — a
mutable, atomically-swappable reference running ``api.tuned`` contexts
read through — whose ``poll()`` re-reads the manifest (content-hash
staleness stamp) and hot-swaps the stores in place; ``swap`` refuses
epochs older than the live one (the staleness guard), verifies the
manifest's ``profiles_digest`` against the files on disk, retains the
last N generations, and ``rollback()`` reverts a regressing epoch and
poisons it against re-adoption.
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import os
import pathlib

from repro.core.cell import Geom, OpCell

PROFILE_JSON_VERSION = 2

OP_TO_MPI = {
    "allgather": "MPI_Allgather",
    "allreduce": "MPI_Allreduce",
    "alltoall": "MPI_Alltoall",
    "bcast": "MPI_Bcast",
    "gather": "MPI_Gather",
    "reduce": "MPI_Reduce",
    "reducescatter": "MPI_Reduce_scatter_block",
    "scan": "MPI_Scan",
    "exscan": "MPI_Exscan",
    "scatter": "MPI_Scatter",
    # fused collective-matmul extension ops (no MPI counterpart; MPIX_ names
    # keep the Listing-1 text profiles round-trippable)
    "allgather_matmul": "MPIX_Allgather_matmul",
    "matmul_reducescatter": "MPIX_Matmul_reduce_scatter",
    "matmul_accumulate": "MPIX_Matmul_accumulate",
    "matmul_reducescatter_2d": "MPIX_Matmul_reduce_scatter_2d",
}
MPI_TO_OP = {v: k for k, v in OP_TO_MPI.items()}


@dataclasses.dataclass(frozen=True)
class Range:
    lo: int          # bytes, inclusive
    hi: int          # bytes, inclusive
    impl: str        # mock-up name


@dataclasses.dataclass
class Profile:
    op: str
    axis_size: int
    ranges: list[Range] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)
    geom: Geom | None = None    # fused-op matmul geometry partition
    # interconnect-tier token (``OpCell.profile_tier()``): "" = flat/
    # untiered, "<name>" = flat on a known tier, "<out>/<in>[@q<p2>]" =
    # hierarchical.  Part of the store key — a profile tuned on one tier
    # must NEVER answer a lookup from another (a DCN-crossing cell and an
    # all-ICI cell of the same (op, p, nbytes) have different winners).
    tier: str = ""

    def __post_init__(self):
        self.ranges = sorted(self.ranges, key=lambda r: r.lo)
        self._los = [r.lo for r in self.ranges]
        for a, b in zip(self.ranges, self.ranges[1:]):
            if b.lo <= a.hi:
                raise ValueError(f"overlapping ranges {a} / {b}")

    # -- lookup ------------------------------------------------------------
    def lookup(self, nbytes: int) -> str | None:
        """Replacement impl for ``nbytes``, or None (use the default)."""
        i = bisect.bisect_right(self._los, nbytes) - 1
        if i >= 0 and self.ranges[i].lo <= nbytes <= self.ranges[i].hi:
            return self.ranges[i].impl
        return None

    def lookup_nearest(self, nbytes: int) -> str | None:
        """``lookup`` that falls back to the CLOSEST range when ``nbytes``
        misses every range — used when a cell resolves to a nearest-geometry
        profile whose tuned sizes differ from the querying cell's."""
        hit = self.lookup(nbytes)
        if hit is not None or not self.ranges:
            return hit
        best = min(self.ranges,
                   key=lambda r: min(abs(nbytes - r.lo), abs(nbytes - r.hi)))
        return best.impl

    # -- Listing-1 text format ----------------------------------------------
    def to_text(self) -> str:
        impls = sorted({r.impl for r in self.ranges})
        ids = {name: i + 2 for i, name in enumerate(impls)}  # 1 = default
        lines = [
            "# pgtune profile v2",
            OP_TO_MPI.get(self.op, self.op),
            f"{self.axis_size} # nb. of. processes",
            f"{len(impls)} # nb. of mock-up impl.",
        ]
        if self.tier:
            # a comment line to v1 parsers; the tier key to v2 (flat
            # untiered profiles stay byte-identical)
            lines.insert(1, f"#@tier {self.tier}")
        if self.geom is not None:
            # a comment line to v1 parsers; geometry to v2.  The trailing
            # p2 token (inner axis of a 2-D cell) is only written when
            # nonzero, so 1-D geometry lines stay byte-identical.
            g = self.geom
            line = (f"#@geom {g.dtype} {g.mm_k} {g.mm_m} {g.mm_n} "
                    f"{g.mm_role}")
            if g.p2:
                line += f" {g.p2}"
            lines.insert(1, line)
        lines += [f"{ids[name]} {name}" for name in impls]
        lines.append(f"{len(self.ranges)} # nb. of ranges")
        lines += [f"{r.lo} {r.hi} {ids[r.impl]}" for r in self.ranges]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_text(cls, text: str) -> "Profile":
        geom = None
        tier = ""
        for ln in text.splitlines():
            if ln.startswith("#@tier"):
                tier = ln.split(None, 1)[1].strip() if " " in ln else ""
            if ln.startswith("#@geom"):
                parts = ln.split()
                _, dt, k, m, n, role = parts[:6]
                p2 = int(parts[6]) if len(parts) > 6 else 0
                geom = Geom(dt, int(k), int(m), int(n), role, p2)
        raw = [ln.split("#")[0].strip() for ln in text.splitlines()]
        rows = [ln for ln in raw if ln]
        opname = rows[0]
        op = MPI_TO_OP.get(opname, opname)
        axis_size = int(rows[1])
        n_impl = int(rows[2])
        table: dict[int, str] = {}
        for ln in rows[3:3 + n_impl]:
            num, name = ln.split(None, 1)
            table[int(num)] = name.strip()
        n_ranges = int(rows[3 + n_impl])
        ranges = []
        for ln in rows[4 + n_impl:4 + n_impl + n_ranges]:
            lo, hi, alg = ln.split()
            ranges.append(Range(int(lo), int(hi), table[int(alg)]))
        return cls(op=op, axis_size=axis_size, ranges=ranges, geom=geom,
                   tier=tier)

    # -- JSON ----------------------------------------------------------------
    def to_json(self) -> str:
        d = {
            "version": PROFILE_JSON_VERSION,
            "op": self.op, "axis_size": self.axis_size,
            "ranges": [dataclasses.asdict(r) for r in self.ranges],
            "meta": self.meta,
        }
        if self.geom is not None:
            d["geom"] = dataclasses.asdict(self.geom)
        if self.tier:
            d["tier"] = self.tier
        return json.dumps(d, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "Profile":
        d = json.loads(text)
        geom = Geom(**d["geom"]) if d.get("geom") else None
        return cls(op=d["op"], axis_size=d["axis_size"],
                   ranges=[Range(**r) for r in d["ranges"]],
                   meta=d.get("meta", {}), geom=geom,
                   tier=d.get("tier", ""))


def _geom_tag(geom: Geom) -> str:
    """Filesystem-safe geometry suffix for profile filenames."""
    tag = (f"{geom.dtype}_k{geom.mm_k}m{geom.mm_m}n{geom.mm_n}"
           f"_{geom.mm_role}")
    if geom.p2:
        tag += f"_q{geom.p2}"
    return tag


def _tier_tag(tier: str) -> str:
    """Filesystem-safe tier suffix (the token may carry '/' and '@')."""
    return tier.replace("/", "--").replace("@", "-")


class ProfileStore:
    """All loaded profiles; the PGMPITuneD in-memory state."""

    def __init__(self, profiles: list[Profile] | None = None):
        self._by_key: dict[
            tuple[str, int, Geom | None, str], Profile] = {}
        for p in profiles or []:
            self.add(p)

    def add(self, p: Profile) -> None:
        self._by_key[(p.op, p.axis_size, p.geom, p.tier)] = p

    def get(self, op: str, axis_size: int, geom: Geom | None = None,
            tier: str = "") -> Profile | None:
        return self._by_key.get((op, axis_size, geom, tier))

    def lookup(self, op: str, axis_size: int, nbytes: int,
               tier: str = "") -> str | None:
        """Geometry-less lookup (plain collectives, legacy callers)."""
        p = self.get(op, axis_size, tier=tier)
        return p.lookup(nbytes) if p else None

    def lookup_cell(self, cell: OpCell) -> str | None:
        """Resolve a dispatch cell: exact geometry profile first; on an
        exact MISS — no profile for this geometry, OR the exact profile's
        tuned ranges don't cover ``cell.nbytes`` — the nearest OTHER tuned
        geometry (same role + dtype + p2 + TIER, minimal log-space shape
        distance); then the geometry-less (op, axis_size, tier) profile.

        The middle step must run on BOTH kinds of exact miss: an exact
        profile whose ranges miss the size used to fall straight through
        to the geometry-less lookup, silently shadowing a tuned
        near-geometry profile that did cover it.

        Every step is pinned to ``cell.profile_tier()`` — nearest-geometry
        fallback must never answer across interconnect tiers (a flat-ICI
        winner is wrong on a DCN-crossing cell of identical shape), and
        hierarchical plain cells fold their inner size into the token so
        an 8-way flat profile can't shadow a 2x4 hierarchical one."""
        t = cell.profile_tier()
        g = cell.geom()
        if g is not None:
            prof = self._by_key.get((cell.op, cell.p, g, t))
            if prof is not None:
                hit = prof.lookup(cell.nbytes)
                if hit is not None:
                    return hit
            near = [(geom, p)
                    for (op, ax, geom, tr), p in self._by_key.items()
                    if op == cell.op and ax == cell.p and geom is not None
                    and geom != g
                    and tr == t
                    and geom.mm_role == g.mm_role
                    and geom.dtype == g.dtype
                    and geom.p2 == g.p2]
            if near:
                _, nprof = min(near,
                               key=lambda kv: (g.distance(kv[0]), kv[0]))
                hit = nprof.lookup_nearest(cell.nbytes)
                if hit is not None:
                    return hit
        return self.lookup(cell.op, cell.p, cell.nbytes, t)

    def __len__(self) -> int:
        return len(self._by_key)

    def __iter__(self):
        return iter(self._by_key.values())

    # -- disk ----------------------------------------------------------------
    def save(self, directory: str | pathlib.Path, *, fmt: str = "text",
             epoch: int | None = None,
             source_digest: str | None = None) -> None:
        """Write one file per profile; with ``epoch=`` also stamp the
        directory as that fleet generation by writing ``MANIFEST.json``
        LAST (see ``write_manifest``) so watchers never observe a new
        epoch before its profiles are complete."""
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        for (op, p_size, geom, tier), prof in sorted(
                self._by_key.items(),
                key=lambda kv: (kv[0][0], kv[0][1], str(kv[0][2]),
                                kv[0][3])):
            stem = f"{op}_p{p_size}"
            if geom is not None:
                stem += "_" + _geom_tag(geom)
            if tier:
                stem += "_t" + _tier_tag(tier)
            if fmt == "text":
                (d / f"{stem}.pgtune").write_text(prof.to_text())
            else:
                (d / f"{stem}.json").write_text(prof.to_json())
        if epoch is not None:
            write_manifest(d, epoch, source_digest=source_digest, base=self)

    @classmethod
    def load(cls, directory: str | pathlib.Path) -> "ProfileStore":
        d = pathlib.Path(directory)
        store = cls()
        for f in sorted(d.glob("*.pgtune")):
            text = f.read_text()
            if not text.lstrip().startswith("# pgtune profile v2"):
                import warnings
                warnings.warn(
                    f"profile file {f} is schema v1 (no 'pgtune profile v2' "
                    "header); v1 parse paths are deprecated — re-save with "
                    "the current tuner (see ROADMAP 'Trace v1 sunset')",
                    DeprecationWarning, stacklevel=2)
            store.add(Profile.from_text(text))
        for f in sorted(d.glob("*.json")):
            if f.name == MANIFEST_NAME:
                continue
            text = f.read_text()
            if "version" not in json.loads(text):
                # symmetric with the headerless-.pgtune warning above: the
                # v1 sunset criterion can only trip if BOTH formats warn
                import warnings
                warnings.warn(
                    f"profile file {f} is schema v1 (no 'version' field); "
                    "v1 parse paths are deprecated — re-save with the "
                    "current tuner (see ROADMAP 'Trace v1 sunset')",
                    DeprecationWarning, stacklevel=2)
            store.add(Profile.from_json(text))
        return store


# ---------------------------------------------------------------------------
# fleet epochs: the profile-directory MANIFEST
# ---------------------------------------------------------------------------

MANIFEST_NAME = "MANIFEST.json"


def _census(stores) -> dict:
    """Per-op profile/geometry counts across the given stores — the
    manifest's quick sanity view of what a generation covers."""
    out: dict[str, dict[str, int]] = {}
    geoms: dict[str, set] = {}
    for store in stores:
        if store is None:
            continue
        for prof in store:
            c = out.setdefault(prof.op, {"profiles": 0, "geometries": 0})
            c["profiles"] += 1
            if prof.geom is not None:
                geoms.setdefault(prof.op, set()).add(prof.geom)
    for op, gs in geoms.items():
        out[op]["geometries"] = len(gs)
    return out


def profiles_digest(directory: str | pathlib.Path) -> str:
    """sha256 over every profile file under ``directory`` (recursive:
    base files + phase subdirectories; the manifest itself and tmp files
    excluded) — the manifest records this at publish time and
    ``StoreRef.poll`` recomputes it at adoption, so manifest↔profile
    skew (a manifest paired with profiles it was not written for) is
    detected instead of served."""
    import hashlib
    d = pathlib.Path(directory)
    h = hashlib.sha256()
    for p in sorted(d.rglob("*")):
        if (not p.is_file() or p.suffix not in (".pgtune", ".json")
                or p.name == MANIFEST_NAME
                or p.name.endswith(".tmp")):
            continue
        h.update(str(p.relative_to(d)).encode())
        h.update(b"\0")
        h.update(p.read_bytes())
        h.update(b"\0")
    return "sha256:" + h.hexdigest()


def write_manifest(directory: str | pathlib.Path, epoch: int, *,
                   source_digest: str | None = None,
                   base: "ProfileStore | None" = None,
                   phases: "dict[str, ProfileStore] | None" = None,
                   demotions: "dict[tuple[str, str], str] | None" = None) \
        -> pathlib.Path:
    """Stamp a profile directory as fleet generation ``epoch``.

    The manifest is the hot-swap unit: ``StoreRef.poll`` re-reads THIS
    file and reloads only when its content changes.  Callers must write
    all profile files first and the manifest last (this function writes
    via tmp + ``os.replace``, so the manifest itself appears atomically).
    ``source_digest`` records provenance — the digest of the trace shards
    the generation was tuned from (``trace.shard_digest``) — and
    ``profiles_digest`` is computed HERE, over the already-written
    profile files, so an adopting reader can verify the manifest and the
    profiles belong to the same generation.

    The publishing process's DEMOTION ledger rides along: a tuning run
    that demoted a wire impl (tolerance breach in selfcheck) must not
    publish profiles that a fresh serving process — whose own ledger is
    empty — would happily route back onto the demoted impl.  Pass
    ``demotions=`` to override; the default snapshots
    ``collectives.demotions()``.  ``StoreRef.poll`` re-applies the list
    on adoption.
    """
    if demotions is None:
        from repro.core import collectives as _C
        demotions = _C.demotions()
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    man = {
        "manifest_version": 1,
        "epoch": int(epoch),
        "source": source_digest,
        "profiles_digest": profiles_digest(d),
        "base_profiles": len(base) if base is not None else 0,
        "phases": {ph: len(st) for ph, st in sorted((phases or {}).items())},
        "geometry_census": _census([base, *(phases or {}).values()]),
        "demotions": [[op, name, reason] for (op, name), reason
                      in sorted(demotions.items())],
    }
    path = d / MANIFEST_NAME
    tmp = d / (MANIFEST_NAME + ".tmp")
    tmp.write_text(json.dumps(man, indent=1) + "\n")
    os.replace(tmp, path)
    return path


def _apply_demotions(man: dict) -> int:
    """Re-apply a manifest's demotion ledger to this process's
    ``collectives`` registry (the adoption half of the persistence
    round-trip).  Unknown impls — e.g. a manifest published by a newer
    build — are skipped with a warning, never fatal.  Returns the number
    of newly applied demotions."""
    rows = man.get("demotions") or []
    if not rows:
        return 0
    from repro.core import collectives as _C
    applied = 0
    for row in rows:
        try:
            op, name, reason = row
            if not _C.is_demoted(op, name):
                _C.demote(op, name, reason=f"manifest: {reason}")
                applied += 1
        except Exception as e:
            import warnings
            warnings.warn(
                f"manifest demotion entry {row!r} not applied "
                f"({type(e).__name__}: {e})")
    return applied


def read_manifest(directory: str | pathlib.Path) -> dict | None:
    """The directory's manifest dict, or None (absent / unreadable —
    legacy pre-epoch profile directories have no manifest)."""
    path = pathlib.Path(directory) / MANIFEST_NAME
    try:
        man = json.loads(path.read_text())
    except (OSError, ValueError):
        return None
    return man if isinstance(man, dict) and "epoch" in man else None


class StoreRef:
    """A mutable, atomically-swappable reference to resolved profile
    stores plus their epoch — the hot-swap unit of fleet retuning.

    ``api.tuned(store_ref=ref)`` contexts read impl choices through the
    ref at dispatch time, and ``api.Plan.vector(ref)`` re-derives runtime
    dispatch plans from it — so swapping in a new generation changes what
    a running server serves WITHOUT a re-jit.  State is one tuple
    attribute assigned in a single store, so readers never observe a
    half-swapped generation.  ``swap`` refuses epochs older than the live
    one (the staleness rule: a delayed writer must not roll a fleet
    back); ``poll`` re-reads ``MANIFEST.json`` in the watched directory
    and swaps when a newer epoch has landed.

    Fault tolerance: the last ``history`` adopted generations are
    RETAINED in memory, so ``rollback()`` can revert a regressing epoch
    without touching disk (the ``api.EpochTripwire`` path).  A rolled-
    back epoch is POISONED — ``poll``/``swap`` refuse to re-adopt it even
    though its manifest is still the newest on disk — and adoption
    verifies the manifest's ``profiles_digest`` against the profile
    files actually present, refusing manifest↔profile skew.
    """

    def __init__(self, base: "ProfileStore | None" = None,
                 phases: "dict[str, ProfileStore] | None" = None,
                 epoch: int = -1,
                 directory: str | pathlib.Path | None = None,
                 history: int = 4):
        self._state = (int(epoch), base, dict(phases or {}))
        self.directory = pathlib.Path(directory) if directory else None
        self._stamp: str | None = None
        self.history = int(history)
        self._history: list[tuple] = []      # prior (epoch, base, phases)
        self._poisoned: set[int] = set()

    # -- reads (each reads the state tuple once; no torn views) -------------
    @property
    def epoch(self) -> int:
        return self._state[0]

    @property
    def base(self) -> "ProfileStore | None":
        return self._state[1]

    @property
    def phases(self) -> "dict[str, ProfileStore]":
        return self._state[2]

    def lookup(self, cell: OpCell, phase: str) -> str | None:
        """One consistent-generation resolution: the phase store for
        ``phase`` first, then the base store (same precedence as
        ``api.tuned(phase_profiles=..., profiles=...)``)."""
        _epoch, base, phases = self._state
        store = phases.get(phase)
        name = store.lookup_cell(cell) if store is not None else None
        if name is None and base is not None:
            name = base.lookup_cell(cell)
        return name

    # -- writes --------------------------------------------------------------
    def swap(self, base: "ProfileStore | None",
             phases: "dict[str, ProfileStore] | None",
             epoch: int) -> bool:
        """Atomically install a new generation; refuse stale,
        already-live, or poisoned (rolled-back) epochs (returns False,
        live state unchanged).  The outgoing generation is pushed onto
        the retained history so ``rollback`` can revert to it."""
        import warnings
        live = self.epoch
        if int(epoch) < live:
            warnings.warn(
                f"StoreRef.swap: refusing stale epoch {epoch} "
                f"(live epoch is {live})")
            return False
        if int(epoch) == live:
            return False
        if int(epoch) in self._poisoned:
            warnings.warn(
                f"StoreRef.swap: refusing poisoned epoch {epoch} "
                "(rolled back earlier; publish a fresh epoch instead)")
            return False
        if live >= 0:
            self._history.append(self._state)
            del self._history[:-self.history]
        self._state = (int(epoch), base, dict(phases or {}))
        return True

    def rollback(self) -> int | None:
        """Revert to the most recently retained generation — the
        auto-rollback path when a freshly adopted epoch regresses in the
        field.  The abandoned epoch is POISONED (never re-adopted by
        ``poll`` even though its manifest still looks newest) and the
        previous generation's stores become live again in one atomic
        assignment: readers and ``Plan.vector`` re-derivation see the
        reverted generation immediately, with zero re-jits.  Returns the
        restored epoch, or None when no history is retained."""
        import warnings
        if not self._history:
            warnings.warn("StoreRef.rollback: no retained generation to "
                          "roll back to; keeping the live epoch")
            return None
        bad = self.epoch
        if bad >= 0:
            self._poisoned.add(bad)
        self._state = self._history.pop()
        warnings.warn(f"StoreRef.rollback: epoch {bad} rolled back; "
                      f"serving epoch {self.epoch} again (epoch {bad} "
                      "poisoned)")
        return self.epoch

    def poll(self) -> bool:
        """Re-read the watched directory's manifest; reload + swap when a
        NEWER epoch has landed.  Returns True iff a swap happened.  All
        failures (no directory, no/bad manifest, profile load errors,
        manifest↔profile digest skew, a poisoned epoch) leave the live
        generation serving and return False — a broken push must not
        take a fleet down.

        The staleness stamp is CONTENT-based (a hash of the manifest
        text): a same-size, same-mtime manifest replacement — which a
        ``(st_mtime_ns, st_size)`` stat stamp provably misses, since
        consecutive epochs usually serialize to the same byte length —
        still triggers adoption.  The manifest is a few hundred bytes,
        so the read-per-poll costs less than the bug did."""
        if self.directory is None:
            return False
        man_path = self.directory / MANIFEST_NAME
        import warnings
        try:
            text = man_path.read_text()
        except OSError:
            # legacy manifest-less directory: adopt it once as epoch 0
            if self.epoch < 0 and self.directory.is_dir():
                try:
                    base, phases = load_stores(self.directory)
                except Exception:
                    return False
                if base is None and not phases:
                    return False
                return self.swap(base, phases, 0)
            return False
        import hashlib
        stamp = hashlib.sha256(text.encode()).hexdigest()
        if stamp == self._stamp:
            return False
        self._stamp = stamp
        try:
            man = json.loads(text)
        except ValueError:
            man = None
        if not isinstance(man, dict) or "epoch" not in man:
            return False
        epoch = int(man["epoch"])
        if epoch in self._poisoned:
            warnings.warn(
                f"StoreRef.poll: manifest at {man_path} still carries "
                f"poisoned epoch {epoch}; keeping epoch {self.epoch} "
                "(publish a fresh epoch to recover)")
            return False
        if epoch <= self.epoch:
            if epoch < self.epoch:
                warnings.warn(
                    f"StoreRef.poll: {man_path} regressed to epoch "
                    f"{epoch} (live epoch is {self.epoch}); refusing "
                    "the stale generation")
            return False
        want = man.get("profiles_digest")
        if want is not None:
            have = profiles_digest(self.directory)
            if have != want:
                # clear the stamp: the PROFILES may be repaired without
                # the manifest changing, and an unchanged-stamp
                # short-circuit would never look again (re-warning each
                # poll until the skew is fixed is the point)
                self._stamp = None
                warnings.warn(
                    f"StoreRef.poll: epoch {epoch} at {self.directory} "
                    f"has manifest/profile skew (manifest records "
                    f"{want[:18]}…, files hash to {have[:18]}…); "
                    f"keeping epoch {self.epoch}")
                return False
        try:
            base, phases = load_stores(self.directory)
        except Exception as e:
            self._stamp = None     # same repair-without-manifest logic
            warnings.warn(f"StoreRef.poll: epoch {epoch} at "
                          f"{self.directory} failed to load "
                          f"({type(e).__name__}: {e}); keeping epoch "
                          f"{self.epoch}")
            return False
        if not self.swap(base, phases, epoch):
            return False
        # the adopted generation's demotion ledger applies to THIS
        # process too — its profiles were tuned with those impls excluded
        _apply_demotions(man)
        return True


# ---------------------------------------------------------------------------
# directory / environment resolution (serve + train consumers)
# ---------------------------------------------------------------------------

PROFILE_DIR_ENV = "PGTUNE_PROFILE_DIR"


def load_stores(directory: str | pathlib.Path) \
        -> tuple["ProfileStore | None", dict[str, "ProfileStore"]]:
    """Load ``(base_store, phase_stores)`` from a profile directory.

    Layout: profile files (``*.pgtune`` / ``*.json``) at the top level form
    the phase-agnostic base store; each SUBDIRECTORY containing profile
    files becomes a phase store keyed by the subdirectory name (the layout
    ``tuner.TraceTuneReport.save`` writes).  Either part may be absent.
    """
    d = pathlib.Path(directory)
    if not d.is_dir():
        raise FileNotFoundError(f"profile directory {d} does not exist")
    base = ProfileStore.load(d)
    phases: dict[str, ProfileStore] = {}
    for sub in sorted(p for p in d.iterdir() if p.is_dir()):
        store = ProfileStore.load(sub)
        if len(store):
            phases[sub.name] = store
    return (base if len(base) else None), phases


def resolve_stores(directory: str | pathlib.Path | None = None, *,
                   watch: bool = False):
    """Profile-loading precedence: explicit ``directory`` argument >
    ``$PGTUNE_PROFILE_DIR`` > none (returns ``(None, {})``).

    An explicit directory that is missing or malformed raises (the caller
    asked for it); a stale or broken env var only warns and serves untuned
    — it must not crash (or half-initialize profiles in) processes that
    never asked for them.  The env path is all-or-nothing: any load
    failure, including a parse error in one phase subdirectory, falls back
    to the full no-profile mode ``(None, {})``.

    With ``watch=True`` the return value is a ``StoreRef`` instead: the
    resolved directory's current generation (epoch from ``MANIFEST.json``;
    0 for a legacy manifest-less directory; -1 when nothing is loadable
    yet), watching the directory — call ``ref.poll()`` periodically to
    pick up new epochs, and hand the ref to ``api.tuned(store_ref=...)``
    / ``api.Plan.vector(ref)``.  A missing-or-empty directory is NOT an
    error in watch mode: the ref starts empty and the first poll after a
    push adopts it.
    """
    if watch:
        d = directory or os.environ.get(PROFILE_DIR_ENV, "")
        ref = StoreRef(directory=d or None)
        ref.poll()
        return ref
    if directory:
        return load_stores(directory)
    d = os.environ.get(PROFILE_DIR_ENV, "")
    if not d:
        return None, {}
    try:
        return load_stores(d)
    except FileNotFoundError:
        import warnings
        warnings.warn(f"${PROFILE_DIR_ENV}={d} does not exist; "
                      "serving untuned defaults")
        return None, {}
    except Exception as e:                     # malformed profile text, ...
        import warnings
        warnings.warn(f"${PROFILE_DIR_ENV}={d} failed to load "
                      f"({type(e).__name__}: {e}); serving untuned "
                      "defaults")
        return None, {}
