"""Workload traces: ``TuneContext.record`` as a first-class artifact.

The paper's offline pass (§4.2) tunes each collective *in isolation* over a
synthetic size sweep; its PGMPI predecessor (arXiv:1606.00215) instead tunes
the op mix a real application issues per callsite.  A ``Trace`` captures that
mix from live model traffic: every dispatch the api records — forward
all-gathers, backward reduce-scatters, prefill vs decode serving steps — is
aggregated into ``(OpCell, phase, impl) -> count`` cells, where ``OpCell``
(core/cell.py) carries the FULL communication problem: op, axis size,
payload bytes, dtype and — for the fused collective-matmul ops — the
per-callsite GEMM dims ``(mm_k, mm_m, mm_n)`` and the gather/scatter/
contract role.

Phases are the coarse callsite classes of an LM workload:

=========  ===============================================================
phase      traffic
=========  ===============================================================
fwd        forward-pass collectives (ambient default under training)
bwd        custom-VJP backward collectives + gradient sync (dist/ops,
           train/trainer tag these via ``api.phase("bwd")``)
prefill    serving prompt ingestion (launch/serve tags these)
decode     serving token-by-token steps (launch/serve tags these)
=========  ===============================================================

The on-disk form is JSONL — one aggregated cell per line, so traces from
many hosts/steps concatenate and ``merge`` trivially.  **Schema v2** adds the
geometry fields (``v: 2``; ``mm``/``role`` only present on fused cells):

    {"v": 2, "op": "allgather_matmul", "p": 8, "nbytes": 4096,
     "dtype": "float32", "mm": [512, 64, 16], "role": "gather",
     "phase": "fwd", "impl": "default", "count": 24}

v1 lines (no ``v`` key, bare 5-field cells) still load: their geometry is
defaulted — dtype ``float32``, no GEMM dims — which for fused ops means
"geometry unknown" (``OpCell.fused`` is False); the measured backend cannot
replay such a cell and note-skips it.

``tuner.tune_trace`` consumes a ``Trace`` and emits per-phase
``ProfileStore``s (see DESIGN_TRACE.md), which ``api.tuned(phase_profiles=
...)`` applies at dispatch — the backward can pick a different mock-up than
the forward for the same message size.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Iterator

from repro.core.cell import OpCell

SCHEMA_VERSION = 2


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One aggregated dispatch cell."""
    cell: OpCell
    phase: str = "fwd"
    impl: str = "default"
    count: int = 1

    # -- convenience views (the cell is the key) -----------------------------
    @property
    def op(self) -> str:
        return self.cell.op

    @property
    def axis_size(self) -> int:
        return self.cell.p

    @property
    def nbytes(self) -> int:
        return self.cell.nbytes

    def key(self) -> tuple[OpCell, str, str]:
        return (self.cell, self.phase, self.impl)

    @classmethod
    def of(cls, op: str, axis_size: int, nbytes: int, phase: str = "fwd",
           impl: str = "default", count: int = 1, **geom) -> "TraceEntry":
        """Build from bare fields (tests, hand-written traces); ``geom``
        passes ``dtype``/``mm_k``/``mm_m``/``mm_n``/``mm_role`` through."""
        return cls(OpCell(op, axis_size, nbytes, **geom), phase, impl, count)

    def to_json(self) -> str:
        d = _cell_dict(self.cell)
        d.update(phase=self.phase, impl=self.impl, count=self.count)
        return json.dumps(d)

    @classmethod
    def from_dict(cls, d: dict) -> "TraceEntry":
        """Build from a decoded JSONL object; v1 objects (no ``v`` key)
        load with defaulted geometry — fused ops come back with unknown
        GEMM dims."""
        return cls(cell=_cell_from_dict(d), phase=d.get("phase", "fwd"),
                   impl=d.get("impl", "default"),
                   count=int(d.get("count", 1)))

    @classmethod
    def from_json(cls, line: str) -> "TraceEntry":
        return cls.from_dict(json.loads(line))


def _cell_dict(cell: OpCell) -> dict:
    """The schema-v2 JSON object for one cell (shared by trace entry
    lines and shard ``#@lat`` measurement lines)."""
    d = {"v": SCHEMA_VERSION, "op": cell.op, "p": cell.p,
         "nbytes": cell.nbytes, "dtype": cell.dtype}
    if cell.fused:
        d["mm"] = [cell.mm_k, cell.mm_m, cell.mm_n]
        d["role"] = cell.mm_role
    if cell.p2:
        d["p2"] = cell.p2      # inner axis of a 2-D / hierarchical cell
    if cell.tier:
        d["tier"] = cell.tier  # interconnect-tier token ("out/in" or flat)
    return d


def _cell_from_dict(d: dict) -> OpCell:
    mm = d.get("mm") or (0, 0, 0)
    return OpCell(op=d["op"], p=int(d["p"]), nbytes=int(d["nbytes"]),
                  dtype=d.get("dtype", "float32"),
                  mm_k=int(mm[0]), mm_m=int(mm[1]), mm_n=int(mm[2]),
                  mm_role=d.get("role", ""), p2=int(d.get("p2", 0)),
                  tier=d.get("tier", ""))


class Trace:
    """An aggregated multiset of dispatch cells (order-independent)."""

    def __init__(self, entries: Iterable[TraceEntry] | None = None):
        self._cells: dict[tuple[OpCell, str, str], int] = {}
        for e in entries or ():
            self._add(e.key(), e.count)

    def _add(self, key: tuple[OpCell, str, str], count: int) -> None:
        if count <= 0:
            raise ValueError(f"non-positive count {count} for {key}")
        self._cells[key] = self._cells.get(key, 0) + count

    # -- construction --------------------------------------------------------
    @classmethod
    def from_record(cls, record) -> "Trace":
        """Build from ``TuneContext.record`` entries (``DispatchRecord``
        with a ``.cell``; legacy ``(op, p, nbytes, impl, phase)`` 5-tuples
        are accepted with defaulted geometry)."""
        t = cls()
        for r in record:
            if hasattr(r, "cell"):
                t._add((r.cell, r.phase, r.impl), 1)
            else:
                op, p, nbytes, impl, phase = r
                t._add((OpCell(op, p, nbytes), phase, impl), 1)
        return t

    @classmethod
    def from_context(cls, ctx) -> "Trace":
        return cls.from_record(ctx.record)

    # -- views ---------------------------------------------------------------
    @property
    def entries(self) -> list[TraceEntry]:
        return [TraceEntry(cell, phase, impl, count)
                for (cell, phase, impl), count in sorted(self._cells.items())]

    def __len__(self) -> int:
        return len(self._cells)

    def __iter__(self) -> Iterator[TraceEntry]:
        return iter(self.entries)

    def __eq__(self, other) -> bool:
        return isinstance(other, Trace) and self._cells == other._cells

    def total(self) -> int:
        """Total dispatch count across all cells."""
        return sum(self._cells.values())

    def phases(self) -> list[str]:
        return sorted({k[1] for k in self._cells})

    def ops(self) -> list[str]:
        return sorted({k[0].op for k in self._cells})

    def histogram(self) -> dict[tuple[OpCell, str], int]:
        """``(cell, phase) -> count`` (summed over impls — the tuner
        re-decides the impl, so the recorded one is provenance)."""
        out: dict[tuple[OpCell, str], int] = {}
        for (cell, phase, _impl), count in self._cells.items():
            k = (cell, phase)
            out[k] = out.get(k, 0) + count
        return out

    def cells(self, phase: str | None = None) -> dict[OpCell, int]:
        """``OpCell -> count`` for one phase (or all)."""
        out: dict[OpCell, int] = {}
        for (cell, ph, _impl), count in self._cells.items():
            if phase is not None and ph != phase:
                continue
            out[cell] = out.get(cell, 0) + count
        return out

    def filter(self, *, phase: str | None = None,
               op: str | None = None) -> "Trace":
        keep = [e for e in self.entries
                if (phase is None or e.phase == phase)
                and (op is None or e.op == op)]
        return Trace(keep)

    def merge(self, *others: "Trace") -> "Trace":
        """Sum counts cell-wise (traces from many steps/hosts)."""
        out = Trace(self.entries)
        for o in others:
            for e in o.entries:
                out._add(e.key(), e.count)
        return out

    @classmethod
    def merge_shards(cls, directory, *,
                     pattern: str = "shard-*.jsonl",
                     verify_digest: bool = True) -> "MergeReport":
        """Merge a fleet directory of per-server trace shards (the files
        ``ShardRecorder.flush`` writes) into one fleet trace, QUARANTINING
        anything a hostile fleet can produce instead of raising.

        Cells are deduplicated by key with count SUMMATION, so the merged
        trace preserves the total dispatch weight of the SURVIVING shards
        exactly: ``report.trace.total()`` equals the sum of the merged
        shards' totals.  Shards from mixed schema generations merge fine
        (v1-origin geometry-less fused cells stay distinct problems from
        their v2 geometry twins).

        A shard is quarantined — excluded whole from the merged trace,
        recorded in the report with a reason and its dropped dispatch
        weight — when it is unreadable, its ``#@shard`` header is corrupt
        or disagrees with its filename (meta skew), its header sha256
        does not match the body (torn write, bit rot, post-hoc
        tampering), or any trace line fails to parse.  Partial trust is
        deliberately refused: a shard that lies about one line may lie
        about any, so salvage weight is ACCOUNTED (``ShardNote.salvaged``)
        but never merged.

        An empty or absent directory returns an EMPTY report with a
        warning — a cold-started fleet's first epoch is a no-op merge,
        not a crash (the old behavior raised ``FileNotFoundError``).
        """
        import warnings
        d = pathlib.Path(directory)
        paths = sorted(d.glob(pattern)) if d.is_dir() else []
        if not paths:
            warnings.warn(
                f"no trace shards matching {pattern!r} under {d} — "
                "empty fleet epoch (cold start?); merge is a no-op")
            return MergeReport(cls(), [])
        out = cls()
        notes: list[ShardNote] = []
        for p in paths:
            note, entries = _ingest_shard(cls, p,
                                          verify_digest=verify_digest)
            notes.append(note)
            if note.status == "merged":
                for e in entries:
                    out._add(e.key(), e.count)
        bad = [n for n in notes if n.status != "merged"]
        if bad:
            warnings.warn(
                f"merge_shards: quarantined {len(bad)}/{len(notes)} "
                f"shard(s) under {d}: "
                + "; ".join(f"{n.path.name} ({n.reason})" for n in bad))
        return MergeReport(out, notes)

    def summary(self) -> str:
        lines = [f"trace: {len(self)} cells, {self.total()} dispatches"]
        for ph in self.phases():
            cells = self.cells(phase=ph)
            n = sum(cells.values())
            ops = sorted({c.op for c in cells})
            lines.append(f"  {ph}: {n} dispatches over {len(cells)} cells "
                         f"({', '.join(ops)})")
        return "\n".join(lines)

    # -- disk ----------------------------------------------------------------
    def to_jsonl(self) -> str:
        return "".join(e.to_json() + "\n" for e in self.entries)

    @classmethod
    def from_jsonl(cls, text: str, *, source: str | None = None) -> "Trace":
        """Parse JSONL; any v1 line (no ``"v"`` KEY in the decoded object
        — substring tests misclassify lines whose string values contain
        ``"v"``) triggers ONE ``DeprecationWarning`` naming ``source``
        (the v1 sunset step — the lines still load with defaulted
        geometry, but fused cells lose their GEMM and the measured
        backend note-skips them; re-record)."""
        objs = [json.loads(ln) for ln in text.splitlines()
                if ln.strip() and not ln.lstrip().startswith("#")]
        n_v1 = sum(1 for d in objs if "v" not in d)
        if n_v1:
            import warnings
            warnings.warn(
                f"trace {source or '<string>'} carries {n_v1} schema-v1 "
                "line(s) (no 'v' key); v1 parse paths are deprecated — "
                "re-record with the current dispatcher (see ROADMAP "
                "'Trace v1 sunset')", DeprecationWarning, stacklevel=2)
        return cls([TraceEntry.from_dict(d) for d in objs])

    def save(self, path: str | pathlib.Path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_jsonl())

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Trace":
        p = pathlib.Path(path)
        return cls.from_jsonl(p.read_text(), source=str(p))


# ---------------------------------------------------------------------------
# fleet shards: per-server sampled recording + epoch-stamped shard files
# ---------------------------------------------------------------------------

SHARD_HEADER = "#@shard "
LAT_PREFIX = "#@lat "

_SHARD_NAME_RE = None  # lazily-compiled shard filename pattern


def _shard_name_parts(name: str) -> tuple[str, int] | None:
    """``(server, epoch)`` encoded in a shard filename, or None."""
    global _SHARD_NAME_RE
    if _SHARD_NAME_RE is None:
        import re
        _SHARD_NAME_RE = re.compile(r"^shard-(.+)-e(\d+)\.jsonl$")
    m = _SHARD_NAME_RE.match(name)
    return (m.group(1), int(m.group(2))) if m else None


def _body_digest(body: str) -> str:
    """sha256 over a shard's body text (everything after the header
    line) — written into the ``#@shard`` header, verified at merge."""
    import hashlib
    return "sha256:" + hashlib.sha256(body.encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class ShardNote:
    """One shard's fate in a ``merge_shards`` pass."""
    path: pathlib.Path
    server: str | None          # from the #@shard header (None: no header)
    epoch: int | None
    status: str                 # "merged" | "quarantined"
    reason: str = ""            # quarantine cause ("" when merged)
    dispatches: int = 0         # weight merged into the fleet trace
    claimed: int | None = None  # header-claimed dispatch weight
    salvaged: int = 0           # parseable weight in a quarantined shard

    @property
    def dropped(self) -> int:
        """Dispatch weight this shard failed to contribute: the header's
        claim when it survived corruption, else whatever still parsed."""
        if self.status == "merged":
            return 0
        return self.claimed if self.claimed is not None else self.salvaged


@dataclasses.dataclass
class MergeReport:
    """The structured result of ``Trace.merge_shards``: the merged trace
    of every healthy shard plus per-shard accounting — what merged, what
    was quarantined and why, and how much dispatch weight was dropped.
    Nothing is silent: a fleet tune sees exactly what it is tuning from.
    """
    trace: Trace
    shards: list[ShardNote]

    @property
    def merged(self) -> list[ShardNote]:
        return [n for n in self.shards if n.status == "merged"]

    @property
    def quarantined(self) -> list[ShardNote]:
        return [n for n in self.shards if n.status == "quarantined"]

    @property
    def dropped_weight(self) -> int:
        """Best-effort dispatch weight lost to quarantine (header claims
        where readable, parseable-prefix weight otherwise)."""
        return sum(n.dropped for n in self.quarantined)

    def total(self) -> int:
        return self.trace.total()

    def __len__(self) -> int:
        return len(self.trace)

    def summary(self) -> str:
        lines = [f"merge: {len(self.merged)} shard(s) merged "
                 f"({self.trace.total()} dispatches), "
                 f"{len(self.quarantined)} quarantined "
                 f"({self.dropped_weight} dispatches dropped)"]
        for n in self.quarantined:
            lines.append(f"  quarantined {n.path.name}: {n.reason} "
                         f"(claimed={n.claimed}, salvaged={n.salvaged})")
        return "\n".join(lines)


def _ingest_shard(trace_cls, path: pathlib.Path, *, verify_digest: bool) \
        -> tuple[ShardNote, list[TraceEntry]]:
    """Read one shard defensively: returns its ``ShardNote`` and (when
    healthy) its parsed entries.  Every failure mode quarantines the
    whole shard — weight accounting over partial parses is kept, but
    partially-trusted data never reaches the merged trace."""
    server = epoch = claimed = None
    try:
        text = path.read_text()
    except OSError as e:
        return ShardNote(path, None, None, "quarantined",
                         f"unreadable: {e}"), []
    head, sep, body = text.partition("\n")
    meta = None
    if head.startswith(SHARD_HEADER):
        try:
            meta = json.loads(head[len(SHARD_HEADER):])
        except ValueError:
            return ShardNote(path, None, None, "quarantined",
                             "header-corrupt"), []
    if meta is not None:
        server, epoch = meta.get("server"), meta.get("epoch")
        claimed = meta.get("dispatches")
        if not isinstance(claimed, int) or claimed < 0:
            claimed = None
        named = _shard_name_parts(path.name)
        if named is not None and (server, epoch) != named:
            return ShardNote(path, server, epoch, "quarantined",
                             f"meta-skew: header says "
                             f"({server!r}, e{epoch}), filename says "
                             f"({named[0]!r}, e{named[1]})",
                             claimed=claimed), []
        want = meta.get("sha256")
        if verify_digest and want is not None:
            if not sep or _body_digest(body) != want:
                # count what still parses, for the accounting only
                salvaged = _salvage_weight(body)
                return ShardNote(path, server, epoch, "quarantined",
                                 "digest-mismatch (torn write or "
                                 "tampering)", claimed=claimed,
                                 salvaged=salvaged), []
    else:
        body = text                       # headerless legacy trace file
    entries: list[TraceEntry] = []
    salvaged = 0
    objs: list[dict] = []
    for i, ln in enumerate(body.splitlines()):
        if not ln.strip() or ln.lstrip().startswith("#"):
            continue
        try:
            d = json.loads(ln)
            e = TraceEntry.from_dict(d)
            if e.count <= 0:
                raise ValueError(f"non-positive count {e.count}")
        except Exception as exc:
            return ShardNote(path, server, epoch, "quarantined",
                             f"parse-error at line {i + 2}: "
                             f"{type(exc).__name__}", claimed=claimed,
                             salvaged=salvaged), []
        objs.append(d)
        entries.append(e)
        salvaged += e.count
    n_v1 = sum(1 for d in objs if "v" not in d)
    if n_v1:
        import warnings
        warnings.warn(
            f"trace {path} carries {n_v1} schema-v1 line(s) (no 'v' "
            "key); v1 parse paths are deprecated — re-record with the "
            "current dispatcher (see ROADMAP 'Trace v1 sunset')",
            DeprecationWarning, stacklevel=2)
    return ShardNote(path, server, epoch, "merged", dispatches=salvaged,
                     claimed=claimed), entries


def _salvage_weight(body: str) -> int:
    """Dispatch weight of the lines in a corrupt shard body that still
    parse — accounting for the merge report, never merged."""
    total = 0
    for ln in body.splitlines():
        if not ln.strip() or ln.lstrip().startswith("#"):
            continue
        try:
            total += max(0, TraceEntry.from_dict(json.loads(ln)).count)
        except Exception:
            continue
    return total


class ShardRecorder:
    """A ``record=`` sink for ``api.tuned`` that samples dispatches across
    recompilations into a bounded cell multiset and flushes epoch-stamped
    per-server shard files — one fleet server's contribution to the next
    tuning generation.

    A plain ``record=[]`` list grows with every re-trace (new shapes,
    donation misses) for the life of a serving process; the recorder
    instead aggregates ``(cell, phase, impl) -> count`` with two bounds:

    * counts for admitted cells are exact (an int per cell is cheap);
    * DISTINCT cells are admitted by reservoir sampling (Algorithm R over
      the stream of first-seen cells): once ``max_cells`` are held, the
      ``i``-th new cell replaces a uniformly random incumbent with
      probability ``max_cells / i``, so under shape churn the shard is a
      uniform sample of the cell population and memory stays bounded.
      Evicted/undrawn dispatch weight is accounted in the shard header's
      ``dropped`` field — sampling is explicit, never silent.

    Exploration measurements (``observe``) keep at most ``reservoir``
    latency samples per (cell, impl), also via Algorithm R; they ride in
    the shard as ``#@lat`` comment lines (invisible to ``Trace`` parsers,
    read back by ``load_shard_latencies``) and feed the next epoch's
    tuning via ``tuner.FeedbackBackend``.

    ``flush(directory, epoch)`` writes ``shard-<server>-e<epoch>.jsonl``
    atomically (tmp + fsync + ``os.replace``, so a crash mid-flush leaves
    either the old file or the new one, never a torn hybrid) and RESETS
    the recorder — each shard is one epoch's window, not a cumulative
    history.  The ``#@shard`` header carries a sha256 over the shard BODY
    (everything after the header line), which ``Trace.merge_shards``
    verifies — a truncated or bit-rotted shard is quarantined, not merged.
    """

    def __init__(self, server: str, *, max_cells: int = 4096,
                 reservoir: int = 32, seed: int = 0):
        import random
        self.server = str(server)
        self.max_cells = int(max_cells)
        self.reservoir = int(reservoir)
        self._rng = random.Random(seed)
        self._reset()

    def _reset(self) -> None:
        self._counts: dict[tuple[OpCell, str, str], int] = {}
        self._keys: list[tuple[OpCell, str, str]] = []
        self._seen_keys = 0
        self.dropped = 0
        self._lat: dict[tuple[OpCell, str], list[float]] = {}
        self._lat_n: dict[tuple[OpCell, str], int] = {}

    # -- the api.tuned record sink -------------------------------------------
    def append(self, rec) -> None:
        """Record one dispatch (``DispatchRecord`` or legacy 5-tuple)."""
        if hasattr(rec, "cell"):
            key = (rec.cell, rec.phase, rec.impl)
        else:
            op, p, nbytes, impl, phase = rec
            key = (OpCell(op, p, nbytes), phase, impl)
        if key in self._counts:
            self._counts[key] += 1
            return
        self._seen_keys += 1
        if len(self._counts) < self.max_cells:
            self._counts[key] = 1
            self._keys.append(key)
            return
        j = self._rng.randrange(self._seen_keys)
        if j < self.max_cells:
            victim = self._keys[j]
            self.dropped += self._counts.pop(victim)
            self._keys[j] = key
            self._counts[key] = 1
        else:
            self.dropped += 1

    # -- exploration feedback ------------------------------------------------
    def observe(self, cell: OpCell, impl: str, latency_s: float) -> None:
        """Feed one live latency measurement for (cell, impl) — the
        exploration budget's signal back into the next epoch."""
        key = (cell, impl)
        n = self._lat_n.get(key, 0) + 1
        self._lat_n[key] = n
        buf = self._lat.setdefault(key, [])
        if len(buf) < self.reservoir:
            buf.append(float(latency_s))
            return
        j = self._rng.randrange(n)
        if j < self.reservoir:
            buf[j] = float(latency_s)

    # -- views ---------------------------------------------------------------
    def trace(self) -> Trace:
        return Trace(TraceEntry(c, ph, im, n)
                     for (c, ph, im), n in self._counts.items())

    def total(self) -> int:
        return sum(self._counts.values())

    def __len__(self) -> int:
        return len(self._counts)

    # -- disk ----------------------------------------------------------------
    def flush(self, directory: str | pathlib.Path,
              epoch: int) -> pathlib.Path:
        """Write this window's epoch-stamped shard file and reset."""
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        path = d / f"shard-{self.server}-e{int(epoch):06d}.jsonl"
        body_lines = [e.to_json() for e in self.trace().entries]
        for (cell, impl), buf in sorted(self._lat.items(),
                                        key=lambda kv: (kv[0][0], kv[0][1])):
            m = _cell_dict(cell)
            m.update(impl=impl, lat_s=buf,
                     observed=self._lat_n[(cell, impl)])
            body_lines.append(LAT_PREFIX + json.dumps(m))
        body = "".join(ln + "\n" for ln in body_lines)
        header = {"server": self.server, "epoch": int(epoch),
                  "cells": len(self._counts), "dispatches": self.total(),
                  "dropped": self.dropped,
                  "sha256": _body_digest(body)}
        tmp = path.with_name(path.name + ".tmp")
        import os
        with open(tmp, "w") as f:
            f.write(SHARD_HEADER + json.dumps(header) + "\n")
            f.write(body)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self._reset()
        return path


def shard_meta(path: str | pathlib.Path) -> dict | None:
    """The ``#@shard`` header of one shard file, or None."""
    with open(path) as f:
        first = f.readline()
    if not first.startswith(SHARD_HEADER):
        return None
    try:
        return json.loads(first[len(SHARD_HEADER):])
    except ValueError:
        return None


def shard_digest(directory: str | pathlib.Path, *,
                 pattern: str = "shard-*.jsonl") -> str:
    """Content digest over the shard set (sorted by filename) — the
    provenance a profile generation's MANIFEST records as ``source``."""
    import hashlib
    d = pathlib.Path(directory)
    h = hashlib.sha256()
    for p in sorted(d.glob(pattern)):
        h.update(p.name.encode())
        h.update(b"\0")
        h.update(p.read_bytes())
    return "sha256:" + h.hexdigest()


def load_shard_latencies(directory: str | pathlib.Path, *,
                         pattern: str = "shard-*.jsonl",
                         skip: "Iterable[str | pathlib.Path]" = ()) \
        -> dict[tuple[OpCell, str], list[float]]:
    """All exploration measurements across a fleet's shard files:
    ``(cell, impl) -> [latency_s, ...]`` (samples concatenated across
    servers; feed to ``tuner.FeedbackBackend``).

    Malformed ``#@lat`` lines are skipped with one warning per file — a
    corrupt shard must not take the feedback loop down.  ``skip`` names
    shards to exclude entirely (pass the quarantined paths from a
    ``MergeReport`` so a quarantined shard's measurements are not
    trusted either).
    """
    out: dict[tuple[OpCell, str], list[float]] = {}
    d = pathlib.Path(directory)
    skipped = {pathlib.Path(s).name for s in skip}
    for p in sorted(d.glob(pattern)):
        if p.name in skipped:
            continue
        try:
            text = p.read_text()
        except OSError:
            continue
        bad = 0
        for ln in text.splitlines():
            if not ln.startswith(LAT_PREFIX):
                continue
            try:
                m = json.loads(ln[len(LAT_PREFIX):])
                key = (_cell_from_dict(m), m["impl"])
                samples = [float(t) for t in m["lat_s"]]
            except Exception:
                bad += 1
                continue
            out.setdefault(key, []).extend(samples)
        if bad:
            import warnings
            warnings.warn(f"load_shard_latencies: skipped {bad} "
                          f"malformed #@lat line(s) in {p}")
    return out
