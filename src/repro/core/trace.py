"""Workload traces: ``TuneContext.record`` as a first-class artifact.

The paper's offline pass (§4.2) tunes each collective *in isolation* over a
synthetic size sweep; its PGMPI predecessor (arXiv:1606.00215) instead tunes
the op mix a real application issues per callsite.  A ``Trace`` captures that
mix from live model traffic: every dispatch the api records — forward
all-gathers, backward reduce-scatters, prefill vs decode serving steps — is
aggregated into ``(OpCell, phase, impl) -> count`` cells, where ``OpCell``
(core/cell.py) carries the FULL communication problem: op, axis size,
payload bytes, dtype and — for the fused collective-matmul ops — the
per-callsite GEMM dims ``(mm_k, mm_m, mm_n)`` and the gather/scatter/
contract role.

Phases are the coarse callsite classes of an LM workload:

=========  ===============================================================
phase      traffic
=========  ===============================================================
fwd        forward-pass collectives (ambient default under training)
bwd        custom-VJP backward collectives + gradient sync (dist/ops,
           train/trainer tag these via ``api.phase("bwd")``)
prefill    serving prompt ingestion (launch/serve tags these)
decode     serving token-by-token steps (launch/serve tags these)
=========  ===============================================================

The on-disk form is JSONL — one aggregated cell per line, so traces from
many hosts/steps concatenate and ``merge`` trivially.  **Schema v2** adds the
geometry fields (``v: 2``; ``mm``/``role`` only present on fused cells):

    {"v": 2, "op": "allgather_matmul", "p": 8, "nbytes": 4096,
     "dtype": "float32", "mm": [512, 64, 16], "role": "gather",
     "phase": "fwd", "impl": "default", "count": 24}

v1 lines (no ``v`` key, bare 5-field cells) still load: their geometry is
defaulted — dtype ``float32``, no GEMM dims — which for fused ops means
"geometry unknown" (``OpCell.fused`` is False); the measured backend cannot
replay such a cell and note-skips it.

``tuner.tune_trace`` consumes a ``Trace`` and emits per-phase
``ProfileStore``s (see DESIGN_TRACE.md), which ``api.tuned(phase_profiles=
...)`` applies at dispatch — the backward can pick a different mock-up than
the forward for the same message size.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Iterator

from repro.core.cell import OpCell

SCHEMA_VERSION = 2


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One aggregated dispatch cell."""
    cell: OpCell
    phase: str = "fwd"
    impl: str = "default"
    count: int = 1

    # -- convenience views (the cell is the key) -----------------------------
    @property
    def op(self) -> str:
        return self.cell.op

    @property
    def axis_size(self) -> int:
        return self.cell.p

    @property
    def nbytes(self) -> int:
        return self.cell.nbytes

    def key(self) -> tuple[OpCell, str, str]:
        return (self.cell, self.phase, self.impl)

    @classmethod
    def of(cls, op: str, axis_size: int, nbytes: int, phase: str = "fwd",
           impl: str = "default", count: int = 1, **geom) -> "TraceEntry":
        """Build from bare fields (tests, hand-written traces); ``geom``
        passes ``dtype``/``mm_k``/``mm_m``/``mm_n``/``mm_role`` through."""
        return cls(OpCell(op, axis_size, nbytes, **geom), phase, impl, count)

    def to_json(self) -> str:
        d = {"v": SCHEMA_VERSION, "op": self.cell.op, "p": self.cell.p,
             "nbytes": self.cell.nbytes, "dtype": self.cell.dtype}
        if self.cell.fused:
            d["mm"] = [self.cell.mm_k, self.cell.mm_m, self.cell.mm_n]
            d["role"] = self.cell.mm_role
        if self.cell.p2:
            d["p2"] = self.cell.p2      # inner axis of a 2-D cell
        d.update(phase=self.phase, impl=self.impl, count=self.count)
        return json.dumps(d)

    @classmethod
    def from_json(cls, line: str) -> "TraceEntry":
        """Parse a v2 line; v1 lines (no ``v`` key) load with defaulted
        geometry — fused ops come back with unknown GEMM dims."""
        d = json.loads(line)
        mm = d.get("mm") or (0, 0, 0)
        cell = OpCell(op=d["op"], p=int(d["p"]), nbytes=int(d["nbytes"]),
                      dtype=d.get("dtype", "float32"),
                      mm_k=int(mm[0]), mm_m=int(mm[1]), mm_n=int(mm[2]),
                      mm_role=d.get("role", ""), p2=int(d.get("p2", 0)))
        return cls(cell=cell, phase=d.get("phase", "fwd"),
                   impl=d.get("impl", "default"),
                   count=int(d.get("count", 1)))


class Trace:
    """An aggregated multiset of dispatch cells (order-independent)."""

    def __init__(self, entries: Iterable[TraceEntry] | None = None):
        self._cells: dict[tuple[OpCell, str, str], int] = {}
        for e in entries or ():
            self._add(e.key(), e.count)

    def _add(self, key: tuple[OpCell, str, str], count: int) -> None:
        if count <= 0:
            raise ValueError(f"non-positive count {count} for {key}")
        self._cells[key] = self._cells.get(key, 0) + count

    # -- construction --------------------------------------------------------
    @classmethod
    def from_record(cls, record) -> "Trace":
        """Build from ``TuneContext.record`` entries (``DispatchRecord``
        with a ``.cell``; legacy ``(op, p, nbytes, impl, phase)`` 5-tuples
        are accepted with defaulted geometry)."""
        t = cls()
        for r in record:
            if hasattr(r, "cell"):
                t._add((r.cell, r.phase, r.impl), 1)
            else:
                op, p, nbytes, impl, phase = r
                t._add((OpCell(op, p, nbytes), phase, impl), 1)
        return t

    @classmethod
    def from_context(cls, ctx) -> "Trace":
        return cls.from_record(ctx.record)

    # -- views ---------------------------------------------------------------
    @property
    def entries(self) -> list[TraceEntry]:
        return [TraceEntry(cell, phase, impl, count)
                for (cell, phase, impl), count in sorted(self._cells.items())]

    def __len__(self) -> int:
        return len(self._cells)

    def __iter__(self) -> Iterator[TraceEntry]:
        return iter(self.entries)

    def __eq__(self, other) -> bool:
        return isinstance(other, Trace) and self._cells == other._cells

    def total(self) -> int:
        """Total dispatch count across all cells."""
        return sum(self._cells.values())

    def phases(self) -> list[str]:
        return sorted({k[1] for k in self._cells})

    def ops(self) -> list[str]:
        return sorted({k[0].op for k in self._cells})

    def histogram(self) -> dict[tuple[OpCell, str], int]:
        """``(cell, phase) -> count`` (summed over impls — the tuner
        re-decides the impl, so the recorded one is provenance)."""
        out: dict[tuple[OpCell, str], int] = {}
        for (cell, phase, _impl), count in self._cells.items():
            k = (cell, phase)
            out[k] = out.get(k, 0) + count
        return out

    def cells(self, phase: str | None = None) -> dict[OpCell, int]:
        """``OpCell -> count`` for one phase (or all)."""
        out: dict[OpCell, int] = {}
        for (cell, ph, _impl), count in self._cells.items():
            if phase is not None and ph != phase:
                continue
            out[cell] = out.get(cell, 0) + count
        return out

    def filter(self, *, phase: str | None = None,
               op: str | None = None) -> "Trace":
        keep = [e for e in self.entries
                if (phase is None or e.phase == phase)
                and (op is None or e.op == op)]
        return Trace(keep)

    def merge(self, *others: "Trace") -> "Trace":
        """Sum counts cell-wise (traces from many steps/hosts)."""
        out = Trace(self.entries)
        for o in others:
            for e in o.entries:
                out._add(e.key(), e.count)
        return out

    def summary(self) -> str:
        lines = [f"trace: {len(self)} cells, {self.total()} dispatches"]
        for ph in self.phases():
            cells = self.cells(phase=ph)
            n = sum(cells.values())
            ops = sorted({c.op for c in cells})
            lines.append(f"  {ph}: {n} dispatches over {len(cells)} cells "
                         f"({', '.join(ops)})")
        return "\n".join(lines)

    # -- disk ----------------------------------------------------------------
    def to_jsonl(self) -> str:
        return "".join(e.to_json() + "\n" for e in self.entries)

    @classmethod
    def from_jsonl(cls, text: str, *, source: str | None = None) -> "Trace":
        """Parse JSONL; any v1 line (no ``"v"`` key) triggers ONE
        ``DeprecationWarning`` naming ``source`` (the v1 sunset step — the
        lines still load with defaulted geometry, but fused cells lose
        their GEMM and the measured backend note-skips them; re-record)."""
        lines = [ln for ln in text.splitlines()
                 if ln.strip() and not ln.lstrip().startswith("#")]
        n_v1 = sum(1 for ln in lines if '"v"' not in ln)
        if n_v1:
            import warnings
            warnings.warn(
                f"trace {source or '<string>'} carries {n_v1} schema-v1 "
                "line(s) (no 'v' key); v1 parse paths are deprecated — "
                "re-record with the current dispatcher (see ROADMAP "
                "'Trace v1 sunset')", DeprecationWarning, stacklevel=2)
        return cls([TraceEntry.from_json(ln) for ln in lines])

    def save(self, path: str | pathlib.Path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_jsonl())

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Trace":
        p = pathlib.Path(path)
        return cls.from_jsonl(p.read_text(), source=str(p))
