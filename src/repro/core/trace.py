"""Workload traces: ``TuneContext.record`` as a first-class artifact.

The paper's offline pass (§4.2) tunes each collective *in isolation* over a
synthetic size sweep; its PGMPI predecessor (arXiv:1606.00215) instead tunes
the op mix a real application issues per callsite.  A ``Trace`` captures that
mix from live model traffic: every dispatch the api records — forward
all-gathers, backward reduce-scatters, prefill vs decode serving steps — is
aggregated into ``(op, axis_size, nbytes, phase, impl) -> count`` cells.

Phases are the coarse callsite classes of an LM workload:

=========  ===============================================================
phase      traffic
=========  ===============================================================
fwd        forward-pass collectives (ambient default under training)
bwd        custom-VJP backward collectives + gradient sync (dist/ops,
           train/trainer tag these via ``api.phase("bwd")``)
prefill    serving prompt ingestion (launch/serve tags these)
decode     serving token-by-token steps (launch/serve tags these)
=========  ===============================================================

The on-disk form is JSONL — one aggregated cell per line, so traces from
many hosts/steps concatenate and ``merge`` trivially:

    {"op": "reducescatter", "p": 8, "nbytes": 4096, "phase": "bwd",
     "impl": "default", "count": 24}

``tuner.tune_trace`` consumes a ``Trace`` and emits per-phase
``ProfileStore``s (see DESIGN_TRACE.md), which ``api.tuned(phase_profiles=
...)`` applies at dispatch — the backward can pick a different mock-up than
the forward for the same message size.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Iterator


@dataclasses.dataclass(frozen=True)
class TraceEntry:
    """One aggregated dispatch cell."""
    op: str
    axis_size: int
    nbytes: int
    phase: str = "fwd"
    impl: str = "default"
    count: int = 1

    def key(self) -> tuple[str, int, int, str, str]:
        return (self.op, self.axis_size, self.nbytes, self.phase, self.impl)

    def to_json(self) -> str:
        return json.dumps({"op": self.op, "p": self.axis_size,
                           "nbytes": self.nbytes, "phase": self.phase,
                           "impl": self.impl, "count": self.count})

    @classmethod
    def from_json(cls, line: str) -> "TraceEntry":
        d = json.loads(line)
        return cls(op=d["op"], axis_size=int(d["p"]),
                   nbytes=int(d["nbytes"]), phase=d.get("phase", "fwd"),
                   impl=d.get("impl", "default"),
                   count=int(d.get("count", 1)))


class Trace:
    """An aggregated multiset of dispatch cells (order-independent)."""

    def __init__(self, entries: Iterable[TraceEntry] | None = None):
        self._cells: dict[tuple[str, int, int, str, str], int] = {}
        for e in entries or ():
            self._add(e.key(), e.count)

    def _add(self, key: tuple[str, int, int, str, str], count: int) -> None:
        if count <= 0:
            raise ValueError(f"non-positive count {count} for {key}")
        self._cells[key] = self._cells.get(key, 0) + count

    # -- construction --------------------------------------------------------
    @classmethod
    def from_record(cls, record) -> "Trace":
        """Build from ``TuneContext.record`` 5-tuples
        ``(op, axis_size, nbytes, impl, phase)``."""
        t = cls()
        for op, p, nbytes, impl, phase in record:
            t._add((op, p, nbytes, phase, impl), 1)
        return t

    @classmethod
    def from_context(cls, ctx) -> "Trace":
        return cls.from_record(ctx.record)

    # -- views ---------------------------------------------------------------
    @property
    def entries(self) -> list[TraceEntry]:
        return [TraceEntry(op, p, nbytes, phase, impl, count)
                for (op, p, nbytes, phase, impl), count
                in sorted(self._cells.items())]

    def __len__(self) -> int:
        return len(self._cells)

    def __iter__(self) -> Iterator[TraceEntry]:
        return iter(self.entries)

    def __eq__(self, other) -> bool:
        return isinstance(other, Trace) and self._cells == other._cells

    def total(self) -> int:
        """Total dispatch count across all cells."""
        return sum(self._cells.values())

    def phases(self) -> list[str]:
        return sorted({k[3] for k in self._cells})

    def ops(self) -> list[str]:
        return sorted({k[0] for k in self._cells})

    def histogram(self) -> dict[tuple[str, int, int, str], int]:
        """``(op, axis_size, nbytes, phase) -> count`` (summed over impls —
        the tuner re-decides the impl, so the recorded one is provenance)."""
        out: dict[tuple[str, int, int, str], int] = {}
        for (op, p, nbytes, phase, _impl), count in self._cells.items():
            k = (op, p, nbytes, phase)
            out[k] = out.get(k, 0) + count
        return out

    def cells(self, phase: str | None = None) \
            -> dict[tuple[str, int, int], int]:
        """``(op, axis_size, nbytes) -> count`` for one phase (or all)."""
        out: dict[tuple[str, int, int], int] = {}
        for (op, p, nbytes, ph, _impl), count in self._cells.items():
            if phase is not None and ph != phase:
                continue
            k = (op, p, nbytes)
            out[k] = out.get(k, 0) + count
        return out

    def filter(self, *, phase: str | None = None,
               op: str | None = None) -> "Trace":
        keep = [e for e in self.entries
                if (phase is None or e.phase == phase)
                and (op is None or e.op == op)]
        return Trace(keep)

    def merge(self, *others: "Trace") -> "Trace":
        """Sum counts cell-wise (traces from many steps/hosts)."""
        out = Trace(self.entries)
        for o in others:
            for e in o.entries:
                out._add(e.key(), e.count)
        return out

    def summary(self) -> str:
        lines = [f"trace: {len(self)} cells, {self.total()} dispatches"]
        for ph in self.phases():
            cells = self.cells(phase=ph)
            n = sum(cells.values())
            ops = sorted({op for op, _, _ in cells})
            lines.append(f"  {ph}: {n} dispatches over {len(cells)} cells "
                         f"({', '.join(ops)})")
        return "\n".join(lines)

    # -- disk ----------------------------------------------------------------
    def to_jsonl(self) -> str:
        return "".join(e.to_json() + "\n" for e in self.entries)

    @classmethod
    def from_jsonl(cls, text: str) -> "Trace":
        entries = [TraceEntry.from_json(ln) for ln in text.splitlines()
                   if ln.strip() and not ln.lstrip().startswith("#")]
        return cls(entries)

    def save(self, path: str | pathlib.Path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_jsonl())

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "Trace":
        return cls.from_jsonl(pathlib.Path(path).read_text())
