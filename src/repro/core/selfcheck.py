"""Multi-device SPMD validation of every mock-up against the numpy oracle.

Run as a subprocess (so the forced host-device count never leaks into the
parent):

    python -m repro.core.selfcheck --devices 8 [--json]

Exercises every registered implementation through a REAL ``shard_map`` over a
multi-device mesh (the vmap semantic tests cover tracing; this covers SPMD
lowering + execution), comparing against dense numpy references.

Quantized-wire mock-ups (``wire_q8``/``wire_fp8``) are checked against a
PER-WIRE-DTYPE relative-error bound instead of the exact atol: a wire impl
whose max-norm relative error exceeds ``wire_tol(dtype, hops)`` is DEMOTED
from the admissible set (``collectives.demote``) exactly like a failed
guideline — reported under ``"demoted"`` in the JSON, not as a suite
failure.  ``run_gate`` exposes the same gate in-process for arbitrary
payloads (the adversarial-demotion tests and the bench gates use it).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def wire_hops(op: str, p: int, p2: int = 0) -> int:
    """Number of independently-quantized error terms that can ADD into one
    output element of a wire impl — the multiplier on the single-roundtrip
    ``wire_tol`` base bound.

    Gather-style rings quantize each block once at its origin and no two
    blocks' errors meet (hops=1).  Reduction rings accumulate: the
    travelling-accumulator reduce-scatter requantizes the partial sum on
    every one of its p-1 hops, and the wire allreduce adds the AG
    re-quantize on top.  ``matmul_accumulate`` streams WEIGHT blocks that
    are each quantized only once — but the stationary-x contraction sums
    all p-1 wire-crossed blocks' independent errors into every output
    element, so the additive count is p-1, not 1 (counting requantize
    events of the travelling data, the old rule, under-bounds it and
    spuriously demotes benign payloads as p grows).  A 2-D cell's error
    budget is set by its inner reduction ring of size ``p2`` (the outer
    stream is gather-style); pass ``p2`` for those."""
    if op in ("reducescatter", "matmul_reducescatter", "matmul_accumulate"):
        return max(p - 1, 1)
    if op == "allreduce":
        return max(p, 1)
    if op == "matmul_reducescatter_2d":
        q = p2 if p2 else p
        return max(q - 1, 1)
    return 1


def rel_err(got, want) -> float:
    """Max-norm relative error — the wire-tolerance metric."""
    import numpy as np
    g = np.asarray(got, np.float64)
    w = np.asarray(want, np.float64)
    return float(np.max(np.abs(g - w)) / max(np.max(np.abs(w)), 1e-30))


def run_gate(op: str, name: str, x, *, w=None, demote: bool = True,
             p2: int = 0):
    """Run one impl of ``op`` on a CONCRETE stacked payload ``x`` ([p, ...],
    one leading row block per rank) under ``vmap`` and apply the wire
    tolerance gate against the dense numpy oracle.

    Returns ``(ok, rel, tol)``.  For a quantized-wire impl that breaks its
    tolerance the impl is demoted (unless ``demote=False``); non-wire impls
    are gated at the wire-agnostic 1e-5 bound and never demoted.  For a
    hierarchical (``Impl.hier``) mock-up pass ``p2`` (inner axis size,
    dividing ``p``): the p ranks run as a nested (p//p2, p2) vmap mesh in
    outer-major order.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import collectives as C
    from repro.kernels.quant import wire_tol

    impl = C.REGISTRY[op][name]
    p = x.shape[0]
    xs = jnp.asarray(x)
    xn = np.asarray(x, np.float64)
    if getattr(impl, "hier", False):
        if op not in ("allgather", "allreduce", "reducescatter"):
            raise KeyError(f"run_gate does not model hier {op!r}")
        if p2 <= 1 or p % p2:
            raise ValueError(
                f"hier impl {name!r} needs p2 in (1, p) dividing p={p}")
        nested = xs.reshape((p // p2, p2) + x.shape[1:])
        got = jax.vmap(jax.vmap(
            lambda s: impl.fn(s, "o", inner_axis="i"), axis_name="i"),
            axis_name="o")(nested)
        got = np.asarray(got).reshape((p,) + got.shape[2:])
        if op == "allgather":
            full = xn.reshape((-1,) + xn.shape[2:])
            want = np.broadcast_to(full, (p,) + full.shape)
        elif op == "allreduce":
            want = np.broadcast_to(xn.sum(0), (p,) + xn.shape[1:])
        else:
            want = xn.sum(0).reshape((p, -1) + xn.shape[2:])
    elif op in ("allgather", "allreduce", "reducescatter"):
        got = jax.vmap(lambda s: impl.fn(s, "x"), axis_name="x")(xs)
        if op == "allgather":
            full = xn.reshape((-1,) + xn.shape[2:])
            want = np.broadcast_to(full, (p,) + full.shape)
        elif op == "allreduce":
            want = np.broadcast_to(xn.sum(0), (p,) + xn.shape[1:])
        else:
            want = xn.sum(0).reshape((p, -1) + xn.shape[2:])
    elif op in ("allgather_matmul", "matmul_reducescatter"):
        wj = jnp.asarray(w)
        got = jax.vmap(lambda s: impl.fn(s, "x", w=wj), axis_name="x")(xs)
        wn = np.asarray(w, np.float64)
        if op == "allgather_matmul":
            full = xn.reshape(-1, xn.shape[-1]) @ wn
            want = np.broadcast_to(full, (p,) + full.shape)
        else:
            want = (xn @ wn).sum(0).reshape(p, -1, wn.shape[-1])
    elif op == "matmul_accumulate":
        # x = stacked weight K-blocks [p, k_loc, m]; w = stationary [T, K]
        stat = jnp.asarray(w)
        got = jax.vmap(lambda s: impl.fn(s, "x", x=stat), axis_name="x")(xs)
        full_w = xn.reshape(-1, xn.shape[-1])
        wantv = np.asarray(w, np.float64) @ full_w
        want = np.broadcast_to(wantv, (p,) + wantv.shape)
    else:
        raise KeyError(f"run_gate does not model {op!r}")
    rel = rel_err(got, want)
    if impl.wire_dtype is None:
        return rel <= 1e-5, rel, 1e-5
    tol = wire_tol(impl.wire_dtype, wire_hops(op, p))
    ok = rel <= tol
    if not ok and demote:
        C.demote(op, name, reason=f"tolerance rel={rel:.3g} > {tol:.3g}")
    return ok, rel, tol


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro._compat import shard_map

    from repro.core import collectives as C

    P_ = args.devices
    mesh = Mesh(np.array(jax.devices()[:P_]), ("x",))
    rng = np.random.default_rng(42)

    def run(fn, x, **kw):
        sm = shard_map(lambda a: fn(a, "x", **kw), mesh=mesh,
                       in_specs=P("x"), out_specs=P("x"), check_vma=False)
        return np.asarray(jax.jit(sm)(x)).reshape((P_, -1) + x.shape[1:])

    n, w = 6, 3
    x = rng.normal(size=(P_, n, w)).astype(np.float32)
    xb = rng.normal(size=(P_, P_ * n, w)).astype(np.float32)
    xf = jnp.asarray(x.reshape(P_ * n, w))
    xbf = jnp.asarray(xb.reshape(P_ * P_ * n, w))
    full = x.reshape(P_ * n, w)

    from repro.kernels.quant import wire_tol

    results = {}
    demoted = []

    def rtol_for(op, nm):
        wd = C.REGISTRY[op][nm].wire_dtype
        return None if wd is None else wire_tol(wd, wire_hops(op, P_))

    def check(name, got, want, rank=None, *, rtol=None, key=None):
        g = got if rank is None else got[rank]
        if rtol is None:
            ok = bool(np.allclose(g, want, atol=1e-5))
        else:
            # wire tolerance gate: max-norm relative error per wire dtype;
            # breaking it demotes the impl, it does not fail the suite.
            rel = rel_err(g, want)
            ok = rel <= rtol
            if not ok and key is not None:
                C.demote(key[0], key[1],
                         reason=f"tolerance rel={rel:.3g} > {rtol:.3g}")
                demoted.append(name)
        results[name] = ok
        if not args.json:
            tag = "OK" if ok else ("DEMOTED" if name in demoted else "FAIL")
            print(f"{name:44s} {tag}")

    for nm in C.impl_names("allgather"):
        if C.REGISTRY["allgather"][nm].hier:
            continue                     # needs inner_axis — hier section
        y = run(C.REGISTRY["allgather"][nm].fn, xf)
        check(f"allgather/{nm}", y, np.broadcast_to(full, (P_,) + full.shape),
              rtol=rtol_for("allgather", nm), key=("allgather", nm))
    want = x.sum(0)
    for nm in C.impl_names("allreduce"):
        if C.REGISTRY["allreduce"][nm].hier:
            continue
        y = run(C.REGISTRY["allreduce"][nm].fn, xf, chunk=2)
        check(f"allreduce/{nm}", y, np.broadcast_to(want, (P_,) + want.shape),
              rtol=rtol_for("allreduce", nm), key=("allreduce", nm))
    wantrs = xb.sum(0).reshape(P_, n, w)
    for nm in C.impl_names("reducescatter"):
        if C.REGISTRY["reducescatter"][nm].hier:
            continue
        check(f"reducescatter/{nm}", run(C.REGISTRY["reducescatter"][nm].fn, xbf),
              wantrs, rtol=rtol_for("reducescatter", nm),
              key=("reducescatter", nm))

    # hierarchical MPIX mock-ups (and the defaults' inner_axis path): a
    # REAL two-axis ("o" outer/slow, "i" inner/fast) mesh; the joint-group
    # result in outer-major block order must match the flat oracle exactly
    d_h = 2
    mesh_h = Mesh(np.array(jax.devices()[:P_]).reshape(d_h, P_ // d_h),
                  ("o", "i"))

    def run_h(fn, xin):
        sm = shard_map(lambda a: fn(a, "o", inner_axis="i"), mesh=mesh_h,
                       in_specs=P(("o", "i")), out_specs=P(("o", "i")),
                       check_vma=False)
        return np.asarray(jax.jit(sm)(xin)).reshape(
            (P_, -1) + xin.shape[1:])

    for op, xin, wanth in (
            ("allgather", xf, np.broadcast_to(full, (P_,) + full.shape)),
            ("allreduce", xf, np.broadcast_to(want, (P_,) + want.shape)),
            ("reducescatter", xbf, wantrs)):
        for nm in C.impl_names(op):
            impl = C.REGISTRY[op][nm]
            if not (impl.hier or nm == "default"):
                continue
            check(f"{op}@{d_h}x{P_ // d_h}/{nm}", run_h(impl.fn, xin),
                  wanth)
    wanta2a = xb.reshape(P_, P_, n, w).transpose(1, 0, 2, 3).reshape(
        P_, P_ * n, w)
    for nm in C.impl_names("alltoall"):
        check(f"alltoall/{nm}", run(C.REGISTRY["alltoall"][nm].fn, xbf), wanta2a)
    for nm in C.impl_names("bcast"):
        y = run(C.REGISTRY["bcast"][nm].fn, xf, root=3)
        check(f"bcast/{nm}", y, np.broadcast_to(x[3], (P_, n, w)))
    for nm in C.impl_names("gather"):
        y = run(C.REGISTRY["gather"][nm].fn, xf, root=2)
        check(f"gather/{nm}", y, full, rank=2)
    wantsc = xb[5].reshape(P_, n, w)
    for nm in C.impl_names("scatter"):
        check(f"scatter/{nm}", run(C.REGISTRY["scatter"][nm].fn, xbf, root=5),
              wantsc)
    for nm in C.impl_names("reduce"):
        y = run(C.REGISTRY["reduce"][nm].fn, xf, root=1, chunk=2)
        check(f"reduce/{nm}", y, x.sum(0), rank=1)
    wantscan = np.cumsum(x, axis=0)
    for nm in C.impl_names("scan"):
        check(f"scan/{nm}", run(C.REGISTRY["scan"][nm].fn, xf), wantscan)
    check("exscan/default", run(C.REGISTRY["exscan"]["default"].fn, xf),
          wantscan - x)

    # fused collective-matmul ops: w is a shard-local (replicated) closure
    # operand; output width differs from the input so run() can't reshape
    wm = rng.normal(size=(w, 4)).astype(np.float32)

    def run_mm(fn, xin, out_shape):
        sm = shard_map(lambda a: fn(a, "x", w=jnp.asarray(wm)), mesh=mesh,
                       in_specs=P("x"), out_specs=P("x"), check_vma=False)
        return np.asarray(jax.jit(sm)(xin)).reshape((P_,) + out_shape)

    want_agmm = full @ wm
    for nm in C.impl_names("allgather_matmul"):
        y = run_mm(C.REGISTRY["allgather_matmul"][nm].fn, xf,
                   want_agmm.shape)
        check(f"allgather_matmul/{nm}", y,
              np.broadcast_to(want_agmm, (P_,) + want_agmm.shape),
              rtol=rtol_for("allgather_matmul", nm),
              key=("allgather_matmul", nm))
    want_mmrs = (xb @ wm).sum(0).reshape(P_, n, 4)
    for nm in C.impl_names("matmul_reducescatter"):
        y = run_mm(C.REGISTRY["matmul_reducescatter"][nm].fn, xbf, (n, 4))
        check(f"matmul_reducescatter/{nm}", y, want_mmrs,
              rtol=rtol_for("matmul_reducescatter", nm),
              key=("matmul_reducescatter", nm))

    # matmul_accumulate: the SHARDED operand is the K-dim weight block; the
    # stationary x [T, K] is a shard-local closure operand
    k_loc, t_rows = 2, 5
    wacc = rng.normal(size=(P_ * k_loc, 4)).astype(np.float32)
    xacc = rng.normal(size=(t_rows, P_ * k_loc)).astype(np.float32)
    want_acc = xacc @ wacc

    def run_acc(fn):
        sm = shard_map(lambda wb: fn(wb, "x", x=jnp.asarray(xacc)),
                       mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                       check_vma=False)
        return np.asarray(jax.jit(sm)(jnp.asarray(wacc))).reshape(
            (P_, t_rows, 4))

    for nm in C.impl_names("matmul_accumulate"):
        y = run_acc(C.REGISTRY["matmul_accumulate"][nm].fn)
        check(f"matmul_accumulate/{nm}", y,
              np.broadcast_to(want_acc, (P_,) + want_acc.shape),
              rtol=rtol_for("matmul_accumulate", nm),
              key=("matmul_accumulate", nm))

    # matmul_reducescatter_2d: a REAL two-axis mesh ("a" = the outer
    # weight-stream/gather axis, "b" = the inner reduce-scatter axis).
    # Forward: shard (a=i, b=j) holds x's j-th K-slice and W's (j K-rows,
    # i col-block) — the row_matmul(fsdp_dim=1) layout — so the inner RS
    # performs the model-axis contraction sum and the outer stream the
    # data-axis weight gather.  Xpose: the cotangent's rows shard over
    # "a" (gathered + CONTRACTED), each "b" rank contributes a different
    # stationary x (the data-batch sum of the dw schedule).
    d2 = 2
    q2 = P_ // d2
    mesh2 = Mesh(np.array(jax.devices()[:P_]).reshape(d2, q2), ("a", "b"))
    t2, kl2, ml2 = 2 * q2, 3, 4
    x2d = rng.normal(size=(t2, q2 * kl2)).astype(np.float32)
    w2d = rng.normal(size=(q2 * kl2, d2 * ml2)).astype(np.float32)
    g2d = rng.normal(size=(t2, d2 * ml2)).astype(np.float32)
    xb2d = rng.normal(size=(t2, q2 * kl2)).astype(np.float32)
    want_2d = x2d @ w2d                                       # [t2, M]
    want_2dt = sum(                                           # [M, kl2]
        g2d.T @ xb2d[:, j * kl2:(j + 1) * kl2] for j in range(q2))

    for nm in C.impl_names("matmul_reducescatter_2d"):
        fn = C.REGISTRY["matmul_reducescatter_2d"][nm].fn

        def body_f(xb, wb, fn=fn):
            return fn(wb, "a", x=xb, rs_axis="b")

        sm = shard_map(body_f, mesh=mesh2,
                       in_specs=(P(None, "b"), P("b", "a")),
                       out_specs=P("b", None), check_vma=False)
        y = np.asarray(jax.jit(sm)(jnp.asarray(x2d), jnp.asarray(w2d)))
        check(f"matmul_reducescatter_2d/{nm}", y, want_2d)

        def body_t(gb, xb, fn=fn):
            return fn(gb, "a", x=xb, rs_axis="b", xpose=True)

        sm_t = shard_map(body_t, mesh=mesh2,
                         in_specs=(P("a", None), P(None, "b")),
                         out_specs=P("b", None), check_vma=False)
        yt = np.asarray(jax.jit(sm_t)(jnp.asarray(g2d), jnp.asarray(xb2d)))
        check(f"matmul_reducescatter_2d/{nm}/xpose", yt, want_2dt)

    fails = [k for k, v in results.items() if not v and k not in demoted]
    if args.json:
        print(json.dumps({"devices": P_, "total": len(results),
                          "failures": fails, "demoted": demoted}))
    else:
        print(f"\n{len(results)} checks, failures: {fails or 'none'}, "
              f"demoted: {demoted or 'none'}")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
