"""Performance-guideline registry (paper §3.1, Table 1).

Three guideline classes from the paper (and its predecessor [6]):

* ``pattern``          — MPI_A(n) ≤ MPI_B(n) between semantically equivalent
                         operations: GL1..GL22 (+ our ⊕ TPU-native extras).
* ``monotony``         — T_op(n1) ≤ T_op(n2) for n1 ≤ n2.
* ``split_robustness`` — running the op once on n is not slower than k times
                         on n/k.

Pattern guidelines are 1:1 with mock-up implementations in
``collectives.REGISTRY`` (an Impl with ``guideline=="GL<k>"`` *is* the
right-hand side of that guideline).  This module adds the declarative
listing, lookup helpers, and the Table-1 memory model surface used by the
dispatcher's scratch budget (the paper's ``size_msg_buffer_bytes``).
"""
from __future__ import annotations

import dataclasses

from repro.core.collectives import REGISTRY, Impl


@dataclasses.dataclass(frozen=True)
class Guideline:
    gl_id: str            # "GL1".."GL22" or "EXT:<name>"
    op: str               # LHS collective
    mockup: str           # RHS mock-up impl name in REGISTRY[op]
    statement: str        # human-readable A <= B

    @property
    def impl(self) -> Impl:
        return REGISTRY[self.op][self.mockup]

    def extra_bytes(self, payload_bytes: int, p: int) -> int:
        """Table-1 additional memory requirement of the mock-up."""
        return int(self.impl.extra_bytes(payload_bytes, p))


def _collect() -> list[Guideline]:
    gls: list[Guideline] = []
    for op, impls in REGISTRY.items():
        for name, impl in impls.items():
            if name == "default" or impl.guideline is None:
                continue
            gl_id = impl.guideline
            if gl_id == "EXT":
                # qualify with the op when the mock-up name alone is not
                # unique (e.g. "fused_ring" exists for both fused
                # collective-matmul ops)
                gl_id = (f"EXT:{name}" if "_as_" in name
                         else f"EXT:{op}.{name}")
            if impl.hier:
                stmt = (f"{op}@(inter x intra)(n) <= {name}(n)  "
                        "[per-tier decomposition must not lose to one flat "
                        "collective over the joint group when a ring step "
                        "crosses the slow tier]")
            elif name.startswith("fused_ring"):
                stmt = (f"{op}(n) <= {name}(n)  "
                        "[fused overlap must not lose to collective+matmul]")
            elif name.startswith("wire_"):
                stmt = (f"{op}(n) <= {name}(n) | err <= tol({impl.wire_dtype})"
                        "  [quantized wire must win AND hold its per-dtype "
                        "error bound — accuracy-conditional admissibility]")
            else:
                stmt = f"{op}(n) <= {name.replace('_as_', ' -> ')}(n)"
            gls.append(Guideline(gl_id=gl_id, op=op, mockup=name,
                                 statement=stmt))

    def key(g: Guideline):
        if g.gl_id.startswith("GL"):
            return (0, int(g.gl_id[2:]))
        return (1, g.gl_id)

    return sorted(gls, key=key)


GUIDELINES: list[Guideline] = _collect()

PAPER_GUIDELINES: list[Guideline] = [
    g for g in GUIDELINES if g.gl_id.startswith("GL")]

EXTENSION_GUIDELINES: list[Guideline] = [
    g for g in GUIDELINES if g.gl_id.startswith("EXT")]


def by_id(gl_id: str) -> Guideline:
    for g in GUIDELINES:
        if g.gl_id == gl_id:
            return g
    raise KeyError(gl_id)


def for_op(op: str) -> list[Guideline]:
    return [g for g in GUIDELINES if g.op == op]


def paper_coverage() -> dict[str, str]:
    """GL id -> mock-up name; asserts the full GL1..GL22 catalog is present
    (GL20 is the only scan guideline; GL4/8/12/16/18/22 are the padded
    irregular emulations, see DESIGN.md §3)."""
    cov = {g.gl_id: g.mockup for g in PAPER_GUIDELINES}
    missing = [f"GL{k}" for k in range(1, 23) if f"GL{k}" not in cov]
    if missing:
        raise AssertionError(f"guideline catalog incomplete: {missing}")
    return cov
