"""Collective implementations: defaults + guideline mock-ups (GL1-GL22 + ⊕).

This is the PGMPITuneLib mock-up catalog re-derived for the ``jax.lax``
collective vocabulary (see DESIGN.md §3).  Every function here operates on
*per-shard* arrays inside ``shard_map`` (or ``vmap(axis_name=...)`` in the
semantic tests) and communicates over a single named mesh axis.

Conventions (axis size ``p``, per-shard payload ``n`` rows along dim 0):

=============== =============================== ===========================
op              input (per shard)               output (per shard)
=============== =============================== ===========================
allgather       ``[n, ...]``                    ``[p*n, ...]``
allreduce       ``[n, ...]``                    ``[n, ...]`` (sum over axis)
reducescatter   ``[p*n, ...]``                  ``[n, ...]``
alltoall        ``[p*n, ...]``                  ``[p*n, ...]``
bcast           ``[n, ...]``                    ``[n, ...]`` (root's values)
gather          ``[n, ...]``                    ``[p*n, ...]`` (valid on root)
scatter         ``[p*n, ...]`` (valid on root)  ``[n, ...]``
reduce          ``[n, ...]``                    ``[n, ...]`` (valid on root)
scan            ``[n, ...]``                    inclusive prefix over ranks
exscan          ``[n, ...]``                    exclusive prefix over ranks
=============== =============================== ===========================

Rooted collectives have no TPU/XLA primitive; their "default" is the
composition XLA itself would pick (documented per op).  "valid on root"
means only the root shard's output is part of the contract; non-root
shards may receive the full result (superset semantics) or zeros.

Irregular ("v") emulations attach the paper's ``2pI`` count/displacement
metadata as a real (tiny) collective kept alive through
``lax.optimization_barrier`` so its cost stays visible in the HLO.

MOCK-UPS CALL CONCRETE SUB-IMPLEMENTATIONS, NEVER THE DISPATCHER — exactly
as PGMPITuneLib mock-ups call ``PMPI_*`` (library defaults), not the
intercepted entry points.  This rules out recursive re-tuning.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

import repro._compat  # noqa: F401  (vmap rule for optimization_barrier)
from repro.core._axis import (axis_index, axis_size, pshift, ring_perm,
                              shift_perm)

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _n_rows(x) -> int:
    return int(x.shape[0])


def _pad_rows(x, n_pad: int):
    """Zero-pad dim 0 of ``x`` up to ``n_pad`` rows."""
    n = _n_rows(x)
    if n_pad == n:
        return x
    pad = [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def _one_hot_place(x, axis: str, scale: int = 1):
    """Place ``x`` at row-offset ``axis_index*n`` inside a ``p*n`` zero buffer
    (the paper's GL3/GL13 "p-times-larger send buffer").  Additive placement
    replaces the paper's MPI_BOR (identical result, MXU/float friendly)."""
    p = axis_size(axis)
    n = _n_rows(x)
    buf = jnp.zeros((p * n,) + x.shape[1:], x.dtype)
    idx = axis_index(axis)
    return lax.dynamic_update_slice(buf, x, (idx * n,) + (0,) * (x.ndim - 1))


def _v_metadata(x, axis: str):
    """The irregular-collective count/displacement exchange: ``2p`` ints of
    metadata all-gathered over the axis (Table 1's ``2pI`` term)."""
    n = _n_rows(x)
    meta = jnp.stack(  # (count, displ)
        [jnp.int32(n), (n * axis_index(axis)).astype(jnp.int32)])
    return lax.all_gather(meta, axis, axis=0, tiled=True)


def _attach(y, meta):
    """Keep the metadata exchange alive in the HLO (prevent DCE) without
    touching the payload values."""
    y, _ = lax.optimization_barrier((y, meta))
    return y


def _rel(idx, root: int, p: int):
    """Rank relative to a static root (binomial schedules)."""
    if root == 0:
        return idx
    return (idx - root) % p


def _abs_perm(rel_pairs, root: int, p: int):
    """Map relative-rank (src, dst) pairs to absolute ranks."""
    if root == 0:
        return rel_pairs
    return [((s + root) % p, (d + root) % p) for (s, d) in rel_pairs]


def _is_pow2(p: int) -> bool:
    return p & (p - 1) == 0


# ---------------------------------------------------------------------------
# defaults (what an untuned lowering would emit)
# ---------------------------------------------------------------------------


def allgather_default(x, axis: str, *, inner_axis: str | None = None, **_):
    """Flat: one ``all_gather``.  Hierarchical (``inner_axis`` set): the
    untuned two-axis lowering — gather the intra tier, then the inter
    tier, yielding outer-major block order (what one flat gather over the
    joint ``(axis, inner_axis)`` group produces)."""
    if inner_axis is not None:
        x = lax.all_gather(x, inner_axis, axis=0, tiled=True)
    return lax.all_gather(x, axis, axis=0, tiled=True)


def allreduce_default(x, axis: str, *, inner_axis: str | None = None, **_):
    return lax.psum(x, axis if inner_axis is None else (axis, inner_axis))


def reducescatter_default(x, axis: str, *, inner_axis: str | None = None,
                          **_):
    y = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    if inner_axis is not None:
        y = lax.psum_scatter(y, inner_axis, scatter_dimension=0, tiled=True)
    return y


def alltoall_default(x, axis: str, **_):
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)


def bcast_as_psum(x, axis: str, *, root: int = 0, **_):
    """XLA's canonical broadcast-from-root: select + all-reduce."""
    idx = axis_index(axis)
    return lax.psum(jnp.where(idx == root, x, jnp.zeros_like(x)), axis)


def gather_as_allgather(x, axis: str, *, root: int = 0, **_):
    """(GL11) root-gather served by all-gather; non-roots get a superset."""
    del root
    return lax.all_gather(x, axis, axis=0, tiled=True)


def scatter_as_alltoall(x, axis: str, *, root: int = 0, **_):
    """Default scatter: mask non-root buffers, all-to-all, keep segment root.
    Single primitive; moves p*n where a tree scatter moves n*(p-1)/p·log p."""
    idx = axis_index(axis)
    xz = jnp.where(idx == root, x, jnp.zeros_like(x))
    y = lax.all_to_all(xz, axis, split_axis=0, concat_axis=0, tiled=True)
    n = _n_rows(x) // axis_size(axis)
    return lax.slice_in_dim(y, root * n, (root + 1) * n, axis=0)


def reduce_as_allreduce(x, axis: str, *, root: int = 0, **_):
    """(GL14) rooted reduce served by psum; non-roots ignore the result."""
    del root
    return lax.psum(x, axis)


def scan_default(x, axis: str, *, op: str = "add", **_):
    """Inclusive prefix over ranks — Hillis–Steele with log2(p) ppermutes."""
    p = axis_size(axis)
    idx = axis_index(axis)
    y = x
    d = 1
    while d < p:
        shifted = pshift(y, axis, shift_perm(p, d))
        if op == "add":
            y = y + shifted  # ppermute zero-fill is the additive identity
        elif op == "max":
            y = jnp.where(idx >= d, jnp.maximum(y, shifted), y)
        else:
            raise ValueError(f"unsupported scan op {op!r}")
        d *= 2
    return y


def exscan_default(x, axis: str, *, op: str = "add", **_):
    """Exclusive prefix: shift inputs one rank up, then inclusive scan."""
    p = axis_size(axis)
    shifted = pshift(x, axis, shift_perm(p, 1))
    if op == "max":
        idx = axis_index(axis)
        neg = jnp.full_like(x, -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating)
                            else jnp.iinfo(x.dtype).min)
        shifted = jnp.where(idx == 0, neg, shifted)
    return scan_default(shifted, axis, op=op)


# ---------------------------------------------------------------------------
# MPI_Allgather mock-ups
# ---------------------------------------------------------------------------


def allgather_as_gather_bcast(x, axis: str, **_):
    """(GL1) Gather + Bcast."""
    g = gather_as_allgather(x, axis, root=0)
    return bcast_as_psum(g, axis, root=0)


def allgather_as_alltoall(x, axis: str, **_):
    """(GL2) p-times replicated send buffer, then all-to-all."""
    p = axis_size(axis)
    reps = (p,) + (1,) * (x.ndim - 1)
    big = jnp.tile(x, reps)
    return lax.all_to_all(big, axis, split_axis=0, concat_axis=0, tiled=True)


def allgather_as_allreduce(x, axis: str, **_):
    """(GL3) one-hot placement into a p·n zero buffer, then all-reduce."""
    return lax.psum(_one_hot_place(x, axis), axis)


def allgather_as_allgatherv(x, axis: str, **_):
    """(GL4) irregular emulation: counts/displs metadata + padded gather."""
    meta = _v_metadata(x, axis)
    y = lax.all_gather(x, axis, axis=0, tiled=True)
    return _attach(y, meta)


def allgather_as_ring(x, axis: str, **_):
    """(⊕) (p-1)-step neighbour ring — ICI-local traffic only (the
    BlueGene/Q-style topology-native schedule the paper could not inject)."""
    p = axis_size(axis)
    n = _n_rows(x)
    idx = axis_index(axis)
    buf = _one_hot_place(x, axis)
    cur = x
    for s in range(1, p):
        cur = pshift(cur, axis, ring_perm(p, 1))
        src = (idx - s) % p  # originating rank of the block received now
        buf = lax.dynamic_update_slice(
            buf, cur, (src * n,) + (0,) * (x.ndim - 1))
    return buf


def allgather_as_doubling(x, axis: str, **_):
    """(⊕) recursive doubling: log2(p) rounds, partner i XOR d.  Requires a
    power-of-two axis; the registry guards this."""
    p = axis_size(axis)
    assert _is_pow2(p), "recursive doubling needs power-of-two axis"
    buf = _one_hot_place(x, axis)
    d = 1
    while d < p:
        pairs = [(i, i ^ d) for i in range(p)]
        buf = buf + pshift(buf, axis, pairs)
        d *= 2
    return buf


# ---------------------------------------------------------------------------
# MPI_Allreduce mock-ups
# ---------------------------------------------------------------------------


def allreduce_as_reduce_bcast(x, axis: str, **_):
    """(GL5) Reduce + Bcast through the library defaults."""
    r = reduce_as_allreduce(x, axis, root=0)
    return bcast_as_psum(r, axis, root=0)


def allreduce_as_tree_reduce_bcast(x, axis: str, **_):
    """(⊕/GL5-variant) binomial-tree Reduce + binomial-tree Bcast — the
    schedule an MPI library's 'nonoverlapping' algorithm uses (Fig. 7)."""
    r = reduce_as_tree(x, axis, root=0)
    return bcast_as_tree(r, axis, root=0)


def allreduce_as_rsb_allgather(x, axis: str, **_):
    """(GL6) Reduce_scatter_block + Allgather (ring / Rabenseifner).  Pads
    n up to a multiple of p (the paper's "small c for padding")."""
    p = axis_size(axis)
    n = _n_rows(x)
    n_pad = -(-n // p) * p
    xp = _pad_rows(x, n_pad)
    rs = lax.psum_scatter(xp, axis, scatter_dimension=0, tiled=True)
    y = lax.all_gather(rs, axis, axis=0, tiled=True)
    return lax.slice_in_dim(y, 0, n, axis=0)


def allreduce_as_rs_allgatherv(x, axis: str, *, chunk: int = 1, **_):
    """(GL7) Reduce_scatter + Allgatherv with round-robin chunks of size
    ``chunk`` (the paper's C) — the Fig.-7 winner.  Emulated with chunk-
    aligned padding + the 2pI metadata exchange."""
    p = axis_size(axis)
    n = _n_rows(x)
    c = max(1, min(int(chunk), n))
    k = -(-(-(-n // c)) // p)  # ceil(ceil(n/c)/p) chunks per rank
    n_pad = p * k * c
    xp = _pad_rows(x, n_pad)
    meta = _v_metadata(x, axis)
    rs = lax.psum_scatter(xp, axis, scatter_dimension=0, tiled=True)
    y = lax.all_gather(rs, axis, axis=0, tiled=True)
    return _attach(lax.slice_in_dim(y, 0, n, axis=0), meta)


def allreduce_as_doubling(x, axis: str, **_):
    """(⊕) recursive-doubling all-reduce: log2(p)·(α + nβ) — latency-optimal
    for small payloads where the ring's 2(p-1)α dominates."""
    p = axis_size(axis)
    assert _is_pow2(p), "recursive doubling needs power-of-two axis"
    y = x
    d = 1
    while d < p:
        y = y + pshift(y, axis, [(i, i ^ d) for i in range(p)])
        d *= 2
    return y


# ---------------------------------------------------------------------------
# MPI_Alltoall mock-ups
# ---------------------------------------------------------------------------


def alltoall_as_alltoallv(x, axis: str, **_):
    """(GL8) irregular emulation: metadata + padded all-to-all."""
    meta = _v_metadata(x, axis)
    y = lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)
    return _attach(y, meta)


def alltoall_as_ppermute(x, axis: str, **_):
    """(⊕) (p-1) shifted-ring rounds; latency-regime alternative to the
    bisection-limited monolithic all-to-all."""
    p = axis_size(axis)
    n = _n_rows(x) // p
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    out = jnp.zeros_like(x)
    # my own chunk stays in place
    own = lax.dynamic_slice(x, (idx * n,) + zeros, (n,) + x.shape[1:])
    out = lax.dynamic_update_slice(out, own, (idx * n,) + zeros)
    for s in range(1, p):
        dst = (idx + s) % p
        piece = lax.dynamic_slice(x, (dst * n,) + zeros, (n,) + x.shape[1:])
        recv = pshift(piece, axis, ring_perm(p, s))
        src = (idx - s) % p
        out = lax.dynamic_update_slice(out, recv, (src * n,) + zeros)
    return out


# ---------------------------------------------------------------------------
# MPI_Bcast mock-ups
# ---------------------------------------------------------------------------


def bcast_as_allgatherv(x, axis: str, *, root: int = 0, **_):
    """(GL9) root contributes n, everyone else 0, via allgatherv: emulated as
    masked all-gather + static segment select + metadata."""
    idx = axis_index(axis)
    n = _n_rows(x)
    xz = jnp.where(idx == root, x, jnp.zeros_like(x))
    meta = _v_metadata(x, axis)
    y = lax.all_gather(xz, axis, axis=0, tiled=True)
    return _attach(lax.slice_in_dim(y, root * n, (root + 1) * n, axis=0), meta)


def bcast_as_scatter_allgather(x, axis: str, *, root: int = 0, **_):
    """(GL10) Scatter + Allgather (van de Geijn) — bandwidth-optimal large-
    message broadcast.  Pads n to a multiple of p."""
    p = axis_size(axis)
    n = _n_rows(x)
    n_pad = -(-n // p) * p
    xp = _pad_rows(x, n_pad)
    sc = scatter_as_alltoall(xp, axis, root=root)
    y = lax.all_gather(sc, axis, axis=0, tiled=True)
    return lax.slice_in_dim(y, 0, n, axis=0)


def bcast_as_tree(x, axis: str, *, root: int = 0, **_):
    """(⊕) binomial-tree broadcast: ceil(log2 p) ppermute rounds."""
    p = axis_size(axis)
    idx = axis_index(axis)
    y = jnp.where(idx == root, x, jnp.zeros_like(x))
    d = 1
    while d < p:
        rel_pairs = [(r, r + d) for r in range(d) if r + d < p]
        y = y + pshift(y, axis, _abs_perm(rel_pairs, root, p))
        d *= 2
    return y


# ---------------------------------------------------------------------------
# MPI_Gather mock-ups
# ---------------------------------------------------------------------------


def gather_as_gatherv(x, axis: str, *, root: int = 0, **_):
    """(GL12) irregular emulation: metadata + gather; non-roots zeroed to
    keep rooted semantics observable."""
    meta = _v_metadata(x, axis)
    y = lax.all_gather(x, axis, axis=0, tiled=True)
    idx = axis_index(axis)
    y = jnp.where(idx == root, y, jnp.zeros_like(y))
    return _attach(y, meta)


def gather_as_reduce(x, axis: str, *, root: int = 0, **_):
    """(GL13) one-hot placement + rooted reduce (additive ≡ the paper's BOR
    on disjoint supports)."""
    return reduce_as_allreduce(_one_hot_place(x, axis), axis, root=root)


def gather_as_tree(x, axis: str, *, root: int = 0, **_):
    """(⊕) binomial-tree gather on a p·n zero-merged buffer."""
    p = axis_size(axis)
    idx = axis_index(axis)
    rel = _rel(idx, root, p)
    del rel  # merge is positional; masking handled by zero-fill
    y = _one_hot_place(x, axis)
    d = 1
    while d < p:
        rel_pairs = [(r + d, r) for r in range(0, p, 2 * d) if r + d < p]
        y = y + pshift(y, axis, _abs_perm(rel_pairs, root, p))
        d *= 2
    return y


# ---------------------------------------------------------------------------
# MPI_Reduce mock-ups
# ---------------------------------------------------------------------------


def reduce_as_rsb_gather(x, axis: str, *, root: int = 0, **_):
    """(GL15) Reduce_scatter_block + Gather (padded)."""
    p = axis_size(axis)
    n = _n_rows(x)
    n_pad = -(-n // p) * p
    xp = _pad_rows(x, n_pad)
    rs = lax.psum_scatter(xp, axis, scatter_dimension=0, tiled=True)
    y = gather_as_allgather(rs, axis, root=root)
    return lax.slice_in_dim(y, 0, n, axis=0)


def reduce_as_rs_gatherv(x, axis: str, *, root: int = 0, chunk: int = 1, **_):
    """(GL16) chunked Reduce_scatter + Gatherv (paper's C, metadata cost)."""
    p = axis_size(axis)
    n = _n_rows(x)
    c = max(1, min(int(chunk), n))
    k = -(-(-(-n // c)) // p)
    n_pad = p * k * c
    xp = _pad_rows(x, n_pad)
    meta = _v_metadata(x, axis)
    rs = lax.psum_scatter(xp, axis, scatter_dimension=0, tiled=True)
    y = gather_as_allgather(rs, axis, root=root)
    return _attach(lax.slice_in_dim(y, 0, n, axis=0), meta)


def reduce_as_tree(x, axis: str, *, root: int = 0, **_):
    """(⊕) binomial-tree reduce to root: log2(p) rounds."""
    p = axis_size(axis)
    y = x
    d = 1
    while d < p:
        rel_pairs = [(r + d, r) for r in range(0, p, 2 * d) if r + d < p]
        y = y + pshift(y, axis, _abs_perm(rel_pairs, root, p))
        d *= 2
    return y


# ---------------------------------------------------------------------------
# MPI_Reduce_scatter_block mock-ups
# ---------------------------------------------------------------------------


def rsb_as_reduce_scatter(x, axis: str, **_):
    """(GL17) Reduce + Scatter through the defaults."""
    r = reduce_as_allreduce(x, axis, root=0)
    return scatter_as_alltoall(r, axis, root=0)


def rsb_as_reduce_scatter_irr(x, axis: str, **_):
    """(GL18) irregular reduce_scatter emulation: metadata + psum_scatter."""
    meta = _v_metadata(x, axis)
    y = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    return _attach(y, meta)


def rsb_as_allreduce(x, axis: str, **_):
    """(GL19) Allreduce + keep my block."""
    p = axis_size(axis)
    n = _n_rows(x) // p
    y = lax.psum(x, axis)
    idx = axis_index(axis)
    return lax.dynamic_slice(
        y, (idx * n,) + (0,) * (x.ndim - 1), (n,) + x.shape[1:])


# ---------------------------------------------------------------------------
# MPI_Scan mock-ups
# ---------------------------------------------------------------------------


def scan_as_exscan_reducelocal(x, axis: str, *, op: str = "add", **_):
    """(GL20) Exscan + local reduction."""
    ex = exscan_default(x, axis, op=op)
    if op == "add":
        return ex + x
    if op == "max":
        return jnp.maximum(ex, x)
    raise ValueError(f"unsupported scan op {op!r}")


# ---------------------------------------------------------------------------
# MPI_Scatter mock-ups
# ---------------------------------------------------------------------------


def scatter_as_bcast(x, axis: str, *, root: int = 0, **_):
    """(GL21) Bcast the whole buffer + local slice."""
    p = axis_size(axis)
    n = _n_rows(x) // p
    y = bcast_as_psum(x, axis, root=root)
    idx = axis_index(axis)
    return lax.dynamic_slice(
        y, (idx * n,) + (0,) * (x.ndim - 1), (n,) + x.shape[1:])


def scatter_as_scatterv(x, axis: str, *, root: int = 0, **_):
    """(GL22) irregular emulation: metadata + scatter."""
    meta = _v_metadata(x, axis)
    return _attach(scatter_as_alltoall(x, axis, root=root), meta)


def scatter_as_tree(x, axis: str, *, root: int = 0, **_):
    """(⊕) binomial-tree scatter: root halves its range every round."""
    p = axis_size(axis)
    assert _is_pow2(p), "tree scatter needs power-of-two axis"
    n = _n_rows(x) // p
    idx = axis_index(axis)
    rel = _rel(idx, root, p)
    zeros = (0,) * (x.ndim - 1)
    # rotate into relative-rank layout so tree ranges stay contiguous;
    # rank rel r finally reads chunk (r+root)%p == its absolute chunk.
    y = jnp.roll(x, -root * n, axis=0)
    y = jnp.where(idx == root, y, jnp.zeros_like(y))
    d = p // 2
    while d >= 1:
        rel_pairs = [(r, r + d) for r in range(0, p, 2 * d)]
        send = lax.dynamic_slice(
            y, ((rel + d) % p * n,) + zeros, (d * n,) + x.shape[1:])
        recv = pshift(send, axis, _abs_perm(rel_pairs, root, p))
        keep = lax.dynamic_slice(y, (rel * n,) + zeros, (d * n,) + x.shape[1:])
        y = lax.dynamic_update_slice(y, keep + recv, (rel * n,) + zeros)
        d //= 2
    return lax.dynamic_slice(y, (rel * n,) + zeros, (n,) + x.shape[1:])


# ---------------------------------------------------------------------------
# fused collective-matmul ops (latency-hiding mock-ups, kernels/)
# ---------------------------------------------------------------------------
#
# Three extra ops extend the vocabulary beyond MPI's: a matmul fused to the
# collective feeding (or consuming) it.  Semantics (the second operand is
# passed by keyword; per-shard shapes, axis size ``p``):
#
#   allgather_matmul       x [n, K], w [K, M]     -> all_gather(x) @ w [p*n, M]
#   matmul_reducescatter   x [p*n, K], w [K, M]   -> reduce_scatter(x @ w) [n, M]
#   matmul_accumulate      w [K/p, M], x [T, K]   -> x @ all_gather(w) [T, M]
#   matmul_reducescatter_2d
#       w [K, M/d] over ag axis (size d), x [T, K], rs axis (size q)
#       -> reduce_scatter(x @ all_gather(w, cols, ag), rows, rs) [T/q, M]
#       (xpose=True: g [T/q, M] over ag axis, x [T, K]
#        -> reduce_scatter(all_gather(g, rows, ag)T @ x, rows, rs) [M/d, K])
#
# ``default`` is the unfused composition today's dist/ops emit; ``fused_ring``
# (and ``fused_ring2d`` for the two-axis op) is the
# kernels/collective_matmul.py ring schedule that overlaps each chunk's
# transfer with the previous chunk's matmul.  The tuner arbitrates the two via
# the overlap-aware cost model (max(comm, compute) per step instead of sum).
# Note ``matmul_accumulate`` and ``matmul_reducescatter_2d`` take the
# STREAMED operand (the K-dim / column-block weight shard, or the xpose
# cotangent shard) first — the dispatcher keys on the bytes the collective
# moves over its OUTER axis.


def allgather_matmul_default(x, axis: str, *, w, return_gathered: bool = False,
                             **_):
    """Unfused composition: all_gather then one dense matmul."""
    g = lax.all_gather(x, axis, axis=0, tiled=True)
    out = jnp.matmul(g, w)
    return (out, g) if return_gathered else out


def allgather_matmul_fused_ring(x, axis: str, *, w,
                                return_gathered: bool = False, **_):
    """(⊕) ring allgather-matmul: chunk s+1 in flight while chunk s is on
    the MXU.  The backend check lives HERE (not at callsites): on TPU the
    tier-3 in-kernel RDMA ring is used; everywhere else the ppermute
    reference ring — CPU CI never even imports the RDMA module."""
    from repro.kernels import collective_matmul as cmm
    if cmm.on_tpu():
        from repro.kernels import collective_matmul_rdma as rdma
        return rdma.ring_allgather_matmul_rdma(
            x, w, axis, return_gathered=return_gathered)
    return cmm.ring_allgather_matmul(x, w, axis,
                                     return_gathered=return_gathered)


def matmul_reducescatter_default(x, axis: str, *, w, **_):
    """Unfused composition: one dense matmul then reduce-scatter."""
    return lax.psum_scatter(jnp.matmul(x, w), axis, scatter_dimension=0,
                            tiled=True)


def matmul_reducescatter_fused_ring(x, axis: str, *, w, **_):
    """(⊕) ring matmul-reducescatter: the travelling accumulator is in
    flight while the next block's contribution is computed."""
    from repro.kernels import collective_matmul as cmm
    return cmm.ring_matmul_reducescatter(x, w, axis)


def matmul_accumulate_default(w, axis: str, *, x,
                              return_gathered: bool = False, **_):
    """Unfused composition: all_gather the K-dim weight shards, then one
    dense matmul over the full contraction."""
    full = lax.all_gather(w, axis, axis=0, tiled=True)
    out = jnp.matmul(x, full)
    return (out, full) if return_gathered else out


def matmul_accumulate_fused_ring(w, axis: str, *, x,
                                 return_gathered: bool = False, **_):
    """(⊕) accumulate ring: weight block s+1 in flight while block s's
    partial product accumulates (kernels/collective_matmul.py)."""
    from repro.kernels import collective_matmul as cmm
    return cmm.ring_matmul_accumulate(x, w, axis,
                                      return_gathered=return_gathered)


def matmul_reducescatter_2d_default(w, axis: str, *, x, rs_axis: str,
                                    xpose: bool = False,
                                    return_gathered: bool = False, **_):
    """Unfused 2-D composition: gather the streamed operand over ``axis``
    (the outer axis the dispatcher keys on), one dense matmul, then
    reduce-scatter the output rows over ``rs_axis``.

    ``xpose=False``: w ``[K, m_loc]`` col-gathered -> psum_scatter(x @ W).
    ``xpose=True``: the payload is the cotangent shard g ``[t_loc, M]``
    row-gathered and CONTRACTED -> psum_scatter(Gᵀ @ x) — the transpose
    schedule of the paired VJP.
    """
    if xpose:
        full = lax.all_gather(w, axis, axis=0, tiled=True)
        return lax.psum_scatter(jnp.matmul(jnp.swapaxes(full, 0, 1), x),
                                rs_axis, scatter_dimension=0, tiled=True)
    full = lax.all_gather(w, axis, axis=1, tiled=True)
    out = lax.psum_scatter(jnp.matmul(x, full), rs_axis,
                           scatter_dimension=0, tiled=True)
    return (out, full) if return_gathered else out


def matmul_reducescatter_2d_fused_ring(w, axis: str, *, x, rs_axis: str,
                                       xpose: bool = False,
                                       return_gathered: bool = False, **_):
    """(⊕) nested 2-D ring: outer weight (or cotangent) stream over
    ``axis``, inner matmul-reducescatter (or contract-stream) over
    ``rs_axis``, issue-before-consume on both axes
    (kernels/collective_matmul.py)."""
    from repro.kernels import collective_matmul as cmm
    if xpose:
        return cmm.ring_matmul_reducescatter_2d_t(w, x, rs_axis, axis)
    return cmm.ring_matmul_reducescatter_2d(
        x, w, rs_axis, axis, return_gathered=return_gathered)


# ---------------------------------------------------------------------------
# quantized-wire mock-ups (wire_q8 / wire_fp8): the ring schedules with the
# travelling operand compressed to an 8-bit wire dtype + per-block scales
# (kernels/quant.py).  Quantize-on-send, dequantize-on-receive, reductions
# accumulate in f32 after dequant.  These are APPROXIMATE impls: their
# admissibility is gated by the selfcheck numeric-tolerance check (a cell
# that breaks its wire tolerance demotes the impl via ``demote`` below,
# exactly like a failed guideline).
# ---------------------------------------------------------------------------


def allgather_wire(x, axis: str, *, wire_dtype: str = "int8", **_):
    """(⊕) ring allgather over the quantized wire: each rank's chunk is
    quantized ONCE at its origin and the (values, scales) pair travels the
    ring unchanged; the own chunk never crosses the wire and stays exact."""
    from repro.kernels import quant as Q
    p = axis_size(axis)
    if p == 1:
        return x
    n = _n_rows(x)
    idx = axis_index(axis)
    zeros = (0,) * (x.ndim - 1)
    out = jnp.zeros((p * n,) + x.shape[1:], x.dtype)
    out = lax.dynamic_update_slice(out, x, (idx * n,) + zeros)
    q, sc = Q.quantize(x, wire_dtype)
    for s in range(1, p):
        q = pshift(q, axis, ring_perm(p, 1))
        sc = pshift(sc, axis, ring_perm(p, 1))
        src = (idx - s) % p
        out = lax.dynamic_update_slice(out, Q.dequantize(q, sc, x.dtype),
                                       (src * n,) + zeros)
    return out


def reducescatter_wire(x, axis: str, *, wire_dtype: str = "int8", **_):
    """(⊕) ring reduce-scatter over the quantized wire: the travelling
    accumulator is requantized before every hop; local contributions are
    added to the DEQUANTIZED f32 accumulator (accumulate-in-f32 rule)."""
    from repro.kernels import quant as Q
    p = axis_size(axis)
    if p == 1:
        return x
    rows = _n_rows(x)
    n = rows // p
    idx = axis_index(axis)
    acc = None
    for s in range(p):
        blk_id = (idx + (p - 1 - s)) % p
        blk = lax.dynamic_slice(x, (blk_id * n,) + (0,) * (x.ndim - 1),
                                (n,) + x.shape[1:])
        contrib = blk.astype(jnp.float32)
        acc = contrib if acc is None else acc + contrib
        if s < p - 1:
            q, sc = Q.quantize(acc, wire_dtype)
            q = pshift(q, axis, ring_perm(p, 1))
            sc = pshift(sc, axis, ring_perm(p, 1))
            acc = Q.dequantize(q, sc, jnp.float32)
    return acc.astype(x.dtype)


def allreduce_wire(x, axis: str, *, wire_dtype: str = "int8", **_):
    """(⊕) quantized-wire allreduce = padded wire reduce-scatter + wire
    allgather (the GL6 decomposition with both phases on the 8-bit wire)."""
    p = axis_size(axis)
    if p == 1:
        return x
    n = _n_rows(x)
    k = -(-n // p)
    xp = _pad_rows(x, k * p)
    red = reducescatter_wire(xp, axis, wire_dtype=wire_dtype)
    out = allgather_wire(red, axis, wire_dtype=wire_dtype)
    return out[:n] if out.shape[0] != n else out


def allgather_matmul_wire(x, axis: str, *, w, wire_dtype: str = "int8",
                          return_gathered: bool = False, **_):
    """(⊕) ring allgather-matmul with the activation chunk on the
    quantized wire (kernels/collective_matmul.py tier-1c)."""
    from repro.kernels import collective_matmul as cmm
    return cmm.ring_allgather_matmul_wire(
        x, w, axis, wire_dtype=wire_dtype, return_gathered=return_gathered)


def matmul_reducescatter_wire(x, axis: str, *, w, wire_dtype: str = "int8",
                              **_):
    """(⊕) ring matmul-reducescatter with the travelling accumulator on
    the quantized wire (requantized per hop, f32 accumulate)."""
    from repro.kernels import collective_matmul as cmm
    return cmm.ring_matmul_reducescatter_wire(x, w, axis,
                                              wire_dtype=wire_dtype)


def matmul_accumulate_wire(w, axis: str, *, x, wire_dtype: str = "int8",
                           return_gathered: bool = False, **_):
    """(⊕) accumulate ring with the weight block on the quantized wire."""
    from repro.kernels import collective_matmul as cmm
    return cmm.ring_matmul_accumulate_wire(
        x, w, axis, wire_dtype=wire_dtype, return_gathered=return_gathered)


# ---------------------------------------------------------------------------
# hierarchical (two-tier) mock-ups — MPIX_* extension family.
#
# These are the ONLY impls that take a second axis: ``axis`` is the OUTER
# (inter-tier, slow) axis and ``inner_axis`` the INNER (intra-tier, fast)
# one.  They decompose a joint-group collective into per-tier ring stages
# (kernels/hierarchical.py) so the bulk of the bytes stay on the fast
# tier; admissibility is gated on ``Impl.hier`` — a flat mock-up must
# never be offered a two-axis cell (it would silently reduce over one
# axis only), and a hier mock-up is meaningless on a flat cell.
# ---------------------------------------------------------------------------


def _need_inner(name: str, inner_axis):
    if inner_axis is None:
        raise ValueError(
            f"{name} is a hierarchical mock-up: it needs inner_axis= "
            "(the intra-tier axis) in addition to the outer axis")


def allreduce_hier(x, axis: str, *, inner_axis: str | None = None, **_):
    """(⊕ MPIX_rs_ar_ag) RS-intra → AR-inter → AG-intra."""
    _need_inner("MPIX_rs_ar_ag", inner_axis)
    from repro.kernels import hierarchical as H
    return H.hier_allreduce(x, axis, inner_axis)


def allgather_hier(x, axis: str, *, inner_axis: str | None = None, **_):
    """(⊕ MPIX_ag_ag) AG-intra → AG-inter (outer-major block order)."""
    _need_inner("MPIX_ag_ag", inner_axis)
    from repro.kernels import hierarchical as H
    return H.hier_allgather(x, axis, inner_axis)


def reducescatter_hier(x, axis: str, *, inner_axis: str | None = None, **_):
    """(⊕ MPIX_rs_rs) RS-inter → RS-intra (the MPIX_ag_ag dual)."""
    _need_inner("MPIX_rs_rs", inner_axis)
    from repro.kernels import hierarchical as H
    return H.hier_reduce_scatter(x, axis, inner_axis)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Impl:
    """One algorithm for one logical collective."""
    name: str
    op: str
    fn: Callable
    guideline: str | None  # "GL<k>", "EXT" (⊕), or None for the default
    # extra scratch bytes(payload_bytes, p) — the Table-1 memory model.
    extra_bytes: Callable[[int, int], int]
    requires_pow2: bool = False
    desc: str = ""
    # wire dtype of a quantized-wire mock-up ("int8" / "float8_e4m3fn");
    # None = the wire carries the compute dtype.  Non-None marks the impl
    # accuracy-conditional: selfcheck's tolerance gate may demote it.
    wire_dtype: str | None = None
    # True for the two-axis (hierarchical) mock-ups: the impl REQUIRES
    # ``inner_axis=`` and is only admissible on hierarchical cells
    # (``OpCell.hier``); flat impls are only admissible on flat cells.
    # The default impl handles both worlds itself.
    hier: bool = False

    def __call__(self, x, axis, **kw):
        return self.fn(x, axis, **kw)


_I = 4  # extent of an int32 "MPI_INT" (Table 1's I)


def _nb0(nbytes: int, p: int) -> int:  # no extra memory
    del nbytes, p
    return 0


def _reg() -> dict[str, dict[str, Impl]]:
    def mk(name, op, fn, gl, extra, pow2=False, desc="", wire=None,
           hier=False):
        return Impl(name, op, fn, gl, extra, pow2, desc, wire, hier)

    # quantized-wire mock-ups share one family shape: MPIX_-style name
    # (wire_q8 / wire_fp8 — the MPIX_ prefix marks a beyond-the-standard
    # extension, like MPIX_Allreduce_q8), EXT guideline, wire_dtype bound
    # via partial and recorded on the Impl for the costmodel / selfcheck.
    _WIRES = (("wire_q8", "int8"), ("wire_fp8", "float8_e4m3fn"))

    def mk_wire(op, fn, extra, desc):
        return [mk(nm, op, partial(fn, wire_dtype=wd), "EXT", extra,
                   desc=f"MPIX_{op}_{nm[5:]}: {desc}", wire=wd)
                for nm, wd in _WIRES]

    r: dict[str, dict[str, Impl]] = {}

    r["allgather"] = {i.name: i for i in [
        mk("default", "allgather", allgather_default, None, _nb0,
           desc="lax.all_gather (XLA ring)"),
        mk("allgather_as_gather_bcast", "allgather", allgather_as_gather_bcast,
           "GL1", _nb0),
        mk("allgather_as_alltoall", "allgather", allgather_as_alltoall,
           "GL2", lambda n, p: p * n, desc="p× larger send buffer"),
        mk("allgather_as_allreduce", "allgather", allgather_as_allreduce,
           "GL3", lambda n, p: p * n, desc="p× larger send buffer"),
        mk("allgather_as_allgatherv", "allgather", allgather_as_allgatherv,
           "GL4", lambda n, p: 2 * p * _I, desc="displs+recvcounts"),
        mk("allgather_as_ring", "allgather", allgather_as_ring,
           "EXT", lambda n, p: p * n),
        mk("allgather_as_doubling", "allgather", allgather_as_doubling,
           "EXT", lambda n, p: p * n, pow2=True),
        mk("MPIX_ag_ag", "allgather", allgather_hier, "EXT",
           lambda n, p: p * n, hier=True,
           desc="hierarchical AG-intra -> AG-inter: node block assembled "
                "on the fast tier, streamed across the slow tier once"),
        *mk_wire("allgather", allgather_wire,
                 lambda n, p: p * n + n // 2,
                 desc="ring with the chunk on the 8-bit wire "
                      "(quantized once at origin)"),
    ]}

    r["allreduce"] = {i.name: i for i in [
        mk("default", "allreduce", allreduce_default, None, _nb0,
           desc="lax.psum"),
        mk("allreduce_as_reduce_bcast", "allreduce", allreduce_as_reduce_bcast,
           "GL5", _nb0),
        mk("allreduce_as_tree_reduce_bcast", "allreduce",
           allreduce_as_tree_reduce_bcast, "EXT", _nb0,
           desc="binomial reduce+bcast ('nonoverlapping')"),
        mk("allreduce_as_rsb_allgather", "allreduce",
           allreduce_as_rsb_allgather, "GL6",
           lambda n, p: (n + p) + (n + p) // p, desc="padded RS + AG"),
        mk("allreduce_as_rs_allgatherv", "allreduce",
           allreduce_as_rs_allgatherv, "GL7",
           lambda n, p: max(n // p + 1, 1) + 2 * p * _I,
           desc="chunked RS + AGv (Fig.7 winner)"),
        mk("allreduce_as_doubling", "allreduce", allreduce_as_doubling,
           "EXT", _nb0, pow2=True, desc="recursive doubling (latency-opt)"),
        mk("MPIX_rs_ar_ag", "allreduce", allreduce_hier, "EXT",
           lambda n, p: n + max(n // p, 1), hier=True,
           desc="hierarchical RS-intra -> AR-inter -> AG-intra: full "
                "buffer only moves on the fast tier; 1/q of it crosses "
                "the slow tier"),
        *mk_wire("allreduce", allreduce_wire,
                 lambda n, p: (n + p) + (n + p) // p,
                 desc="padded wire RS + wire AG (GL6 shape, 8-bit wire)"),
    ]}

    r["alltoall"] = {i.name: i for i in [
        mk("default", "alltoall", alltoall_default, None, _nb0,
           desc="lax.all_to_all"),
        mk("alltoall_as_alltoallv", "alltoall", alltoall_as_alltoallv,
           "GL8", lambda n, p: 2 * p * _I),
        mk("alltoall_as_ppermute", "alltoall", alltoall_as_ppermute,
           "EXT", lambda n, p: n),
    ]}

    r["bcast"] = {i.name: i for i in [
        mk("default", "bcast", bcast_as_psum, None, _nb0,
           desc="select + all-reduce (XLA canonical)"),
        mk("bcast_as_allgatherv", "bcast", bcast_as_allgatherv,
           "GL9", lambda n, p: 2 * p * _I + n),
        mk("bcast_as_scatter_allgather", "bcast", bcast_as_scatter_allgather,
           "GL10", lambda n, p: (n + p) + (n + p) // p,
           desc="van de Geijn"),
        mk("bcast_as_tree", "bcast", bcast_as_tree, "EXT", _nb0,
           desc="binomial tree"),
    ]}

    r["gather"] = {i.name: i for i in [
        mk("default", "gather", gather_as_allgather, None,
           lambda n, p: p * n, desc="all_gather; non-roots superset"),
        mk("gather_as_allgather", "gather", gather_as_allgather,
           "GL11", lambda n, p: p * n),
        mk("gather_as_gatherv", "gather", gather_as_gatherv,
           "GL12", lambda n, p: 2 * p * _I),
        mk("gather_as_reduce", "gather", gather_as_reduce,
           "GL13", lambda n, p: p * n, desc="one-hot + reduce"),
        mk("gather_as_tree", "gather", gather_as_tree,
           "EXT", lambda n, p: p * n),
    ]}

    r["reduce"] = {i.name: i for i in [
        mk("default", "reduce", reduce_as_allreduce, None,
           lambda n, p: n, desc="psum; non-roots superset"),
        mk("reduce_as_allreduce", "reduce", reduce_as_allreduce,
           "GL14", lambda n, p: n),
        mk("reduce_as_rsb_gather", "reduce", reduce_as_rsb_gather,
           "GL15", lambda n, p: (n + p) + (n + p) // p),
        mk("reduce_as_rs_gatherv", "reduce", reduce_as_rs_gatherv,
           "GL16", lambda n, p: max(n // p + 1, 1) + 2 * p * _I),
        mk("reduce_as_tree", "reduce", reduce_as_tree, "EXT", _nb0),
    ]}

    r["reducescatter"] = {i.name: i for i in [
        mk("default", "reducescatter", reducescatter_default, None, _nb0,
           desc="lax.psum_scatter"),
        mk("rsb_as_reduce_scatter", "reducescatter", rsb_as_reduce_scatter,
           "GL17", lambda n, p: n, desc="reduce + scatter"),
        mk("rsb_as_reduce_scatter_irr", "reducescatter",
           rsb_as_reduce_scatter_irr, "GL18", lambda n, p: p * _I),
        mk("rsb_as_allreduce", "reducescatter", rsb_as_allreduce,
           "GL19", lambda n, p: n),
        mk("MPIX_rs_rs", "reducescatter", reducescatter_hier, "EXT",
           lambda n, p: 2 * max(n // p, 1), hier=True,
           desc="hierarchical RS-inter -> RS-intra (MPIX_ag_ag dual): "
                "slow tier reduces node blocks, fast tier finishes"),
        *mk_wire("reducescatter", reducescatter_wire,
                 lambda n, p: 2 * max(n // p, 1),
                 desc="ring with the travelling accumulator requantized "
                      "per hop (f32 accumulate)"),
    ]}

    r["scan"] = {i.name: i for i in [
        mk("default", "scan", scan_default, None, _nb0,
           desc="Hillis-Steele over ppermute"),
        mk("scan_as_exscan_reducelocal", "scan", scan_as_exscan_reducelocal,
           "GL20", _nb0),
    ]}

    r["exscan"] = {i.name: i for i in [
        mk("default", "exscan", exscan_default, None, _nb0),
    ]}

    r["allgather_matmul"] = {i.name: i for i in [
        mk("default", "allgather_matmul", allgather_matmul_default, None,
           lambda n, p: p * n, desc="all_gather then dense matmul (unfused)"),
        mk("fused_ring", "allgather_matmul", allgather_matmul_fused_ring,
           "EXT", lambda n, p: p * n + 2 * n,
           desc="ring overlap: chunk matmul while next chunk in flight"),
        *mk_wire("allgather_matmul", allgather_matmul_wire,
                 lambda n, p: p * n + 2 * n + n // 2,
                 desc="fused ring, activation chunk on the 8-bit wire"),
    ]}

    r["matmul_reducescatter"] = {i.name: i for i in [
        mk("default", "matmul_reducescatter", matmul_reducescatter_default,
           None, lambda n, p: n, desc="dense matmul then psum_scatter"),
        mk("fused_ring", "matmul_reducescatter",
           matmul_reducescatter_fused_ring, "EXT",
           lambda n, p: 2 * max(n // p, 1),
           desc="ring overlap: travelling accumulator hides matmul"),
        *mk_wire("matmul_reducescatter", matmul_reducescatter_wire,
                 lambda n, p: 2 * max(n // p, 1),
                 desc="fused ring, partial-product accumulator on the "
                      "8-bit wire (requantized per hop)"),
    ]}

    r["matmul_accumulate"] = {i.name: i for i in [
        mk("default", "matmul_accumulate", matmul_accumulate_default, None,
           lambda n, p: p * n,
           desc="all_gather K-dim weight then dense matmul (unfused)"),
        mk("fused_ring", "matmul_accumulate", matmul_accumulate_fused_ring,
           "EXT", lambda n, p: p * n + 2 * n,
           desc="ring overlap: weight block in flight while partials "
                "accumulate"),
        *mk_wire("matmul_accumulate", matmul_accumulate_wire,
                 lambda n, p: p * n + 2 * n + n // 2,
                 desc="fused ring, weight block on the 8-bit wire "
                      "(quantized once at origin)"),
    ]}

    r["matmul_reducescatter_2d"] = {i.name: i for i in [
        mk("default", "matmul_reducescatter_2d",
           matmul_reducescatter_2d_default, None,
           lambda n, p: p * n,
           desc="all_gather weight cols then dense matmul then psum_scatter"
                " (unfused 2-D composition)"),
        mk("fused_ring2d", "matmul_reducescatter_2d",
           matmul_reducescatter_2d_fused_ring, "EXT",
           lambda n, p: p * n + 2 * n,
           desc="nested rings: outer weight stream over the gather axis, "
                "inner matmul-reducescatter over the scatter axis"),
    ]}

    r["scatter"] = {i.name: i for i in [
        mk("default", "scatter", scatter_as_alltoall, None, _nb0,
           desc="masked all_to_all + segment select"),
        mk("scatter_as_bcast", "scatter", scatter_as_bcast,
           "GL21", lambda n, p: n, desc="bcast + local slice"),
        mk("scatter_as_scatterv", "scatter", scatter_as_scatterv,
           "GL22", lambda n, p: 2 * p * _I),
        mk("scatter_as_tree", "scatter", scatter_as_tree,
           "EXT", _nb0, pow2=True),
    ]}

    return r


REGISTRY: dict[str, dict[str, Impl]] = _reg()

OPS = tuple(REGISTRY.keys())

# ---------------------------------------------------------------------------
# demotion ledger: impls removed from the admissible set at runtime.
# A quantized-wire impl whose numeric error exceeds its wire tolerance on a
# representative payload (core/selfcheck.py) is demoted here and from then on
# is treated exactly like a failed guideline: api._select falls back to the
# default, api._admissible_impls / tuner skip it, plan vectors never carry
# it.  Process-local state, keyed (op, impl name).
# ---------------------------------------------------------------------------

_DEMOTED: dict[tuple[str, str], str] = {}


def demote(op: str, name: str, reason: str = "tolerance") -> None:
    """Remove ``(op, name)`` from the admissible set for this process."""
    if name == "default":
        raise ValueError("the default impl cannot be demoted")
    if name not in REGISTRY[op]:
        raise KeyError(f"unknown impl {op}.{name}")
    _DEMOTED[(op, name)] = reason


def is_demoted(op: str, name: str) -> bool:
    return (op, name) in _DEMOTED


def demotions() -> dict[tuple[str, str], str]:
    """Snapshot of the current demotion ledger (copy)."""
    return dict(_DEMOTED)


def clear_demotions() -> None:
    _DEMOTED.clear()


def get_impl(op: str, name: str | None = None) -> Impl:
    table = REGISTRY[op]
    return table[name or "default"]


def impl_names(op: str, *, include_default: bool = True) -> list[str]:
    names = list(REGISTRY[op].keys())
    if not include_default:
        names = [n for n in names if n != "default"]
    return names
