"""Measured-latency backend: ReproMPI's Algorithm 1 on JAX host devices.

Timing procedure (paper Algorithm 1): synchronize, t = now, run collective,
record t' - t.  The dissemination-barrier analogue here is a jitted 1-element
psum executed (and blocked on) before every sample; collectives themselves
are pre-compiled so only execution is timed.

Replay is keyed on the full ``OpCell``: a fused collective-matmul cell is
re-executed with the *recorded* GEMM — dtype and ``(mm_k, mm_m, mm_n)``
exactly as the callsite issued them — not a canonical square weight, so
wall-clock replay prices the actual matmul.  Fused cells without recorded
geometry (v1 traces) cannot be replayed; the tuner note-skips them.

This backend runs on whatever devices the process sees (CPU host devices in
this container).  Its absolute numbers are CPU-flavored; the tuner uses it to
validate *orderings* and to exercise the full offline-tuning pipeline, while
production-scale decisions use ``core.costmodel``.
"""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro._compat import shard_map

from repro.core import collectives as C
from repro.core.cell import OpCell

AXIS = "bench"

#: ops whose cells carry a fused-matmul geometry the replay must honor
MATMUL_OPS = ("allgather_matmul", "matmul_reducescatter", "matmul_accumulate")


@lru_cache(maxsize=1)
def _mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs, (AXIS,))


def axis_size() -> int:
    return _mesh().devices.size


def host_cell(op: str, nbytes: int, *, dtype: str = "float32",
              **geom) -> OpCell:
    """An ``OpCell`` at the axis size the host devices form (benchmarks)."""
    return OpCell(op, axis_size(), nbytes, dtype, **geom)


def problem_shapes(cell: OpCell) -> dict[str, tuple[int, ...]]:
    """Per-shard operand shapes the replay builds for ``cell`` — pure
    function of the cell, unit-testable without devices.

    ``x`` is the sharded operand (the collective payload), ``w`` the
    shard-local second operand of the fused ops (absent for plain
    collectives).  Fused shapes come from the RECORDED GEMM dims.
    """
    p = cell.p
    if cell.op in MATMUL_OPS:
        if not cell.fused:
            raise ValueError(
                f"cell {cell} has no recorded matmul geometry; a fused op "
                "cannot be replayed without it (v1 trace?)")
        if cell.op == "allgather_matmul":
            return {"x": (max(1, cell.mm_m // p), cell.mm_k),
                    "w": (cell.mm_k, cell.mm_n)}
        if cell.op == "matmul_reducescatter":
            rows = max(p, (cell.mm_m // p) * p)   # psum_scatter must divide
            return {"x": (rows, cell.mm_k), "w": (cell.mm_k, cell.mm_n)}
        # matmul_accumulate: the payload is the K-dim weight shard
        k_loc = max(1, cell.mm_k // p)
        return {"x": (k_loc, cell.mm_n), "w": (cell.mm_m, p * k_loc)}
    itemsize = cell.itemsize
    n_rows = max(1, cell.nbytes // itemsize)
    if cell.op in ("alltoall", "reducescatter", "scatter"):
        # v-style ops: nbytes is the per-chunk payload, input is p chunks
        n_rows *= p
    return {"x": (n_rows, 1)}


@lru_cache(maxsize=512)
def _compiled(cell: OpCell, impl: str):
    mesh = _mesh()
    p = mesh.devices.size
    if cell.p != p:
        raise ValueError(
            f"measured backend runs at p={p}, not {cell.p}")
    fn = C.REGISTRY[cell.op][impl].fn
    shapes = problem_shapes(cell)
    dt = jnp.dtype(cell.dtype if cell.dtype else "float32")

    if cell.op == "matmul_accumulate":
        # streamed operand = the weight shard; the stationary x is a
        # shard-local closure constant with the recorded [mm_m, mm_k]
        stat = jnp.ones(shapes["w"], dt)

        def body(wb):
            return fn(wb, AXIS, x=stat)
    elif cell.op in MATMUL_OPS:
        w = jnp.ones(shapes["w"], dt)

        def body(x):
            return fn(x, AXIS, w=w)
    else:
        def body(x):
            return fn(x, AXIS)

    sm = shard_map(body, mesh=mesh, in_specs=P(AXIS), out_specs=P(AXIS),
                   check_vma=False)
    spec = NamedSharding(mesh, P(AXIS))
    rows, width = shapes["x"]
    x = jax.device_put(jnp.ones((p * rows, width), dt), spec)
    return jax.jit(sm).lower(x).compile(), x


@lru_cache(maxsize=1)
def _barrier():
    mesh = _mesh()

    def body(x):
        return jax.lax.psum(x, AXIS)

    sm = shard_map(body, mesh=mesh, in_specs=P(AXIS), out_specs=P(),
                   check_vma=False)
    spec = NamedSharding(mesh, P(AXIS))
    x = jax.device_put(jnp.ones((mesh.devices.size,), jnp.float32), spec)
    return jax.jit(sm).lower(x).compile(), x


def sample_latency(cell: OpCell, impl: str, count: int,
                   *, barrier: bool = True) -> list[float]:
    """``count`` barrier-synced wall-clock samples of one cell (s)."""
    fn, x = _compiled(cell, impl)
    bar, bx = _barrier()
    # warm one execution so first-run allocation noise is out of the samples
    jax.block_until_ready(fn(x))
    out = []
    for _ in range(count):
        if barrier:
            jax.block_until_ready(bar(bx))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        out.append(time.perf_counter() - t0)
    return out


def make_sampler(cell: OpCell, impl: str):
    """Adapter to the NREP estimator's (msize, count) -> latencies shape.

    The probe size rescales the cell via ``OpCell.scaled_to`` — for fused
    cells the recorded GEMM aspect (K, N and the role) is preserved while
    the payload-tied dim shrinks/grows with the message size.
    """
    def sampler(msize: int, count: int):
        return sample_latency(cell.scaled_to(msize), impl, count)
    return sampler
