"""Measured-latency backend: ReproMPI's Algorithm 1 on JAX host devices.

Timing procedure (paper Algorithm 1): synchronize, t = now, run collective,
record t' - t.  The dissemination-barrier analogue here is a jitted 1-element
psum executed (and blocked on) before every sample; collectives themselves
are pre-compiled so only execution is timed.

Replay is keyed on the full ``OpCell``: a fused collective-matmul cell is
re-executed with the *recorded* GEMM — dtype and ``(mm_k, mm_m, mm_n)``
exactly as the callsite issued them — not a canonical square weight, so
wall-clock replay prices the actual matmul.  Fused cells without recorded
geometry (v1 traces) cannot be replayed; the tuner note-skips them.

This backend runs on whatever devices the process sees (CPU host devices in
this container).  Its absolute numbers are CPU-flavored; the tuner uses it to
validate *orderings* and to exercise the full offline-tuning pipeline, while
production-scale decisions use ``core.costmodel``.
"""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro._compat import shard_map

from repro.core import collectives as C
from repro.core.cell import OpCell

AXIS = "bench"
AXIS2 = "bench2"       # inner axis of the 2-D replay mesh

#: ops whose cells carry a fused-matmul geometry the replay must honor
MATMUL_OPS = ("allgather_matmul", "matmul_reducescatter", "matmul_accumulate",
              "matmul_reducescatter_2d")


@lru_cache(maxsize=1)
def _mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs, (AXIS,))


@lru_cache(maxsize=8)
def _mesh2(p: int, p2: int) -> Mesh:
    """The (outer, inner) replay mesh of a 2-D cell; requires the host
    devices to factor exactly as p x p2."""
    devs = np.array(jax.devices())
    if devs.size != p * p2:
        raise ValueError(
            f"2-D replay needs {p}x{p2}={p * p2} host devices, "
            f"have {devs.size}")
    return Mesh(devs.reshape(p, p2), (AXIS, AXIS2))


def axis_size() -> int:
    return _mesh().devices.size


def host_cell(op: str, nbytes: int, *, dtype: str = "float32",
              **geom) -> OpCell:
    """An ``OpCell`` at the axis size the host devices form (benchmarks)."""
    return OpCell(op, axis_size(), nbytes, dtype, **geom)


def problem_shapes(cell: OpCell) -> dict[str, tuple[int, ...]]:
    """Per-shard operand shapes the replay builds for ``cell`` — pure
    function of the cell, unit-testable without devices.

    ``x`` is the sharded operand (the collective payload), ``w`` the
    shard-local second operand of the fused ops (absent for plain
    collectives).  Fused shapes come from the RECORDED GEMM dims.
    """
    p = cell.p
    if cell.op in MATMUL_OPS:
        if not cell.fused:
            raise ValueError(
                f"cell {cell} has no recorded matmul geometry; a fused op "
                "cannot be replayed without it (v1 trace?)")
        if cell.op == "matmul_reducescatter_2d":
            q = max(cell.p2, 1)
            if cell.mm_role == "2dT":
                # payload = the cotangent row block [mm_k/p, mm_m]; its
                # cols must divide the inner rs axis; x is shard-local
                t_loc = max(1, cell.mm_k // p)
                m_pad = max(q, (cell.mm_m // q) * q)
                return {"x": (t_loc, m_pad),
                        "w": (p * t_loc, cell.mm_n)}
            # payload = the weight column block [mm_k, mm_n/p]; the
            # shard-local x rows must divide the inner rs axis
            rows = max(q, (cell.mm_m // q) * q)
            return {"x": (cell.mm_k, max(1, cell.mm_n // p)),
                    "w": (rows, cell.mm_k)}
        if cell.op == "allgather_matmul":
            return {"x": (max(1, cell.mm_m // p), cell.mm_k),
                    "w": (cell.mm_k, cell.mm_n)}
        if cell.op == "matmul_reducescatter":
            rows = max(p, (cell.mm_m // p) * p)   # psum_scatter must divide
            return {"x": (rows, cell.mm_k), "w": (cell.mm_k, cell.mm_n)}
        # matmul_accumulate: the payload is the K-dim weight shard
        k_loc = max(1, cell.mm_k // p)
        return {"x": (k_loc, cell.mm_n), "w": (cell.mm_m, p * k_loc)}
    itemsize = cell.itemsize
    n_rows = max(1, cell.nbytes // itemsize)
    if cell.op in ("alltoall", "reducescatter", "scatter"):
        # v-style ops: nbytes is the per-chunk payload, input is one chunk
        # per rank of the (possibly hierarchical) group
        n_rows *= cell.world()
    return {"x": (n_rows, 1)}


@lru_cache(maxsize=512)
def _compiled(cell: OpCell, impl: str):
    if cell.op == "matmul_reducescatter_2d":
        return _compiled_2d(cell, impl)
    if cell.hier:
        return _compiled_hier(cell, impl)
    mesh = _mesh()
    p = mesh.devices.size
    if cell.p != p:
        raise ValueError(
            f"measured backend runs at p={p}, not {cell.p}")
    fn = C.REGISTRY[cell.op][impl].fn
    shapes = problem_shapes(cell)
    dt = jnp.dtype(cell.dtype if cell.dtype else "float32")

    if cell.op == "matmul_accumulate":
        # streamed operand = the weight shard; the stationary x is a
        # shard-local closure constant with the recorded [mm_m, mm_k]
        stat = jnp.ones(shapes["w"], dt)

        def body(wb):
            return fn(wb, AXIS, x=stat)
    elif cell.op in MATMUL_OPS:
        w = jnp.ones(shapes["w"], dt)

        def body(x):
            return fn(x, AXIS, w=w)
    else:
        def body(x):
            return fn(x, AXIS)

    sm = shard_map(body, mesh=mesh, in_specs=P(AXIS), out_specs=P(AXIS),
                   check_vma=False)
    spec = NamedSharding(mesh, P(AXIS))
    rows, width = shapes["x"]
    x = jax.device_put(jnp.ones((p * rows, width), dt), spec)
    return jax.jit(sm).lower(x).compile(), x


def _compiled_hier(cell: OpCell, impl: str):
    """Compile a HIERARCHICAL plain cell's replay: the joint ``p x p2``
    group as a real two-axis host mesh, payload sharded over both axes in
    outer-major order (exactly the dispatch-time layout), the impl called
    with ``inner_axis=`` — so the measured backend replays the same
    composed schedule the api would run."""
    mesh = _mesh2(cell.p, cell.p2)
    fn = C.REGISTRY[cell.op][impl].fn
    shapes = problem_shapes(cell)
    dt = jnp.dtype(cell.dtype if cell.dtype else "float32")

    def body(x):
        return fn(x, AXIS, inner_axis=AXIS2)

    sm = shard_map(body, mesh=mesh, in_specs=P((AXIS, AXIS2)),
                   out_specs=P((AXIS, AXIS2)), check_vma=False)
    spec = NamedSharding(mesh, P((AXIS, AXIS2)))
    rows, width = shapes["x"]
    x = jax.device_put(jnp.ones((cell.world() * rows, width), dt), spec)
    return jax.jit(sm).lower(x).compile(), x


def _compiled_2d(cell: OpCell, impl: str):
    """Compile a 2-D cell's replay on the (outer, inner) host mesh.

    The payload streams over the OUTER axis exactly as at dispatch: the
    forward cell shards the weight's columns over ``AXIS``, the ``2dT``
    cell shards the cotangent's rows; the stationary operand is a
    shard-local closure constant with the recorded per-rank shape."""
    q = max(cell.p2, 1)
    mesh = _mesh2(cell.p, q)
    fn = C.REGISTRY[cell.op][impl].fn
    shapes = problem_shapes(cell)
    dt = jnp.dtype(cell.dtype if cell.dtype else "float32")
    stat = jnp.ones(shapes["w"], dt)
    xpose = cell.mm_role == "2dT"

    def body(payload):
        return fn(payload, AXIS, x=stat, rs_axis=AXIS2, xpose=xpose)

    rows, cols = shapes["x"]
    if xpose:
        in_spec, x = P(AXIS, None), jnp.ones((cell.p * rows, cols), dt)
    else:
        in_spec, x = P(None, AXIS), jnp.ones((rows, cell.p * cols), dt)
    sm = shard_map(body, mesh=mesh, in_specs=in_spec,
                   out_specs=P(AXIS2, None), check_vma=False)
    spec = NamedSharding(mesh, in_spec)
    x = jax.device_put(x, spec)
    return jax.jit(sm).lower(x).compile(), x


@lru_cache(maxsize=1)
def _barrier():
    mesh = _mesh()

    def body(x):
        return jax.lax.psum(x, AXIS)

    sm = shard_map(body, mesh=mesh, in_specs=P(AXIS), out_specs=P(),
                   check_vma=False)
    spec = NamedSharding(mesh, P(AXIS))
    x = jax.device_put(jnp.ones((mesh.devices.size,), jnp.float32), spec)
    return jax.jit(sm).lower(x).compile(), x


def sample_latency(cell: OpCell, impl: str, count: int,
                   *, barrier: bool = True) -> list[float]:
    """``count`` barrier-synced wall-clock samples of one cell (s)."""
    fn, x = _compiled(cell, impl)
    bar, bx = _barrier()
    # warm one execution so first-run allocation noise is out of the samples
    jax.block_until_ready(fn(x))
    out = []
    for _ in range(count):
        if barrier:
            jax.block_until_ready(bar(bx))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        out.append(time.perf_counter() - t0)
    return out


def sweep_axis(op: str, sizes, *, impl: str = "default",
               count: int = 5) -> list[tuple[int, float]]:
    """Measured ``(payload_bytes, median_seconds)`` points of one op's
    default ring over the host axis — the input ``costmodel.fit_topo`` /
    ``costmodel.MeshTopo.fit`` turn into per-tier alpha/beta/gamma.

    The per-tier Topo parameters a hierarchical cost model prices with
    must come from sweeps like this, not assumed constants: fit the tier
    you can run (``fit_topo(axis_size(), sweep_axis("allgather", ...),
    sweep_axis("allreduce", ...))``) and derive unreachable tiers via the
    published hardware RATIOS (``Topo.scaled``), keeping the fitted
    absolutes."""
    import statistics
    out = []
    for nbytes in sizes:
        cell = host_cell(op, int(nbytes))
        out.append((int(nbytes),
                    statistics.median(sample_latency(cell, impl, count))))
    return out


def make_sampler(cell: OpCell, impl: str):
    """Adapter to the NREP estimator's (msize, count) -> latencies shape.

    The probe size rescales the cell via ``OpCell.scaled_to`` — for fused
    cells the recorded GEMM aspect (K, N and the role) is preserved while
    the payload-tied dim shrinks/grows with the message size.
    """
    def sampler(msize: int, count: int):
        return sample_latency(cell.scaled_to(msize), impl, count)
    return sampler
