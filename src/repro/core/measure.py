"""Measured-latency backend: ReproMPI's Algorithm 1 on JAX host devices.

Timing procedure (paper Algorithm 1): synchronize, t = now, run collective,
record t' - t.  The dissemination-barrier analogue here is a jitted 1-element
psum executed (and blocked on) before every sample; collectives themselves
are pre-compiled so only execution is timed.

This backend runs on whatever devices the process sees (CPU host devices in
this container).  Its absolute numbers are CPU-flavored; the tuner uses it to
validate *orderings* and to exercise the full offline-tuning pipeline, while
production-scale decisions use ``core.costmodel``.
"""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro._compat import shard_map

from repro.core import collectives as C

AXIS = "bench"

# ops that carry a second (shard-local) matmul operand; measured with a
# square [MM_WIDTH, MM_WIDTH] weight so wall-clock includes the fused (or
# trailing/leading) MXU work the cost model prices via ``fused_mm_cols``
MATMUL_OPS = ("allgather_matmul", "matmul_reducescatter")
MM_WIDTH = 64


@lru_cache(maxsize=1)
def _mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs, (AXIS,))


def axis_size() -> int:
    return _mesh().devices.size


def _input_rows(op: str, n_rows: int, p: int) -> int:
    """Rows of the per-shard input for a payload of ``n_rows`` rows."""
    if op in ("alltoall", "reducescatter", "scatter"):
        # v-style ops: n_rows is the per-chunk payload, input is p chunks
        return n_rows * p
    if op == "matmul_reducescatter":
        # the dispatch key (and hence the replayed nbytes) is the FULL
        # [p*n, K] input payload — build exactly that many rows, rounded
        # to a multiple of p so psum_scatter divides
        return max(p, (n_rows // p) * p)
    return n_rows


@lru_cache(maxsize=512)
def _compiled(op: str, impl: str, n_rows: int, width: int, dtype_name: str):
    mesh = _mesh()
    p = mesh.devices.size
    fn = C.REGISTRY[op][impl].fn
    rows = _input_rows(op, n_rows, p)

    if op in MATMUL_OPS:
        w = jnp.ones((width, width), jnp.dtype(dtype_name))

        def body(x):
            return fn(x, AXIS, w=w)
    else:
        def body(x):
            return fn(x, AXIS)

    sm = shard_map(body, mesh=mesh, in_specs=P(AXIS), out_specs=P(AXIS),
                   check_vma=False)
    spec = NamedSharding(mesh, P(AXIS))
    x = jax.device_put(
        jnp.ones((p * rows, width), jnp.dtype(dtype_name)), spec)
    return jax.jit(sm).lower(x).compile(), x


@lru_cache(maxsize=1)
def _barrier():
    mesh = _mesh()

    def body(x):
        return jax.lax.psum(x, AXIS)

    sm = shard_map(body, mesh=mesh, in_specs=P(AXIS), out_specs=P(),
                   check_vma=False)
    spec = NamedSharding(mesh, P(AXIS))
    x = jax.device_put(jnp.ones((mesh.devices.size,), jnp.float32), spec)
    return jax.jit(sm).lower(x).compile(), x


def sample_latency(op: str, impl: str, nbytes: int, count: int,
                   *, width: int = 1, dtype=jnp.float32,
                   barrier: bool = True) -> list[float]:
    """``count`` barrier-synced wall-clock samples of one collective (s)."""
    if op in MATMUL_OPS:
        width = MM_WIDTH
    itemsize = jnp.dtype(dtype).itemsize
    n_rows = max(1, nbytes // (itemsize * width))
    fn, x = _compiled(op, impl, n_rows, width, jnp.dtype(dtype).name)
    bar, bx = _barrier()
    # warm one execution so first-run allocation noise is out of the samples
    jax.block_until_ready(fn(x))
    out = []
    for _ in range(count):
        if barrier:
            jax.block_until_ready(bar(bx))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        out.append(time.perf_counter() - t0)
    return out


def make_sampler(op: str, impl: str):
    """Adapter to the NREP estimator's (msize, count) -> latencies shape."""
    def sampler(msize: int, count: int):
        return sample_latency(op, impl, msize, count)
    return sampler
