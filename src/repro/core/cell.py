"""The tuning cell: one communication problem, with its full geometry.

The paper's method compares a collective against its mock-ups *on the actual
communication problem* — "type of communication, message size, number of
processes" (Hunold 2017; the PGMPI predecessor tunes per callsite).  A bare
``(op, p, nbytes)`` tuple loses exactly the part of the problem the fused
collective-matmul ops add: which GEMM rides on the collective.  ``OpCell``
is the first-class record every layer keys on:

* ``api`` captures one per dispatch (``DispatchRecord.cell``),
* ``core.trace`` aggregates them (schema-v2 JSONL),
* ``core.profiles`` keys geometry profiles on ``OpCell.geom()``,
* ``core.measure`` replays the *recorded* GEMM on host devices,
* ``core.costmodel.latency_cell`` prices the overlap from the true flops.

Geometry convention for fused matmul ops (the full logical GEMM is always
``[mm_m, mm_k] @ [mm_k, mm_n]``):

=======================  =========================  =======================
op                       collective operand         ``mm_role``
=======================  =========================  =======================
allgather_matmul         x ``[mm_m/p, mm_k]``       ``gather``  — the
                                                    gathered dim is the
                                                    output-ROW dim
matmul_reducescatter     x ``[mm_m, mm_k]``         ``scatter`` — output
                                                    rows are
                                                    reduce-scattered
matmul_accumulate        w ``[mm_k/p, mm_n]``       ``contract`` — the
                                                    gathered dim is
                                                    CONTRACTED away
matmul_reducescatter_2d  w ``[mm_k, mm_n/p]``       ``2d`` — weight cols
                                                    gathered over the outer
                                                    (``p``) axis, output
                                                    rows reduce-scattered
                                                    over the inner (``p2``)
                                                    axis
matmul_reducescatter_2d  g ``[mm_k/p, mm_m]``       ``2dT`` — the transpose
(``xpose=True``)                                    schedule: the gathered
                                                    dim is CONTRACTED,
                                                    output rows scattered
                                                    over ``p2``
=======================  =========================  =======================

``p2`` is the SECOND axis size of a two-axis cell: the inner
reduce-scatter axis of the fused 2-D op, or the intra (fast-tier) axis of
a HIERARCHICAL plain collective (``allreduce``/``allgather``/
``reducescatter`` issued over an (inter, intra) axis pair — the
RS-intra→AR-inter→AG-intra decomposition family).  ``p`` is always the
axis the payload streams over (2-D) or the OUTER/inter axis
(hierarchical).  1-D cells keep ``p2 == 0``; ``world()`` is the device
count the cell needs (``p`` or ``p * p2``).  For 2-D cells the recorded
GEMM dims are the PER-RANK problem — ``[mm_m, mm_k] @ [mm_k, mm_n]`` is
the matmul one rank performs across the whole nested ring — consistent
with the 1-D convention (e.g. ``matmul_reducescatter``'s ``mm_k`` is the
local partial-contraction depth).

``tier`` is the interconnect-tier token of the cell's axes under a
hierarchical ``costmodel.MeshTopo``: ``""`` for flat/untiered cells (the
pre-hierarchy behaviour), a single tier name (``"v5e-dcn"``) for a flat
cell on a known tier, or ``"<outer>/<inner>"`` for two-axis cells.  The
token partitions profiles (see ``OpCell.profile_tier`` /
``ProfileStore.lookup_cell``) so a flat-tier tuning result is never
served to a hierarchical cell with the same ``(op, p, nbytes)`` —
and vice versa.

Plain collectives carry ``mm_k == mm_m == mm_n == 0`` and ``mm_role == ""``
(``fused`` is False); their dtype is still recorded.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

#: roles a fused matmul operand can play in its collective
MM_ROLES = ("gather", "scatter", "contract", "2d", "2dT")

#: dispatcher op -> role of its fused matmul (None for plain collectives;
#: the 2-D op's ``xpose=True`` direction records as "2dT")
OP_MM_ROLE = {
    "allgather_matmul": "gather",
    "matmul_reducescatter": "scatter",
    "matmul_accumulate": "contract",
    "matmul_reducescatter_2d": "2d",
}

#: mm_role -> fused dispatcher op (inverse of OP_MM_ROLE; both 2-D roles
#: fold onto the one 2-D op)
ROLE_TO_OP = {
    "gather": "allgather_matmul",
    "scatter": "matmul_reducescatter",
    "contract": "matmul_accumulate",
    "2d": "matmul_reducescatter_2d",
    "2dT": "matmul_reducescatter_2d",
}

#: compiled-HLO collective class -> dispatcher op name.  collective-permute
#: has no dispatcher registry entry (no mock-ups) but still gets a cell so
#: XLA-level scans (analysis/interpose) map EVERY collective instruction.
HLO_TO_OP = {
    "all-gather": "allgather",
    "all-reduce": "allreduce",
    "reduce-scatter": "reducescatter",
    "all-to-all": "alltoall",
    "collective-permute": "collective_permute",
}


@dataclasses.dataclass(frozen=True, order=True)
class Geom:
    """The matmul geometry of a fused cell — the profile partition key.

    ``p2`` is the inner axis size of a 2-D cell (0 for 1-D cells): two
    meshes with the same GEMM but different inner axes are different
    communication problems, so they partition into different profiles.
    """
    dtype: str
    mm_k: int
    mm_m: int
    mm_n: int
    mm_role: str
    p2: int = 0

    def distance(self, other: "Geom") -> float:
        """Log-space shape distance for the nearest-cell profile fallback
        (same role/dtype/p2 assumed; see ``ProfileStore.lookup_cell``)."""
        d = 0.0
        for a, b in ((self.mm_k, other.mm_k), (self.mm_m, other.mm_m),
                     (self.mm_n, other.mm_n)):
            d += abs(math.log2(max(a, 1)) - math.log2(max(b, 1)))
        return d


@dataclasses.dataclass(frozen=True, order=True)
class OpCell:
    """One tuning cell: collective type, scale, payload, and geometry."""
    op: str
    p: int                      # axis size the payload streams over
    nbytes: int                 # payload bytes of the collective operand
    dtype: str = "float32"
    mm_k: int = 0               # contraction dim of the fused GEMM
    mm_m: int = 0               # output rows of the fused GEMM
    mm_n: int = 0               # output cols of the fused GEMM
    mm_role: str = ""           # one of MM_ROLES or "" (plain)
    p2: int = 0                 # inner axis size (2-D / hierarchical cells)
    tier: str = ""              # interconnect-tier token ("" = flat/untiered)

    #: plain ops that may carry a second (intra) axis — the hierarchical
    #: decomposition family
    HIER_OPS = ("allreduce", "allgather", "reducescatter")

    def __post_init__(self):
        if self.mm_role and self.mm_role not in MM_ROLES:
            raise ValueError(f"unknown mm_role {self.mm_role!r}")
        if self.p2 and self.mm_role not in ("2d", "2dT"):
            if self.mm_role or self.op not in self.HIER_OPS:
                raise ValueError(
                    f"p2={self.p2} only valid for 2-D roles or the "
                    f"hierarchical plain ops {self.HIER_OPS}, not "
                    f"op={self.op!r} role={self.mm_role!r}")

    # -- views ---------------------------------------------------------------
    @property
    def fused(self) -> bool:
        """True when the cell carries a recorded GEMM geometry."""
        return self.mm_k > 0

    @property
    def hier(self) -> bool:
        """True for a hierarchical plain cell: a collective issued over an
        (inter, intra) axis pair — ``p`` outer ranks × ``p2`` inner ranks —
        with no fused GEMM (the fused 2-D op keeps its own role)."""
        return self.p2 > 0 and not self.fused

    def profile_tier(self) -> str:
        """The tier token profiles partition on.  Hierarchical plain cells
        fold the inner axis size in (their ``Geom`` is None, so nothing
        else separates an 8-way flat cell from a 2×4 hierarchical one);
        fused 2-D cells already carry ``p2`` inside their ``Geom``."""
        if self.hier:
            return f"{self.tier or 'hier'}@q{self.p2}"
        return self.tier

    def world(self) -> int:
        """Device count the cell's communication problem spans: ``p`` for
        1-D cells, ``p * p2`` for 2-D cells — what the measured backend
        needs the host mesh to factor as."""
        return self.p * self.p2 if self.p2 else self.p

    @property
    def itemsize(self) -> int:
        try:
            return int(np.dtype(self.dtype).itemsize)
        except TypeError:
            return 4

    def flops(self) -> int:
        """MAC-pair flop count of the full logical GEMM (2 per element)."""
        return 2 * self.mm_k * self.mm_m * self.mm_n

    def geom(self) -> Geom | None:
        """Geometry partition key, or None for plain / unknown-geometry
        cells (v1 traces carry fused ops with no recorded dims)."""
        if not self.fused:
            return None
        return Geom(self.dtype, self.mm_k, self.mm_m, self.mm_n,
                    self.mm_role, self.p2)

    def key(self) -> tuple:
        return dataclasses.astuple(self)

    # -- derived cells -------------------------------------------------------
    def scaled_to(self, nbytes: int) -> "OpCell":
        """The same problem at a different payload size (NREP probes).

        For fused cells the dimension tied to the collective operand is
        rescaled so the replayed GEMM stays consistent with the payload:
        ``gather``/``scatter`` scale the row dim ``mm_m``; ``contract``
        scales the contraction dim ``mm_k``; ``2d`` scales the output-col
        dim ``mm_n`` (the streamed weight's width) and ``2dT`` the
        contraction dim ``mm_k`` (the streamed cotangent's rows).  The
        returned nbytes is re-derived from the integral dims — rounded to
        whole rows/blocks and never below ONE row/block, so a fused cell's
        "1-byte" NREP anchor is really its minimal-GEMM floor (one K-row /
        one weight block), not a literal byte.
        """
        if not self.fused:
            return dataclasses.replace(self, nbytes=max(int(nbytes), 1))
        it = self.itemsize
        if self.mm_role == "gather":
            n = max(1, int(nbytes) // (self.mm_k * it))
            return dataclasses.replace(self, nbytes=n * self.mm_k * it,
                                       mm_m=self.p * n)
        if self.mm_role == "scatter":
            rows = max(self.p,
                       (int(nbytes) // (self.mm_k * it) // self.p) * self.p)
            return dataclasses.replace(self, nbytes=rows * self.mm_k * it,
                                       mm_m=rows)
        if self.mm_role == "2d":
            # payload = the weight shard [mm_k, mm_n/p]: scale its width
            cols = max(1, int(nbytes) // (self.mm_k * it))
            return dataclasses.replace(self, nbytes=cols * self.mm_k * it,
                                       mm_n=self.p * cols)
        if self.mm_role == "2dT":
            # payload = the cotangent shard [mm_k/p, mm_m]: scale its rows
            rows = max(1, int(nbytes) // (self.mm_m * it))
            return dataclasses.replace(self, nbytes=rows * self.mm_m * it,
                                       mm_k=self.p * rows)
        k_loc = max(1, int(nbytes) // (self.mm_n * it))
        return dataclasses.replace(self, nbytes=k_loc * self.mm_n * it,
                                   mm_k=self.p * k_loc)

    # -- construction --------------------------------------------------------
    @classmethod
    def plain(cls, op: str, p: int, nbytes: int,
              dtype: str = "float32") -> "OpCell":
        return cls(op=op, p=p, nbytes=nbytes, dtype=dtype)

    @classmethod
    def from_hlo(cls, base_op: str, p: int, nbytes: int,
                 dtype: str = "float32", *,
                 gemm: "tuple[int, int, int] | None" = None,
                 mm_role: str = "") -> "OpCell":
        """The tuning cell for one compiled-HLO collective site.

        ``base_op`` is the HLO opcode class with any async suffix stripped
        (``"all-gather"``, ``"reduce-scatter"``, ...).  When the site sits
        adjacent to a ``dot`` — an all-gather feeding a matmul, or a matmul
        feeding a reduce-scatter — ``gemm=(mm_k, mm_m, mm_n)`` plus
        ``mm_role`` map it to the corresponding FUSED dispatcher op, so the
        cost model prices the fused-ring mock-ups against what XLA actually
        emitted.  Raises ``KeyError`` for a collective class with no
        dispatcher counterpart (callers surface these as unmapped instead
        of silently skipping them).
        """
        if gemm is not None and mm_role:
            mm_k, mm_m, mm_n = gemm
            return cls(op=ROLE_TO_OP[mm_role], p=p, nbytes=nbytes,
                       dtype=dtype, mm_k=mm_k, mm_m=mm_m, mm_n=mm_n,
                       mm_role=mm_role)
        op = HLO_TO_OP.get(base_op)
        if op is None:
            raise KeyError(
                f"no dispatcher op for HLO collective {base_op!r}")
        return cls.plain(op, p, nbytes, dtype)
