"""The dispatching collective API — the PGMPITuneLib "PMPI layer".

All framework code (dist/, models/, train/) calls these entry points instead
of raw ``jax.lax`` collectives.  Selection order per call:

1. explicit ``impl=`` argument              (unit tests, hillclimbing)
2. context ``force`` table                  (PGMPITuneCLI ``--module=op:alg=x``)
3. ``PGTUNE_MODULE`` environment variable   (same syntax as the paper's CLI)
4. phase-specific performance profiles      (trace-replay tuning; the store
   matching the active ``api.phase`` tag)
5. loaded performance profiles              (PGMPITuneD online redirection)
6. the live fleet ``store_ref``             (hot-swappable epochal stores;
   see ``profiles.StoreRef``)
7. the default implementation

Dispatch happens at TRACE time: JAX shapes are static, so the profile's
O(log M) binary search runs while tracing and the compiled program contains
only the winning algorithm — zero runtime overhead (an improvement over the
paper's runtime hash+bsearch, see DESIGN.md §2).

Fleet hot-swap is the exception: ``tuned(plan=Plan(), store_ref=ref)``
switches eligible sites to RUNTIME dispatch — the trace emits
``lax.switch`` over every admissible impl and reads the branch index from
a traced plan vector (``plan_input``), so a new profile epoch changes the
vector's CONTENTS, never the compiled program: zero re-jits on swap.

The context also carries the scratch budget (the paper's
``size_msg_buffer_bytes``): a mock-up whose Table-1 extra memory exceeds the
budget is not applied, exactly like PGMPITuneLib refusing replacements when
the user-controlled buffer is too small.

Every dispatch is recorded; ``format_footer()`` emits the paper's Listing-2
``#@pgmpi alg <op> <bytes> <impl>`` trailer.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading

from repro.core import collectives as C
from repro.core._axis import axis_size
from repro.core.cell import OP_MM_ROLE, OpCell
from repro.core.profiles import OP_TO_MPI, ProfileStore

_TLS = threading.local()


DEFAULT_PHASE = "fwd"


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    """One dispatched collective: the full problem cell, the impl the
    dispatcher chose, and the workload phase tag.  Destructures as the
    legacy ``(op, p, nbytes, impl, phase)`` 5-tuple."""
    cell: OpCell
    impl: str
    phase: str

    @property
    def op(self) -> str:
        return self.cell.op

    @property
    def p(self) -> int:
        return self.cell.p

    @property
    def nbytes(self) -> int:
        return self.cell.nbytes

    def __iter__(self):
        yield from (self.cell.op, self.cell.p, self.cell.nbytes, self.impl,
                    self.phase)


@dataclasses.dataclass
class TuneContext:
    profiles: ProfileStore | None = None
    force: dict[str, str] = dataclasses.field(default_factory=dict)
    scratch_budget_bytes: int | None = None
    record: list[DispatchRecord] = dataclasses.field(default_factory=list)
    chunk_bytes: int = 0
    phase_profiles: dict[str, ProfileStore] | None = None
    # fleet retuning: live hot-swappable stores (profiles.StoreRef) and
    # the runtime-dispatch plan (api.Plan) — see module docstring
    store_ref: object | None = None
    plan: "Plan | None" = None
    # per-axis interconnect map (costmodel.MeshTopo): stamps each
    # dispatched cell's tier token so profiles / traces key by tier
    mesh_topo: object | None = None


def _ctx() -> TuneContext | None:
    return getattr(_TLS, "ctx", None)


_GLOBAL_MESH_TOPO = None


def set_mesh_topo(topo) -> None:
    """Install a process-wide ``costmodel.MeshTopo`` describing which
    interconnect tier each mesh axis runs on.  Dispatch stamps every
    cell's ``tier`` token from it (a ``tuned(mesh_topo=...)`` context
    overrides it); ``None`` uninstalls."""
    global _GLOBAL_MESH_TOPO
    _GLOBAL_MESH_TOPO = topo


def current_mesh_topo():
    ctx = _ctx()
    if ctx is not None and ctx.mesh_topo is not None:
        return ctx.mesh_topo
    return _GLOBAL_MESH_TOPO


def current_phase() -> str:
    """The active workload phase tag (see ``phase``); default ``"fwd"``."""
    return getattr(_TLS, "phase", DEFAULT_PHASE)


@contextlib.contextmanager
def phase(name: str):
    """Tag every dispatch issued inside with workload phase ``name``.

    Phases name the coarse callsite classes of an LM step — ``fwd`` (the
    ambient default), ``bwd`` (custom-VJP backwards + grad sync; dist/ops
    and train/trainer set this), ``prefill`` / ``decode`` (serving; set by
    launch/serve).  The tag is captured at TRACE time into
    ``TuneContext.record`` and selects the matching store from
    ``tuned(phase_profiles=...)``.
    """
    prev = current_phase()
    _TLS.phase = name
    try:
        yield
    finally:
        _TLS.phase = prev


@contextlib.contextmanager
def tuned(profiles: ProfileStore | None = None,
          force: dict[str, str] | None = None,
          scratch_budget_bytes: int | None = None,
          chunk_bytes: int = 0,
          phase_profiles: dict[str, ProfileStore] | None = None,
          record: list | None = None,
          store_ref=None,
          plan: "Plan | None" = None,
          mesh_topo=None):
    """Activate tuning for every ``repro.core.api`` collective issued inside.

    ``force`` maps op name -> impl name (the CLI library's static selection);
    ``profiles`` is the PGMPITuneD mode.  ``phase_profiles`` maps a phase
    tag (see ``phase``) to a phase-specific ``ProfileStore`` consulted
    before ``profiles`` — the trace-replay tuner (``tuner.tune_trace``)
    emits these.  ``record`` lets the caller supply the sink dispatches
    are appended to (a list shared across nested builder contexts, or a
    ``trace.ShardRecorder`` sampling across recompilations).  Without any
    of these, defaults are used but calls are still recorded.

    Fleet mode: ``store_ref`` (a ``profiles.StoreRef``) is consulted
    after the explicit stores and read LIVE — swapping a new epoch into
    the ref changes what later jit traces select without rebuilding the
    context.  ``plan`` additionally switches eligible sites to runtime
    dispatch (``lax.switch`` over admissible impls, branch index from the
    ``plan_input`` vector), so a swap takes effect in ALREADY-COMPILED
    steps with zero re-jits.
    """
    prev = _ctx()
    ctx = TuneContext(profiles=profiles, force=dict(force or {}),
                      scratch_budget_bytes=scratch_budget_bytes,
                      chunk_bytes=chunk_bytes,
                      phase_profiles=(dict(phase_profiles)
                                      if phase_profiles else None),
                      record=record if record is not None else [],
                      store_ref=store_ref, plan=plan, mesh_topo=mesh_topo)
    _TLS.ctx = ctx
    try:
        yield ctx
    finally:
        _TLS.ctx = prev


def parse_module_spec(spec: str) -> dict[str, str]:
    """Parse the paper's ``--module=allgather:alg=allgather_as_gather_bcast``
    syntax (';'-separated for multiple ops)."""
    out: dict[str, str] = {}
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        op, _, alg = part.partition(":")
        key, _, val = alg.partition("=")
        if key != "alg" or not val:
            raise ValueError(f"bad module spec {part!r}")
        out[op.strip()] = val.strip()
    return out


_ENV_FORCE_CACHE: tuple[str, dict[str, str]] = ("", {})


def _env_force() -> dict[str, str]:
    """Parsed ``PGTUNE_MODULE``, memoized on the raw string — dispatch is a
    trace-time hot path and the env var rarely changes mid-process."""
    global _ENV_FORCE_CACHE
    spec = os.environ.get("PGTUNE_MODULE", "")
    if spec != _ENV_FORCE_CACHE[0]:
        _ENV_FORCE_CACHE = (spec, parse_module_spec(spec) if spec else {})
    return _ENV_FORCE_CACHE[1]


def _payload_bytes(x) -> int:
    return int(x.size) * x.dtype.itemsize


def _make_cell(op: str, payload, axis: str, kw) -> OpCell:
    """The dispatch-time tuning cell: payload + full problem geometry.

    ``payload`` is the operand the collective moves (its bytes are the
    dispatch key); for fused ops the per-callsite GEMM dims are read off
    the actual operands, so profiles/traces/measurement all see the true
    matmul.
    """
    p = axis_size(axis)
    nbytes = _payload_bytes(payload)
    role = OP_MM_ROLE.get(op)
    mt = current_mesh_topo()
    if role is None:
        inner = kw.get("inner_axis")
        if inner is not None:
            # hierarchical plain cell: p = outer (slow) axis, p2 = inner
            tier = mt.tier_token(axis, inner) if mt is not None else ""
            return OpCell(op, p, nbytes, str(payload.dtype),
                          p2=axis_size(inner), tier=tier)
        tier = mt.tier_token(axis) if mt is not None else ""
        return OpCell(op, p, nbytes, str(payload.dtype), tier=tier)
    if role == "2d":
        # two-axis op: p = outer stream axis, p2 = inner reduce-scatter
        # axis; recorded dims are the PER-RANK GEMM (see core/cell.py).
        # The tier token is always (stream axis / rs axis) — the costmodel
        # swaps them itself for the transpose schedule.
        p2 = axis_size(kw["rs_axis"])
        tier = (mt.tier_token(axis, kw["rs_axis"])
                if mt is not None else "")
        if kw.get("xpose"):  # payload g [T/p, M] streamed+contracted
            mm_k, mm_m = p * payload.shape[0], payload.shape[-1]
            mm_n = kw["x"].shape[-1]
            return OpCell(op, p, nbytes, str(payload.dtype),
                          mm_k, mm_m, mm_n, "2dT", p2, tier)
        # payload w [K, M/p] column block streamed over the outer axis
        mm_k, mm_m = payload.shape[0], kw["x"].shape[0]
        mm_n = p * payload.shape[-1]
        return OpCell(op, p, nbytes, str(payload.dtype),
                      mm_k, mm_m, mm_n, "2d", p2, tier)
    tier = mt.tier_token(axis) if mt is not None else ""
    if role == "gather":     # payload x [n, K] gathered over rows, w [K, M]
        mm_k, mm_m = payload.shape[-1], p * payload.shape[0]
        mm_n = kw["w"].shape[-1]
    elif role == "scatter":  # payload x [p*n, K] rows scattered, w [K, M]
        mm_k, mm_m = payload.shape[-1], payload.shape[0]
        mm_n = kw["w"].shape[-1]
    else:                    # contract: payload = streamed w block [K/p, M]
        mm_k, mm_m = p * payload.shape[0], kw["x"].shape[0]
        mm_n = payload.shape[-1]
    return OpCell(op, p, nbytes, str(payload.dtype), mm_k, mm_m, mm_n, role,
                  tier=tier)


def _select(op: str, payload, axis: str, impl: str | None, kw) -> str:
    ctx = _ctx()
    # hot-path short-circuit: with no explicit impl, no force table, no
    # profiles and no phase profiles, the answer is "default" — skip the
    # cell/phase/profile machinery entirely (dispatch runs at trace time
    # but sits on every collective of every jit trace; see
    # benchmarks/bench_dispatch.py for the win).  The pow2 and scratch
    # guards never demote "default", so skipping them is exact.
    if impl is None and (ctx is None or (not ctx.force and ctx.profiles is
                                         None and ctx.phase_profiles is
                                         None and ctx.store_ref is
                                         None)) and not _env_force():
        if ctx is not None:
            ctx.record.append(DispatchRecord(_make_cell(op, payload, axis,
                                                        kw),
                                             "default", current_phase()))
        return "default"
    cell = _make_cell(op, payload, axis, kw)
    p, nbytes = cell.p, cell.nbytes
    ph = current_phase()
    name = impl
    if name is None and ctx is not None and op in ctx.force:
        name = ctx.force[op]
    if name is None:
        env = _env_force()
        if op in env:
            name = env[op]
    if name is None and ctx is not None:
        if ctx.phase_profiles is not None:
            store = ctx.phase_profiles.get(ph)
            if store is not None:
                name = store.lookup_cell(cell)
        if name is None and ctx.profiles is not None:
            name = ctx.profiles.lookup_cell(cell)
        if name is None and ctx.store_ref is not None:
            # the live fleet generation: read through the mutable ref so a
            # hot-swapped epoch is picked up by every later jit trace
            name = ctx.store_ref.lookup(cell, ph)
    if name is None:
        name = "default"
    cand = C.REGISTRY[op].get(name)
    if cand is None:
        raise KeyError(f"unknown impl {name!r} for op {op!r}")
    # pow2 guard + scratch budget (paper's size_msg_buffer_bytes semantics)
    # + demotion ledger (a quantized-wire impl that broke its tolerance)
    # + tier-world guard (a hier mock-up needs a two-axis cell; a flat
    #   mock-up over one axis would silently reduce a hier problem wrong)
    if cand.requires_pow2 and (
            (p & (p - 1)) != 0
            or (cell.p2 and (cell.p2 & (cell.p2 - 1)) != 0)):
        name, cand = "default", C.REGISTRY[op]["default"]
    if name != "default" and getattr(cand, "hier", False) != cell.hier:
        name, cand = "default", C.REGISTRY[op]["default"]
    if name != "default" and C.is_demoted(op, name):
        name, cand = "default", C.REGISTRY[op]["default"]
    if (ctx is not None and ctx.scratch_budget_bytes is not None
            and name != "default"
            and cand.extra_bytes(nbytes, p) > ctx.scratch_budget_bytes):
        name, cand = "default", C.REGISTRY[op]["default"]
    if ctx is not None:
        ctx.record.append(DispatchRecord(cell, name, ph))
    return name


# ---------------------------------------------------------------------------
# runtime dispatch plans (fleet hot-swap; DESIGN_TRACE.md "epochal hot-swap")
# ---------------------------------------------------------------------------

#: recorded impl marker for sites dispatched through a runtime plan — the
#: branch taken is decided per call by the plan vector, not at trace time
PLAN_IMPL = "plan"


class Plan:
    """A runtime dispatch plan: the fixed-capacity impl-index vector that
    makes profile hot-swaps take effect WITHOUT a re-jit.

    Static dispatch bakes the chosen impl into the jit trace, so a new
    profile epoch would need a re-trace to matter.  Under a Plan, each
    eligible dispatch site instead emits ``lax.switch`` over its full
    admissible impl list and reads the branch index out of a traced int32
    vector the step function feeds in (``plan_input``).  The vector's
    SHAPE is the fixed ``capacity`` — it never changes, so neither does
    the compiled program; its CONTENTS are re-derived from the live
    stores (``vector(ref)``) whenever an epoch lands.

    Sites are keyed ``(cell, phase)``: later recompilations (new shapes,
    donation misses) re-register existing sites onto their stable slots
    and allocate fresh slots for new cells from the spare capacity.  When
    capacity runs out (or an op's admissible set collapses to just the
    default) the site falls back to ordinary static dispatch — graceful,
    and visible via ``len(plan)`` vs ``plan.capacity``.
    """

    def __init__(self, capacity: int = 128):
        self.capacity = int(capacity)
        self._sites: dict[tuple[OpCell, str],
                          tuple[int, tuple[str, ...]]] = {}

    def __len__(self) -> int:
        return len(self._sites)

    def slot(self, cell: OpCell, phase: str,
             impls: tuple[str, ...]) -> int | None:
        """Stable vector slot for a dispatch site (None = dispatch
        statically: capacity exhausted, or the admissible set drifted
        from what this site was registered with)."""
        key = (cell, phase)
        hit = self._sites.get(key)
        if hit is not None:
            s, known = hit
            return s if known == impls else None
        if len(self._sites) >= self.capacity:
            return None
        s = len(self._sites)
        self._sites[key] = (s, impls)
        return s

    def sites(self) -> list[tuple[OpCell, str, tuple[str, ...]]]:
        return [(cell, ph, impls) for (cell, ph), (_s, impls)
                in sorted(self._sites.items(), key=lambda kv: kv[1][0])]

    def _resolve(self, cell, ph, store_ref, base, phases):
        if store_ref is not None:
            return store_ref.lookup(cell, ph)
        store = (phases or {}).get(ph)
        name = store.lookup_cell(cell) if store is not None else None
        if name is None and base is not None:
            name = base.lookup_cell(cell)
        return name

    def vector(self, store_ref=None, *, base: ProfileStore | None = None,
               phases: dict[str, ProfileStore] | None = None):
        """The plan vector for the CURRENT profile generation: slot i
        holds the index (into that site's admissible impl list, 0 =
        default) the live stores select.  Unregistered slots stay 0."""
        import numpy as np
        vec = np.zeros(self.capacity, dtype=np.int32)
        for (cell, ph), (s, impls) in self._sites.items():
            name = self._resolve(cell, ph, store_ref, base, phases)
            if name in impls:
                vec[s] = impls.index(name)
        return vec

    def explore(self, store_ref=None, *, eps: float, rng,
                base: ProfileStore | None = None,
                phases: dict[str, ProfileStore] | None = None):
        """The exploration-budget vector: start from ``vector(...)`` and,
        per site, with probability ``eps`` flip to the runner-up impl —
        the next entry in the site's admissible ring (profiles only store
        winners, so "next" stands in for second-best; for default-serving
        sites that is the first mock-up).  Returns ``(vec, explored)``
        where ``explored`` maps ``(cell, phase) -> impl`` for the flipped
        sites, so the serve loop can attribute the latencies it measures
        (``ShardRecorder.observe``) to what actually ran."""
        vec = self.vector(store_ref, base=base, phases=phases)
        explored: dict[tuple[OpCell, str], str] = {}
        for (cell, ph), (s, impls) in sorted(self._sites.items(),
                                             key=lambda kv: kv[1][0]):
            if len(impls) < 2 or float(rng.random()) >= eps:
                continue
            vec[s] = (int(vec[s]) + 1) % len(impls)
            explored[(cell, ph)] = impls[vec[s]]
        return vec, explored


@contextlib.contextmanager
def plan_input(vec):
    """Expose the enclosing step function's traced plan-vector argument
    to dispatch sites (builders wrap the model call in this; the vector
    itself must be an ARGUMENT of the jitted function — a closed-over
    array would be baked in as a constant and defeat the hot swap)."""
    prev = getattr(_TLS, "plan_vec", None)
    _TLS.plan_vec = vec
    try:
        yield
    finally:
        _TLS.plan_vec = prev


class EpochTripwire:
    """Plan-level auto-rollback: revert a freshly adopted epoch whose
    OBSERVED cost regresses past the prior epoch's.

    The tuner's staleness/digest guards stop bad *publishes*; nothing on
    the read side stops a *well-formed but wrong* epoch — profiles tuned
    from poisoned measurements that make every step slower.  The tripwire
    closes that hole at the one place regression is observable: the serve
    loop's per-step cost.  Feed it each step's observed cost (wall-clock
    delta, or the modeled cost the bench synthesizes) via ``observe``;
    it buckets costs by the ``StoreRef``'s live epoch, takes the median
    of a finished epoch's window as the next epoch's baseline, and when
    the current epoch's windowed median exceeds ``threshold ×`` baseline
    it calls ``ref.rollback()`` — vector contents only, zero re-jit,
    and the bad epoch is poisoned against re-adoption.

    The window is a deque of the last ``window`` costs; medians make a
    single exploration spike or latency outlier unable to trip it (the
    same robustness argument as ``tuner.FeedbackBackend``'s MAD filter).
    """

    def __init__(self, ref, *, threshold: float = 1.5, window: int = 8,
                 min_samples: int = 4):
        self.ref = ref
        self.threshold = float(threshold)
        self.window = int(window)
        self.min_samples = int(min_samples)
        self._epoch = ref.epoch
        self._costs: list[float] = []
        self._baseline: float | None = None   # prior epoch's median cost
        self.fired: list[tuple[int, int]] = []  # (bad epoch, restored)

    @property
    def baseline(self) -> float | None:
        return self._baseline

    def observe(self, cost: float) -> bool:
        """Record one observed step cost under the CURRENT live epoch;
        returns True iff this observation fired a rollback."""
        import statistics
        epoch = self.ref.epoch
        if epoch != self._epoch:
            if epoch > self._epoch and len(self._costs) >= self.min_samples:
                # the finished epoch's steady-state cost becomes the new
                # epoch's yardstick
                self._baseline = statistics.median(self._costs)
            # on epoch < self._epoch (a rollback we didn't fire) the
            # baseline stays: it IS the restored epoch's own median
            self._costs = []
            self._epoch = epoch
        self._costs.append(float(cost))
        del self._costs[:-self.window]
        if self._baseline is None or len(self._costs) < self.min_samples:
            return False
        med = statistics.median(self._costs)
        if med <= self.threshold * self._baseline:
            return False
        restored = self.ref.rollback()
        if restored is None:
            return False   # nothing retained; keep serving + observing
        self.fired.append((epoch, restored))
        self._epoch = restored
        self._costs = []
        return True


def _admissible_impls(op: str, cell: OpCell,
                      ctx: TuneContext) -> tuple[str, ...]:
    """The impls a runtime plan may switch between for one site, in a
    deterministic order (default first) — the same §4.2 admission rules
    static dispatch applies (pow2 guard, Table-1 scratch budget), which
    only depend on the static cell, never on the profile choice."""
    reg = C.REGISTRY[op]
    p, nbytes = cell.p, cell.nbytes
    out = []
    for name in ["default"] + sorted(n for n in reg if n != "default"):
        impl = reg[name]
        if impl.requires_pow2 and (
                (p & (p - 1)) != 0
                or (cell.p2 and (cell.p2 & (cell.p2 - 1)) != 0)):
            continue
        if name != "default" and getattr(impl, "hier", False) != cell.hier:
            continue
        if name != "default" and C.is_demoted(op, name):
            continue
        if (ctx.scratch_budget_bytes is not None and name != "default"
                and impl.extra_bytes(nbytes, p) > ctx.scratch_budget_bytes):
            continue
        out.append(name)
    return tuple(out)


_NO_PLAN = object()


def _dispatch_plan(op: str, payload, axis: str, ctx: TuneContext,
                   plan_vec, kw):
    """Emit the runtime-dispatch form of one site: ``lax.switch`` over
    the admissible impls, branch index read from the plan vector.
    Returns ``_NO_PLAN`` when the site must dispatch statically."""
    cell = _make_cell(op, payload, axis, kw)
    impls = _admissible_impls(op, cell, ctx)
    if len(impls) < 2:
        return _NO_PLAN
    slot = ctx.plan.slot(cell, current_phase(), impls)
    if slot is None:
        return _NO_PLAN
    ctx.record.append(DispatchRecord(cell, PLAN_IMPL, current_phase()))
    import jax.numpy as jnp
    from jax import lax
    from repro.core._axis import axis_is_vmapped, force_full_perm
    idx = jnp.clip(plan_vec[slot], 0, len(impls) - 1)
    reg = C.REGISTRY[op]
    branches = [(lambda f: (lambda _: f(payload, axis, **kw)))(reg[n].fn)
                for n in impls]
    # switch branches trace deferred, past pshift's own partial-perm
    # fallback — vmap-emulated axes must be told to pad proactively
    axes = [a for a in (axis, kw.get("rs_axis"))
            if isinstance(a, str) and axis_is_vmapped(a)]
    with force_full_perm(axes):
        return lax.switch(idx, branches, 0)


def _dispatch(op: str, payload, axis: str, impl: str | None, /, **kw):
    ctx = _ctx()
    if ctx is not None and ctx.chunk_bytes and "chunk" not in kw:
        itemsize = payload.dtype.itemsize
        kw["chunk"] = max(1, ctx.chunk_bytes // itemsize)
    if impl is None and ctx is not None and ctx.plan is not None:
        plan_vec = getattr(_TLS, "plan_vec", None)
        if (plan_vec is not None and op not in ctx.force
                and op not in _env_force()):
            out = _dispatch_plan(op, payload, axis, ctx, plan_vec, kw)
            if out is not _NO_PLAN:
                return out
    name = _select(op, payload, axis, impl, kw)
    return C.REGISTRY[op][name].fn(payload, axis, **kw)


# -- public entry points -----------------------------------------------------

def allgather(x, axis: str, *, inner_axis: str | None = None,
              impl: str | None = None):
    """With ``inner_axis`` the gather runs over the joint
    ``(axis, inner_axis)`` group in outer-major block order — ``axis`` is
    the OUTER (slow-tier) axis — and the cell records ``p2`` + the tier
    token, making the hierarchical ``MPIX_*`` mock-ups admissible."""
    if inner_axis is None:
        return _dispatch("allgather", x, axis, impl)
    return _dispatch("allgather", x, axis, impl, inner_axis=inner_axis)


def allreduce(x, axis: str, *, inner_axis: str | None = None,
              impl: str | None = None, **kw):
    """With ``inner_axis`` the sum runs over the joint group (see
    ``allgather``)."""
    if inner_axis is not None:
        kw["inner_axis"] = inner_axis
    return _dispatch("allreduce", x, axis, impl, **kw)


def reducescatter(x, axis: str, *, inner_axis: str | None = None,
                  impl: str | None = None):
    """With ``inner_axis`` the scatter runs over the joint group: rank
    ``(i, j)`` receives joint-sum block ``i*q + j`` (outer-major)."""
    if inner_axis is None:
        return _dispatch("reducescatter", x, axis, impl)
    return _dispatch("reducescatter", x, axis, impl, inner_axis=inner_axis)


def alltoall(x, axis: str, *, impl: str | None = None):
    return _dispatch("alltoall", x, axis, impl)


def bcast(x, axis: str, *, root: int = 0, impl: str | None = None):
    return _dispatch("bcast", x, axis, impl, root=root)


def gather(x, axis: str, *, root: int = 0, impl: str | None = None):
    return _dispatch("gather", x, axis, impl, root=root)


def scatter(x, axis: str, *, root: int = 0, impl: str | None = None):
    return _dispatch("scatter", x, axis, impl, root=root)


def reduce(x, axis: str, *, root: int = 0, impl: str | None = None, **kw):
    return _dispatch("reduce", x, axis, impl, root=root, **kw)


def scan(x, axis: str, *, op: str = "add", impl: str | None = None):
    return _dispatch("scan", x, axis, impl, op=op)


def exscan(x, axis: str, *, op: str = "add", impl: str | None = None):
    return _dispatch("exscan", x, axis, impl, op=op)


def allgather_matmul(x, w, axis: str, *, impl: str | None = None,
                     return_gathered: bool = False):
    """``all_gather(x, rows) @ w`` — fused-vs-unfused is a tuner decision.

    ``x`` per-shard ``[n, K]`` (the dispatch key is its payload, i.e. the
    bytes the collective moves), ``w`` ``[K, M]`` shard-local.  With
    ``return_gathered=True`` also returns ``all_gather(x)`` (the ring
    materializes it for free; custom VJPs reuse it instead of re-gathering).
    """
    return _dispatch("allgather_matmul", x, axis, impl, w=w,
                     return_gathered=return_gathered)


def matmul_reducescatter(x, w, axis: str, *, impl: str | None = None):
    """``reduce_scatter(x @ w, rows)`` — the mirror of ``allgather_matmul``
    (and its backward pairing).  ``x`` per-shard ``[p*n, K]``, ``w``
    ``[K, M]``; partial products are summed over ``axis`` and row-block i
    lands on shard i."""
    return _dispatch("matmul_reducescatter", x, axis, impl, w=w)


def matmul_accumulate(x, w, axis: str, *, impl: str | None = None,
                      return_gathered: bool = False):
    """``x @ all_gather(w, rows)`` — the contraction-dim collective matmul.

    ``w`` per-shard ``[K/p, M]`` (the K-dim FSDP weight shard; its payload
    is the dispatch key — those are the bytes the collective streams), ``x``
    ``[T, K]`` shard-local -> ``[T, M]``.  The gathered dim is CONTRACTED
    away, so neither row-block ring applies; the ``fused_ring`` mock-up
    streams weight blocks around the ring and accumulates partial products.
    ``return_gathered=True`` additionally returns the assembled full weight
    (the ring materializes it for free; custom VJPs reuse it for dx).
    """
    return _dispatch("matmul_accumulate", w, axis, impl, x=x,
                     return_gathered=return_gathered)


def matmul_reducescatter_2d(x, w, rs_axis: str, ag_axis: str, *,
                            impl: str | None = None,
                            return_gathered: bool = False):
    """``reduce_scatter(x @ all_gather(w, cols over ag_axis), rows over
    rs_axis)`` — the weight-stationary 2-D collective matmul.

    ``w`` per-shard ``[K, M/d]`` (the data-axis FSDP column block of a
    row-parallel weight; its payload is the dispatch key — those are the
    bytes the OUTER ring streams), ``x`` ``[T, K]`` shard-local ->
    ``[T/q, M]`` summed over ``rs_axis``.  Fuses BOTH the data-axis weight
    all-gather and the model-axis reduce-scatter around one matmul;
    fused-vs-unfused is a dispatcher decision per 2-D cell
    (``p`` = outer/gather axis, ``p2`` = inner/scatter axis).
    ``return_gathered=True`` additionally returns the assembled full
    weight ``[K, M]`` (the outer ring materializes it for free; the paired
    VJP reuses it for dx).
    """
    return _dispatch("matmul_reducescatter_2d", w, ag_axis, impl, x=x,
                     rs_axis=rs_axis, return_gathered=return_gathered)


def matmul_reducescatter_2d_t(g, x, rs_axis: str, ag_axis: str, *,
                              impl: str | None = None):
    """``reduce_scatter(all_gather(g, rows over ag_axis)ᵀ @ x, rows over
    rs_axis)`` — the TRANSPOSE 2-D schedule (the dw of the paired VJP).

    ``g`` per-shard ``[T/q, M]`` (the cotangent's gather-axis row block —
    the dispatch payload; its gathered dim is CONTRACTED away), ``x``
    ``[T, K]`` shard-local -> ``[M/d, K]`` summed over ``rs_axis``.
    Unlike the forward, the gather axis is the INNER ring here (the outer
    ring is the travelling accumulator over ``rs_axis``) — ``p`` still
    records the gather/stream axis, ``p2`` the scatter axis.  Dispatches
    through the same op as the forward (cells record role ``2dT``), so
    the tuner arbitrates it per cell too.
    """
    return _dispatch("matmul_reducescatter_2d", g, ag_axis, impl, x=x,
                     rs_axis=rs_axis, xpose=True)


def format_footer(ctx: TuneContext) -> str:
    """The paper's Listing-2 footer: which algorithm served each call."""
    lines = []
    seen = set()
    for op, p, nbytes, name, *_phase in ctx.record:
        key = (op, p, nbytes, name)
        if key in seen:
            continue
        seen.add(key)
        mpi = OP_TO_MPI.get(op, op)
        label = "default" if name == "default" else name
        lines.append(f"#@pgmpi alg {mpi} {nbytes} {label}")
    if ctx.scratch_budget_bytes is not None:
        lines.append(
            f"#@pgmpi config size_msg_buffer_bytes {ctx.scratch_budget_bytes}")
    return "\n".join(lines)
