"""The dispatching collective API — the PGMPITuneLib "PMPI layer".

All framework code (dist/, models/, train/) calls these entry points instead
of raw ``jax.lax`` collectives.  Selection order per call:

1. explicit ``impl=`` argument              (unit tests, hillclimbing)
2. context ``force`` table                  (PGMPITuneCLI ``--module=op:alg=x``)
3. ``PGTUNE_MODULE`` environment variable   (same syntax as the paper's CLI)
4. phase-specific performance profiles      (trace-replay tuning; the store
   matching the active ``api.phase`` tag)
5. loaded performance profiles              (PGMPITuneD online redirection)
6. the default implementation

Dispatch happens at TRACE time: JAX shapes are static, so the profile's
O(log M) binary search runs while tracing and the compiled program contains
only the winning algorithm — zero runtime overhead (an improvement over the
paper's runtime hash+bsearch, see DESIGN.md §2).

The context also carries the scratch budget (the paper's
``size_msg_buffer_bytes``): a mock-up whose Table-1 extra memory exceeds the
budget is not applied, exactly like PGMPITuneLib refusing replacements when
the user-controlled buffer is too small.

Every dispatch is recorded; ``format_footer()`` emits the paper's Listing-2
``#@pgmpi alg <op> <bytes> <impl>`` trailer.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading

from repro.core import collectives as C
from repro.core._axis import axis_size
from repro.core.cell import OP_MM_ROLE, OpCell
from repro.core.profiles import OP_TO_MPI, ProfileStore

_TLS = threading.local()


DEFAULT_PHASE = "fwd"


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    """One dispatched collective: the full problem cell, the impl the
    dispatcher chose, and the workload phase tag.  Destructures as the
    legacy ``(op, p, nbytes, impl, phase)`` 5-tuple."""
    cell: OpCell
    impl: str
    phase: str

    @property
    def op(self) -> str:
        return self.cell.op

    @property
    def p(self) -> int:
        return self.cell.p

    @property
    def nbytes(self) -> int:
        return self.cell.nbytes

    def __iter__(self):
        yield from (self.cell.op, self.cell.p, self.cell.nbytes, self.impl,
                    self.phase)


@dataclasses.dataclass
class TuneContext:
    profiles: ProfileStore | None = None
    force: dict[str, str] = dataclasses.field(default_factory=dict)
    scratch_budget_bytes: int | None = None
    record: list[DispatchRecord] = dataclasses.field(default_factory=list)
    chunk_bytes: int = 0
    phase_profiles: dict[str, ProfileStore] | None = None


def _ctx() -> TuneContext | None:
    return getattr(_TLS, "ctx", None)


def current_phase() -> str:
    """The active workload phase tag (see ``phase``); default ``"fwd"``."""
    return getattr(_TLS, "phase", DEFAULT_PHASE)


@contextlib.contextmanager
def phase(name: str):
    """Tag every dispatch issued inside with workload phase ``name``.

    Phases name the coarse callsite classes of an LM step — ``fwd`` (the
    ambient default), ``bwd`` (custom-VJP backwards + grad sync; dist/ops
    and train/trainer set this), ``prefill`` / ``decode`` (serving; set by
    launch/serve).  The tag is captured at TRACE time into
    ``TuneContext.record`` and selects the matching store from
    ``tuned(phase_profiles=...)``.
    """
    prev = current_phase()
    _TLS.phase = name
    try:
        yield
    finally:
        _TLS.phase = prev


@contextlib.contextmanager
def tuned(profiles: ProfileStore | None = None,
          force: dict[str, str] | None = None,
          scratch_budget_bytes: int | None = None,
          chunk_bytes: int = 0,
          phase_profiles: dict[str, ProfileStore] | None = None,
          record: list | None = None):
    """Activate tuning for every ``repro.core.api`` collective issued inside.

    ``force`` maps op name -> impl name (the CLI library's static selection);
    ``profiles`` is the PGMPITuneD mode.  ``phase_profiles`` maps a phase
    tag (see ``phase``) to a phase-specific ``ProfileStore`` consulted
    before ``profiles`` — the trace-replay tuner (``tuner.tune_trace``)
    emits these.  ``record`` lets the caller supply the list dispatches are
    appended to (shared across nested builder contexts).  Without any of
    these, defaults are used but calls are still recorded.
    """
    prev = _ctx()
    ctx = TuneContext(profiles=profiles, force=dict(force or {}),
                      scratch_budget_bytes=scratch_budget_bytes,
                      chunk_bytes=chunk_bytes,
                      phase_profiles=(dict(phase_profiles)
                                      if phase_profiles else None),
                      record=record if record is not None else [])
    _TLS.ctx = ctx
    try:
        yield ctx
    finally:
        _TLS.ctx = prev


def parse_module_spec(spec: str) -> dict[str, str]:
    """Parse the paper's ``--module=allgather:alg=allgather_as_gather_bcast``
    syntax (';'-separated for multiple ops)."""
    out: dict[str, str] = {}
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        op, _, alg = part.partition(":")
        key, _, val = alg.partition("=")
        if key != "alg" or not val:
            raise ValueError(f"bad module spec {part!r}")
        out[op.strip()] = val.strip()
    return out


_ENV_FORCE_CACHE: tuple[str, dict[str, str]] = ("", {})


def _env_force() -> dict[str, str]:
    """Parsed ``PGTUNE_MODULE``, memoized on the raw string — dispatch is a
    trace-time hot path and the env var rarely changes mid-process."""
    global _ENV_FORCE_CACHE
    spec = os.environ.get("PGTUNE_MODULE", "")
    if spec != _ENV_FORCE_CACHE[0]:
        _ENV_FORCE_CACHE = (spec, parse_module_spec(spec) if spec else {})
    return _ENV_FORCE_CACHE[1]


def _payload_bytes(x) -> int:
    return int(x.size) * x.dtype.itemsize


def _make_cell(op: str, payload, axis: str, kw) -> OpCell:
    """The dispatch-time tuning cell: payload + full problem geometry.

    ``payload`` is the operand the collective moves (its bytes are the
    dispatch key); for fused ops the per-callsite GEMM dims are read off
    the actual operands, so profiles/traces/measurement all see the true
    matmul.
    """
    p = axis_size(axis)
    nbytes = _payload_bytes(payload)
    role = OP_MM_ROLE.get(op)
    if role is None:
        return OpCell(op, p, nbytes, str(payload.dtype))
    if role == "2d":
        # two-axis op: p = outer stream axis, p2 = inner reduce-scatter
        # axis; recorded dims are the PER-RANK GEMM (see core/cell.py)
        p2 = axis_size(kw["rs_axis"])
        if kw.get("xpose"):  # payload g [T/p, M] streamed+contracted
            mm_k, mm_m = p * payload.shape[0], payload.shape[-1]
            mm_n = kw["x"].shape[-1]
            return OpCell(op, p, nbytes, str(payload.dtype),
                          mm_k, mm_m, mm_n, "2dT", p2)
        # payload w [K, M/p] column block streamed over the outer axis
        mm_k, mm_m = payload.shape[0], kw["x"].shape[0]
        mm_n = p * payload.shape[-1]
        return OpCell(op, p, nbytes, str(payload.dtype),
                      mm_k, mm_m, mm_n, "2d", p2)
    if role == "gather":     # payload x [n, K] gathered over rows, w [K, M]
        mm_k, mm_m = payload.shape[-1], p * payload.shape[0]
        mm_n = kw["w"].shape[-1]
    elif role == "scatter":  # payload x [p*n, K] rows scattered, w [K, M]
        mm_k, mm_m = payload.shape[-1], payload.shape[0]
        mm_n = kw["w"].shape[-1]
    else:                    # contract: payload = streamed w block [K/p, M]
        mm_k, mm_m = p * payload.shape[0], kw["x"].shape[0]
        mm_n = payload.shape[-1]
    return OpCell(op, p, nbytes, str(payload.dtype), mm_k, mm_m, mm_n, role)


def _select(op: str, payload, axis: str, impl: str | None, kw) -> str:
    ctx = _ctx()
    # hot-path short-circuit: with no explicit impl, no force table, no
    # profiles and no phase profiles, the answer is "default" — skip the
    # cell/phase/profile machinery entirely (dispatch runs at trace time
    # but sits on every collective of every jit trace; see
    # benchmarks/bench_dispatch.py for the win).  The pow2 and scratch
    # guards never demote "default", so skipping them is exact.
    if impl is None and (ctx is None or (not ctx.force and ctx.profiles is
                                         None and ctx.phase_profiles is
                                         None)) and not _env_force():
        if ctx is not None:
            ctx.record.append(DispatchRecord(_make_cell(op, payload, axis,
                                                        kw),
                                             "default", current_phase()))
        return "default"
    cell = _make_cell(op, payload, axis, kw)
    p, nbytes = cell.p, cell.nbytes
    ph = current_phase()
    name = impl
    if name is None and ctx is not None and op in ctx.force:
        name = ctx.force[op]
    if name is None:
        env = _env_force()
        if op in env:
            name = env[op]
    if name is None and ctx is not None:
        if ctx.phase_profiles is not None:
            store = ctx.phase_profiles.get(ph)
            if store is not None:
                name = store.lookup_cell(cell)
        if name is None and ctx.profiles is not None:
            name = ctx.profiles.lookup_cell(cell)
    if name is None:
        name = "default"
    cand = C.REGISTRY[op].get(name)
    if cand is None:
        raise KeyError(f"unknown impl {name!r} for op {op!r}")
    # pow2 guard + scratch budget (paper's size_msg_buffer_bytes semantics)
    if cand.requires_pow2 and (p & (p - 1)) != 0:
        name, cand = "default", C.REGISTRY[op]["default"]
    if (ctx is not None and ctx.scratch_budget_bytes is not None
            and name != "default"
            and cand.extra_bytes(nbytes, p) > ctx.scratch_budget_bytes):
        name, cand = "default", C.REGISTRY[op]["default"]
    if ctx is not None:
        ctx.record.append(DispatchRecord(cell, name, ph))
    return name


def _dispatch(op: str, payload, axis: str, impl: str | None, /, **kw):
    name = _select(op, payload, axis, impl, kw)
    fn = C.REGISTRY[op][name].fn
    ctx = _ctx()
    if ctx is not None and ctx.chunk_bytes and "chunk" not in kw:
        itemsize = payload.dtype.itemsize
        kw["chunk"] = max(1, ctx.chunk_bytes // itemsize)
    return fn(payload, axis, **kw)


# -- public entry points -----------------------------------------------------

def allgather(x, axis: str, *, impl: str | None = None):
    return _dispatch("allgather", x, axis, impl)


def allreduce(x, axis: str, *, impl: str | None = None, **kw):
    return _dispatch("allreduce", x, axis, impl, **kw)


def reducescatter(x, axis: str, *, impl: str | None = None):
    return _dispatch("reducescatter", x, axis, impl)


def alltoall(x, axis: str, *, impl: str | None = None):
    return _dispatch("alltoall", x, axis, impl)


def bcast(x, axis: str, *, root: int = 0, impl: str | None = None):
    return _dispatch("bcast", x, axis, impl, root=root)


def gather(x, axis: str, *, root: int = 0, impl: str | None = None):
    return _dispatch("gather", x, axis, impl, root=root)


def scatter(x, axis: str, *, root: int = 0, impl: str | None = None):
    return _dispatch("scatter", x, axis, impl, root=root)


def reduce(x, axis: str, *, root: int = 0, impl: str | None = None, **kw):
    return _dispatch("reduce", x, axis, impl, root=root, **kw)


def scan(x, axis: str, *, op: str = "add", impl: str | None = None):
    return _dispatch("scan", x, axis, impl, op=op)


def exscan(x, axis: str, *, op: str = "add", impl: str | None = None):
    return _dispatch("exscan", x, axis, impl, op=op)


def allgather_matmul(x, w, axis: str, *, impl: str | None = None,
                     return_gathered: bool = False):
    """``all_gather(x, rows) @ w`` — fused-vs-unfused is a tuner decision.

    ``x`` per-shard ``[n, K]`` (the dispatch key is its payload, i.e. the
    bytes the collective moves), ``w`` ``[K, M]`` shard-local.  With
    ``return_gathered=True`` also returns ``all_gather(x)`` (the ring
    materializes it for free; custom VJPs reuse it instead of re-gathering).
    """
    return _dispatch("allgather_matmul", x, axis, impl, w=w,
                     return_gathered=return_gathered)


def matmul_reducescatter(x, w, axis: str, *, impl: str | None = None):
    """``reduce_scatter(x @ w, rows)`` — the mirror of ``allgather_matmul``
    (and its backward pairing).  ``x`` per-shard ``[p*n, K]``, ``w``
    ``[K, M]``; partial products are summed over ``axis`` and row-block i
    lands on shard i."""
    return _dispatch("matmul_reducescatter", x, axis, impl, w=w)


def matmul_accumulate(x, w, axis: str, *, impl: str | None = None,
                      return_gathered: bool = False):
    """``x @ all_gather(w, rows)`` — the contraction-dim collective matmul.

    ``w`` per-shard ``[K/p, M]`` (the K-dim FSDP weight shard; its payload
    is the dispatch key — those are the bytes the collective streams), ``x``
    ``[T, K]`` shard-local -> ``[T, M]``.  The gathered dim is CONTRACTED
    away, so neither row-block ring applies; the ``fused_ring`` mock-up
    streams weight blocks around the ring and accumulates partial products.
    ``return_gathered=True`` additionally returns the assembled full weight
    (the ring materializes it for free; custom VJPs reuse it for dx).
    """
    return _dispatch("matmul_accumulate", w, axis, impl, x=x,
                     return_gathered=return_gathered)


def matmul_reducescatter_2d(x, w, rs_axis: str, ag_axis: str, *,
                            impl: str | None = None,
                            return_gathered: bool = False):
    """``reduce_scatter(x @ all_gather(w, cols over ag_axis), rows over
    rs_axis)`` — the weight-stationary 2-D collective matmul.

    ``w`` per-shard ``[K, M/d]`` (the data-axis FSDP column block of a
    row-parallel weight; its payload is the dispatch key — those are the
    bytes the OUTER ring streams), ``x`` ``[T, K]`` shard-local ->
    ``[T/q, M]`` summed over ``rs_axis``.  Fuses BOTH the data-axis weight
    all-gather and the model-axis reduce-scatter around one matmul;
    fused-vs-unfused is a dispatcher decision per 2-D cell
    (``p`` = outer/gather axis, ``p2`` = inner/scatter axis).
    ``return_gathered=True`` additionally returns the assembled full
    weight ``[K, M]`` (the outer ring materializes it for free; the paired
    VJP reuses it for dx).
    """
    return _dispatch("matmul_reducescatter_2d", w, ag_axis, impl, x=x,
                     rs_axis=rs_axis, return_gathered=return_gathered)


def matmul_reducescatter_2d_t(g, x, rs_axis: str, ag_axis: str, *,
                              impl: str | None = None):
    """``reduce_scatter(all_gather(g, rows over ag_axis)ᵀ @ x, rows over
    rs_axis)`` — the TRANSPOSE 2-D schedule (the dw of the paired VJP).

    ``g`` per-shard ``[T/q, M]`` (the cotangent's gather-axis row block —
    the dispatch payload; its gathered dim is CONTRACTED away), ``x``
    ``[T, K]`` shard-local -> ``[M/d, K]`` summed over ``rs_axis``.
    Unlike the forward, the gather axis is the INNER ring here (the outer
    ring is the travelling accumulator over ``rs_axis``) — ``p`` still
    records the gather/stream axis, ``p2`` the scatter axis.  Dispatches
    through the same op as the forward (cells record role ``2dT``), so
    the tuner arbitrates it per cell too.
    """
    return _dispatch("matmul_reducescatter_2d", g, ag_axis, impl, x=x,
                     rs_axis=rs_axis, xpose=True)


def format_footer(ctx: TuneContext) -> str:
    """The paper's Listing-2 footer: which algorithm served each call."""
    lines = []
    seen = set()
    for op, p, nbytes, name, *_phase in ctx.record:
        key = (op, p, nbytes, name)
        if key in seen:
            continue
        seen.add(key)
        mpi = OP_TO_MPI.get(op, op)
        label = "default" if name == "default" else name
        lines.append(f"#@pgmpi alg {mpi} {nbytes} {label}")
    if ctx.scratch_budget_bytes is not None:
        lines.append(
            f"#@pgmpi config size_msg_buffer_bytes {ctx.scratch_budget_bytes}")
    return "\n".join(lines)
