"""Offline tuning pass (paper §4.2): benchmark → detect violations → profile.

Workflow, faithful to the paper's three steps:

1. NREP estimation per (op, msize)   — measured backend only (Alg. 1, Eq. 1).
2. Benchmark default + every mock-up; a *violation* is a mock-up at least
   ``min_win`` (paper: 10%) faster than the default.  Among violating
   mock-ups the fastest is selected; one range per message size is written
   (degenerate [s, s] ranges exactly like Listing 1), then adjacent equal
   selections are coalesced.
3. The resulting ``ProfileStore`` drives ``api.tuned(profiles=...)`` — the
   PGMPITuneD online phase.

Two interchangeable backends:

* ``CostModelBackend``  — α-β-γ model (production scales: p = 16…1024).
* ``MeasuredBackend``   — wall-clock on host devices with barrier + NREP.

The tuner also verifies the other two guideline classes from [6]
(monotony / split-robustness) and reports — but does not repair — those.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Sequence

from repro.core import costmodel, measure, nrep
from repro.core.collectives import REGISTRY
from repro.core.profiles import Profile, ProfileStore, Range

DEFAULT_SIZES = (1, 8, 32, 64, 100, 512, 1024, 4096, 8192, 32768,
                 100_000, 1_048_576, 16_777_216)


@dataclasses.dataclass(frozen=True)
class Measurement:
    op: str
    impl: str
    axis_size: int
    nbytes: int
    latency: float          # seconds (median for measured backend)
    nrep: int = 1


@dataclasses.dataclass(frozen=True)
class Violation:
    gl_kind: str            # "pattern" | "monotony" | "split_robustness"
    op: str
    axis_size: int
    nbytes: int
    detail: str
    speedup: float          # default / best  (>1 means violation)
    best_impl: str | None = None


@dataclasses.dataclass
class TuneReport:
    measurements: list[Measurement]
    violations: list[Violation]
    profiles: ProfileStore

    def summary(self) -> str:
        pat = [v for v in self.violations if v.gl_kind == "pattern"]
        lines = [f"measurements: {len(self.measurements)}",
                 f"pattern violations: {len(pat)}",
                 f"other violations: {len(self.violations) - len(pat)}",
                 f"profiles written: {len(self.profiles)}"]
        return "\n".join(lines)


class CostModelBackend:
    """Latency = analytic model; deterministic, any axis size."""

    name = "costmodel"

    def __init__(self, topo: costmodel.Topo, *, chunk_bytes: int = 0):
        self.topo = topo
        self.chunk_bytes = chunk_bytes

    def latency(self, op: str, impl: str, p: int, nbytes: int) -> float:
        return costmodel.latency(op, impl, p, nbytes, self.topo,
                                 chunk_bytes=self.chunk_bytes)

    def nrep_for(self, op: str, impl: str, nbytes: int) -> int:
        return 1


class MeasuredBackend:
    """Wall-clock on host devices; NREP via the paper's estimator."""

    name = "measured"

    def __init__(self, *, rse_1byte: float = 0.05, rse_large: float = 0.10,
                 K: int = 5, max_nrep: int = 50):
        self.rse_1byte = rse_1byte
        self.rse_large = rse_large
        self.K = K
        self.max_nrep = max_nrep
        self._one_byte: dict[tuple[str, str], nrep.OneByteEstimate] = {}

    def _ob(self, op: str, impl: str) -> nrep.OneByteEstimate:
        key = (op, impl)
        if key not in self._one_byte:
            self._one_byte[key] = nrep.estimate_1byte(
                measure.make_sampler(op, impl),
                rse_threshold=self.rse_1byte, batch0=5, max_samples=60)
        return self._one_byte[key]

    def nrep_for(self, op: str, impl: str, nbytes: int) -> int:
        n = nrep.estimate_nrep(measure.make_sampler(op, impl), nbytes,
                               self._ob(op, impl),
                               rse_threshold=self.rse_large, K=self.K)
        return min(n, self.max_nrep)

    def latency(self, op: str, impl: str, p: int, nbytes: int) -> float:
        if p != measure.axis_size():
            raise ValueError(
                f"measured backend runs at p={measure.axis_size()}, not {p}")
        count = self.nrep_for(op, impl, nbytes)
        samples = measure.sample_latency(op, impl, nbytes, count)
        return statistics.median(samples)


def tune(ops: Sequence[str] | None = None,
         sizes: Sequence[int] = DEFAULT_SIZES,
         axis_size: int = 16,
         backend=None,
         *, min_win: float = 0.10,
         scratch_budget_bytes: int | None = None,
         coalesce: bool = True) -> TuneReport:
    """Run the full offline pass and build profiles.

    ``min_win`` is the paper's "only replace if the mock-up is at least 10%
    faster"; ``scratch_budget_bytes`` enforces Table-1 extra memory.
    """
    ops = list(ops or REGISTRY.keys())
    backend = backend or CostModelBackend(costmodel.V5E_ICI)
    p = axis_size
    ms: list[Measurement] = []
    vios: list[Violation] = []
    store = ProfileStore()

    for op in ops:
        picks: list[tuple[int, str]] = []   # (nbytes, winning impl)
        lat_by_size: dict[int, dict[str, float]] = {}
        for nbytes in sizes:
            lats: dict[str, float] = {}
            for impl_name, impl in REGISTRY[op].items():
                if impl.requires_pow2 and (p & (p - 1)) != 0:
                    continue
                if (scratch_budget_bytes is not None
                        and impl_name != "default"
                        and impl.extra_bytes(nbytes, p) > scratch_budget_bytes):
                    continue
                t = backend.latency(op, impl_name, p, nbytes)
                if math.isinf(t):
                    continue
                lats[impl_name] = t
                ms.append(Measurement(op, impl_name, p, nbytes, t,
                                      backend.nrep_for(op, impl_name, nbytes)))
            lat_by_size[nbytes] = lats
            t_def = lats["default"]
            cands = {k: v for k, v in lats.items() if k != "default"}
            if not cands:
                continue
            best = min(cands, key=cands.get)
            if cands[best] < t_def * (1.0 - min_win):
                gl = REGISTRY[op][best].guideline or "EXT"
                vios.append(Violation(
                    "pattern", op, p, nbytes,
                    f"{gl}: {op} default {t_def:.3e}s > {best} "
                    f"{cands[best]:.3e}s", t_def / cands[best], best))
                picks.append((nbytes, best))

        # monotony: T(n1) <= T(n2) for n1 < n2 (default impl)
        sorted_sizes = sorted(lat_by_size)
        for a, b in zip(sorted_sizes, sorted_sizes[1:]):
            ta, tb = lat_by_size[a]["default"], lat_by_size[b]["default"]
            if ta > tb * (1.0 + min_win):
                vios.append(Violation(
                    "monotony", op, p, b,
                    f"T({a}B)={ta:.3e} > T({b}B)={tb:.3e}", ta / tb))
        # split-robustness: k chunks of n/k not faster than one op on n
        for nbytes in sorted_sizes:
            if nbytes < 8:
                continue
            for k in (2, 4):
                part = nbytes // k
                if part in lat_by_size:
                    t_whole = lat_by_size[nbytes]["default"]
                    t_split = k * lat_by_size[part]["default"]
                    if t_split < t_whole * (1.0 - min_win):
                        vios.append(Violation(
                            "split_robustness", op, p, nbytes,
                            f"{k}x{part}B = {t_split:.3e} < {t_whole:.3e}",
                            t_whole / t_split))

        if picks:
            ranges = [Range(nb, nb, impl) for nb, impl in sorted(picks)]
            if coalesce:
                ranges = _coalesce(ranges)
            store.add(Profile(op=op, axis_size=p, ranges=ranges,
                              meta={"backend": backend.name,
                                    "min_win": min_win}))

    return TuneReport(measurements=ms, violations=vios, profiles=store)


def _coalesce(ranges: list[Range]) -> list[Range]:
    """Merge adjacent measured sizes that picked the same impl into one
    closed range (covers the gap between the discrete sizes)."""
    out: list[Range] = []
    for r in ranges:
        if out and out[-1].impl == r.impl:
            out[-1] = Range(out[-1].lo, r.hi, r.impl)
        else:
            out.append(r)
    return out
