"""Offline tuning pass (paper §4.2): benchmark → detect violations → profile.

Workflow, faithful to the paper's three steps:

1. NREP estimation per (op, msize)   — measured backend only (Alg. 1, Eq. 1).
2. Benchmark default + every mock-up; a *violation* is a mock-up at least
   ``min_win`` (paper: 10%) faster than the default.  Among violating
   mock-ups the fastest is selected; one range per message size is written
   (degenerate [s, s] ranges exactly like Listing 1), then adjacent equal
   selections are coalesced.
3. The resulting ``ProfileStore`` drives ``api.tuned(profiles=...)`` — the
   PGMPITuneD online phase.

Two interchangeable backends:

* ``CostModelBackend``  — α-β-γ model (production scales: p = 16…1024).
* ``MeasuredBackend``   — wall-clock on host devices with barrier + NREP.

The tuner also verifies the other two guideline classes from [6]
(monotony / split-robustness) and reports — but does not repair — those.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Sequence

from repro.core import costmodel, measure, nrep
from repro.core import profiles as profiles_mod
from repro.core.cell import OpCell
from repro.core.collectives import REGISTRY, is_demoted
from repro.core.profiles import Profile, ProfileStore, Range

DEFAULT_SIZES = (1, 8, 32, 64, 100, 512, 1024, 4096, 8192, 32768,
                 100_000, 1_048_576, 16_777_216)


@dataclasses.dataclass(frozen=True)
class Measurement:
    cell: OpCell
    impl: str
    latency: float          # seconds (median for measured backend)
    nrep: int = 1

    @property
    def op(self) -> str:
        return self.cell.op

    @property
    def axis_size(self) -> int:
        return self.cell.p

    @property
    def nbytes(self) -> int:
        return self.cell.nbytes


@dataclasses.dataclass(frozen=True)
class Violation:
    gl_kind: str            # "pattern" | "monotony" | "split_robustness"
    op: str
    axis_size: int
    nbytes: int
    detail: str
    speedup: float          # default / best  (>1 means violation)
    best_impl: str | None = None


@dataclasses.dataclass
class TuneReport:
    measurements: list[Measurement]
    violations: list[Violation]
    profiles: ProfileStore
    notes: list[str] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        pat = [v for v in self.violations if v.gl_kind == "pattern"]
        lines = [f"measurements: {len(self.measurements)}",
                 f"pattern violations: {len(pat)}",
                 f"other violations: {len(self.violations) - len(pat)}",
                 f"profiles written: {len(self.profiles)}"]
        lines += [f"note: {n}" for n in self.notes]
        return "\n".join(lines)


class CostModelBackend:
    """Latency = analytic model; deterministic, any axis size.

    Backends price ``OpCell``s: a cell with recorded matmul geometry is
    priced from its true flops (``costmodel.latency_cell``); geometry-less
    cells use the canonical table.

    ``topo`` may be a flat ``costmodel.Topo`` or a per-axis
    ``costmodel.MeshTopo``: with a mesh topo, each cell's ``tier`` token
    resolves to its (outer, inner) tier pair, so a DCN-crossing cell and
    an all-ICI cell of the same shape price differently — and the
    hierarchical ``MPIX_*`` mock-ups become finitely priced on
    hierarchical cells.
    """

    name = "costmodel"
    supported_axis_size: int | None = None      # any p

    def __init__(self, topo: "costmodel.Topo | costmodel.MeshTopo", *,
                 chunk_bytes: int = 0):
        self.topo = topo
        self.chunk_bytes = chunk_bytes

    def latency(self, cell: OpCell, impl: str) -> float:
        return costmodel.latency_cell(cell, impl, self.topo,
                                      chunk_bytes=self.chunk_bytes)

    def nrep_for(self, cell: OpCell, impl: str) -> int:
        return 1


class MeasuredBackend:
    """Wall-clock on host devices; NREP via the paper's estimator.

    Replays each cell's RECORDED problem — for fused collective-matmul
    cells that is the callsite's actual GEMM ``(dtype, mm_k, mm_m, mm_n)``,
    not a canonical square weight.  Fused cells without geometry (v1
    traces) are unmeasurable (``inf``), which the tuner note-skips.
    """

    name = "measured"

    def __init__(self, *, rse_1byte: float = 0.05, rse_large: float = 0.10,
                 K: int = 5, max_nrep: int = 50):
        self.rse_1byte = rse_1byte
        self.rse_large = rse_large
        self.K = K
        self.max_nrep = max_nrep
        self._one_byte: dict[tuple, nrep.OneByteEstimate] = {}
        self._nrep: dict[tuple, int] = {}

    @property
    def supported_axis_size(self) -> int:
        """Wall clock only exists at the axis size the host devices form;
        the trace-replay tuner skips (and notes) every other cell."""
        return measure.axis_size()

    @staticmethod
    def _measurable(cell: OpCell) -> bool:
        return cell.op not in measure.MATMUL_OPS or cell.fused

    def _ob(self, cell: OpCell, impl: str) -> nrep.OneByteEstimate:
        # for fused cells scaled_to(1) floors at ONE GEMM row/block, so the
        # anchor is the minimal fused problem rather than a literal byte —
        # a conservatively high floor; max_nrep bounds the resulting reps
        key = (cell.scaled_to(1), impl)
        if key not in self._one_byte:
            self._one_byte[key] = nrep.estimate_1byte(
                measure.make_sampler(cell, impl),
                rse_threshold=self.rse_1byte, batch0=5, max_samples=60)
        return self._one_byte[key]

    def nrep_for(self, cell: OpCell, impl: str) -> int:
        # memoized: latency() and the Measurement record both ask, and each
        # estimate costs real barrier-synced timed samples
        if not self._measurable(cell):
            return 1
        key = (cell, impl)
        if key not in self._nrep:
            n = nrep.estimate_nrep(measure.make_sampler(cell, impl),
                                   cell.nbytes, self._ob(cell, impl),
                                   rse_threshold=self.rse_large, K=self.K)
            self._nrep[key] = min(n, self.max_nrep)
        return self._nrep[key]

    def latency(self, cell: OpCell, impl: str) -> float:
        if cell.world() != measure.axis_size():
            raise ValueError(
                f"measured backend runs at world={measure.axis_size()}, "
                f"not {cell.world()} (cell p={cell.p}, p2={cell.p2})")
        if not self._measurable(cell):
            # fused op without recorded geometry: nothing faithful to replay
            return math.inf
        count = self.nrep_for(cell, impl)
        samples = measure.sample_latency(cell, impl, count)
        return statistics.median(samples)


def tune(ops: Sequence[str] | None = None,
         sizes: Sequence[int] = DEFAULT_SIZES,
         axis_size: int = 16,
         backend=None,
         *, min_win: float = 0.10,
         scratch_budget_bytes: int | None = None,
         coalesce: bool = True) -> TuneReport:
    """Run the full offline pass and build profiles.

    ``min_win`` is the paper's "only replace if the mock-up is at least 10%
    faster"; ``scratch_budget_bytes`` enforces Table-1 extra memory.
    """
    ops = list(ops or REGISTRY.keys())
    backend = backend or CostModelBackend(costmodel.V5E_ICI)
    p = axis_size
    ms: list[Measurement] = []
    vios: list[Violation] = []
    notes: list[str] = []
    store = ProfileStore()

    sup = getattr(backend, "supported_axis_size", None)
    if sup is not None and p != sup:
        notes.append(f"axis_size {p} != backend's host axis size {sup}; "
                     "nothing measured (run on a mesh of that size or use "
                     "the cost-model backend)")
        return TuneReport(measurements=ms, violations=vios, profiles=store,
                          notes=notes)

    for op in ops:
        picks: list[tuple[int, str]] = []   # (nbytes, winning impl)
        lat_by_size: dict[int, dict[str, float]] = {}
        for nbytes in sizes:
            lats = _measure_cell(OpCell(op, p, nbytes), backend,
                                 scratch_budget_bytes, ms)
            t_def = lats.get("default")
            if t_def is None:
                # default unmeasurable (inf latency / skipped by the
                # backend): nothing to compare mock-ups against — skip the
                # size rather than crash, and record why.
                notes.append(f"{op} p={p} {nbytes}B: default impl "
                             "unmeasurable; size skipped")
                continue
            lat_by_size[nbytes] = lats
            cands = {k: v for k, v in lats.items() if k != "default"}
            if not cands:
                continue
            best = min(cands, key=cands.get)
            if cands[best] < t_def * (1.0 - min_win):
                gl = REGISTRY[op][best].guideline or "EXT"
                vios.append(Violation(
                    "pattern", op, p, nbytes,
                    f"{gl}: {op} default {t_def:.3e}s > {best} "
                    f"{cands[best]:.3e}s", t_def / cands[best], best))
                picks.append((nbytes, best))

        # monotony: T(n1) <= T(n2) for n1 < n2 (default impl)
        sorted_sizes = sorted(lat_by_size)
        for a, b in zip(sorted_sizes, sorted_sizes[1:]):
            ta, tb = lat_by_size[a]["default"], lat_by_size[b]["default"]
            if ta > tb * (1.0 + min_win):
                vios.append(Violation(
                    "monotony", op, p, b,
                    f"T({a}B)={ta:.3e} > T({b}B)={tb:.3e}", ta / tb))
        # split-robustness: k chunks of n/k not faster than one op on n
        for nbytes in sorted_sizes:
            if nbytes < 8:
                continue
            for k in (2, 4):
                part = nbytes // k
                if part in lat_by_size:
                    t_whole = lat_by_size[nbytes]["default"]
                    t_split = k * lat_by_size[part]["default"]
                    if t_split < t_whole * (1.0 - min_win):
                        vios.append(Violation(
                            "split_robustness", op, p, nbytes,
                            f"{k}x{part}B = {t_split:.3e} < {t_whole:.3e}",
                            t_whole / t_split))

        if picks:
            ranges = [Range(nb, nb, impl) for nb, impl in sorted(picks)]
            if coalesce:
                ranges = _coalesce(ranges)
            store.add(Profile(op=op, axis_size=p, ranges=ranges,
                              meta={"backend": backend.name,
                                    "min_win": min_win}))

    return TuneReport(measurements=ms, violations=vios, profiles=store,
                      notes=notes)


def _measure_cell(cell: OpCell, backend,
                  scratch_budget_bytes: int | None,
                  ms: list[Measurement]) -> dict[str, float]:
    """Benchmark every admissible impl of one tuning cell — the §4.2
    admission rules (pow2 guard, Table-1 scratch budget, inf filter)
    shared by the sweep tuner and the trace-replay tuner.  Appends to
    ``ms`` and returns ``{impl: latency}``."""
    lats: dict[str, float] = {}
    p, nbytes = cell.p, cell.nbytes
    for impl_name, impl in REGISTRY[cell.op].items():
        if impl.requires_pow2 and (
                (p & (p - 1)) != 0
                or (cell.p2 and (cell.p2 & (cell.p2 - 1)) != 0)):
            continue
        # hier impls only fit hierarchical cells and vice versa; the cost
        # model prices the mismatch inf, but the measured backend would
        # CRASH replaying a flat mock-up on a two-axis problem — gate here
        if getattr(impl, "hier", False) != cell.hier and impl_name != "default":
            continue
        if impl_name != "default" and is_demoted(cell.op, impl_name):
            continue
        if (scratch_budget_bytes is not None
                and impl_name != "default"
                and impl.extra_bytes(nbytes, p) > scratch_budget_bytes):
            continue
        t = backend.latency(cell, impl_name)
        if math.isinf(t):
            continue
        lats[impl_name] = t
        ms.append(Measurement(cell, impl_name, t,
                              backend.nrep_for(cell, impl_name)))
    return lats


# ---------------------------------------------------------------------------
# trace replay (PGMPI-style per-callsite tuning, arXiv:1606.00215)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceTuneReport:
    """Result of tuning against a recorded workload trace.

    ``phase_profiles`` maps each phase tag found in the trace to a
    ``ProfileStore`` built from the (op, axis_size, nbytes) cells that phase
    actually issued — feed it to ``api.tuned(phase_profiles=...)``.
    ``est_default_s`` / ``est_tuned_s`` are the backend's frequency-weighted
    total collective latency per phase (each cell weighted by its trace
    count), i.e. the modeled communication time of replaying the trace with
    defaults vs with the emitted profiles.
    """
    phase_profiles: dict[str, ProfileStore]
    measurements: list[Measurement]
    est_default_s: dict[str, float]
    est_tuned_s: dict[str, float]
    notes: list[str] = dataclasses.field(default_factory=list)

    def store(self, phase: str) -> ProfileStore | None:
        return self.phase_profiles.get(phase)

    def summary(self) -> str:
        lines = []
        for ph in sorted(self.est_default_s):
            d, t = self.est_default_s[ph], self.est_tuned_s[ph]
            n = len(self.phase_profiles.get(ph, ()))
            sp = d / t if t > 0 else 1.0
            lines.append(f"{ph}: {n} profiles, modeled {d*1e6:.1f}us -> "
                         f"{t*1e6:.1f}us ({sp:.2f}x)")
        lines += [f"note: {n}" for n in self.notes]
        return "\n".join(lines) or "empty trace"

    def save(self, directory, *, fmt: str = "text",
             epoch: int | None = None,
             source_digest: str | None = None) -> None:
        """One subdirectory per phase (``<dir>/<phase>/<op>_p<P>.pgtune``) —
        the layout ``profiles.load_stores`` / ``PGTUNE_PROFILE_DIR``
        consumers read back.

        With ``epoch=`` the write becomes a fleet profile *generation*: a
        top-level ``MANIFEST.json`` (epoch, source-shard digest, geometry
        census) is written LAST, so a ``resolve_stores(watch=True)`` ref
        polling the directory only ever swaps in a complete epoch."""
        import pathlib
        d = pathlib.Path(directory)
        for ph, store in sorted(self.phase_profiles.items()):
            store.save(d / ph, fmt=fmt)
        if epoch is not None:
            profiles_mod.write_manifest(d, epoch, source_digest=source_digest,
                                        phases=self.phase_profiles)


def tune_trace(trace, backend=None, *, min_win: float = 0.10,
               scratch_budget_bytes: int | None = None,
               coalesce: bool = True) -> TraceTuneReport:
    """Tune against a recorded op mix instead of a synthetic size sweep.

    For every phase in ``trace`` and every (op, axis_size, nbytes) cell that
    phase recorded, benchmark the default and every admissible mock-up on
    ``backend`` and select the fastest mock-up that beats the default by at
    least ``min_win`` — exactly the §4.2 violation rule, but evaluated only
    at the message sizes / axis sizes the workload actually issued and
    weighted by how often it issued them.  Emits one ``ProfileStore`` per
    phase, so e.g. the backward's reduce-scatters can select a different
    mock-up than the forward's all-gathers.

    With a ``MeasuredBackend`` this is the ROADMAP "measured-backend trace
    replay": each recorded cell is re-executed on the host devices with its
    RECORDED problem — fused collective-matmul cells replay the callsite's
    actual GEMM ``(dtype, mm_k, mm_m, mm_n)`` — and timed (serving profiles
    from wall clock, not the model).  Cells whose ``p`` differs from
    ``measure.axis_size()`` cannot be replayed and are skipped with a note;
    so are fused cells without recorded geometry (v1 traces).

    Emitted profiles are keyed like the cells: fused cells produce one
    geometry profile per ``(op, p, Geom)`` — the store's nearest-cell
    fallback covers unseen shapes at dispatch.
    """
    backend = backend or CostModelBackend(costmodel.V5E_ICI)
    sup = getattr(backend, "supported_axis_size", None)
    ms: list[Measurement] = []
    notes: list[str] = []
    phase_profiles: dict[str, ProfileStore] = {}
    est_default: dict[str, float] = {}
    est_tuned: dict[str, float] = {}
    # fwd and bwd often share cells; measure each OpCell once — this
    # matters for the measured backend doing real timed runs
    lat_cache: dict[OpCell, dict[str, float]] = {}

    for ph in trace.phases():
        picks: dict[tuple, list[tuple[int, str]]] = {}
        t_d = t_t = 0.0
        for cell, weight in sorted(trace.cells(phase=ph).items()):
            op, p, nbytes = cell.op, cell.p, cell.nbytes
            if op not in REGISTRY:
                notes.append(f"{ph}: unknown op {op!r}; cell skipped")
                continue
            if sup is not None and cell.world() != sup:
                wd = (f"world={cell.world()} (p={p}, p2={cell.p2})"
                      if cell.p2 else f"p={p}")
                notes.append(f"{ph}: {op} {nbytes}B: {wd} != host axis "
                             f"size {sup}; cell skipped")
                continue
            if cell not in lat_cache:
                lat_cache[cell] = _measure_cell(cell, backend,
                                                scratch_budget_bytes, ms)
            lats = lat_cache[cell]
            t_def = lats.get("default")
            if t_def is None:
                # don't let a fused cell's inf latency vanish silently: say
                # WHY it was unmeasurable — a fused op without recorded
                # geometry (v1 trace) has nothing faithful to replay, and
                # the report footer must carry that (regression:
                # measure.sample_latency inf inside tune_trace aggregation)
                if op in measure.MATMUL_OPS and not cell.fused:
                    notes.append(
                        f"{ph}: {op} p={p} {nbytes}B: fused cell has no "
                        "recorded GEMM geometry (v1 trace?); unmeasurable, "
                        "cell skipped — re-record the trace with schema v2")
                else:
                    notes.append(f"{ph}: {op} p={p} {nbytes}B: default impl "
                                 "unmeasurable; cell skipped")
                continue
            t_d += weight * t_def
            cands = {k: v for k, v in lats.items() if k != "default"}
            best = min(cands, key=cands.get) if cands else None
            if best is not None and cands[best] < t_def * (1.0 - min_win):
                picks.setdefault(
                    (op, p, cell.geom(), cell.profile_tier()), []).append(
                    (nbytes, best))
                t_t += weight * cands[best]
            else:
                t_t += weight * t_def

        for (op, p, geom, tier), pk in sorted(
                picks.items(), key=lambda kv: (kv[0][0], kv[0][1],
                                               str(kv[0][2]), kv[0][3])):
            ranges = [Range(nb, nb, impl) for nb, impl in sorted(pk)]
            if coalesce:
                ranges = _coalesce(ranges)
            meta = {"backend": backend.name, "min_win": min_win,
                    "phase": ph, "source": "trace"}
            phase_profiles.setdefault(ph, ProfileStore()).add(
                Profile(op=op, axis_size=p, ranges=ranges, meta=meta,
                        geom=geom, tier=tier))
        est_default[ph] = t_d
        est_tuned[ph] = t_t

    return TraceTuneReport(phase_profiles=phase_profiles, measurements=ms,
                           est_default_s=est_default, est_tuned_s=est_tuned,
                           notes=notes)


# ---------------------------------------------------------------------------
# fleet feedback (exploration-budget measurements -> next epoch's tuner)
# ---------------------------------------------------------------------------


def _mad_filter(samples: list[float], k: float) -> list[float]:
    """Median/MAD outlier rejection: keep samples within ``k`` robust
    deviations of the median.  The scale is floored at 5% of |median|
    (and an absolute epsilon) because the MAD of near-identical samples
    is 0, which would reject every sample but the exact median.  Returns
    at least ``[median]`` so a cell never loses ALL its observations."""
    if len(samples) < 3 or k <= 0:
        return list(samples)
    med = statistics.median(samples)
    mad = statistics.median([abs(x - med) for x in samples])
    scale = max(mad, 0.05 * abs(med), 1e-12)
    kept = [x for x in samples if abs(x - med) <= k * scale]
    return kept or [med]


class FeedbackBackend:
    """A backend that prefers LIVE fleet measurements over its base estimate.

    The exploration budget (``Plan.explore`` + ``ShardRecorder.observe``)
    deposits real ``(cell, impl, latency)`` samples into the trace shards;
    ``trace.load_shard_latencies`` collects them across the fleet.  Wrapping
    the next epoch's tuner backend in this class makes ``tune_trace`` price
    any (cell, impl) with enough observed samples from the fleet's own wall
    clock — the loop that lets profiles track hardware/load drift — while
    everything unexplored still falls back to the base backend.

    Fleet measurements are HOSTILE inputs: one explored step that landed
    on a network hiccup can be 100× the true latency, and with only a
    handful of samples per (cell, impl) even a median shifts.  Samples
    are therefore filtered at construction with median/MAD outlier
    rejection (drop anything more than ``mad_k`` robust deviations from
    the median; the MAD is floored at 5% of the median so near-identical
    samples don't reject everything); ``rejected`` counts the dropped
    samples for the chaos gates.  Set ``mad_k=0`` to disable.
    """

    def __init__(self, base, observed: dict[tuple[OpCell, str],
                                            Sequence[float]],
                 *, min_samples: int = 3, mad_k: float = 4.0):
        self.base = base
        self.name = f"feedback+{base.name}"
        self.min_samples = min_samples
        self.mad_k = float(mad_k)
        self.rejected = 0
        self._obs: dict[tuple[OpCell, str], list[float]] = {}
        for k, v in observed.items():
            if len(v) == 0:
                continue
            kept = _mad_filter([float(x) for x in v], self.mad_k)
            self.rejected += len(v) - len(kept)
            self._obs[k] = kept

    @property
    def supported_axis_size(self) -> int | None:
        # cells WITH observations need no replay, but unexplored cells
        # still hit the base backend, so its replay constraint stands
        return getattr(self.base, "supported_axis_size", None)

    def observed_for(self, cell: OpCell, impl: str) -> list[float]:
        return list(self._obs.get((cell, impl), ()))

    def latency(self, cell: OpCell, impl: str) -> float:
        s = self._obs.get((cell, impl))
        if s is not None and len(s) >= self.min_samples:
            return statistics.median(s)
        return self.base.latency(cell, impl)

    def nrep_for(self, cell: OpCell, impl: str) -> int:
        s = self._obs.get((cell, impl))
        if s is not None and len(s) >= self.min_samples:
            return len(s)
        return self.base.nrep_for(cell, impl)


def estimate_trace_cost(trace, backend=None, *,
                        base: ProfileStore | None = None,
                        phases: dict[str, ProfileStore] | None = None,
                        scratch_budget_bytes: int | None = None
                        ) -> dict[str, float]:
    """Frequency-weighted modeled collective time of serving ``trace``
    under a given set of profiles — the fleet benchmark's yardstick for
    "the merged profile beats any single-shard profile on the union
    workload".

    For every recorded cell the impl the stores would dispatch (phase
    store, then ``base``, then the default) is priced on ``backend`` and
    weighted by the cell's trace count.  Inadmissible or unmeasurable
    selections fall back to the default impl, mirroring dispatch.
    """
    backend = backend or CostModelBackend(costmodel.V5E_ICI)
    out: dict[str, float] = {}
    for ph in trace.phases():
        total = 0.0
        for cell, weight in sorted(trace.cells(phase=ph).items()):
            if cell.op not in REGISTRY:
                continue
            name = None
            store = (phases or {}).get(ph)
            if store is not None:
                name = store.lookup_cell(cell)
            if name is None and base is not None:
                name = base.lookup_cell(cell)
            if name is None or name not in REGISTRY[cell.op]:
                name = "default"
            impl = REGISTRY[cell.op][name]
            p, nbytes = cell.p, cell.nbytes
            if name != "default" and (
                    (impl.requires_pow2 and (
                        (p & (p - 1)) != 0
                        or (cell.p2 and (cell.p2 & (cell.p2 - 1)) != 0)))
                    or getattr(impl, "hier", False) != cell.hier
                    or is_demoted(cell.op, name)
                    or (scratch_budget_bytes is not None
                        and impl.extra_bytes(nbytes, p)
                        > scratch_budget_bytes)):
                name = "default"
            t = backend.latency(cell, name)
            if math.isinf(t) and name != "default":
                t = backend.latency(cell, "default")
            if math.isinf(t):
                continue
            total += weight * t
        out[ph] = total
    return out


def _coalesce(ranges: list[Range]) -> list[Range]:
    """Merge adjacent measured sizes that picked the same impl into one
    closed range (covers the gap between the discrete sizes)."""
    out: list[Range] = []
    for r in ranges:
        if out and out[-1].impl == r.impl:
            out[-1] = Range(out[-1].lo, r.hi, r.impl)
        else:
            out.append(r)
    return out
