"""Axis helpers usable under both shard_map and vmap(axis_name=...).

All collective mock-ups are written against these thin wrappers so the same
code path is exercised by (a) single-device vmap semantic tests, (b)
multi-host-device shard_map tests, and (c) the production mesh lowering.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax import lax

_TLS = threading.local()


def axis_size(axis_name: str) -> int:
    """Static size of a named axis (trace-time Python int)."""
    if hasattr(lax, "axis_size"):
        return int(lax.axis_size(axis_name))
    # Fallback: psum of a unit literal is folded to the axis size.
    return int(lax.psum(1, axis_name))


def axis_index(axis_name: str):
    """Index of this shard along ``axis_name`` (traced int32)."""
    return lax.axis_index(axis_name)


def ring_perm(p: int, shift: int = 1) -> list[tuple[int, int]]:
    """Permutation sending rank i -> rank (i + shift) % p (ICI ring hop)."""
    return [(i, (i + shift) % p) for i in range(p)]


def shift_perm(p: int, shift: int) -> list[tuple[int, int]]:
    """Non-wrapping shift: rank i -> i + shift (ranks without a source
    receive zeros, which ppermute guarantees)."""
    if shift >= 0:
        return [(i, i + shift) for i in range(p - shift)]
    return [(i, i + shift) for i in range(-shift, p)]


def axis_is_vmapped(axis_name: str) -> bool:
    """True when ``axis_name`` is bound by a vmap ``BatchTrace`` in the
    CURRENT trace chain (as opposed to a shard_map mesh axis).  Callers
    that defer collective tracing (``lax.switch`` branches — the runtime
    dispatch plans) must ask here, at the call site: inside the branch
    the chain is cut and the answer is unknowable."""
    from jax._src import core as _core
    t = getattr(_core.trace_ctx, "trace", None)
    while t is not None:
        data = getattr(t, "axis_data", None)
        if (type(t).__name__ == "BatchTrace" and data is not None
                and data.name == axis_name):
            return True
        t = getattr(t, "parent_trace", None)
    return False


@contextlib.contextmanager
def force_full_perm(axis_names):
    """Make ``pshift`` over these axes emit COMPLETE permutations for the
    duration.  Needed around deferred tracing (``lax.switch`` branches)
    of a vmap-emulated axis: the batching rule that rejects partial perms
    runs after ``pshift``'s own try/except has returned, so the proactive
    padding must be requested from outside."""
    prev = getattr(_TLS, "full_perm_axes", frozenset())
    _TLS.full_perm_axes = prev | frozenset(axis_names)
    try:
        yield
    finally:
        _TLS.full_perm_axes = prev


def pshift(x, axis_name: str, pairs: list[tuple[int, int]]):
    """``lax.ppermute`` that accepts *partial* permutations everywhere.

    Under shard_map/SPMD, partial source-target pair lists are legal (ranks
    with no source receive zeros) and lower to a single collective-permute.
    The vmap batching rule, however, asserts a complete permutation; there we
    complete the permutation with dummy pairs and mask the fake deliveries
    back to zero — semantics identical, only exercised in single-device
    semantic tests.
    """
    p = axis_size(axis_name)
    if len(pairs) == p:
        return lax.ppermute(x, axis_name, pairs)
    if axis_name not in getattr(_TLS, "full_perm_axes", frozenset()):
        try:
            return lax.ppermute(x, axis_name, pairs)
        except AssertionError:
            pass
    srcs = {s for s, _ in pairs}
    dsts = {d for _, d in pairs}
    free_s = [i for i in range(p) if i not in srcs]
    free_d = [i for i in range(p) if i not in dsts]
    full = list(pairs) + list(zip(free_s, free_d))
    y = lax.ppermute(x, axis_name, full)
    keep = jnp.asarray([i in dsts for i in range(p)])
    mask = keep[axis_index(axis_name)]
    return jnp.where(mask, y, jnp.zeros_like(y))


def tie_to_axis(x, axis_name: str):
    """Make ``x`` a *mapped* operand of ``axis_name``.

    Old jax's ``all_to_all`` batching rule miscomputes when an operand is
    unmapped over the vmap axis (e.g. a constant cotangent entering a
    custom-VJP bwd).  A no-op select against ``axis_index`` ties the value
    to the axis; under shard_map/SPMD it compiles to the identity.
    """
    idx = axis_index(axis_name)
    return jnp.where(idx >= 0, x, jnp.zeros_like(x))


def tree_rounds(p: int) -> int:
    """Number of rounds of a binomial tree over p ranks."""
    r = 0
    while (1 << r) < p:
        r += 1
    return r
