"""Deterministic synthetic data pipeline.

Every (step, arch, shape) produces the same batch on every host — each
process could generate only its shard (seeded by (step, shard_index)) with
no I/O or inter-host coordination, which is how the launcher would feed
thousands of workers.  Token streams are Zipf-ish (structured enough that
loss decreases during the example runs, unlike uniform noise).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs of one global batch (dry-run / jit signature)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.encdec is not None:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), jnp.bfloat16)
        dec = max(seq // cfg.encdec.dec_ratio, 16)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, dec), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, dec), jnp.int32)
    if cfg.vlm is not None:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vlm.n_patches, cfg.vlm.patch_dim), jnp.bfloat16)
        txt = max(seq - cfg.vlm.n_patches, 16)
        specs["tokens"] = jax.ShapeDtypeStruct((batch, txt), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((batch, txt), jnp.int32)
    return specs


def make_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
               *, shard: int = 0, n_shards: int = 1) -> dict:
    """Host-side numpy batch (the given shard slice of the global batch)."""
    assert batch % n_shards == 0
    b_loc = batch // n_shards
    rng = np.random.default_rng((hash(cfg.name) & 0xFFFF, step, shard))
    specs = batch_specs(cfg, batch, seq)
    t_shape = (b_loc,) + specs["tokens"].shape[1:]
    # Zipf-distributed ids with per-sequence offset => learnable structure
    base = rng.zipf(1.3, size=t_shape).astype(np.int64)
    offs = rng.integers(0, 97, size=(b_loc, 1))
    toks = ((base + offs) % cfg.vocab_size).astype(np.int32)
    out = {"tokens": toks, "labels": toks.copy()}
    if cfg.encdec is not None:
        out["frames"] = rng.standard_normal(
            (b_loc, seq, cfg.d_model), dtype=np.float32)
    if cfg.vlm is not None:
        out["patches"] = rng.standard_normal(
            (b_loc, cfg.vlm.n_patches, cfg.vlm.patch_dim), dtype=np.float32)
    return out
