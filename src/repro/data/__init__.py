from repro.data.synthetic import make_batch, batch_specs  # noqa: F401
