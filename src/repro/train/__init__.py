from repro.train.trainer import Trainer, make_step_fns  # noqa: F401
