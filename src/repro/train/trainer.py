"""The distributed training step: manual-SPMD end to end.

Structure of one step (all collectives through ``repro.core.api``):

1. microbatch scan with gradient accumulation (overlaps the per-microbatch
   backward reduce-scatters with the next microbatch's compute under XLA's
   latency-hiding scheduler),
2. FSDP: per-layer all-gather fwd / reduce-scatter bwd (custom VJPs in
   dist/ops.py) — grads for "data"-sharded leaves arrive already summed
   over the data axis,
3. cross-pod sync: one tunable all-reduce over the "pod" axis per leaf —
   combined with (2) this IS the hierarchical RS→AR→AG schedule, at 1/|data|
   of the naive cross-pod payload,  optionally compressed to bf16,
4. replicated-leaf grads pmean'd over "data",
5. optimizer update (sharded states).

The paper's tuning enters at trace time: pass ``profiles=`` (offline-tuned
``ProfileStore``) or ``force={"allreduce": "allreduce_as_rsb_allgather"}``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import api
from repro.dist.axes import AXES, axis_size_or_1, has_axis
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.params import (ParamSpec, init_tree, tree_map_specs,
                                 tree_pspecs)
from repro.optim import get_optimizer, lr_schedule


# ---------------------------------------------------------------------------
# gradient finalization
# ---------------------------------------------------------------------------


def finalize_grads(grads, spec_tree, *, compress: str = "none"):
    """Cross-shard gradient reduction (see module docstring)."""
    d = axis_size_or_1(AXES.data)
    pod = axis_size_or_1(AXES.pod)

    def fin(g, spec: ParamSpec):
        fsdp = "data" in spec.dims
        if has_axis(AXES.data) and not fsdp:
            g = api.allreduce(g, AXES.data)
        if has_axis(AXES.pod):
            if compress == "bf16":
                g = api.allreduce(g.astype(jnp.bfloat16), AXES.pod).astype(
                    jnp.float32)
            else:
                g = api.allreduce(g, AXES.pod)
        return g / (d * pod if not fsdp else pod)

    return _map_with_specs(fin, grads, spec_tree)


def _map_with_specs(fn, tree, spec_tree):
    flat_s, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    flat_t = treedef.flatten_up_to(tree)
    return jax.tree.unflatten(treedef, [fn(t, s) for t, s in
                                        zip(flat_t, flat_s)])


def _fsdp_mean(grads, spec_tree):
    """FSDP leaves got SUM over data from the reduce-scatter; divide."""
    d = axis_size_or_1(AXES.data)

    def fin(g, spec: ParamSpec):
        return g / d if "data" in spec.dims else g

    return _map_with_specs(fin, grads, spec_tree)


# ---------------------------------------------------------------------------
# optimizer-state sharding
# ---------------------------------------------------------------------------


def opt_state_pspecs(opt_name: str, spec_tree):
    """PartitionSpecs of the optimizer state, mirroring the params."""
    if opt_name == "adamw":
        ms = tree_map_specs(lambda s: s.pspec(), spec_tree)
        return {"m": ms, "v": ms, "count": P()}
    if opt_name == "adafactor":
        def fac(s: ParamSpec):
            if len(s.shape) >= 2:
                return {"vr": P(*s.dims[:-1]),
                        "vc": P(*(s.dims[:-2] + s.dims[-1:]))}
            return {"v": s.pspec()}
        return {"f": tree_map_specs(fac, spec_tree), "count": P()}
    raise ValueError(opt_name)


# ---------------------------------------------------------------------------
# step functions (to be wrapped in shard_map by the caller)
# ---------------------------------------------------------------------------


def make_step_fns(cfg: ModelConfig, *, n_micro: int = 1,
                  compress: str = "none", base_lr: float = 3e-4,
                  warmup: int = 100, total_steps: int = 10_000):
    """Returns (init_fn, train_fn) operating on SHARD-LOCAL values.

    init_fn(key)                     -> (params, opt_state)
    train_fn(params, opt, batch, i)  -> (params, opt, metrics)
    """
    opt_init, opt_update = get_optimizer(cfg.optimizer)

    def spec_tree():
        return lm.model_specs(cfg, axis_size_or_1(AXES.model))

    def init_fn(key):
        fold = 0
        if has_axis(AXES.data):
            fold = lax.axis_index(AXES.data) * axis_size_or_1(AXES.model)
        if has_axis(AXES.model):
            fold = fold + lax.axis_index(AXES.model)
        params = init_tree(spec_tree(), key, fold=fold)
        return params, opt_init(params)

    def train_fn(params, opt_state, batch, step_idx):
        specs = spec_tree()

        def loss_of(p, mb):
            return lm.loss_fn(p, cfg, mb)[0]

        if n_micro > 1:
            def micro(carry, mb):
                acc, = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(
                                       lambda x: x / n_micro, g))
                return (acc,), l

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            mbs = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)
            (grads,), losses = lax.scan(micro, (zeros,), mbs)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        # grad sync is backward-phase traffic: the trace-replay tuner may
        # give these allreduces a different profile than fwd collectives
        with api.phase("bwd"):
            grads = _fsdp_mean(grads, specs)
            grads = finalize_grads(grads, specs, compress=compress)
        lr = lr_schedule(step_idx, base_lr=base_lr, warmup=warmup,
                         total=total_steps)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)

        # metrics: global mean loss + grad-norm (cheap diagnostics)
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        for ax in (AXES.data, AXES.model, AXES.pod):
            if has_axis(ax):
                gsq = api.allreduce(gsq[None], ax)[0]
                if ax == AXES.data:
                    loss = api.allreduce(loss[None], ax)[0] / \
                        axis_size_or_1(ax)
                if ax == AXES.pod:
                    loss = api.allreduce(loss[None], ax)[0] / \
                        axis_size_or_1(ax)
        metrics = {"loss": loss, "grad_norm": jnp.sqrt(gsq), "lr": lr}
        return params, opt_state, metrics

    return init_fn, train_fn


# ---------------------------------------------------------------------------
# host-side trainer (single- or multi-device via shard_map)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    mesh: Mesh | None = None
    n_micro: int = 1
    compress: str = "none"
    profiles: Any = None
    phase_profiles: dict | None = None   # phase tag -> ProfileStore
    force: dict | None = None
    base_lr: float = 3e-4
    warmup: int = 100
    record: list | None = None           # shared dispatch-record sink

    def _tuned(self):
        return api.tuned(profiles=self.profiles,
                         phase_profiles=self.phase_profiles,
                         force=self.force, record=self.record)

    def __post_init__(self):
        from repro._compat import shard_map
        self.tp = (self.mesh.shape.get("model", 1) if self.mesh else 1)
        self.specs = lm.model_specs(self.cfg, self.tp)
        self.pspecs = tree_pspecs(self.specs)
        opt_ps = opt_state_pspecs(self.cfg.optimizer, self.specs)
        init_fn, train_fn = make_step_fns(self.cfg, n_micro=self.n_micro,
                                          compress=self.compress,
                                          base_lr=self.base_lr,
                                          warmup=self.warmup)
        dp_axes = self._dp_axes()
        batch_p = P(dp_axes)

        if self.mesh is None:
            self._init = jax.jit(init_fn)
            self._step = jax.jit(train_fn, donate_argnums=(0, 1))
            return

        with self._tuned():
            sm_init = shard_map(
                init_fn, mesh=self.mesh, in_specs=P(),
                out_specs=(self.pspecs, opt_ps), check_vma=False)

            def batch_specs_tree(batch):
                return jax.tree.map(lambda _: batch_p, batch)

            def step(params, opt, batch, i):
                sm = shard_map(
                    train_fn, mesh=self.mesh,
                    in_specs=(self.pspecs, opt_ps,
                              batch_specs_tree(batch), P()),
                    out_specs=(self.pspecs, opt_ps,
                               {"loss": P(), "grad_norm": P(), "lr": P()}),
                    check_vma=False)
                return sm(params, opt, batch, i)

            self._init = jax.jit(sm_init)
            self._step = jax.jit(step, donate_argnums=(0, 1))

    def _dp_axes(self):
        if self.mesh is None:
            return None
        axes = [a for a in ("pod", "data") if a in self.mesh.shape]
        return tuple(axes) if axes else None

    def init(self, seed: int = 0):
        with self._tuned():
            return self._init(jax.random.key(seed))

    def step(self, params, opt_state, batch, i):
        with self._tuned():
            return self._step(params, opt_state, batch,
                              jnp.asarray(i, jnp.int32))

    def put_batch(self, batch):
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, batch)
        sp = NamedSharding(self.mesh, P(self._dp_axes()))
        return jax.tree.map(lambda x: jax.device_put(x, sp), batch)
