"""The mesh-axis registry: which named axes exist and what each one means.

=======  ==================================================================
axis     role
=======  ==================================================================
data     FSDP/ZeRO-3 parameter sharding + batch data parallelism (ICI);
         also the sequence axis for seq-sharded long-context decode
model    tensor parallelism (Megatron col/row splits) and expert
         parallelism for MoE (ICI)
pod      pure data parallelism across pods (DCN) — params never shard here
=======  ==================================================================

``has_axis``/``axis_size_or_1`` are TRACE-time queries of the enclosing
binding (shard_map mesh axis, or ``vmap(axis_name=...)`` in semantic tests).
Outside any binding every ``dist.ops`` primitive degrades to its local
meaning, so the same model code runs unsharded under plain ``jit``.
"""
from __future__ import annotations

import dataclasses

from jax import core as _core
from jax import lax

from repro.core._axis import axis_size


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Canonical axis names; import ``AXES`` rather than string literals."""
    data: str = "data"
    model: str = "model"
    pod: str = "pod"

    def __iter__(self):
        return iter((self.data, self.model, self.pod))


AXES = MeshAxes()


def has_axis(axis_name: str | None) -> bool:
    """True iff ``axis_name`` is bound in the current trace (static)."""
    if not axis_name:
        return False
    frame = getattr(_core, "axis_frame", None)
    if frame is not None:
        try:
            frame(axis_name)
            return True
        except NameError:
            return False
    # newer jax: no core.axis_frame — probe by resolving the axis size
    try:
        if hasattr(lax, "axis_size"):
            lax.axis_size(axis_name)
        else:
            axis_size(axis_name)
        return True
    except (NameError, KeyError, ValueError, TypeError):
        return False


def axis_size_or_1(axis_name: str | None) -> int:
    """Static size of ``axis_name``, or 1 when it is not bound."""
    return axis_size(axis_name) if has_axis(axis_name) else 1
