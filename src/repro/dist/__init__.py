"""repro.dist — the sharded "application" layer over the tuned dispatcher.

* ``repro.dist.axes`` — mesh-axis registry (``AXES``, ``has_axis``,
  ``axis_size_or_1``)
* ``repro.dist.ops``  — custom-VJP model-parallel primitives whose forward
  and backward collectives all dispatch through ``repro.core.api``

This package is the repo's equivalent of MPI user code: models call
``dist.ops``; ``core.api`` is the PMPI interposition layer that redirects
each call to the best guideline mock-up.
"""
from repro.dist import ops  # noqa: F401
from repro.dist.axes import AXES, MeshAxes, axis_size_or_1, has_axis  # noqa: F401
from repro.dist.ops import (allgather_matmul, col_matmul,  # noqa: F401
                            ep_alltoall, fsdp_gather, fsdp_matmul,
                            matmul_accumulate, matmul_reducescatter,
                            matmul_reducescatter_2d, row_matmul,
                            tp_allgather, tp_allreduce, tp_copy,
                            tp_psum_grad, tp_reducescatter)
