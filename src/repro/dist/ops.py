"""Sharded model-parallel primitives — the "MPI application code" layer.

These are the ops the model stack (models/, train/) calls.  Every forward
AND backward collective is issued through ``repro.core.api``, never raw
``jax.lax`` — so an active ``api.tuned(profiles=..., force=...)`` context or
a ``PGTUNE_MODULE`` env spec transparently redirects training and serving
traffic to guideline mock-ups, exactly as PGMPITuneLib intercepts ``MPI_*``
into tuned ``PMPI_*`` compositions.  Because the custom VJPs below route the
backward collective through the same dispatcher, the tuner's per-(op, p,
message-size) choices apply to the backward pass too.

Gradient pairing (per-shard semantics; axis size ``p``):

===================  =========================  ==========================
op                   forward collective         backward collective
===================  =========================  ==========================
fsdp_gather          api.allgather (data)       api.reducescatter (data)
tp_allgather         api.allgather (model)      api.reducescatter (model)
tp_reducescatter     api.reducescatter          api.allgather
tp_allreduce         api.allreduce              identity (Megatron "g")
tp_copy              identity                   api.allreduce (Megatron "f")
tp_psum_grad         identity                   api.allreduce (weight marker)
ep_alltoall          api.alltoall               api.alltoall (self-transpose)
row_matmul           api.allreduce              identity
col_matmul           identity                   api.matmul_reducescatter +
                                                api.allgather (input grad)
allgather_matmul     api.allgather_matmul       api.matmul_reducescatter (dx)
                                                + api.allgather (dw remat)
matmul_reducescatter api.matmul_reducescatter   api.allgather_matmul (dx; the
                                                gathered cotangent is reused
                                                for dw)
fsdp_matmul          api.allgather_matmul       api.matmul_reducescatter (dw)
                     (data — weight gather      — the FSDP grad
                     fused into the matmul)     reduce-scatter, fused
matmul_accumulate    api.matmul_accumulate      api.matmul_reducescatter (dw
                     (data — K-dim weight       reduce-scatter over K rows);
                     gather, CONTRACTED away)   dx reuses the gathered weight
matmul_reducescatter api.matmul_reducescatter   api.allgather_matmul (dx) +
_2d                  _2d (data-gather AND       api.matmul_reducescatter_2d_t
                     model-reduce-scatter       (dw — the fused 2-D
                     fused around one matmul)   TRANSPOSE schedule: axes
                                                swap roles)
===================  =========================  ==========================

The fused pair (``allgather_matmul`` / ``matmul_reducescatter``) exposes the
collective-matmul overlap to the tuner: the dispatcher chooses between the
unfused composition and the ring ``fused_ring`` kernel per (op, p, nbytes).
``col_matmul``'s input-grad all-reduce is decomposed as reduce-scatter +
all-gather so its matmul-reduce-scatter half is fused-selectable (falls back
to the single all-reduce when the row count does not divide the axis);
``row_matmul(..., fsdp_dim=1)`` fuses the DATA-axis weight gather of a
row-parallel weight into the matmul itself (the fsdp_gather→matmul sites in
models/), keeping the model-axis reduction a classic tunable all-reduce.

``tp_copy`` marks a replicated ACTIVATION entering a model-sharded region
(its cotangents arrive partial per shard and must be summed);
``tp_psum_grad`` marks a replicated WEIGHT used by every shard (partial
weight grads must be summed before the optimizer).  Identical math, distinct
ops so dispatch records and profiles stay attributable.

When the named axis is NOT bound in the current trace every op degrades to
identity / a local matmul: single-device ``jit`` runs the exact same model
code unsharded.

Every backward collective is issued under ``api.phase("bwd")``, so dispatch
records (and the trace-replay tuner's per-phase profiles — see
DESIGN_TRACE.md) distinguish forward from backward traffic.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core._axis import axis_index, axis_size, tie_to_axis
from repro.dist.axes import AXES, has_axis


def _moved(fn, x, dim: int):
    """Apply a leading-dim collective along ``dim``."""
    if dim in (0, -x.ndim):
        return fn(x)
    return jnp.moveaxis(fn(jnp.moveaxis(x, dim, 0)), 0, dim)


# ---------------------------------------------------------------------------
# allgather <-> reducescatter pair
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gather(dim: int, axis: str, x):
    return _moved(lambda a: api.allgather(a, axis), x, dim)


def _gather_fwd(dim, axis, x):
    return _gather(dim, axis, x), None


def _gather_bwd(dim, axis, _, g):
    with api.phase("bwd"):
        return (_moved(lambda a: api.reducescatter(a, axis), g, dim),)


_gather.defvjp(_gather_fwd, _gather_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _scatter(dim: int, axis: str, x):
    return _moved(lambda a: api.reducescatter(a, axis), x, dim)


def _scatter_fwd(dim, axis, x):
    return _scatter(dim, axis, x), None


def _scatter_bwd(dim, axis, _, g):
    with api.phase("bwd"):
        return (_moved(lambda a: api.allgather(a, axis), g, dim),)


_scatter.defvjp(_scatter_fwd, _scatter_bwd)


def fsdp_gather(x, dim: int = 0, axis: str = AXES.data):
    """All-gather a ZeRO-3-sharded param along ``dim`` over the data axis;
    the backward reduce-scatters the grad back to the owner shard (summed
    over the axis — see train/trainer.py for the /d normalization)."""
    if not has_axis(axis):
        return x
    return _gather(dim, axis, x)


def tp_allgather(x, dim: int, axis: str = AXES.model):
    """All-gather a model-sharded activation along ``dim``."""
    if not has_axis(axis):
        return x
    return _gather(dim, axis, x)


def tp_reducescatter(x, dim: int = 0, axis: str = AXES.model):
    """Reduce-scatter along ``dim`` over the model axis (sum + keep own
    block); backward all-gathers the cotangent."""
    if not has_axis(axis):
        return x
    return _scatter(dim, axis, x)


# ---------------------------------------------------------------------------
# allreduce <-> identity pair (Megatron f/g)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _allreduce(axis: str, x):
    return api.allreduce(x, axis)


def _allreduce_fwd(axis, x):
    return _allreduce(axis, x), None


def _allreduce_bwd(axis, _, g):
    # the reduced value is ONE logical tensor replicated over the axis; its
    # (replicated) cotangent passes through untouched
    return (g,)


_allreduce.defvjp(_allreduce_fwd, _allreduce_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _psum_grad(axis: str, x):
    return x


def _psum_grad_fwd(axis, x):
    return x, None


def _psum_grad_bwd(axis, _, g):
    with api.phase("bwd"):
        return (api.allreduce(g, axis),)


_psum_grad.defvjp(_psum_grad_fwd, _psum_grad_bwd)


def tp_allreduce(x, axis: str = AXES.model):
    """Sum partial activations over the model axis (row-parallel output)."""
    if not has_axis(axis):
        return x
    return _allreduce(axis, x)


def tp_copy(x, axis: str = AXES.model):
    """Mark a replicated activation entering a model-sharded region: fwd is
    identity, bwd sums the per-shard partial cotangents."""
    if not has_axis(axis):
        return x
    return _psum_grad(axis, x)


def tp_psum_grad(x, axis: str = AXES.model):
    """Mark a replicated weight used on every model shard: fwd identity,
    bwd sums the partial weight grads over the axis."""
    if not has_axis(axis):
        return x
    return _psum_grad(axis, x)


# ---------------------------------------------------------------------------
# alltoall (expert parallelism)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _alltoall(axis: str, x):
    return api.alltoall(x, axis)


def _alltoall_fwd(axis, x):
    return _alltoall(axis, x), None


def _alltoall_bwd(axis, _, g):
    # y_i[j] = x_j[i] is its own transpose: route the cotangent back through
    # the (tuned) alltoall; tie_to_axis keeps old-jax vmap batching honest
    with api.phase("bwd"):
        return (api.alltoall(tie_to_axis(g, axis), axis),)


_alltoall.defvjp(_alltoall_fwd, _alltoall_bwd)


def ep_alltoall(x, axis: str = AXES.model):
    """Expert dispatch/combine shuffle: rows [p*n, ...] exchanged so shard i
    receives block i of every peer.  Self-inverse; backward is the same
    (tuned) alltoall."""
    if not has_axis(axis):
        return x
    return _alltoall(axis, x)


# ---------------------------------------------------------------------------
# fused collective-matmul pair (tuner arbitrates fused_ring vs unfused)
# ---------------------------------------------------------------------------


def _flat2(x):
    """Collapse leading dims: [..., K] -> ([T, K], T)."""
    t = math.prod(x.shape[:-1])
    return x.reshape(t, x.shape[-1]), t


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _agmm(axis: str, x, w):
    return api.allgather_matmul(x, w, axis)


def _agmm_fwd(axis, x, w):
    return _agmm(axis, x, w), (x, w)


def _agmm_bwd(axis, res, g):
    # out = all_gather(x) @ w.  dx reduces+scatters the per-shard partials
    # g @ w.T (the mirror fused op); dw re-gathers x (rematerialization —
    # the unfused composition would have kept the gathered copy alive).
    x, w = res
    with api.phase("bwd"):
        dx = api.matmul_reducescatter(g, w.T, axis)
        dw = jnp.matmul(api.allgather(x, axis).T, g)
    return dx, dw


_agmm.defvjp(_agmm_fwd, _agmm_bwd)


def allgather_matmul(x, w, axis: str = AXES.model):
    """``all_gather(x, rows) @ w`` — x per-shard ``[n, K]``, w shard-local
    ``[K, M]`` -> ``[p*n, M]``.  Fused-vs-unfused is a dispatcher decision;
    the backward pairs ``matmul_reducescatter`` for the input grad."""
    if not has_axis(axis):
        return jnp.matmul(x, w)
    return _agmm(axis, x, w)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mmrs(axis: str, x, w):
    return api.matmul_reducescatter(x, w, axis)


def _mmrs_fwd(axis, x, w):
    return _mmrs(axis, x, w), (x, w)


def _mmrs_bwd(axis, res, g):
    # out = reduce_scatter(x @ w).  The cotangent must be gathered anyway
    # (transpose of reduce-scatter); the fused op hands the assembled
    # all_gather(g) back so dw reuses it instead of gathering twice.
    x, w = res
    with api.phase("bwd"):
        dx, gg = api.allgather_matmul(g, w.T, axis, return_gathered=True)
        dw = jnp.matmul(x.T, gg)
    return dx, dw


_mmrs.defvjp(_mmrs_fwd, _mmrs_bwd)


def matmul_reducescatter(x, w, axis: str = AXES.model):
    """``reduce_scatter(x @ w, rows)`` — x per-shard ``[p*n, K]`` (partial
    contraction), w ``[K, M]`` -> ``[n, M]`` summed over ``axis``.  The
    backward pairs ``allgather_matmul`` (fused fwd <-> fused bwd)."""
    if not has_axis(axis):
        return jnp.matmul(x, w)
    return _mmrs(axis, x, w)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fsdp_mm(axis: str, x, w):
    x2, _ = _flat2(x)
    zt = api.allgather_matmul(jnp.swapaxes(w, 0, 1), x2.T, axis)
    return zt.T.reshape(*x.shape[:-1], zt.shape[0])


def _fsdp_mm_fwd(axis, x, w):
    # x @ AG(w, dim 1) == (AG(w.T, dim 0) @ x.T).T — the canonical
    # allgather-matmul with the WEIGHT as the gathered operand.  The ring
    # materializes the gathered weight anyway; keep it as the residual
    # (memory parity with the unfused fsdp_gather path, whose autodiff
    # saves the gathered weight too).
    x2, _ = _flat2(x)
    zt, wft = api.allgather_matmul(jnp.swapaxes(w, 0, 1), x2.T, axis,
                                   return_gathered=True)
    return zt.T.reshape(*x.shape[:-1], zt.shape[0]), (x, wft)


def _fsdp_mm_bwd(axis, res, g):
    # dw is the FSDP gradient reduce-scatter, fused with its matmul:
    # dw.T = reduce_scatter(g.T @ x, rows over data).  dx reuses the
    # gathered weight saved by the forward.
    x, wft = res
    g2, _ = _flat2(g)
    x2, _ = _flat2(x)
    with api.phase("bwd"):
        dwt = api.matmul_reducescatter(g2.T, x2, axis)
    dx = jnp.matmul(g2, wft).reshape(x.shape)
    return dx, jnp.swapaxes(dwt, 0, 1)


_fsdp_mm.defvjp(_fsdp_mm_fwd, _fsdp_mm_bwd)


def fsdp_matmul(x, w, axis: str = AXES.data):
    """``x @ all_gather(w, dim 1)`` with the ZeRO-3 weight gather fused into
    the matmul — the fsdp_gather→matmul sites of row-parallel weights.  The
    backward fuses the FSDP grad reduce-scatter the same way."""
    if not has_axis(axis):
        return jnp.matmul(x, w)
    return _fsdp_mm(axis, x, w)


# ---------------------------------------------------------------------------
# weight-stationary 2-D collective matmul (data-gather x model-reduce-scatter)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _mm2d(rs_axis: str, ag_axis: str, x, w):
    return api.matmul_reducescatter_2d(x, w, rs_axis, ag_axis)


def _mm2d_fwd(rs_axis, ag_axis, x, w):
    # the outer ring materializes the col-gathered full weight anyway; keep
    # it as the residual so dx needs no re-gather of w (memory parity with
    # the unfused path, whose autodiff saves the gathered weight too)
    ys, wf = api.matmul_reducescatter_2d(x, w, rs_axis, ag_axis,
                                         return_gathered=True)
    return ys, (x, wf)


def _mm2d_bwd(rs_axis, ag_axis, res, g):
    # ys = RS_q(x @ AG_d(w)): the cotangent g arrives SHARDED over rs_axis.
    # dx = AG_q(g) @ Wᵀ — the 1-D gather-role fused op (transpose of the
    # reduce-scatter); dw is the fused 2-D TRANSPOSE schedule: the rs-axis
    # cotangent gather is CONTRACTED into the ag-axis reduce-scatter
    # (axes swap roles relative to the forward).
    x, wf = res
    with api.phase("bwd"):
        dx = api.allgather_matmul(g, jnp.swapaxes(wf, 0, 1), rs_axis)
        dwt = api.matmul_reducescatter_2d_t(g, x, ag_axis, rs_axis)
    return dx, jnp.swapaxes(dwt, 0, 1)


_mm2d.defvjp(_mm2d_fwd, _mm2d_bwd)


def matmul_reducescatter_2d(x, w, rs_axis: str = AXES.model,
                            ag_axis: str = AXES.data):
    """``reduce_scatter(x @ all_gather(w, cols over ag_axis), rows over
    rs_axis)`` — x ``[T, K]`` shard-local, w ``[K, M/d]`` the data-axis
    FSDP column block -> ``[T/q, M]`` summed over ``rs_axis``.  BOTH
    collectives fuse around one matmul (nested rings); fused-vs-unfused is
    a dispatcher decision per 2-D cell.  The backward pairs
    ``allgather_matmul`` for dx and the fused 2-D transpose schedule
    (``matmul_reducescatter_2d_t``) for dw.

    Degenerate axes fall back to the matching 1-D op.  Rows MUST divide
    the rs axis — the reduce-scatter contract has no well-defined output
    otherwise (same constraint as the 1-D ``matmul_reducescatter``);
    callers like ``row_matmul(fsdp_dim=1)`` guard this and keep the 1-D
    ``tp_allreduce(fsdp_matmul(...))`` composition for ragged rows.
    """
    if not has_axis(ag_axis):
        return matmul_reducescatter(x, w, rs_axis)
    if not has_axis(rs_axis):
        return fsdp_matmul(x, w, ag_axis)
    if x.shape[0] % axis_size(rs_axis) != 0:
        raise ValueError(
            f"matmul_reducescatter_2d: rows {x.shape[0]} must divide the "
            f"rs axis size {axis_size(rs_axis)}; use the unfused "
            "tp_allreduce(fsdp_matmul(...)) composition for ragged rows "
            "(row_matmul(fsdp_dim=1) does this automatically)")
    return _mm2d(rs_axis, ag_axis, x, w)


# ---------------------------------------------------------------------------
# Megatron matmuls
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _acc_mm(axis: str, x, w):
    x2, _ = _flat2(x)
    out = api.matmul_accumulate(x2, w, axis)
    return out.reshape(*x.shape[:-1], w.shape[-1])


def _acc_mm_fwd(axis, x, w):
    # x @ AG(w, dim 0): the gathered dim is contracted away — the accumulate
    # ring.  The ring materializes the full weight anyway; keep it as the
    # residual so dx is a local matmul (memory parity with the unfused
    # fsdp_gather path, whose autodiff saves the gathered weight too).
    x2, _ = _flat2(x)
    out, wf = api.matmul_accumulate(x2, w, axis, return_gathered=True)
    return out.reshape(*x.shape[:-1], w.shape[-1]), (x, wf)


def _acc_mm_bwd(axis, res, g):
    # out = x @ W with W = AG(w, rows).  dw is W's cotangent (x.T @ g)
    # reduce-scattered back to the K-row owner shards — the mirror fused op;
    # dx reuses the gathered weight saved by the forward.
    x, wf = res
    g2, _ = _flat2(g)
    x2, _ = _flat2(x)
    with api.phase("bwd"):
        dw = api.matmul_reducescatter(x2.T, g2, axis)
    dx = jnp.matmul(g2, wf.T).reshape(x.shape)
    return dx, dw


_acc_mm.defvjp(_acc_mm_fwd, _acc_mm_bwd)


def matmul_accumulate(x, w, axis: str = AXES.data):
    """``x @ all_gather(w, dim 0)`` with the K-dim (contraction) weight
    gather fused into the matmul — the ``fsdp_gather(w, 0)`` + matmul
    sites.  ``w`` per-shard ``[K/p, M]``, ``x`` ``[..., K]``.  The gathered
    dim is contracted away, so the row-block rings don't apply; the
    dispatcher arbitrates the accumulate ring vs the unfused composition
    per cell.  The backward pairs ``matmul_reducescatter`` for the weight
    grad (the FSDP reduce-scatter over K rows).

    Unevenly padded shards (``x``'s K != p·rows(w)) fall back to the tuned
    unfused gather + slice — the ring needs equal blocks.
    """
    if not has_axis(axis):
        return jnp.matmul(x, w)
    k = x.shape[-1]
    if k != axis_size(axis) * w.shape[0]:
        full = _gather(0, axis, w)[:k]
        return jnp.matmul(x, full)
    return _acc_mm(axis, x, w)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _col_mm(axis: str, x, w):
    return jnp.matmul(x, w)


def _col_mm_fwd(axis, x, w):
    return jnp.matmul(x, w), (x, w)


def _col_mm_bwd(axis, res, g):
    # dx = allreduce(g @ w.T) decomposed as reduce-scatter + all-gather so
    # the matmul half is fused-selectable; single all-reduce when the row
    # count does not divide the axis.
    x, w = res
    g2, t = _flat2(g)
    x2, _ = _flat2(x)
    with api.phase("bwd"):
        if t % axis_size(axis) == 0:
            ds = api.matmul_reducescatter(g2, w.T, axis)
            dx = api.allgather(ds, axis).reshape(x.shape)
        else:
            dx = api.allreduce(jnp.matmul(g2, w.T), axis).reshape(x.shape)
    dw = jnp.matmul(x2.T, g2)
    return dx, dw


_col_mm.defvjp(_col_mm_fwd, _col_mm_bwd)


def col_matmul(x, w, axis: str = AXES.model, *, fsdp_dim: int | None = None,
               fsdp_axis: str = AXES.data):
    """Column-parallel matmul: ``x`` replicated, ``w`` sharded on its output
    dim -> output sharded on the last dim.  No forward collective; the input
    grad is summed over the axis — via the fused-selectable
    ``matmul_reducescatter`` + all-gather decomposition.

    ``fsdp_dim=0`` declares that ``w`` is additionally FSDP-sharded on its
    INPUT (contraction) dim over ``fsdp_axis`` and fuses that gather into
    the matmul via ``matmul_accumulate`` — the K-dim weight-gather sites;
    the model-axis input-grad sum is carried by a ``tp_copy`` marker.
    Other ``fsdp_dim`` values gather unfused first."""
    if fsdp_dim == 0:
        return matmul_accumulate(tp_copy(x, axis), w, fsdp_axis)
    if fsdp_dim is not None:
        w = fsdp_gather(w, fsdp_dim, fsdp_axis)
    if not has_axis(axis):
        return jnp.matmul(x, w)
    return _col_mm(axis, x, w)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _row_mm(axis: str, x, w):
    x2, _ = _flat2(x)
    ys = api.matmul_reducescatter(x2, w, axis)
    return api.allgather(ys, axis).reshape(*x.shape[:-1], w.shape[-1])


def _row_mm_fwd(axis, x, w):
    return _row_mm(axis, x, w), (x, w)


def _row_mm_bwd(axis, res, g):
    # the reduced output is ONE logical replicated tensor (Megatron "g");
    # its replicated cotangent needs no collective — identical to the
    # monolithic all-reduce formulation's identity backward
    x, w = res
    g2, _ = _flat2(g)
    x2, _ = _flat2(x)
    dx = jnp.matmul(g2, w.T).reshape(x.shape)
    dw = jnp.matmul(x2.T, g2)
    return dx, dw


_row_mm.defvjp(_row_mm_fwd, _row_mm_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _row2d_mm(rs_axis: str, ag_axis: str, x, w):
    x2, _ = _flat2(x)
    ys = api.matmul_reducescatter_2d(x2, w, rs_axis, ag_axis)
    return api.allgather(ys, rs_axis).reshape(*x.shape[:-1], ys.shape[-1])


def _row2d_fwd(rs_axis, ag_axis, x, w):
    x2, _ = _flat2(x)
    ys, wf = api.matmul_reducescatter_2d(x2, w, rs_axis, ag_axis,
                                         return_gathered=True)
    y = api.allgather(ys, rs_axis).reshape(*x.shape[:-1], ys.shape[-1])
    return y, (x, wf)


def _row2d_bwd(rs_axis, ag_axis, res, g):
    # the reduced output is ONE logical replicated tensor (Megatron "g"):
    # its replicated cotangent needs no collective for dx (local matmul
    # against the saved col-gathered weight).  dw re-enters the rs-axis
    # row shard of g and runs the fused 2-D TRANSPOSE schedule — the
    # rs-axis re-gather is contracted into the ag-axis FSDP grad
    # reduce-scatter, both tuner-arbitrated.
    x, wf = res
    g2, t = _flat2(g)
    x2, _ = _flat2(x)
    t_loc = t // axis_size(rs_axis)
    gs = jax.lax.dynamic_slice_in_dim(g2, axis_index(rs_axis) * t_loc,
                                      t_loc, axis=0)
    with api.phase("bwd"):
        dwt = api.matmul_reducescatter_2d_t(gs, x2, ag_axis, rs_axis)
    dx = jnp.matmul(g2, jnp.swapaxes(wf, 0, 1)).reshape(x.shape)
    return dx, jnp.swapaxes(dwt, 0, 1)


_row2d_mm.defvjp(_row2d_fwd, _row2d_bwd)


def row_matmul(x, w, axis: str = AXES.model, *, fsdp_dim: int | None = None,
               fsdp_axis: str = AXES.data):
    """Row-parallel matmul: ``x`` sharded on the last dim, ``w`` sharded on
    its input dim -> partial products summed over the model axis.  The sum
    is issued as reduce-scatter + all-gather so the matmul half is the
    fused-selectable ``matmul_reducescatter`` (single tuned all-reduce when
    the row count does not divide the axis).  The backward needs no
    collective (cotangent is replicated).

    ``fsdp_dim=1`` declares that ``w`` is additionally FSDP-sharded on its
    OUTPUT dim over ``fsdp_axis`` and fuses BOTH collectives around the
    matmul via the weight-stationary 2-D op (``matmul_reducescatter_2d``:
    outer data-axis weight stream, inner model-axis reduce-scatter; the
    replicating model-axis all-gather of the scattered rows stays a
    classic tuned collective).  When either axis is missing — or the row
    count does not divide the model axis — it falls back to the 1-D
    composition ``tp_allreduce(fsdp_matmul(...))``; other ``fsdp_dim``
    values gather unfused first."""
    if fsdp_dim == 1:
        if (has_axis(axis) and has_axis(fsdp_axis)
                and math.prod(x.shape[:-1]) % axis_size(axis) == 0):
            return _row2d_mm(axis, fsdp_axis, x, w)
        return tp_allreduce(fsdp_matmul(x, w, fsdp_axis), axis)
    if fsdp_dim is not None:
        w = fsdp_gather(w, fsdp_dim, fsdp_axis)
    if not has_axis(axis):
        return jnp.matmul(x, w)
    if math.prod(x.shape[:-1]) % axis_size(axis) == 0:
        return _row_mm(axis, x, w)
    return tp_allreduce(jnp.matmul(x, w), axis)
