"""Sharded model-parallel primitives — the "MPI application code" layer.

These are the ops the model stack (models/, train/) calls.  Every forward
AND backward collective is issued through ``repro.core.api``, never raw
``jax.lax`` — so an active ``api.tuned(profiles=..., force=...)`` context or
a ``PGTUNE_MODULE`` env spec transparently redirects training and serving
traffic to guideline mock-ups, exactly as PGMPITuneLib intercepts ``MPI_*``
into tuned ``PMPI_*`` compositions.  Because the custom VJPs below route the
backward collective through the same dispatcher, the tuner's per-(op, p,
message-size) choices apply to the backward pass too.

Gradient pairing (per-shard semantics; axis size ``p``):

=================  ======================  ==========================
op                 forward collective      backward collective
=================  ======================  ==========================
fsdp_gather        api.allgather (data)    api.reducescatter (data)
tp_allgather       api.allgather (model)   api.reducescatter (model)
tp_reducescatter   api.reducescatter       api.allgather
tp_allreduce       api.allreduce           identity (Megatron "g")
tp_copy            identity                api.allreduce (Megatron "f")
tp_psum_grad       identity                api.allreduce (weight marker)
ep_alltoall        api.alltoall            api.alltoall (self-transpose)
row_matmul         api.allreduce           identity
col_matmul         identity                api.allreduce (input grad)
=================  ======================  ==========================

``tp_copy`` marks a replicated ACTIVATION entering a model-sharded region
(its cotangents arrive partial per shard and must be summed);
``tp_psum_grad`` marks a replicated WEIGHT used by every shard (partial
weight grads must be summed before the optimizer).  Identical math, distinct
ops so dispatch records and profiles stay attributable.

When the named axis is NOT bound in the current trace every op degrades to
identity / a local matmul: single-device ``jit`` runs the exact same model
code unsharded.

Every backward collective is issued under ``api.phase("bwd")``, so dispatch
records (and the trace-replay tuner's per-phase profiles — see
DESIGN_TRACE.md) distinguish forward from backward traffic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import api
from repro.core._axis import tie_to_axis
from repro.dist.axes import AXES, has_axis


def _moved(fn, x, dim: int):
    """Apply a leading-dim collective along ``dim``."""
    if dim in (0, -x.ndim):
        return fn(x)
    return jnp.moveaxis(fn(jnp.moveaxis(x, dim, 0)), 0, dim)


# ---------------------------------------------------------------------------
# allgather <-> reducescatter pair
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _gather(dim: int, axis: str, x):
    return _moved(lambda a: api.allgather(a, axis), x, dim)


def _gather_fwd(dim, axis, x):
    return _gather(dim, axis, x), None


def _gather_bwd(dim, axis, _, g):
    with api.phase("bwd"):
        return (_moved(lambda a: api.reducescatter(a, axis), g, dim),)


_gather.defvjp(_gather_fwd, _gather_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _scatter(dim: int, axis: str, x):
    return _moved(lambda a: api.reducescatter(a, axis), x, dim)


def _scatter_fwd(dim, axis, x):
    return _scatter(dim, axis, x), None


def _scatter_bwd(dim, axis, _, g):
    with api.phase("bwd"):
        return (_moved(lambda a: api.allgather(a, axis), g, dim),)


_scatter.defvjp(_scatter_fwd, _scatter_bwd)


def fsdp_gather(x, dim: int = 0, axis: str = AXES.data):
    """All-gather a ZeRO-3-sharded param along ``dim`` over the data axis;
    the backward reduce-scatters the grad back to the owner shard (summed
    over the axis — see train/trainer.py for the /d normalization)."""
    if not has_axis(axis):
        return x
    return _gather(dim, axis, x)


def tp_allgather(x, dim: int, axis: str = AXES.model):
    """All-gather a model-sharded activation along ``dim``."""
    if not has_axis(axis):
        return x
    return _gather(dim, axis, x)


def tp_reducescatter(x, dim: int = 0, axis: str = AXES.model):
    """Reduce-scatter along ``dim`` over the model axis (sum + keep own
    block); backward all-gathers the cotangent."""
    if not has_axis(axis):
        return x
    return _scatter(dim, axis, x)


# ---------------------------------------------------------------------------
# allreduce <-> identity pair (Megatron f/g)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _allreduce(axis: str, x):
    return api.allreduce(x, axis)


def _allreduce_fwd(axis, x):
    return _allreduce(axis, x), None


def _allreduce_bwd(axis, _, g):
    # the reduced value is ONE logical tensor replicated over the axis; its
    # (replicated) cotangent passes through untouched
    return (g,)


_allreduce.defvjp(_allreduce_fwd, _allreduce_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _psum_grad(axis: str, x):
    return x


def _psum_grad_fwd(axis, x):
    return x, None


def _psum_grad_bwd(axis, _, g):
    with api.phase("bwd"):
        return (api.allreduce(g, axis),)


_psum_grad.defvjp(_psum_grad_fwd, _psum_grad_bwd)


def tp_allreduce(x, axis: str = AXES.model):
    """Sum partial activations over the model axis (row-parallel output)."""
    if not has_axis(axis):
        return x
    return _allreduce(axis, x)


def tp_copy(x, axis: str = AXES.model):
    """Mark a replicated activation entering a model-sharded region: fwd is
    identity, bwd sums the per-shard partial cotangents."""
    if not has_axis(axis):
        return x
    return _psum_grad(axis, x)


def tp_psum_grad(x, axis: str = AXES.model):
    """Mark a replicated weight used on every model shard: fwd identity,
    bwd sums the partial weight grads over the axis."""
    if not has_axis(axis):
        return x
    return _psum_grad(axis, x)


# ---------------------------------------------------------------------------
# alltoall (expert parallelism)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _alltoall(axis: str, x):
    return api.alltoall(x, axis)


def _alltoall_fwd(axis, x):
    return _alltoall(axis, x), None


def _alltoall_bwd(axis, _, g):
    # y_i[j] = x_j[i] is its own transpose: route the cotangent back through
    # the (tuned) alltoall; tie_to_axis keeps old-jax vmap batching honest
    with api.phase("bwd"):
        return (api.alltoall(tie_to_axis(g, axis), axis),)


_alltoall.defvjp(_alltoall_fwd, _alltoall_bwd)


def ep_alltoall(x, axis: str = AXES.model):
    """Expert dispatch/combine shuffle: rows [p*n, ...] exchanged so shard i
    receives block i of every peer.  Self-inverse; backward is the same
    (tuned) alltoall."""
    if not has_axis(axis):
        return x
    return _alltoall(axis, x)


# ---------------------------------------------------------------------------
# Megatron matmuls
# ---------------------------------------------------------------------------


def col_matmul(x, w, axis: str = AXES.model):
    """Column-parallel matmul: ``x`` replicated, ``w`` sharded on its output
    dim -> output sharded on the last dim.  No forward collective; the input
    grad is summed over the axis (via ``tp_copy``)."""
    return jnp.matmul(tp_copy(x, axis), w)


def row_matmul(x, w, axis: str = AXES.model):
    """Row-parallel matmul: ``x`` sharded on the last dim, ``w`` sharded on
    its input dim -> partial products summed with a tuned all-reduce.  The
    backward needs no collective (cotangent is replicated)."""
    return tp_allreduce(jnp.matmul(x, w), axis)
