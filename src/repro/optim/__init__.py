"""Sharded functional optimizers (state trees mirror the param sharding)."""
from repro.optim.optimizers import (adafactor_init, adafactor_update,  # noqa: F401
                                    adamw_init, adamw_update,
                                    get_optimizer, lr_schedule)
