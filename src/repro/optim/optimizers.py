"""AdamW and Adafactor, written directly on pytrees.

State leaves inherit the parameter's sharding (the trainer passes matching
PartitionSpecs), so optimizer memory is fully ZeRO-sharded.  Adafactor
(factored second moment, no first moment) is the memory-lean option used by
deepseek-v3 (see its config docstring).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lr_schedule(step, *, base_lr=3e-4, warmup=100, total=10_000):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.minimum(warm, 1.0) * jnp.maximum(cos, 0.1)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    c = state["count"] + 1
    cf = c.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** cf)
        vh = v / (1 - b2 ** cf)
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x:
                         isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x:
                         isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x:
                         isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "count": c}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment)
# ---------------------------------------------------------------------------


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params):
    def mk(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(mk, params), "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, *, lr, b2=0.999, eps=1e-30,
                     clip=1.0, weight_decay=0.0):
    c = state["count"] + 1

    def upd(g, s, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p):
            vr = b2 * s["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * s["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g / jnp.sqrt(r[..., None] * vc[..., None, :] /
                             jnp.maximum(jnp.mean(vc, axis=-1,
                                                  keepdims=True)[..., None, :],
                                         eps) + eps)
            ns = {"vr": vr, "vc": vc}
        else:
            v = b2 * s["v"] + (1 - b2) * g2
            u = g / jnp.sqrt(v + eps)
            ns = {"v": v}
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip)
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (u + weight_decay * pf)
        return new_p.astype(p.dtype), ns

    flat_g, tdef = jax.tree.flatten(grads)
    flat_s = tdef.flatten_up_to(state["f"])
    flat_p = tdef.flatten_up_to(params)
    new_p, new_s = [], []
    for g, s, p in zip(flat_g, flat_s, flat_p):
        np_, ns_ = upd(g, s, p)
        new_p.append(np_)
        new_s.append(ns_)
    return (jax.tree.unflatten(tdef, new_p),
            {"f": jax.tree.unflatten(tdef, new_s), "count": c})


def get_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {name!r}")
