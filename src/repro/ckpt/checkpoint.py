"""Checkpointing: atomic save/restore with manifest + async writer +
elastic resharding on restore.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json      # step, flat keys, shapes/dtypes, mesh shape
        arrays.npz         # full (unsharded) arrays, keyed by flat path

For this container the host gathers full arrays (addressable shards); on a
real multi-host pod each process would write its addressable shards and the
manifest records the global shape — the restore path already reshards from
full arrays to whatever mesh the new jit uses, which is what elastic
restart needs (profiles are re-keyed per the paper: a profile is only valid
for its axis size).

Writes are atomic (tmp dir + rename); ``AsyncCheckpointer`` overlaps the
serialization with training (device->host copy happens synchronously, disk
write on a worker thread) and keeps the newest K checkpoints.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading

import jax
import numpy as np

from repro._compat import tree_flatten_with_path


def _flatten(tree):
    flat, treedef = tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        a = np.asarray(leaf)
        if a.dtype.kind not in "biufc":
            # ml_dtypes (bfloat16, ...) do not survive npz: store raw bits;
            # the manifest keeps the logical dtype for restore
            a = a.view(f"u{a.dtype.itemsize}")
        out[key] = a
    return out, treedef


def save(ckpt_dir, step: int, tree, *, extra: dict | None = None,
         keep: int = 3) -> pathlib.Path:
    d = pathlib.Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_step_{step:09d}"
    final = d / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    arrays, _ = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(d, keep)
    return final


def _gc(d: pathlib.Path, keep: int):
    steps = sorted(p for p in d.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*")
                   if p.is_dir())
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, like_tree):
    """Restore into the structure (and shardings) of ``like_tree`` —
    leaves may be arrays or ShapeDtypeStructs; full arrays are resharded by
    ``jax.device_put`` against the target sharding when present."""
    d = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    data = np.load(d / "arrays.npz")
    flat, treedef = tree_flatten_with_path(like_tree)
    leaves = []
    for path, like in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = data[key]
        tgt = np.dtype(like.dtype)
        if arr.dtype != tgt and tgt.kind not in "biufc" \
                and arr.dtype.itemsize == tgt.itemsize:
            arr = arr.view(tgt)           # raw-bits round trip (bfloat16)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {like.shape}")
        sharding = getattr(like, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree.unflatten(treedef, leaves)


def manifest(ckpt_dir, step: int) -> dict:
    d = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    return json.loads((d / "manifest.json").read_text())


class AsyncCheckpointer:
    """Overlap checkpoint writes with training."""

    def __init__(self, ckpt_dir, *, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, *, extra=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # sync device->host

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra,
                     keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
