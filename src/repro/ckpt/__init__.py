from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_step,  # noqa: F401
                                   restore, save)
