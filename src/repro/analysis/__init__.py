from repro.analysis.hlo import (HloParseError, collective_bytes,  # noqa: F401
                                collective_sites, module_world,
                                parse_instructions)
from repro.analysis.interpose import (assert_bitexact,  # noqa: F401
                                      compile_zoo_hlo, map_sites, rewrite,
                                      scan_potential, tuning_potential)
from repro.analysis.roofline import roofline_terms  # noqa: F401
