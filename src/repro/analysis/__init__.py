from repro.analysis.hlo import collective_bytes  # noqa: F401
from repro.analysis.roofline import roofline_terms  # noqa: F401
