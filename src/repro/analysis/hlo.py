"""HLO text analysis: collective payload bytes per op class.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module text: build a symbol table (instruction name -> result
bytes), then for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute sum the byte sizes of its OPERANDS (the
spec'd convention for the roofline's collective term).

Instructions inside ``while`` (scan) bodies execute once per iteration —
multiply by the loop trip count.  Trip counts are recovered from the
canonical XLA pattern (compare against a constant in the loop condition).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^)]*?\)?"
                       r"[\w\[\],\s{}:#\*]*?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (sums tuple elements)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-class operand bytes (and call counts), weighted by loop trip
    counts.  Returns {"all-gather": {"bytes": int, "count": int}, ...,
    "total_bytes": int}."""
    sizes: dict[str, int] = {}
    # pass 1: symbol table over all computations
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, _op = m.groups()
        sizes[name] = _shape_bytes(type_str)

    # pass 2: computation trip counts (while bodies)
    comp_mult = _loop_multipliers(hlo_text)

    out: dict[str, dict] = defaultdict(lambda: {"bytes": 0, "count": 0})
    current_comp = ""
    for line in hlo_text.splitlines():
        ls = line.strip()
        if _is_header(ls):
            current_comp = _header_name(ls)
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, _type_str, op = m.groups()
        if op.rstrip("-start") not in COLLECTIVES and op not in COLLECTIVES:
            continue
        # operand list = %refs in the parens, excluding the instr itself
        paren = line[line.index(op) + len(op):]
        operands = [o for o in _OPERAND_RE.findall(paren)
                    if o in sizes and o != name]
        b = sum(sizes[o] for o in operands)
        mult = comp_mult.get(current_comp, 1)
        key = op[:-6] if op.endswith("-start") else op
        out[key]["bytes"] += b * mult
        out[key]["count"] += mult

    result = {k: dict(v) for k, v in out.items()}
    result["total_bytes"] = sum(v["bytes"] for v in out.values())
    return result


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    """[(dtype, dims), ...] for every array in an HLO type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def program_costs(hlo_text: str) -> dict:
    """Trip-count-aware program costs parsed from HLO text.

    XLA's ``compiled.cost_analysis()`` counts each ``while`` (scan) body
    ONCE; layer-scans and microbatch-scans therefore undercount by the trip
    product.  This walks every computation, accumulates

      * dot_flops — 2 · |out| · |contraction| per dot (matmul-dominated LM
        programs; elementwise flops are excluded and documented),
      * bytes     — operand + result bytes per instruction (un-fused upper
        bound of HBM traffic),

    and weights each computation by its loop-trip multiplier.
    """
    # symbol table: name -> (bytes, dims-of-first-array)
    sizes: dict[str, int] = {}
    dims: dict[str, list[int]] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, _op = m.groups()
        sizes[name] = _shape_bytes(type_str)
        arr = _shape_dims(type_str)
        dims[name] = arr[0][1] if arr else []

    comp_mult = _loop_multipliers(hlo_text)
    comps = _split_computations(hlo_text)

    # bytes are accumulated only at KERNEL boundaries: instructions in the
    # entry computation and while (scan) bodies.  Fusion bodies / reduce
    # regions are the INSIDE of fused kernels — counting them would treat
    # every fused elementwise op as HBM traffic.
    kernel_comps = set()
    for m in re.finditer(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                         hlo_text):
        kernel_comps.update(m.groups())
    entry = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if entry:
        kernel_comps.add(entry.group(1).rstrip("{").strip())
    for c in comps:
        if c.startswith("main") or c.endswith("_spmd"):
            kernel_comps.add(c)

    total_flops = 0.0
    total_bytes = 0.0
    by_op: dict[str, float] = defaultdict(float)
    per_comp: dict[str, dict] = {}
    # while/conditional pass carries by reference — their bodies are counted
    skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "copy", "while", "conditional", "after-all"}

    # fusions whose root is a dynamic-update-slice update their big operand
    # IN PLACE on real hardware (loop-carried/donated buffers): traffic is
    # the touched region, not the whole buffer.
    dus_fusions = set()
    zero_fusions = set()      # pure dtype-convert: fuses into MXU consumers
    move_fusions = set()      # pure data movement: one pass over the output
    slice_fusions = set()     # slice + elementwise: operand reads capped
    _ZERO = {"parameter", "constant", "convert", "bitcast", "reshape",
             "tuple", "get-tuple-element", "copy"}
    _MOVE = _ZERO | {"transpose", "broadcast", "dynamic-slice", "slice",
                     "concatenate", "pad"}
    for m in re.finditer(r"calls=%?([\w\.\-]+)", hlo_text):
        cname = m.group(1)
        body = comps.get(cname, "")
        if "dynamic-update-slice" in body:
            dus_fusions.add(cname)
            continue
        ops_in = set()
        for ln in body.splitlines():
            mm = _INSTR_RE.match(ln)
            if mm:
                ops_in.add(mm.group(3))
        if ops_in and ops_in <= _ZERO:
            zero_fusions.add(cname)
        elif ops_in and ops_in <= _MOVE:
            move_fusions.add(cname)
        elif ({"dynamic-slice", "slice", "gather"} & ops_in
                and not {"reduce", "dot", "reduce-window"} & ops_in):
            # slices big operands: reads are slice-sized, not buffer-sized
            slice_fusions.add(cname)
    for comp, body in comps.items():
        mult = comp_mult.get(comp, 1)
        count_bytes = comp in kernel_comps
        f = 0.0
        b = 0.0
        for line in body.splitlines():
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, op = m.groups()
            if op in skip_ops:
                continue
            out_b = _shape_bytes(type_str)
            paren = line[line.index(op) + len(op):]
            operands = [o for o in _OPERAND_RE.findall(paren)
                        if o in sizes and o != name]
            if count_bytes:
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the slice, not the operand buffer
                    db = 2 * out_b
                elif op == "dynamic-update-slice":
                    # in-place read-modify-write of the update region
                    upd = sizes.get(operands[1], out_b) if len(
                        operands) > 1 else out_b
                    db = 2 * upd
                elif op == "fusion":
                    called = re.search(r"calls=%?([\w\.\-]+)", line)
                    cname = called.group(1) if called else ""
                    aliasable = any(sizes[o] == out_b for o in operands)
                    if cname in dus_fusions and aliasable:
                        # in-place cache update: touched region only
                        db = 2 * sum(sizes[o] for o in operands
                                     if sizes[o] < out_b)
                    elif cname in zero_fusions:
                        # dtype converts feeding dots: native on the MXU
                        db = 0
                    elif cname in move_fusions:
                        db = 2 * out_b
                    elif cname in slice_fusions:
                        db = out_b + sum(min(sizes[o], out_b)
                                         for o in operands)
                    else:
                        db = out_b + sum(sizes[o] for o in operands)
                else:
                    db = out_b + sum(sizes[o] for o in operands)
                b += db
                by_op[op] += db * mult
            if op == "dot":
                arrs = _shape_dims(type_str)
                out_elems = 1
                for d in (arrs[0][1] if arrs else []):
                    out_elems *= d
                cm = _DOT_CONTRACT_RE.search(line)
                contract = 1
                if cm and operands:
                    lhs_dims = dims.get(operands[0], [])
                    for di in cm.group(1).split(","):
                        if di and int(di) < len(lhs_dims):
                            contract *= lhs_dims[int(di)]
                f += 2.0 * out_elems * contract
        per_comp[comp] = {"mult": mult, "dot_flops": f, "bytes": b}
        total_flops += f * mult
        total_bytes += b * mult

    return {"dot_flops": total_flops, "bytes": total_bytes,
            "computations": len(per_comp),
            "bytes_by_op": dict(sorted(by_op.items(),
                                       key=lambda kv: -kv[1])[:10])}


def _loop_multipliers(hlo_text: str) -> dict[str, int]:
    """computation name -> estimated executions (scan trip counts).

    Heuristic: for every while op, find the trip count from the condition
    computation's `constant(N)` compare; attribute it to the body
    computation's name.  Nested scans multiply."""
    # map condition/body comp -> while instruction line
    body_of_while: dict[str, str] = {}
    cond_of_while: dict[str, str] = {}
    for m in re.finditer(
            r"while\([^)]*\),\s*condition=%?([\w\.\-]+),\s*body=%?"
            r"([\w\.\-]+)", hlo_text):
        cond, body = m.groups()
        body_of_while[body] = cond
        cond_of_while[body] = cond

    # trip count per condition computation: look for compare with constant
    comp_bodies = _split_computations(hlo_text)
    trips: dict[str, int] = {}
    for body, cond in cond_of_while.items():
        text = comp_bodies.get(cond, "")
        consts = [int(x) for x in re.findall(
            r"constant\((\d+)\)", text)]
        trips[body] = max(consts) if consts else 1

    # nested scan multiplication: if a body computation contains a while
    # whose body is another computation, multiply (one level is enough for
    # our stacks: layer-scan x microbatch-scan)
    mult = dict(trips)
    for body, n in trips.items():
        text = comp_bodies.get(body, "")
        for m in re.finditer(r"body=%?([\w\.\-]+)", text):
            inner = m.group(1)
            if inner in mult:
                mult[inner] = mult[inner] * n
    return mult


def _is_header(s: str) -> bool:
    """Computation header: '%name (sig) -> type {' (may contain /*index*/
    comments); instruction lines never END with '{'."""
    return s.endswith("{") and ("->" in s or s.startswith("ENTRY")) and \
        (s.startswith("%") or s.startswith("ENTRY"))


def _header_name(s: str) -> str:
    tok = s.split()[0]
    if tok == "ENTRY":
        tok = s.split()[1]
    return tok.lstrip("%").rstrip("{").strip()


def _split_computations(hlo_text: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if _is_header(s):
            cur = _header_name(s)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
            if s == "}":
                cur = None
    return {k: "\n".join(v) for k, v in comps.items()}
