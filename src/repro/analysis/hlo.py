"""HLO text analysis: collective payload bytes per op class.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module text: build a symbol table (instruction name -> result
bytes), then for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute sum the byte sizes of its OPERANDS (the
spec'd convention for the roofline's collective term).

Async collectives print as ``<op>-start`` / ``<op>-done`` pairs; the
``-start`` instruction carries the operands, so each pair is counted ONCE
at its start (a ``-done`` without a matching start is a parse error — the
bytes would silently vanish otherwise, which is exactly the historical
``rstrip("-start")`` bug this module is tested against).

Instructions inside ``while`` (scan) bodies execute once per iteration —
multiply by the loop trip count.  Trip counts are recovered from the
canonical XLA pattern (compare against a constant in the loop condition).

Parsing conventions (operand bytes, async pairing, trip counts) and the
interposition modes built on top of this module are documented in
``DESIGN_HLO.md``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

#: HLO element type -> numpy-style dtype name (OpCell.dtype convention)
_DTYPE_NAME = {
    "pred": "bool", "s8": "int8", "u8": "uint8", "s16": "int16",
    "u16": "uint16", "bf16": "bfloat16", "f16": "float16", "s32": "int32",
    "u32": "uint32", "f32": "float32", "s64": "int64", "u64": "uint64",
    "f64": "float64", "c64": "complex64", "c128": "complex128",
}

# dims may print with spaces after commas inside tuple types
_SHAPE_RE = re.compile(r"(\w+)\[([\d,\s]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ASYNC_SUFFIXES = ("-start", "-done")


class HloParseError(ValueError):
    """The module text violates a parser invariant (e.g. an async ``-done``
    with no matching ``-start``) — callers gating on 'zero dropped ops'
    treat this as a hard failure, never a silent undercount."""


def split_async(op: str) -> tuple[str, str]:
    """``op`` -> (base op, async role): ``"reduce-scatter-start"`` ->
    ``("reduce-scatter", "start")``; sync ops get role ``""``.  Uses exact
    suffix removal — NEVER ``str.rstrip``, which strips a character CLASS
    (``"reduce-scatter-start".rstrip("-start")`` == ``"reduce-scatte"``,
    the bug that silently dropped every async collective's bytes)."""
    for suf in _ASYNC_SUFFIXES:
        if op.endswith(suf):
            return op[: -len(suf)], suf[1:]
    return op, ""


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (sums tuple elements)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.replace(" ", "").split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    """[(dtype, dims), ...] for every array in an HLO type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.replace(" ", "").split(",")
                         if d]))
    return out


# ---------------------------------------------------------------------------
# instruction-level parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Instr:
    """One parsed HLO instruction line."""
    name: str           # result name (no % sigil)
    type_str: str       # result type text, tuple parens included
    op: str             # opcode as printed (async suffix kept)
    args: str           # everything from the opening '(' of the call on
    computation: str    # enclosing computation name
    line: str           # the raw line

    def operands(self, symbols) -> list[str]:
        """%refs in the call args that are known instructions (excludes
        self-references and computation refs like ``to_apply=%add``)."""
        return [o for o in _OPERAND_RE.findall(self.args)
                if o in symbols and o != self.name]


def _parse_instr(line: str):
    """``(name, type_str, op, args)`` for an instruction line, else None.

    Replaces the old single-regex parse, which dropped any instruction
    whose result type nests parentheses — e.g. the canonical async form
    ``%ar = ((f32[8]), (f32[8])) all-reduce-start(...)`` — and any scalar
    tuple member.  We scan for the opcode: the first ``ident(`` at paren
    AND brace depth zero with a nonempty type to its left (braces guard
    layout annotations like ``{1,0:T(8,128)}``).
    """
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    depth = brace = 0
    for i, ch in enumerate(rhs):
        if ch == "{":
            brace += 1
        elif ch == "}":
            brace = max(0, brace - 1)
        elif ch == "(":
            if brace == 0 and depth == 0:
                j = i
                while j and (rhs[j - 1].isalnum() or rhs[j - 1] in "-_."):
                    j -= 1
                tok = rhs[j:i]
                if tok and not tok[0].isdigit() and rhs[:j].strip():
                    return name, rhs[:j].strip(), tok, rhs[i:]
            depth += 1
        elif ch == ")":
            depth = max(0, depth - 1)
    return None


def parse_instructions(hlo_text: str) -> list[Instr]:
    """Every instruction in the module, with computation attribution."""
    out: list[Instr] = []
    current = ""
    for line in hlo_text.splitlines():
        s = line.strip()
        if _is_header(s):
            current = _header_name(s)
            continue
        p = _parse_instr(line)
        if p is not None:
            name, type_str, op, args = p
            out.append(Instr(name, type_str, op, args, current, line))
    return out


def module_world(hlo_text: str) -> int:
    """Device count of the compiled module (``num_partitions`` x
    ``replica_count`` from the HloModule header; 1 when absent)."""
    header = ""
    for line in hlo_text.splitlines():
        if line.lstrip().startswith("HloModule"):
            header = line
            break
    n = 1
    for key in ("num_partitions", "replica_count"):
        m = re.search(rf"{key}=(\d+)", header)
        if m:
            n *= int(m.group(1))
    return n


_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(.*?)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[([\d,]+)\](?:T\([\d,]+\))?<=\[[\d,]+\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}")


def _parse_groups(args: str) -> tuple[int, int]:
    """``(n_groups, group_size)`` from a collective's attributes.

    Handles both printed forms — explicit ``{{0,1},{2,3}}`` and iota
    ``[2,4]<=[8]`` (shape = (n_groups, group_size)) — plus the
    collective-permute ``source_target_pairs`` (groups = the permutation's
    cycles).  ``(0, 0)`` when no group attribute is present (flat world).
    """
    m = _GROUPS_IOTA_RE.search(args)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        if len(dims) == 1:
            return 1, dims[0]
        n_groups = dims[0]
        size = 1
        for d in dims[1:]:
            size *= d
        return n_groups, size
    m = _GROUPS_EXPLICIT_RE.search(args)
    if m:
        body = m.group(1) + "}"
        groups = re.findall(r"\{([\d,\s]*)\}", body)
        if not groups:
            return 0, 0
        sizes = [len([t for t in g.replace(" ", "").split(",") if t])
                 for g in groups]
        return len(sizes), max(sizes)
    m = _PAIRS_RE.search(args)
    if m:
        pairs = re.findall(r"\{(\d+),\s*(\d+)\}", m.group(0))
        return _permute_cycles([(int(a), int(b)) for a, b in pairs])
    return 0, 0


def _permute_cycles(pairs: list[tuple[int, int]]) -> tuple[int, int]:
    """Cycle decomposition of a collective-permute: ``(n_cycles,
    longest_cycle)`` — the permute analogue of (n_groups, group_size)."""
    if not pairs:
        return 0, 0
    nxt = dict(pairs)
    seen: set[int] = set()
    cycles = []
    for start in sorted(nxt):
        if start in seen:
            continue
        n, cur = 0, start
        while cur not in seen:
            seen.add(cur)
            n += 1
            cur = nxt.get(cur, start)
        cycles.append(n)
    return len(cycles), max(cycles)


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective instruction (async pairs collapse onto the start)."""
    name: str               # instruction name
    hlo_op: str             # opcode as printed (suffix kept)
    base_op: str            # one of COLLECTIVES
    async_role: str         # "" | "start"  (dones are folded into starts)
    computation: str
    mult: int               # loop trip multiplier of the computation
    operand_bytes: int      # payload: summed operand bytes
    result_bytes: int
    dtype: str              # numpy-style name of the first operand array
    n_groups: int           # replica groups (0 = flat world)
    group_size: int         # participants per group (0 = flat world)
    operands: tuple[str, ...]
    line: str


def collective_sites(hlo_text: str) -> list[CollectiveSite]:
    """Every collective in the module, trip-count attributed, async pairs
    validated and collapsed onto their ``-start``.

    Raises :class:`HloParseError` when a ``-done`` has no same-computation
    ``-start`` of the same base op (or vice versa) — an unpaired async op
    means the parse dropped bytes somewhere.
    """
    instrs = parse_instructions(hlo_text)
    sizes = {i.name: _shape_bytes(i.type_str) for i in instrs}
    type_of = {i.name: i.type_str for i in instrs}
    comp_mult = _loop_multipliers(hlo_text)

    sites: list[CollectiveSite] = []
    async_counts: dict[tuple[str, str, str], int] = defaultdict(int)
    for ins in instrs:
        base, role = split_async(ins.op)
        if base not in COLLECTIVES:
            continue
        if role:
            async_counts[(ins.computation, base, role)] += 1
        if role == "done":
            continue            # bytes live on the paired -start
        operands = tuple(ins.operands(sizes))
        ob = sum(sizes[o] for o in operands)
        dtype = ""
        for o in operands:
            arrs = _shape_dims(type_of[o])
            if arrs:
                dtype = _DTYPE_NAME.get(arrs[0][0], arrs[0][0])
                break
        if not dtype:
            arrs = _shape_dims(ins.type_str)
            dtype = _DTYPE_NAME.get(arrs[0][0], "float32") if arrs \
                else "float32"
        n_groups, group_size = _parse_groups(ins.args)
        sites.append(CollectiveSite(
            name=ins.name, hlo_op=ins.op, base_op=base, async_role=role,
            computation=ins.computation,
            mult=comp_mult.get(ins.computation, 1),
            operand_bytes=ob, result_bytes=sizes[ins.name],
            dtype=dtype, n_groups=n_groups, group_size=group_size,
            operands=operands, line=ins.line))

    for (comp, base, role), n in sorted(async_counts.items()):
        other = "done" if role == "start" else "start"
        if async_counts.get((comp, base, other), 0) != n:
            raise HloParseError(
                f"unpaired async collective: {n}x {base}-{role} vs "
                f"{async_counts.get((comp, base, other), 0)}x {base}-{other}"
                f" in computation {comp!r}")
    return sites


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-class operand bytes (and call counts), weighted by loop trip
    counts.  Returns {"all-gather": {"bytes": int, "count": int}, ...,
    "total_bytes": int}.  Async ``-start``/``-done`` pairs count once,
    under the base op name."""
    out: dict[str, dict] = defaultdict(lambda: {"bytes": 0, "count": 0})
    for s in collective_sites(hlo_text):
        out[s.base_op]["bytes"] += s.operand_bytes * s.mult
        out[s.base_op]["count"] += s.mult
    result = {k: dict(v) for k, v in out.items()}
    result["total_bytes"] = sum(v["bytes"] for v in out.values())
    return result


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def program_costs(hlo_text: str) -> dict:
    """Trip-count-aware program costs parsed from HLO text.

    XLA's ``compiled.cost_analysis()`` counts each ``while`` (scan) body
    ONCE; layer-scans and microbatch-scans therefore undercount by the trip
    product.  This walks every computation, accumulates

      * dot_flops — 2 · |out| · |contraction| per dot (matmul-dominated LM
        programs; elementwise flops are excluded and documented),
      * bytes     — operand + result bytes per instruction (un-fused upper
        bound of HBM traffic),

    and weights each computation by its loop-trip multiplier.
    """
    # symbol table: name -> (bytes, dims-of-first-array)
    instrs = parse_instructions(hlo_text)
    sizes: dict[str, int] = {}
    dims: dict[str, list[int]] = {}
    for ins in instrs:
        sizes[ins.name] = _shape_bytes(ins.type_str)
        arr = _shape_dims(ins.type_str)
        dims[ins.name] = arr[0][1] if arr else []

    comp_mult = _loop_multipliers(hlo_text)
    comps = _split_computations(hlo_text)

    # bytes are accumulated only at KERNEL boundaries: instructions in the
    # entry computation and while (scan) bodies.  Fusion bodies / reduce
    # regions are the INSIDE of fused kernels — counting them would treat
    # every fused elementwise op as HBM traffic.
    kernel_comps = set()
    for m in re.finditer(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)",
                         hlo_text):
        kernel_comps.update(m.groups())
    entry = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    if entry:
        kernel_comps.add(entry.group(1).rstrip("{").strip())
    for c in comps:
        if c.startswith("main") or c.endswith("_spmd"):
            kernel_comps.add(c)

    total_flops = 0.0
    total_bytes = 0.0
    by_op: dict[str, float] = defaultdict(float)
    per_comp: dict[str, dict] = {}
    # while/conditional pass carries by reference — their bodies are counted
    skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "copy", "while", "conditional", "after-all"}

    # fusions whose root is a dynamic-update-slice update their big operand
    # IN PLACE on real hardware (loop-carried/donated buffers): traffic is
    # the touched region, not the whole buffer.
    dus_fusions = set()
    zero_fusions = set()      # pure dtype-convert: fuses into MXU consumers
    move_fusions = set()      # pure data movement: one pass over the output
    slice_fusions = set()     # slice + elementwise: operand reads capped
    _ZERO = {"parameter", "constant", "convert", "bitcast", "reshape",
             "tuple", "get-tuple-element", "copy"}
    _MOVE = _ZERO | {"transpose", "broadcast", "dynamic-slice", "slice",
                     "concatenate", "pad"}
    by_comp_instrs: dict[str, list[Instr]] = defaultdict(list)
    for ins in instrs:
        by_comp_instrs[ins.computation].append(ins)
    for m in re.finditer(r"calls=%?([\w\.\-]+)", hlo_text):
        cname = m.group(1)
        body_ops = {i.op for i in by_comp_instrs.get(cname, [])}
        if "dynamic-update-slice" in body_ops:
            dus_fusions.add(cname)
        elif body_ops and body_ops <= _ZERO:
            zero_fusions.add(cname)
        elif body_ops and body_ops <= _MOVE:
            move_fusions.add(cname)
        elif ({"dynamic-slice", "slice", "gather"} & body_ops
                and not {"reduce", "dot", "reduce-window"} & body_ops):
            # slices big operands: reads are slice-sized, not buffer-sized
            slice_fusions.add(cname)
    for comp in comps:
        mult = comp_mult.get(comp, 1)
        count_bytes = comp in kernel_comps
        f = 0.0
        b = 0.0
        for ins in by_comp_instrs.get(comp, []):
            op = ins.op
            if op in skip_ops:
                continue
            out_b = sizes[ins.name]
            operands = ins.operands(sizes)
            if count_bytes:
                if op in ("dynamic-slice", "slice", "gather"):
                    # reads only the slice, not the operand buffer
                    db = 2 * out_b
                elif op == "dynamic-update-slice":
                    # in-place read-modify-write of the update region
                    upd = sizes.get(operands[1], out_b) if len(
                        operands) > 1 else out_b
                    db = 2 * upd
                elif op == "fusion":
                    called = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                    cname = called.group(1) if called else ""
                    aliasable = any(sizes[o] == out_b for o in operands)
                    if cname in dus_fusions and aliasable:
                        # in-place cache update: touched region only
                        db = 2 * sum(sizes[o] for o in operands
                                     if sizes[o] < out_b)
                    elif cname in zero_fusions:
                        # dtype converts feeding dots: native on the MXU
                        db = 0
                    elif cname in move_fusions:
                        db = 2 * out_b
                    elif cname in slice_fusions:
                        db = out_b + sum(min(sizes[o], out_b)
                                         for o in operands)
                    else:
                        db = out_b + sum(sizes[o] for o in operands)
                else:
                    db = out_b + sum(sizes[o] for o in operands)
                b += db
                by_op[op] += db * mult
            if op == "dot":
                arrs = _shape_dims(ins.type_str)
                out_elems = 1
                for d in (arrs[0][1] if arrs else []):
                    out_elems *= d
                cm = _DOT_CONTRACT_RE.search(ins.line)
                contract = 1
                if cm and operands:
                    lhs_dims = dims.get(operands[0], [])
                    for di in cm.group(1).split(","):
                        if di and int(di) < len(lhs_dims):
                            contract *= lhs_dims[int(di)]
                f += 2.0 * out_elems * contract
        per_comp[comp] = {"mult": mult, "dot_flops": f, "bytes": b}
        total_flops += f * mult
        total_bytes += b * mult

    return {"dot_flops": total_flops, "bytes": total_bytes,
            "computations": len(per_comp),
            "bytes_by_op": dict(sorted(by_op.items(),
                                       key=lambda kv: -kv[1])[:10])}


# while operands print with their full (possibly nested-tuple) types inline:
#   while((s32[], f32[2,16]{1,0}) %tuple.3), condition=%c, body=%b
# so the operand part is matched lazily up to the LAST '),' before the
# condition attribute — a greedy-on-nesting [^)]* there silently matched
# nothing on every real module (trip counts all fell back to 1).
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")


def _loop_multipliers(hlo_text: str) -> dict[str, int]:
    """computation name -> estimated executions (scan trip counts).

    Heuristic: for every while op, find the trip count from the condition
    computation's `constant(N)` compare; attribute it to the body
    computation's name.  Nested scans multiply."""
    # map condition/body comp -> while instruction line
    cond_of_while: dict[str, str] = {}
    for m in _WHILE_RE.finditer(hlo_text):
        cond, body = m.groups()
        cond_of_while[body] = cond

    # trip count per condition computation: look for compare with constant
    comp_bodies = _split_computations(hlo_text)
    trips: dict[str, int] = {}
    for body, cond in cond_of_while.items():
        text = comp_bodies.get(cond, "")
        consts = [int(x) for x in re.findall(
            r"constant\((\d+)\)", text)]
        trips[body] = max(consts) if consts else 1

    # nested scan multiplication: if a body computation contains a while
    # whose body is another computation, multiply (one level is enough for
    # our stacks: layer-scan x microbatch-scan)
    mult = dict(trips)
    for body, n in trips.items():
        text = comp_bodies.get(body, "")
        for m in re.finditer(r"body=%?([\w\.\-]+)", text):
            inner = m.group(1)
            if inner in mult:
                mult[inner] = mult[inner] * n
    return mult


def _is_header(s: str) -> bool:
    """Computation header: '%name (sig) -> type {' (may contain /*index*/
    comments); instruction lines never END with '{'.  Newer XLA prints
    computation names without the % sigil, so only the shape is checked."""
    return s.endswith("{") and ("->" in s or s.startswith("ENTRY")) and \
        "=" not in s.split("(")[0]


def _header_name(s: str) -> str:
    tok = s.split()[0]
    if tok == "ENTRY":
        tok = s.split()[1]
    return tok.lstrip("%").rstrip("{").strip()


def _split_computations(hlo_text: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if _is_header(s):
            cur = _header_name(s)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)
            if s == "}":
                cur = None
    return {k: "\n".join(v) for k, v in comps.items()}
