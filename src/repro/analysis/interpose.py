"""Transparent interposition at the XLA/HLO layer.

PGMPITuneLib's pitch is intercepting collectives *without touching user
code*.  The dispatcher (``repro.core.api``) only sees call sites that go
through ``repro.dist`` — but the compiled HLO of ANY jitted function names
every collective XLA emitted, whoever wrote the model.  This module closes
that gap, in two modes:

**report-only** — :func:`tuning_potential` scans a jitted function's
compiled HLO for collective ops (sync and ``-start``/``-done`` async pairs,
including inside ``while``/scan bodies), maps each site to an
:class:`~repro.core.cell.OpCell` (with adjacent-``dot`` detection so an
all-gather feeding a matmul prices as the fused ``allgather_matmul`` cell),
and prices every cell's default against its best mock-up via the cost
model: "this program's collectives vs. their best mock-ups: X.Yx on the
table" — the paper's 'identify the tuning potential of the library' result
lifted to the XLA level.

**rewrite** — :func:`rewrite` re-traces a ``repro.dist``-shaped function
with tuned mock-ups substituted (profiles / force table), matches the
dispatch records against the baseline HLO's collective sites (proof the
interposition touched the sites it claims), runs both compiled programs,
and checks bit-exactness leaf by leaf.

Parser conventions (operand bytes, async pairing, trip counts) are in
``DESIGN_HLO.md``; ``analysis/hlo.py`` owns the text parsing, this module
owns cell mapping and pricing.
"""
from __future__ import annotations

import dataclasses
import re

from repro.analysis.hlo import (CollectiveSite, HloParseError, Instr,
                                _shape_bytes, _shape_dims, collective_sites,
                                module_world, parse_instructions)
from repro.core import costmodel
from repro.core.cell import HLO_TO_OP, OpCell
from repro.core.costmodel import Topo, V5E_ICI
from repro.core.profiles import ProfileStore

__all__ = [
    "SiteCell", "PotentialReport", "RewriteResult", "map_sites",
    "scan_potential", "tuning_potential", "rewrite", "assert_bitexact",
    "compile_zoo_hlo", "HloParseError",
]


# ---------------------------------------------------------------------------
# HLO site -> OpCell mapping (with adjacent-dot / fused-matmul detection)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SiteCell:
    """One HLO collective site resolved to its tuning cell."""
    site: CollectiveSite
    cell: OpCell
    adjacent_dot: str = ""      # dot instruction name, when one is adjacent
    #: True when the adjacency mapped the site onto a FUSED dispatcher op
    #: (allgather_matmul / matmul_reducescatter); an all-reduce fed by a
    #: dot stays a plain cell but keeps ``adjacent_dot`` as the
    #: fused-matmul-candidate marker.
    fused: bool = False


_DIMS_ATTR_RE = re.compile(r"dimensions=\{([\d,]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_RHS_C_RE = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_LHS_B_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_RHS_B_RE = re.compile(r"rhs_batch_dims=\{([\d,]*)\}")


def _ints(rx: re.Pattern, text: str) -> list[int]:
    m = rx.search(text)
    if not m:
        return []
    return [int(x) for x in m.group(1).split(",") if x]


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


@dataclasses.dataclass(frozen=True)
class _DotGeom:
    """GEMM geometry of one HLO dot: full logical [mm_m, mm_k] @ [mm_k,
    mm_n] with batch dims folded into mm_m (flops stay 2·k·m·n)."""
    mm_k: int
    mm_m: int
    mm_n: int
    lhs: str
    rhs: str
    lhs_contracting: tuple[int, ...]
    rhs_contracting: tuple[int, ...]


def _dot_geometry(dot: Instr, dims: dict[str, list[int]]) -> _DotGeom | None:
    names = [o for o in re.findall(r"%([\w\.\-]+)", dot.args) if o in dims]
    if len(names) < 2:
        return None
    lhs, rhs = names[0], names[1]
    ld, rd = dims[lhs], dims[rhs]
    lc = _ints(_LHS_C_RE, dot.line)
    rc = _ints(_RHS_C_RE, dot.line)
    lb = _ints(_LHS_B_RE, dot.line)
    mm_k = _prod(ld[i] for i in lc if i < len(ld)) if lc else 1
    batch = _prod(ld[i] for i in lb if i < len(ld)) if lb else 1
    mm_m = max(1, _prod(ld) // max(mm_k * batch, 1)) * batch
    mm_n = max(1, _prod(rd) // max(mm_k * batch, 1))
    return _DotGeom(mm_k, mm_m, mm_n, lhs, rhs, tuple(lc), tuple(rc))


def _map_one(site: CollectiveSite, comp_instrs: list[Instr],
             dims: dict[str, list[int]], sizes: dict[str, int],
             default_p: int) -> SiteCell:
    """Resolve one collective site to its cell (may raise KeyError for a
    collective class with no dispatcher counterpart)."""
    p = site.group_size or default_p or 1
    dot = None
    # async sites hand their value to consumers via the paired -done, so
    # adjacency detection only runs for sync sites (async stays plain).
    if not site.async_role:
        if site.base_op == "all-gather":
            dot = next((i for i in comp_instrs if i.op == "dot"
                        and site.name in i.operands(sizes)), None)
        elif site.base_op in ("reduce-scatter", "all-reduce") \
                and site.operands:
            producer = next((i for i in comp_instrs
                             if i.name == site.operands[0]), None)
            if producer is not None and producer.op == "dot":
                dot = producer

    if dot is not None:
        g = _dot_geometry(dot, dims)
        if g is not None:
            if site.base_op == "all-gather":
                gdims = _ints(_DIMS_ATTR_RE, site.line)
                gdim = gdims[0] if gdims else 0
                if site.name == g.lhs:
                    role = ("contract" if gdim in g.lhs_contracting
                            else "gather")
                    gemm = (g.mm_k, g.mm_m, g.mm_n)
                else:
                    # gathered operand is the rhs: transpose the logical
                    # GEMM so the gathered side plays lhs (flops identical)
                    role = ("contract" if gdim in g.rhs_contracting
                            else "gather")
                    gemm = (g.mm_k, g.mm_n, g.mm_m)
                return SiteCell(
                    site, OpCell.from_hlo(site.base_op, p,
                                          site.operand_bytes, site.dtype,
                                          gemm=gemm, mm_role=role),
                    adjacent_dot=dot.name, fused=True)
            if site.base_op == "reduce-scatter":
                # matmul_reducescatter convention: the payload is the
                # full-row local input x [mm_m, mm_k] — the dot's lhs
                nbytes = sizes.get(g.lhs, site.operand_bytes)
                return SiteCell(
                    site, OpCell.from_hlo(site.base_op, p, nbytes,
                                          site.dtype,
                                          gemm=(g.mm_k, g.mm_m, g.mm_n),
                                          mm_role="scatter"),
                    adjacent_dot=dot.name, fused=True)
            # dot -> all-reduce: the monolithic allreduce the fused ops
            # replace.  No fused dispatcher op takes this exact shape, so
            # it stays a plain cell — but the adjacency is reported as a
            # fused-matmul candidate.
            return SiteCell(
                site, OpCell.from_hlo(site.base_op, p, site.operand_bytes,
                                      site.dtype),
                adjacent_dot=dot.name, fused=False)
    return SiteCell(site, OpCell.from_hlo(site.base_op, p,
                                          site.operand_bytes, site.dtype))


def map_sites(hlo_text: str, *, default_world: int | None = None) \
        -> tuple[list[SiteCell], list[CollectiveSite]]:
    """Map every collective instruction of a compiled module to an
    ``OpCell``.  Returns ``(mapped, unmapped)`` — a nonempty ``unmapped``
    means a collective class this layer cannot express yet, which report
    consumers treat as a hard failure (the whole point is zero drops)."""
    instrs = parse_instructions(hlo_text)
    dims: dict[str, list[int]] = {}
    for i in instrs:
        arrs = _shape_dims(i.type_str)
        dims[i.name] = arrs[0][1] if arrs else []
    sizes = {i.name: _shape_bytes(i.type_str) for i in instrs}
    by_comp: dict[str, list[Instr]] = {}
    for i in instrs:
        by_comp.setdefault(i.computation, []).append(i)
    world = default_world if default_world is not None \
        else module_world(hlo_text)

    mapped: list[SiteCell] = []
    unmapped: list[CollectiveSite] = []
    for site in collective_sites(hlo_text):
        try:
            mapped.append(_map_one(site, by_comp.get(site.computation, []),
                                   dims, sizes, world))
        except KeyError:
            unmapped.append(site)
    return mapped, unmapped


# ---------------------------------------------------------------------------
# report-only mode: the tuning-potential table
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SiteRow:
    """One priced site of the tuning-potential report."""
    sc: SiteCell
    t_default: float            # modeled seconds, one execution
    best_impl: str
    t_best: float
    tuned_impl: str | None      # profile-selected impl (None: no profiles)
    t_tuned: float

    @property
    def speedup(self) -> float:
        return self.t_default / self.t_best if self.t_best > 0 else 1.0


@dataclasses.dataclass
class PotentialReport:
    """The per-model 'collectives vs. best mock-ups' report."""
    label: str
    world: int
    topo: str
    rows: list[SiteRow]
    unmapped: list[CollectiveSite]

    @property
    def ok(self) -> bool:
        """True when every collective instruction mapped to a cell."""
        return not self.unmapped

    def total_default(self) -> float:
        return sum(r.t_default * r.sc.site.mult for r in self.rows)

    def total_best(self) -> float:
        return sum(r.t_best * r.sc.site.mult for r in self.rows)

    def total_tuned(self) -> float:
        return sum(r.t_tuned * r.sc.site.mult for r in self.rows)

    def potential(self) -> float:
        tb = self.total_best()
        return self.total_default() / tb if tb > 0 else 1.0

    def table(self) -> str:
        hdr = (f"{'site':34} {'op':22} {'p':>4} {'bytes':>12} {'x':>5} "
               f"{'default_us':>11} {'best impl':26} {'best_us':>9} "
               f"{'speedup':>8}")
        lines = [f"# {self.label}: world={self.world} topo={self.topo}",
                 hdr, "-" * len(hdr)]
        for r in sorted(self.rows,
                        key=lambda r: -r.t_default * r.sc.site.mult):
            s = r.sc.site
            name = s.name if len(s.name) <= 34 else s.name[:31] + "..."
            star = "*" if r.sc.fused else (
                "+" if r.sc.adjacent_dot else " ")
            lines.append(
                f"{name:34} {r.sc.cell.op + star:22} {r.sc.cell.p:>4} "
                f"{r.sc.cell.nbytes:>12} {s.mult:>5} "
                f"{r.t_default * 1e6:>11.2f} {r.best_impl:26} "
                f"{r.t_best * 1e6:>9.2f} {r.speedup:>7.2f}x")
        lines.append("-" * len(hdr))
        lines.append(
            f"collectives vs. best mock-ups: {self.potential():.2f}x on "
            f"the table ({self.total_default() * 1e6:.1f}us default vs "
            f"{self.total_best() * 1e6:.1f}us best, {len(self.rows)} "
            f"sites)")
        if any(r.tuned_impl is not None for r in self.rows):
            lines.append(
                f"profile-tuned total: {self.total_tuned() * 1e6:.1f}us "
                f"({self.total_default() / max(self.total_tuned(), 1e-30):.2f}x"
                " vs default)")
        if self.unmapped:
            lines.append(f"UNMAPPED ({len(self.unmapped)}):")
            lines += [f"  {s.hlo_op} {s.name} ({s.operand_bytes} B)"
                      for s in self.unmapped]
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {
            "label": self.label, "world": self.world, "topo": self.topo,
            "ok": self.ok,
            "potential": self.potential(),
            "total_default_s": self.total_default(),
            "total_best_s": self.total_best(),
            "total_tuned_s": self.total_tuned(),
            "n_sites": len(self.rows),
            "n_unmapped": len(self.unmapped),
            "unmapped": [s.hlo_op for s in self.unmapped],
            "rows": [{
                "site": r.sc.site.name,
                "computation": r.sc.site.computation,
                "hlo_op": r.sc.site.hlo_op,
                "op": r.sc.cell.op, "p": r.sc.cell.p,
                "nbytes": r.sc.cell.nbytes, "dtype": r.sc.cell.dtype,
                "mult": r.sc.site.mult,
                "fused": r.sc.fused, "adjacent_dot": r.sc.adjacent_dot,
                "mm": [r.sc.cell.mm_k, r.sc.cell.mm_m, r.sc.cell.mm_n],
                "t_default_s": r.t_default,
                "best_impl": r.best_impl, "t_best_s": r.t_best,
                "tuned_impl": r.tuned_impl, "t_tuned_s": r.t_tuned,
                "speedup": r.speedup,
            } for r in self.rows],
        }


def scan_potential(hlo_text: str, *, topo: Topo = V5E_ICI,
                   profiles: ProfileStore | None = None,
                   default_world: int | None = None,
                   chunk_bytes: int = 0, label: str = "") -> PotentialReport:
    """Price every collective site of a compiled module against its best
    mock-up (and, when ``profiles`` is given, against the profile-selected
    impl — what :func:`rewrite` would substitute)."""
    mapped, unmapped = map_sites(hlo_text, default_world=default_world)
    rows = []
    for sc in mapped:
        sw = costmodel.sweep_cell(sc.cell, topo, chunk_bytes=chunk_bytes)
        t_default = sw.get("default", 0.0)
        best = min(sw, key=sw.get)
        tuned_impl = None
        t_tuned = t_default
        if profiles is not None:
            tuned_impl = profiles.lookup_cell(sc.cell) or "default"
            t_tuned = sw.get(tuned_impl, t_default)
        rows.append(SiteRow(sc, t_default, best, sw[best], tuned_impl,
                            t_tuned))
    return PotentialReport(label=label,
                           world=default_world or module_world(hlo_text),
                           topo=topo.name, rows=rows, unmapped=unmapped)


def tuning_potential(fn, *args, topo: Topo = V5E_ICI,
                     profiles: ProfileStore | None = None,
                     chunk_bytes: int = 0, label: str = "") \
        -> PotentialReport:
    """Report-only interposition: compile ``fn(*args)`` (args may be
    ``ShapeDtypeStruct``s), scan the compiled HLO, price every collective.

    ``fn`` may be a plain callable (it is jitted here) or anything with a
    ``.lower`` method (``jax.jit`` wrappers, shard_map'd programs).
    """
    import jax
    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    hlo = jfn.lower(*args).compile().as_text()
    return scan_potential(hlo, topo=topo, profiles=profiles,
                          chunk_bytes=chunk_bytes,
                          label=label or getattr(fn, "__name__", "fn"))


# ---------------------------------------------------------------------------
# rewrite mode: re-trace with tuned mock-ups + bit-exactness check
# ---------------------------------------------------------------------------

#: dispatcher op -> the HLO collective class its DEFAULT lowering anchors on
#: (fused ops in default mode lower to their primary collective + dot)
OP_TO_HLO_CLASS = {v: k for k, v in HLO_TO_OP.items()} | {
    "allgather_matmul": "all-gather",
    "matmul_accumulate": "all-gather",
    "matmul_reducescatter": "reduce-scatter",
    "matmul_reducescatter_2d": "all-gather",
}


@dataclasses.dataclass
class RewriteResult:
    """Outcome of one transparent rewrite (see :func:`rewrite`)."""
    baseline_out: object
    tuned_out: object
    matched: list               # (DispatchRecord, CollectiveSite) pairs
    unmatched_records: list     # dispatches with no baseline HLO site
    extra_sites: list           # HLO collectives with no dispatch record
    changed: list               # tuned-trace records with impl != default
    bitexact: bool
    diffs: list                 # human-readable per-leaf mismatch lines

    @property
    def n_rewritten(self) -> int:
        return len(self.changed)


def _match_records_to_sites(records, sites):
    """Greedy (class, p, nbytes) matching of dispatch records onto HLO
    collective sites — the evidence that the dispatcher's sites ARE the
    compiled module's collectives."""
    free = list(sites)
    matched, unmatched = [], []
    for r in records:
        if r.p <= 1:
            continue            # axis size 1: no collective is emitted
        klass = OP_TO_HLO_CLASS.get(r.op)
        hit = next(
            (s for s in free if s.base_op == klass
             and s.group_size in (0, r.p)
             and s.operand_bytes == r.nbytes), None)
        if hit is not None:
            free.remove(hit)
            matched.append((r, hit))
        else:
            unmatched.append(r)
    return matched, unmatched, free


def rewrite(fn, *args, profiles: ProfileStore | None = None,
            force: dict | None = None, phase_profiles: dict | None = None,
            chunk_bytes: int = 0) -> RewriteResult:
    """Re-trace ``fn`` with tuned mock-ups substituted and compare.

    Baseline: trace/compile/run under a default (recording) dispatch
    context and scan the compiled HLO; every dispatch record is matched to
    an HLO collective site.  Tuned: re-trace under
    ``api.tuned(profiles=..., force=...)`` — the dispatcher swaps matched
    ``repro.dist``-shaped sites to their tuned mock-ups at trace time —
    then run the rewritten program on the same inputs and compare leaves
    bit-for-bit.  Args must be concrete arrays (both programs execute).
    """
    import jax
    import numpy as np
    from repro.core import api

    # Each trace must actually re-run the dispatcher: jax caches traces by
    # function identity, so without this the tuned pass silently reuses
    # the baseline jaxpr and no substitution happens.
    jax.clear_caches()
    rec0: list = []
    with api.tuned(record=rec0):
        c0 = jax.jit(fn).lower(*args).compile()
    hlo0 = c0.as_text()
    out0 = c0(*args)

    jax.clear_caches()
    rec1: list = []
    with api.tuned(profiles=profiles, force=force,
                   phase_profiles=phase_profiles, chunk_bytes=chunk_bytes,
                   record=rec1):
        c1 = jax.jit(fn).lower(*args).compile()
    out1 = c1(*args)

    mapped, _un = map_sites(hlo0)
    matched, unmatched, extra = _match_records_to_sites(
        rec0, [sc.site for sc in mapped])
    changed = [r for r in rec1 if r.impl != "default"]

    l0, t0 = jax.tree_util.tree_flatten(out0)
    l1, t1 = jax.tree_util.tree_flatten(out1)
    diffs: list[str] = []
    if t0 != t1:
        diffs.append(f"output trees differ: {t0} vs {t1}")
    else:
        for i, (a, b) in enumerate(zip(l0, l1)):
            a = np.asarray(a)
            b = np.asarray(b)
            if a.shape != b.shape or a.dtype != b.dtype:
                diffs.append(f"leaf {i}: {a.dtype}{a.shape} vs "
                             f"{b.dtype}{b.shape}")
            elif a.tobytes() != b.tobytes():
                fa = a.astype(np.float64) if a.dtype.kind in "fc" else a
                fb = b.astype(np.float64) if b.dtype.kind in "fc" else b
                diffs.append(f"leaf {i}: max |delta| = "
                             f"{np.max(np.abs(fa - fb))}")
    return RewriteResult(out0, out1, matched, unmatched, extra, changed,
                         bitexact=not diffs, diffs=diffs)


def assert_bitexact(res: RewriteResult) -> None:
    if not res.bitexact:
        raise AssertionError(
            "rewritten program is not bit-exact vs baseline:\n  "
            + "\n  ".join(res.diffs))


# ---------------------------------------------------------------------------
# zoo integration: compile one model-zoo program on a host mesh
# ---------------------------------------------------------------------------


def compile_zoo_hlo(arch: str, *, kind: str = "train",
                    mesh_shape: tuple[int, int] = (2, 4),
                    smoke: bool = True, seq_len: int = 32,
                    global_batch: int = 8, n_micro: int = 1) \
        -> tuple[str, dict]:
    """Compiled-HLO text of one ``configs/`` zoo program on a host mesh.

    The host-device analogue of ``launch/dryrun.run_cell``: builds the
    smoke-sized model, shard_maps the train / prefill / decode step over a
    (data, model) mesh of host devices, and returns
    ``(hlo_text, info_dict)``.  The caller must have forced enough host
    devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``)
    BEFORE jax initializes.
    """
    import dataclasses as _dc

    import jax
    from jax.sharding import PartitionSpec as P

    from repro._compat import shard_map
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.shapes import ShapeCell, dp_axes, input_specs
    from repro.models import lm

    n_dev = mesh_shape[0] * mesh_shape[1]
    if len(jax.devices()) < n_dev:
        raise RuntimeError(
            f"compile_zoo_hlo needs {n_dev} devices, found "
            f"{len(jax.devices())}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_dev} before jax "
            "initializes")
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh(mesh_shape, ("data", "model"))
    cell = ShapeCell(f"{kind}_hlo", seq_len, global_batch, kind,
                     n_micro=n_micro)

    with mesh:
        args_sds, in_ps = input_specs(cfg, cell, mesh)
        if kind == "train":
            from repro.train.trainer import make_step_fns
            _, train_fn = make_step_fns(cfg, n_micro=cell.n_micro)
            out_ps = (in_ps[0], in_ps[1],
                      {"loss": P(), "grad_norm": P(), "lr": P()})
            fn = shard_map(train_fn, mesh=mesh, in_specs=in_ps,
                           out_specs=out_ps, check_vma=False)
        elif kind == "prefill":
            def pf(params, batch, caches):
                return lm.prefill(params, cfg, batch, caches)
            out_ps = (P(dp_axes(mesh)), in_ps[2])
            fn = shard_map(pf, mesh=mesh, in_specs=in_ps, out_specs=out_ps,
                           check_vma=False)
        elif kind == "decode":
            def dc(params, token, caches, t):
                return lm.decode_step(params, cfg, token, caches, t)
            out_ps = (in_ps[1], in_ps[2])
            fn = shard_map(dc, mesh=mesh, in_specs=in_ps, out_specs=out_ps,
                           check_vma=False)
        else:
            raise ValueError(f"unknown kind {kind!r}")
        hlo = jax.jit(fn).lower(*args_sds).compile().as_text()
    info = {"arch": arch, "kind": kind, "mesh": "x".join(map(str,
                                                             mesh_shape)),
            "smoke": smoke, "seq_len": seq_len,
            "global_batch": global_batch,
            "config": _dc.asdict(cfg) if hasattr(cfg, "__dataclass_fields__")
            else str(cfg)}
    return hlo, info
