"""Roofline terms from a compiled dry-run artifact (TPU v5e constants).

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = collective_bytes_per_device / link_bw      (~50 GB/s/link)

``cost_analysis()`` describes the per-device SPMD module, i.e. the spec's
"HLO_FLOPs / chips".  MODEL_FLOPS uses 6·N·D (dense) or 6·N_active·D (MoE)
for training, 2·N·D for single forward programs.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops_per_device: float
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0

    def finish(self) -> "Roofline":
        self.t_compute = self.flops_per_device / PEAK_FLOPS
        self.t_memory = self.bytes_per_device / HBM_BW
        self.t_collective = self.collective_bytes_per_device / LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops_per_device /
                             self.flops_per_device
                             if self.flops_per_device else 0.0)
        return self

    @property
    def step_time_bound(self) -> float:
        """Lower bound on step time (no overlap assumption: max of terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline the USEFUL model flops achieve
        if the dominant term is fully utilized (the §Perf score)."""
        if self.step_time_bound == 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / \
            self.step_time_bound

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "flops/dev": f"{self.flops_per_device:.3e}",
            "bytes/dev": f"{self.bytes_per_device:.3e}",
            "coll_bytes/dev": f"{self.collective_bytes_per_device:.3e}",
            "t_compute": f"{self.t_compute*1e3:.2f}ms",
            "t_memory": f"{self.t_memory*1e3:.2f}ms",
            "t_collective": f"{self.t_collective*1e3:.2f}ms",
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": f"{self.useful_ratio:.3f}",
            "roofline_fraction": f"{self.roofline_fraction:.3f}",
        }


def model_flops(cfg, cell, n_devices: int) -> float:
    """6·N_active·D training / 2·N_active·D forward, per device."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        total = 6.0 * n_active * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * cell.global_batch
    return total / n_devices


def roofline_terms(arch: str, shape: str, mesh_name: str, *, cost: dict,
                   coll: dict, cfg, cell, n_devices: int,
                   flops_override: float | None = None,
                   bytes_override: float | None = None) -> Roofline:
    flops = float(flops_override if flops_override
                  else cost.get("flops", 0.0))
    byts = float(bytes_override if bytes_override
                 else cost.get("bytes accessed", 0.0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=float(coll.get("total_bytes", 0)),
        model_flops_per_device=model_flops(cfg, cell, n_devices),
    ).finish()
