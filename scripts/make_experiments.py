"""Assemble EXPERIMENTS.md from results/ artifacts (re-runnable)."""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

DRY = ROOT / "results" / "dryrun"
PERF = ROOT / "results" / "perf"

ARCHS = ["llama3.2-3b", "gemma3-1b", "gemma2-9b", "llama3-8b",
         "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b", "whisper-medium",
         "paligemma-3b", "rwkv6-3b", "zamba2-1.2b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(directory):
    out = {}
    for f in sorted(directory.glob("*.json")):
        d = json.loads(f.read_text())
        out[(d["arch"], d["shape"], d["mesh"], d.get("variant",
                                                     "baseline"))] = d
    return out


def ms(s):
    return float(s[:-2])


def roofline_row(d):
    r = d["roofline"]
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{r['t_compute']} | {r['t_memory']} | {r['t_collective']} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']} | "
            f"{r['roofline_fraction']} |")


def mem_gib(d):
    m = d["memory"]
    return (m["argument_bytes"] + m["temp_bytes"]) / 2**30


def coll_break(d):
    c = d["collectives"]
    parts = [f"{k}={v['bytes']/2**30:.2f}GiB/{v['count']}"
             for k, v in sorted(c.items()) if k != "total_bytes"]
    return " ".join(parts)


def main():
    dry = load(DRY)
    perf = load(PERF) if PERF.exists() else {}

    L = []
    A = L.append
    A("# EXPERIMENTS — PGTune-JAX")
    A("")
    A("Paper: *Tuning MPI Collectives by Verifying Performance Guidelines*"
      " (Hunold & Carpen-Amarie, 2017).  Paper text verified against the"
      " stated title (DESIGN.md header).")
    A("")
    A("Hardware target: TPU v5e — 197 TF/s bf16/chip, 819 GB/s HBM,"
      " ~50 GB/s/link ICI.  Container is CPU-only: production numbers are"
      " AOT artifacts (lower+compile on 512 host devices) + the fabric cost"
      " model; host-measured numbers validate orderings only.")
    A("")

    # ---------------- dry-run --------------------------------------------
    A("## §Dry-run — 40 cells × {16×16, 2×16×16}")
    A("")
    ok = sum(1 for d in dry.values() if d["status"] == "ok")
    sk = sum(1 for d in dry.values() if d["status"] == "skip")
    A(f"**{ok} cells compile, {sk} documented skips, 0 failures** "
      f"(skips = `long_500k` on the {sk//2} pure full-attention archs × 2 "
      "meshes; DESIGN.md §Arch-applicability).")
    A("")
    A("Per-cell `memory_analysis()` (argument+temp per device, CPU-backend"
      " caveat: bf16 buffers may be accounted f32, ~2× pessimistic) and the"
      " HLO collective schedule:")
    A("")
    A("| arch | shape | mesh | mem GiB/dev | collective schedule |")
    A("|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            for m in ("16x16", "2x16x16"):
                d = dry.get((a, s, m, "baseline"))
                if not d or d["status"] != "ok":
                    continue
                A(f"| {a} | {s} | {m} | {mem_gib(d):.1f} | {coll_break(d)} |")
    A("")
    A("Multi-pod pass: every non-skipped cell also lowers+compiles on the"
      " 2×16×16 mesh (the `pod` axis shards the batch; gradients sync"
      " hierarchically: in-pod reduce-scatter via the FSDP backward, then a"
      " tunable `pod` all-reduce of 1/16-sized shards).")
    A("")

    # ---------------- roofline -------------------------------------------
    A("## §Roofline — single-pod (16×16) baselines, paper-faithful"
      " (attn_impl=ref)")
    A("")
    A("Terms per the spec: compute = dot_FLOPs/dev ÷ 197 TF/s; memory ="
      " HLO bytes/dev ÷ 819 GB/s; collective = collective operand bytes/dev"
      " ÷ 50 GB/s.  FLOPs/bytes are parsed from the compiled HLO with"
      " **loop-trip-count weighting** (XLA's `cost_analysis()` counts scan"
      " bodies once — underreporting deep stacks by n_layers×n_micro; see"
      " `analysis/hlo.py`).  Bytes are counted at kernel boundaries"
      " (fusion-aware).  `useful` = MODEL_FLOPS(6·N_active·D or 2·N·D) ÷"
      " HLO dot-FLOPs; `frac` = useful-FLOPs roofline fraction at the"
      " dominant term.")
    A("")
    A("| arch | shape | mesh | t_compute | t_memory | t_collective |"
      " bottleneck | useful | frac |")
    A("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            d = dry.get((a, s, "16x16", "baseline"))
            if not d:
                continue
            if d["status"] == "skip":
                A(f"| {a} | {s} | 16x16 | skip | — | — | — | — | — |")
                continue
            A(roofline_row(d))
    A("")
    A("**Reading the baseline.** Every cell is memory-bound: the"
      " paper-faithful reference lowering materializes dense [Sq,Skv]"
      " attention scores, repeated-KV tensors, and (rwkv6) a per-timestep"
      " [hd,hd] state write — exactly the waste the §Perf iterations and"
      " the Pallas kernels remove.  Per-cell one-line diagnosis:")
    A("")
    A("* train/prefill dense — S² score materialization dominates bytes;")
    A("* decode — repeated-KV materialization + full-cache copies;")
    A("* deepseek decode — naive MLA re-up-projects the whole latent cache"
      " per token (the absorbed-matmul variant is the known fix);")
    A("* rwkv6 train/prefill — lax.scan writes [B,H,64,64] f32 state per"
      " token (582 s modeled!); the chunked Pallas kernel keeps state in"
      " VMEM (§Perf pair D);")
    A("* phi3.5/deepseek MoE — capacity-padded dispatch buffers.")
    A("")

    # ---------------- perf ------------------------------------------------
    A("## §Perf — hillclimbing log (hypothesis → change → before → after)")
    A("")
    if perf:
        A("| pair | variant | t_compute | t_memory | t_collective |"
          " bottleneck | frac | mem GiB/dev |")
        A("|---|---|---|---|---|---|---|---|")
        order = [
            ("llama3-8b", "train_4k"), ("deepseek-v3-671b", "prefill_32k"),
            ("gemma3-1b", "decode_32k"), ("rwkv6-3b", "prefill_32k")]
        for a, s in order:
            base = dry.get((a, s, "16x16", "baseline"))
            if base:
                r = base["roofline"]
                A(f"| {a}×{s} | baseline(ref) | {r['t_compute']} |"
                  f" {r['t_memory']} | {r['t_collective']} |"
                  f" {r['bottleneck']} | {r['roofline_fraction']} |"
                  f" {mem_gib(base):.1f} |")
            for key, d in sorted(perf.items()):
                if key[0] == a and key[1] == s and d["status"] == "ok":
                    r = d["roofline"]
                    A(f"| {a}×{s} | {d['variant']} | {r['t_compute']} |"
                      f" {r['t_memory']} | {r['t_collective']} |"
                      f" {r['bottleneck']} | {r['roofline_fraction']} |"
                      f" {mem_gib(d):.1f} |")
    A("")
    A("(Narrative per iteration below is maintained by hand — see the"
      " PERF ITERATION LOG section.)")
    A("")
    print("\n".join(L))


if __name__ == "__main__":
    main()
