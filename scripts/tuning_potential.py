"""Report-only XLA-layer interposition over the ``configs/`` zoo.

Compiles each requested zoo model on a forced host-device mesh, scans the
compiled HLO for EVERY collective instruction (sync, ``-start/-done``
async pairs, ops inside scan/while bodies), maps each site to a tuning
cell, and prices default vs. best mock-up — the paper's "tuning potential"
table lifted to compiled programs.  Exits nonzero on parser errors or any
collective the interposer could not map (CI gates on this).

  python scripts/tuning_potential.py --arch gemma3-1b --arch llama3.2-3b \
      --kind train --mesh 2x4 --out results/hlo_potential
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", action="append", default=[],
                    help="zoo config name (repeatable; default: "
                         "gemma3-1b + llama3.2-3b)")
    ap.add_argument("--kind", default="train",
                    choices=("train", "prefill", "decode"))
    ap.add_argument("--mesh", default="2x4",
                    help="host mesh DATAxMODEL, e.g. 2x4")
    ap.add_argument("--out", default=str(ROOT / "results" /
                                         "hlo_potential"))
    ap.add_argument("--profile-dir", default=None,
                    help="ProfileStore directory: adds a profile-tuned "
                         "column to the report")
    ap.add_argument("--dump-hlo", action="store_true",
                    help="also write the compiled HLO text per model")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)
    archs = args.arch or ["gemma3-1b", "llama3.2-3b"]
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    n_dev = 1
    for x in mesh_shape:
        n_dev *= x
    # must land before jax initializes its backends
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    from repro.analysis.interpose import (HloParseError, compile_zoo_hlo,
                                          scan_potential)
    from repro.core.profiles import resolve_stores

    profiles, _phases = resolve_stores(args.profile_dir)
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    failed = False
    for arch in archs:
        label = f"{arch}/{args.kind}@{args.mesh}"
        try:
            hlo, info = compile_zoo_hlo(arch, kind=args.kind,
                                        mesh_shape=mesh_shape)
            rep = scan_potential(hlo, profiles=profiles, label=label)
        except HloParseError as e:
            print(f"PARSE ERROR [{label}]: {e}", file=sys.stderr)
            failed = True
            continue
        print(rep.table())
        print()
        stem = f"{arch.replace('.', '_')}_{args.kind}"
        (out_dir / f"{stem}.json").write_text(
            json.dumps(rep.to_json(), indent=1) + "\n")
        (out_dir / f"{stem}.txt").write_text(rep.table() + "\n")
        if args.dump_hlo:
            (out_dir / f"{stem}.hlo.txt").write_text(hlo)
        if not rep.ok:
            print(f"UNMAPPED COLLECTIVES [{label}]: "
                  f"{[s.hlo_op for s in rep.unmapped]}", file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
