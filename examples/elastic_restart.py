"""Fault-tolerance demo: inject node failures mid-training; the restart
driver resumes from the newest checkpoint and converges to the SAME final
state as a failure-free run (deterministic, step-keyed data).

  PYTHONPATH=src python examples/elastic_restart.py
"""
import pathlib
import shutil
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.data import make_batch
from repro.ft import run_with_restarts
from repro.train import Trainer


def main():
    cfg = get_config("llama3.2-3b").smoke()
    tr = Trainer(cfg, mesh=None, base_lr=1e-3, warmup=5)
    ckdir = pathlib.Path("results/ckpt_elastic")
    shutil.rmtree(ckdir, ignore_errors=True)

    def init_state():
        p, o = tr.init(0)
        return {"params": p, "opt": o}

    faults = {9: 1, 17: 1}   # two injected node failures

    def step_fn(state, i):
        if i in faults and faults.pop(i):
            raise RuntimeError(f"injected failure at step {i}")
        batch = tr.put_batch(make_batch(cfg, 4, 32, i))
        p, o, m = tr.step(state["params"], state["opt"], batch, i)
        print(f"  step {i:3d} loss {float(m['loss']):.4f}")
        return {"params": p, "opt": o}

    final, stats = run_with_restarts(init_state, step_fn, n_steps=24,
                                     ckpt_dir=ckdir, ckpt_every=6)
    print(f"\nrestarts: {stats['restarts']}, resumed from: "
          f"{stats['resumed_from']}")

    # failure-free reference
    shutil.rmtree(ckdir, ignore_errors=True)
    ref, _ = run_with_restarts(init_state, lambda s, i: step_fn(s, i),
                               n_steps=24, ckpt_dir=ckdir, ckpt_every=6)
    same = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(final["params"]),
                        jax.tree.leaves(ref["params"])))
    print("bit-identical to failure-free run:", same)


if __name__ == "__main__":
    main()
