"""Serving example: prefill a batch of prompts, then batched greedy decode,
with tuned collectives active.

  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b --tokens 24
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import api, costmodel, tuner
from repro.models import lm
from repro.models.params import init_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    s_max = args.prompt_len + args.tokens + 8
    profiles = tuner.tune(
        axis_size=16,
        backend=tuner.CostModelBackend(costmodel.V5E_ICI)).profiles

    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    decode = jax.jit(lambda p, t, c, i: lm.decode_step(p, cfg, t, c, i))

    with api.tuned(profiles=profiles):
        caches = lm.init_caches(cfg, args.batch, s_max)
        t0 = time.time()
        logits, caches = lm.prefill(params, cfg, {"tokens": prompts}, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        tok = tok % cfg.vocab_size
        out = [tok]
        for step in range(args.tokens - 1):
            lg, caches = decode(params, tok, caches,
                                jnp.int32(args.prompt_len + step))
            tok = (jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
                   % cfg.vocab_size)
            out.append(tok)
        dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"generated={gen.shape[1]} tokens in {dt:.2f}s "
          f"({args.batch*gen.shape[1]/dt:.1f} tok/s on 1 CPU core)")
    print("sample ids:", np.asarray(gen[0][:12]))


if __name__ == "__main__":
    main()
