"""Serving example: profile-driven decode, end to end.

Prefill a batch of prompts and greedy-decode with tensor parallelism
emulated over ``vmap(axis_name="model")`` (the CPU stand-in for a TP mesh;
the dispatcher path is identical to shard_map), then close the paper's
offline→online loop against the *recorded* traffic:

1. default serve — every collective is recorded with its phase tag
   (``prefill`` / ``decode`` / ``bwd``-free here);
2. ``tuner.tune_trace`` tunes exactly the recorded (op, p, nbytes, phase)
   mix on the cost-model backend and writes per-phase profiles;
3. re-serve with ``api.tuned(phase_profiles=...)`` — decode steps now
   dispatch to the tuned mock-ups (see the Listing-2 footer).

  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b --tokens 24
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs import get_config
from repro.core import api, costmodel, tuner
from repro.core.trace import Trace
from repro.models import lm
from repro.models.params import init_tree


def serve(cfg, tp, params, prompts, s_max, n_tokens, *, phase_profiles=None):
    batch = prompts.shape[0]
    j_init = jax.jit(jax.vmap(lambda _: lm.init_caches(cfg, batch, s_max),
                              axis_name="model", axis_size=tp,
                              in_axes=None, out_axes=0))
    j_pf = jax.jit(jax.vmap(
        lambda p, c: lm.prefill(p, cfg, {"tokens": prompts}, c),
        axis_name="model"))
    j_dc = jax.jit(jax.vmap(
        lambda p, t, c, i: lm.decode_step(p, cfg, t, c, i),
        axis_name="model", in_axes=(0, None, 0, None)))

    with api.tuned(phase_profiles=phase_profiles) as ctx:
        caches = j_init(0)
        t0 = time.time()
        with api.phase("prefill"):
            logits, caches = j_pf(params, caches)
        tok = (jnp.argmax(logits[0][:, -1], axis=-1).astype(jnp.int32)
               [:, None] % cfg.vocab_size)
        out = [tok]
        with api.phase("decode"):
            for step in range(n_tokens - 1):
                lg, caches = j_dc(params, tok, caches,
                                  jnp.int32(prompts.shape[1] + step))
                tok = (jnp.argmax(lg[0][:, -1], axis=-1).astype(jnp.int32)
                       [:, None] % cfg.vocab_size)
                out.append(tok)
        gen = jnp.concatenate(out, axis=1)
        gen.block_until_ready()
        dt = time.time() - t0
    return gen, dt, ctx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tp", type=int, default=2,
                    help="emulated model-parallel degree")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--topo", default="bgq-like",
                    choices=sorted(costmodel.PRESETS))
    ap.add_argument("--out", default="results/serve_decode")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    s_max = args.prompt_len + args.tokens + 8
    specs = lm.model_specs(cfg, tp=args.tp)
    params = jax.jit(jax.vmap(
        lambda key: init_tree(specs, key, fold=lax.axis_index("model")),
        axis_name="model", axis_size=args.tp, in_axes=None,
        out_axes=0))(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    # 1. default serve, recording the phase-tagged workload trace
    gen, dt, ctx = serve(cfg, args.tp, params, prompts, s_max, args.tokens)
    trace = Trace.from_context(ctx)
    out = pathlib.Path(args.out)
    trace.save(out / "trace.jsonl")
    print(trace.summary())

    # 2. tune the recorded op mix, per phase
    rep = tuner.tune_trace(
        trace, backend=tuner.CostModelBackend(costmodel.PRESETS[args.topo]))
    rep.save(out / "profiles")
    print(rep.summary())

    # 3. re-serve with the tuned per-phase stores
    gen_t, dt_t, ctx_t = serve(cfg, args.tp, params, prompts, s_max,
                               args.tokens, phase_profiles=rep.phase_profiles)
    assert bool(jnp.array_equal(gen, gen_t)), "tuning changed the tokens!"

    print(f"arch={cfg.name} batch={args.batch} tp={args.tp} "
          f"prompt={args.prompt_len} generated={gen.shape[1]} tokens; "
          f"default {dt:.2f}s, tuned {dt_t:.2f}s (CPU emulation)")
    print("sample ids:", np.asarray(gen[0][:12]))
    print("tuned-run dispatch footer:")
    print(api.format_footer(ctx_t))


if __name__ == "__main__":
    main()
