"""Quickstart: the full PGTune-JAX workflow in one minute on CPU.

1. offline-tune the collective layer (cost model, v5e ICI, p=16),
2. write/reload Listing-1 performance profiles,
3. train a tiny LM with the tuned dispatcher active,
4. print the paper's Listing-2 footer showing which mock-ups served which
   payload sizes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp

from repro.configs import get_config
from repro.core import api, costmodel, tuner
from repro.core.profiles import ProfileStore
from repro.data import make_batch
from repro.train import Trainer


def main():
    # --- 1. offline tuning pass (PGMPITuneCLI) -----------------------------
    report = tuner.tune(axis_size=16,
                        backend=tuner.CostModelBackend(costmodel.V5E_ICI))
    print("== tuning report ==")
    print(report.summary())
    for v in report.violations[:5]:
        print(f"  {v.gl_kind:8s} {v.op:14s} {v.nbytes:>8d}B "
              f"x{v.speedup:.2f} -> {v.best_impl}")

    # --- 2. profiles to disk and back (PGMPITuneD) --------------------------
    pdir = pathlib.Path("results/profiles_quickstart")
    report.profiles.save(pdir, fmt="text")
    profiles = ProfileStore.load(pdir)
    print(f"\nprofiles reloaded: {len(profiles)} "
          f"(e.g.)\n{next(iter(profiles)).to_text()}")

    # --- 3. train a tiny LM with tuned collectives --------------------------
    cfg = get_config("llama3.2-3b").smoke()
    tr = Trainer(cfg, mesh=None, profiles=profiles, base_lr=3e-3, warmup=5)
    params, opt = tr.init(0)
    with api.tuned(profiles=profiles) as ctx:
        for i in range(20):
            batch = tr.put_batch(make_batch(cfg, 8, 32, i))
            params, opt, m = tr.step(params, opt, batch, i)
            if i % 5 == 0:
                print(f"step {i:3d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.1e}")

    # --- 4. the Listing-2 footer --------------------------------------------
    print("\n== pgmpi footer (which algorithm served each call) ==")
    print(api.format_footer(ctx) or "#(single-device trace: defaults only)")


if __name__ == "__main__":
    main()
