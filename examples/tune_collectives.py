"""Offline collective tuning CLI — the PGMPITuneCLI workflow.

Benchmarks every mock-up against the default (cost model at production
scale, or measured wall-clock on host devices), detects guideline
violations, and writes Listing-1 performance profiles.

  PYTHONPATH=src python examples/tune_collectives.py \
      --backend costmodel --topo v5e-ici --axis-size 16 --out results/profiles
  PYTHONPATH=src python examples/tune_collectives.py --backend measured
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import costmodel, tuner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("costmodel", "measured"),
                    default="costmodel")
    ap.add_argument("--topo", default="v5e-ici",
                    choices=sorted(costmodel.PRESETS))
    ap.add_argument("--axis-size", type=int, default=16)
    ap.add_argument("--min-win", type=float, default=0.10,
                    help="paper's 10%% replacement threshold")
    ap.add_argument("--scratch-budget", type=int, default=None,
                    help="size_msg_buffer_bytes analogue")
    ap.add_argument("--out", default="results/profiles")
    args = ap.parse_args()

    if args.backend == "costmodel":
        backend = tuner.CostModelBackend(costmodel.PRESETS[args.topo])
        axis = args.axis_size
    else:
        from repro.core import measure
        backend = tuner.MeasuredBackend()
        axis = measure.axis_size()

    rep = tuner.tune(axis_size=axis, backend=backend, min_win=args.min_win,
                     scratch_budget_bytes=args.scratch_budget)
    print(rep.summary())
    print("\nviolations:")
    for v in rep.violations:
        print(f"  {v.gl_kind:16s} {v.op:14s} p={v.axis_size} "
              f"{v.nbytes:>9d}B x{v.speedup:5.2f} {v.best_impl or ''}")
    rep.profiles.save(args.out, fmt="text")
    print(f"\nwrote {len(rep.profiles)} profiles to {args.out}/")


if __name__ == "__main__":
    main()
