"""End-to-end training driver: synthetic data -> tuned collectives ->
fault-tolerant loop (watchdog + async checkpoints + restart).

Default runs a ~small llama-family model for a few hundred steps on CPU;
--full-size selects the real config (for TPU pods).  All collectives go
through the tuned dispatcher; --force overrides per-op algorithms using the
paper's --module syntax.

  PYTHONPATH=src python examples/train_tuned_lm.py --steps 60
  PYTHONPATH=src python examples/train_tuned_lm.py \
      --force "allreduce:alg=allreduce_as_rsb_allgather" --steps 20
"""
import argparse
import dataclasses
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.ckpt import AsyncCheckpointer, checkpoint as ck
from repro.configs import get_config
from repro.core import api, costmodel, tuner
from repro.data import make_batch
from repro.ft import StepWatchdog
from repro.train import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full arch config (TPU pods)")
    ap.add_argument("--force", default="", help="op:alg=name;... override")
    ap.add_argument("--ckpt-dir", default="results/ckpt_example")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.smoke()
        # widen slightly so the run is a real (if small) model
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, d_ff=512)

    profiles = tuner.tune(
        axis_size=16,
        backend=tuner.CostModelBackend(costmodel.V5E_ICI)).profiles
    force = api.parse_module_spec(args.force) if args.force else None

    tr = Trainer(cfg, mesh=None, n_micro=args.n_micro, profiles=profiles,
                 force=force, base_lr=1e-3, warmup=10)
    params, opt = tr.init(0)
    start = 0
    last = ck.latest_step(args.ckpt_dir)
    if last is not None:
        state = ck.restore(args.ckpt_dir, last,
                           {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = last
        print(f"resumed from step {last}")

    acp = AsyncCheckpointer(args.ckpt_dir)
    wd = StepWatchdog(ratio=4.0)
    t0 = time.time()
    for i in range(start, args.steps):
        wd.start_step()
        batch = tr.put_batch(make_batch(cfg, args.batch, args.seq, i))
        params, opt, m = tr.step(params, opt, batch, i)
        if wd.end_step():
            print(f"step {i}: straggler (median {wd.median*1e3:.1f}ms)")
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} "
                  f"({wd.median*1e3:.0f} ms/step)")
        if (i + 1) % args.ckpt_every == 0:
            acp.save(i + 1, {"params": params, "opt": opt})
    acp.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s, "
          f"stragglers={len(wd.straggler_steps)}")


if __name__ == "__main__":
    main()
