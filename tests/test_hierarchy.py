"""Hierarchical per-axis topology: MeshTopo, tier-aware pricing, the
MPIX_* composed mock-ups, and the dispatch/trace/tuner plumbing.

Tentpole coverage for the per-axis topology model:

* the composed hierarchical mock-ups (RS-intra→AR-inter→AG-intra
  allreduce, AG-intra→AG-inter allgather, RS-inter→RS-intra
  reducescatter) match the flat numpy oracle under nested vmap, padding
  included;
* ``MeshTopo`` resolves axis names to per-tier fabrics; ``fit_topo``
  recovers a tier's alpha/beta/gamma from synthetic ring sweeps and
  ``Topo.scaled`` derives an unreachable tier from published RATIOS on
  the fitted absolutes;
* the cost model prices a hierarchical cell's composed schedule below
  the flat joint-ring default on a DCN-crossing mesh, and enforces
  hier↔flat admissibility (each worlds' mock-ups price ``inf`` in the
  other);
* api dispatch with ``inner_axis=`` + an ambient ``MeshTopo`` stamps
  ``p2`` and the tier token, selects the hierarchical mock-up from a
  tier-keyed profile, and refuses cross-world forces;
* tier tokens round-trip trace JSONL and profile text/JSON/disk;
* ``tune_trace`` over a mixed flat/hierarchical trace emits tier-keyed
  profiles that never cross-match.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, collectives as C, costmodel as cm, measure, tuner
from repro.core.cell import OpCell
from repro.core.profiles import Profile, ProfileStore, Range
from repro.core.trace import Trace, TraceEntry

P_OUT, P_IN = 2, 4                 # 2 outer (inter) x 4 inner (intra) ranks
MESH = cm.MeshTopo.of(o=cm.V5E_DCN, i=cm.V5E_ICI)
TIER = "v5e-dcn/v5e-ici"

HIER_IMPL = {"allreduce": "MPIX_rs_ar_ag", "allgather": "MPIX_ag_ag",
             "reducescatter": "MPIX_rs_rs"}


def _run_hier(op, name, x, p=P_OUT, q=P_IN):
    """Run one impl over the nested (outer, inner) vmap mesh on a stacked
    payload ``x`` ([p*q, ...] in outer-major rank order)."""
    fn = C.REGISTRY[op][name].fn
    nested = jnp.asarray(x).reshape((p, q) + x.shape[1:])
    out = jax.vmap(jax.vmap(lambda s: fn(s, "o", inner_axis="i"),
                            axis_name="i"), axis_name="o")(nested)
    return np.asarray(out).reshape((p * q,) + out.shape[2:])


# ---------------------------------------------------------------------------
# semantics: composed mock-ups == flat oracle over the joint group
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["default", "MPIX_rs_ar_ag"])
@pytest.mark.parametrize("n", [8, 5])          # 5: not a multiple of q
def test_hier_allreduce_matches_oracle(name, n):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(P_OUT * P_IN, n, 3)).astype(np.float32)
    got = _run_hier("allreduce", name, x)
    np.testing.assert_allclose(
        got, np.broadcast_to(x.sum(0), x.shape), atol=1e-5)


@pytest.mark.parametrize("name", ["default", "MPIX_ag_ag"])
def test_hier_allgather_matches_oracle(name):
    rng = np.random.default_rng(1)
    x = rng.normal(size=(P_OUT * P_IN, 3, 2)).astype(np.float32)
    got = _run_hier("allgather", name, x)
    full = x.reshape(-1, x.shape[-1] if x.ndim == 2 else x.shape[2])
    full = x.reshape((-1,) + x.shape[2:])
    np.testing.assert_allclose(
        got, np.broadcast_to(full, (P_OUT * P_IN,) + full.shape), atol=1e-5)


@pytest.mark.parametrize("name", ["default", "MPIX_rs_rs"])
def test_hier_reducescatter_matches_oracle(name):
    rng = np.random.default_rng(2)
    w = P_OUT * P_IN
    x = rng.normal(size=(w, w * 3, 2)).astype(np.float32)
    got = _run_hier("reducescatter", name, x)
    want = x.sum(0).reshape(w, 3, 2)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_hier_impl_requires_inner_axis():
    for op, name in HIER_IMPL.items():
        with pytest.raises(ValueError, match="inner_axis"):
            C.REGISTRY[op][name].fn(jnp.ones((4, 2)), "x")


# ---------------------------------------------------------------------------
# MeshTopo resolution + fitting
# ---------------------------------------------------------------------------


def test_mesh_topo_resolution():
    assert MESH.topo("o") is cm.V5E_DCN and MESH.topo("i") is cm.V5E_ICI
    with pytest.raises(KeyError):
        MESH.topo("nope")
    assert MESH.by_tier("v5e-ici") is cm.V5E_ICI
    assert MESH.by_tier("nope") is None
    # flat = fastest axis (the pre-hierarchy assumption), slowest for the
    # joint-ring bound
    assert MESH.flat is cm.V5E_ICI and MESH.slowest is cm.V5E_DCN
    # tier tokens: axis names -> Topo names; unknown axes -> "" (an
    # uninstrumented mesh keeps dispatching flat)
    assert MESH.tier_token("o") == "v5e-dcn"
    assert MESH.tier_token("o", "i") == TIER
    assert MESH.tier_token("z") == "" and MESH.tier_token("o", "z") == ""
    # resolve: "" -> flat/flat; one token -> both slots; out/in -> each
    assert MESH.resolve("") == (cm.V5E_ICI, cm.V5E_ICI)
    assert MESH.resolve("v5e-dcn") == (cm.V5E_DCN, cm.V5E_DCN)
    assert MESH.resolve(TIER) == (cm.V5E_DCN, cm.V5E_ICI)
    assert MESH.resolve("bogus/unknown") == (cm.V5E_ICI, cm.V5E_ICI)


def test_fit_topo_recovers_ring_parameters():
    """Synthetic sweeps generated from a known fabric round-trip through
    the least-squares fit: the per-tier parameters come from measurement,
    not assumed constants."""
    true = cm.Topo("truth", alpha=3.0e-6, link_bw=25e9, gamma=4.0e-12)
    p = 8
    sizes = [1 << s for s in range(10, 24, 2)]
    ag = [(b, cm.t_ring_allgather(p, b, true)) for b in sizes]
    ar = [(b, cm.t_ring_allreduce(p, b, true)) for b in sizes]
    fit = cm.fit_topo(p, ag, ar, name="fit")
    assert fit.alpha == pytest.approx(true.alpha, rel=1e-6)
    assert fit.beta == pytest.approx(true.beta, rel=1e-6)
    assert fit.gamma == pytest.approx(true.gamma, rel=1e-6)
    # without allreduce points, gamma carries over from base
    assert cm.fit_topo(p, ag, base=true).gamma == true.gamma
    with pytest.raises(ValueError):
        cm.fit_topo(p, [(1024, 1e-4)])          # one size: underdetermined
    with pytest.raises(ValueError):
        cm.fit_topo(1, ag)


def test_scaled_tier_derives_from_fitted_absolutes():
    """An unreachable tier (DCN from inside one pod) anchors to the FITTED
    base via the published ratios — absolutes measured, ratios assumed."""
    base = cm.fit_topo(
        4, [(b, cm.t_ring_allgather(4, b, cm.V5E_ICI)) for b in
            (1 << 12, 1 << 16, 1 << 20)], name="fit-ici")
    dcn = base.scaled(name="fit-dcn", alpha_mult=cm.DCN_ALPHA_MULT,
                      bw_mult=cm.DCN_BW_MULT)
    assert dcn.alpha == pytest.approx(base.alpha * 10.0)
    assert dcn.link_bw == pytest.approx(base.link_bw * 0.25)
    assert dcn.gamma == base.gamma
    mt = cm.MeshTopo.of(i=base, o=dcn)
    assert mt.resolve("fit-dcn/fit-ici") == (dcn, base)


def test_mesh_topo_fit_builds_per_axis_tiers():
    pts = {
        "i": (4, [(b, cm.t_ring_allgather(4, b, cm.V5E_ICI))
                  for b in (1 << 12, 1 << 20)], None),
        "o": (2, [(b, cm.t_ring_allgather(2, b, cm.V5E_DCN))
                  for b in (1 << 12, 1 << 20)], None),
    }
    mt = cm.MeshTopo.fit(pts)
    assert mt.topo("i").beta == pytest.approx(cm.V5E_ICI.beta, rel=1e-6)
    assert mt.topo("o").beta == pytest.approx(cm.V5E_DCN.beta, rel=1e-6)


# ---------------------------------------------------------------------------
# pricing: composed schedules vs the flat joint ring; admissibility
# ---------------------------------------------------------------------------


def _hier_cell(op="allreduce", nbytes=4 << 20, tier=TIER):
    return OpCell(op, P_OUT, nbytes, p2=P_IN, tier=tier)


def test_hier_allreduce_priced_below_flat_joint_ring():
    """The guideline the mock-ups exist for: on a DCN-crossing mesh the
    untuned default is one ring through all p*q ranks — every synchronous
    step gated by the DCN link — while the composed schedule moves only a
    1/q share across DCN."""
    cell = _hier_cell()
    B = float(cell.nbytes)
    t_def = cm.latency_cell(cell, "default", MESH)
    assert t_def == pytest.approx(
        cm.t_ring_allreduce(P_OUT * P_IN, B, cm.V5E_DCN))
    t_mpix = cm.latency_cell(cell, "MPIX_rs_ar_ag", MESH)
    assert t_mpix == pytest.approx(
        cm.t_ring_reduce_scatter(P_IN, B, cm.V5E_ICI)
        + cm.t_ring_allreduce(P_OUT, B / P_IN, cm.V5E_DCN)
        + cm.t_ring_allgather(P_IN, B / P_IN, cm.V5E_ICI))
    assert t_mpix < t_def / 2.0


def test_hier_allgather_and_reducescatter_composed_prices():
    B = 1 << 20
    ag = _hier_cell("allgather", B)
    assert cm.latency_cell(ag, "MPIX_ag_ag", MESH) == pytest.approx(
        cm.t_ring_allgather(P_IN, B, cm.V5E_ICI)
        + cm.t_ring_allgather(P_OUT, P_IN * B, cm.V5E_DCN))
    rs = _hier_cell("reducescatter", 8 << 20)
    assert cm.latency_cell(rs, "MPIX_rs_rs", MESH) == pytest.approx(
        cm.t_ring_reduce_scatter(P_OUT, 8 << 20, cm.V5E_DCN)
        + cm.t_ring_reduce_scatter(P_IN, (8 << 20) / P_OUT, cm.V5E_ICI))


def test_hier_flat_admissibility_is_mutual():
    # flat one-axis mock-ups are inadmissible on a hierarchical cell ...
    sw = cm.sweep_cell(_hier_cell(), MESH)
    assert math.isfinite(sw["default"]) and math.isfinite(sw["MPIX_rs_ar_ag"])
    for name, t in sw.items():
        if name not in ("default", "MPIX_rs_ar_ag"):
            assert t == math.inf, name
    # ... and hierarchical mock-ups on a flat cell
    flat = OpCell("allreduce", P_OUT * P_IN, 4 << 20)
    assert cm.latency_cell(flat, "MPIX_rs_ar_ag", MESH) == math.inf
    assert math.isfinite(cm.latency_cell(flat, "default", MESH))


def test_hier_untiered_cell_prices_on_slowest_vs_flat():
    """A hierarchical cell with NO tier token still prices hier-aware:
    default = joint ring on the flat (fastest) assumption is wrong, so
    the resolver maps "" to flat/flat and the joint default rides the
    slower of the two slots — here both flat, i.e. the old behaviour."""
    cell = _hier_cell(tier="")
    t_def = cm.latency_cell(cell, "default", MESH)
    assert t_def == pytest.approx(cm.t_ring_allreduce(
        P_OUT * P_IN, float(cell.nbytes), cm.V5E_ICI))


# ---------------------------------------------------------------------------
# api dispatch: inner_axis + ambient MeshTopo -> tier-stamped cells
# ---------------------------------------------------------------------------


def _tier_profile(impl="MPIX_rs_ar_ag"):
    return ProfileStore([Profile(
        op="allreduce", axis_size=P_OUT,
        ranges=[Range(1, 1 << 30, impl)], tier=f"{TIER}@q{P_IN}")])


def _dispatch_hier(ctx_kw):
    rng = np.random.default_rng(3)
    x = rng.normal(size=(P_OUT, P_IN, 6, 2)).astype(np.float32)
    with api.tuned(**ctx_kw) as ctx:
        got = jax.vmap(jax.vmap(
            lambda s: api.allreduce(s, "o", inner_axis="i"),
            axis_name="i"), axis_name="o")(jnp.asarray(x))
    np.testing.assert_allclose(
        np.asarray(got),
        np.broadcast_to(x.sum((0, 1)), x.shape), atol=1e-5)
    return ctx.record


def test_dispatch_stamps_tier_and_selects_hier_mockup():
    recs = _dispatch_hier(dict(profiles=_tier_profile(), mesh_topo=MESH))
    (rec,) = recs
    assert rec.impl == "MPIX_rs_ar_ag"
    assert rec.cell.p == P_OUT and rec.cell.p2 == P_IN
    assert rec.cell.tier == TIER and rec.cell.hier
    assert rec.cell.profile_tier() == f"{TIER}@q{P_IN}"


def test_dispatch_without_mesh_topo_stays_untiered():
    (rec,) = _dispatch_hier({})
    assert rec.impl == "default"
    assert rec.cell.tier == "" and rec.cell.p2 == P_IN
    assert rec.cell.profile_tier() == f"hier@q{P_IN}"


def test_dispatch_global_mesh_topo_registry():
    api.set_mesh_topo(MESH)
    try:
        (rec,) = _dispatch_hier({})
        assert rec.cell.tier == TIER
    finally:
        api.set_mesh_topo(None)
    (rec,) = _dispatch_hier({})
    assert rec.cell.tier == ""


def test_dispatch_refuses_cross_world_forces():
    # a hier mock-up forced onto a FLAT callsite falls back to default
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(P_OUT * P_IN, 4, 2)), jnp.float32)
    with api.tuned(force={"allreduce": "MPIX_rs_ar_ag"}) as ctx:
        jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    assert [r.impl for r in ctx.record] == ["default"]
    # and a flat mock-up forced onto a hierarchical callsite likewise
    recs = _dispatch_hier(dict(force={"allreduce": "allreduce_as_doubling"}))
    assert [r.impl for r in recs] == ["default"]


# ---------------------------------------------------------------------------
# persistence: tier through trace JSONL, profile text/JSON, disk
# ---------------------------------------------------------------------------


def test_trace_jsonl_roundtrips_tier():
    t = Trace([TraceEntry.of("allreduce", P_OUT, 4096, "fwd", "default", 3,
                             p2=P_IN, tier=TIER),
               TraceEntry.of("allreduce", 8, 4096, "fwd", "default", 2)])
    back = Trace.from_jsonl(t.to_jsonl())
    assert back == t
    cells = sorted(back.cells(), key=lambda c: c.p)
    assert cells[0].tier == TIER and cells[0].p2 == P_IN
    assert cells[1].tier == "" and cells[1].p2 == 0


def test_profile_tier_text_json_disk_roundtrip(tmp_path):
    prof = Profile(op="allreduce", axis_size=P_OUT,
                   ranges=[Range(1, 1 << 20, "MPIX_rs_ar_ag")],
                   tier=f"{TIER}@q{P_IN}")
    assert "#@tier" in prof.to_text()
    for back in (Profile.from_text(prof.to_text()),
                 Profile.from_json(prof.to_json())):
        assert back.tier == prof.tier and back.ranges == prof.ranges
    # untiered profiles stay byte-identical to the pre-tier format
    flat = Profile(op="allreduce", axis_size=8,
                   ranges=[Range(1, 1 << 20, "allreduce_as_doubling")])
    assert "#@tier" not in flat.to_text()
    store = ProfileStore([prof, flat])
    store.save(tmp_path)
    names = sorted(f.name for f in tmp_path.glob("*.pgtune"))
    assert any("_t" in n for n in names)        # tier tag in the filename
    back = ProfileStore.load(tmp_path)
    assert len(back) == 2
    assert back.get("allreduce", P_OUT,
                    tier=f"{TIER}@q{P_IN}").tier == f"{TIER}@q{P_IN}"
    assert back.get("allreduce", 8).tier == ""


# ---------------------------------------------------------------------------
# tuner: tier-keyed profiles from a mixed flat/hierarchical trace
# ---------------------------------------------------------------------------


def test_tune_trace_emits_tier_keyed_profiles():
    t = Trace([TraceEntry.of("allreduce", P_OUT, 4 << 20, "fwd", "default",
                             8, p2=P_IN, tier=TIER),
               TraceEntry.of("allreduce", P_OUT * P_IN, 4 << 20, "fwd",
                             "default", 8)])
    backend = tuner.CostModelBackend(MESH)
    rep = tuner.tune_trace(t, backend=backend)
    store = rep.phase_profiles["fwd"]
    hier_cell = OpCell("allreduce", P_OUT, 4 << 20, p2=P_IN, tier=TIER)
    flat_cell = OpCell("allreduce", P_OUT * P_IN, 4 << 20)
    assert store.lookup_cell(hier_cell) == "MPIX_rs_ar_ag"
    # the flat sibling resolves in its own tier partition and never to a
    # hierarchical mock-up
    flat_sel = store.lookup_cell(flat_cell)
    assert flat_sel != "MPIX_rs_ar_ag"
    # the modeled win is real: tuned estimate strictly below default
    est_def = tuner.estimate_trace_cost(t, backend)
    est_tuned = tuner.estimate_trace_cost(t, backend,
                                          phases=rep.phase_profiles)
    assert est_tuned["fwd"] < est_def["fwd"]


def test_measure_problem_shapes_hier_uses_world():
    """v-style hierarchical cells size their replay input by the JOINT
    group (p*p2 chunks), mirroring the flat path's p chunks."""
    flat = OpCell("reducescatter", 8, 64)
    hier = OpCell("reducescatter", 2, 64, p2=4)
    assert measure.problem_shapes(flat)["x"][0] == \
        measure.problem_shapes(hier)["x"][0] == (64 // 4) * 8
    ar = OpCell("allreduce", 2, 64, p2=4)
    assert measure.problem_shapes(ar)["x"][0] == 64 // 4
