"""Cost model sanity + tuner behaviour (violation detection, thresholds,
profile generation) on both fabric presets."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core import tuner
from repro.core.cell import OpCell
from repro.core.collectives import REGISTRY


def test_latency_monotone_in_bytes():
    for op in REGISTRY:
        for impl in REGISTRY[op]:
            t1 = cm.latency(op, impl, 16, 1024, cm.V5E_ICI)
            t2 = cm.latency(op, impl, 16, 10 * 1024, cm.V5E_ICI)
            if math.isinf(t1):
                continue
            assert t2 >= t1, (op, impl)


@settings(max_examples=60, deadline=None)
@given(st.sampled_from(list(REGISTRY)), st.integers(2, 10),
       st.integers(1, 24))
def test_latency_positive_finite_or_pow2_guard(op, logp, logn):
    p, n = 2 ** logp, 2 ** logn
    for impl in REGISTRY[op]:
        t = cm.latency(op, impl, p, n, cm.V5E_ICI)
        assert t > 0 and not math.isnan(t)


def test_doubling_wins_small_messages():
    """log(p)·α vs 2(p-1)·α: recursive doubling must beat the ring for tiny
    payloads on large axes — the classic latency-regime violation."""
    t_ring = cm.latency("allreduce", "default", 256, 8, cm.V5E_ICI)
    t_dbl = cm.latency("allreduce", "allreduce_as_doubling", 256, 8,
                       cm.V5E_ICI)
    assert t_dbl < t_ring / 5


def test_ring_wins_large_messages():
    t_ring = cm.latency("allreduce", "default", 256, 64 * 2**20, cm.V5E_ICI)
    t_dbl = cm.latency("allreduce", "allreduce_as_doubling", 256, 64 * 2**20,
                       cm.V5E_ICI)
    assert t_ring < t_dbl


def test_vdg_bcast_wins_bandwidth_regime():
    """Scatter+Allgather (GL10, van de Geijn) beats tree bcast for large n."""
    t_tree = cm.latency("bcast", "bcast_as_tree", 64, 16 * 2**20, cm.V5E_ICI)
    t_vdg = cm.latency("bcast", "bcast_as_scatter_allgather", 64, 16 * 2**20,
                       cm.V5E_ICI)
    assert t_vdg < t_tree


def test_naive_pricing_slower_than_optimal():
    for op in ("allgather", "allreduce", "reducescatter"):
        t_n = cm.latency(op, "default", 64, 2**20, cm.BGQ_LIKE)
        # same fabric constants, optimal defaults
        opt = cm.Topo("x", alpha=cm.BGQ_LIKE.alpha,
                      link_bw=cm.BGQ_LIKE.link_bw, gamma=cm.BGQ_LIKE.gamma)
        t_o = cm.latency(op, "default", 64, 2**20, opt)
        assert t_n >= t_o


# ---------------------------------------------------------------------------
# tuner
# ---------------------------------------------------------------------------


def test_tuner_finds_violations_on_bgq_like():
    rep = tuner.tune(axis_size=1024,
                     backend=tuner.CostModelBackend(cm.BGQ_LIKE))
    pat = [v for v in rep.violations if v.gl_kind == "pattern"]
    assert len(pat) > 20
    # the BlueGene/Q story: HW bcast makes gather+bcast/bcast-based mock-ups
    # win for small messages (paper Fig. 5)
    small_ag = [v for v in pat if v.op == "allgather" and v.nbytes <= 32]
    assert small_ag, "expected small-message allgather violations"
    assert len(rep.profiles) >= 5


def test_tuner_min_win_threshold():
    rep_strict = tuner.tune(axis_size=16,
                            backend=tuner.CostModelBackend(cm.V5E_ICI),
                            min_win=0.99)
    assert not [v for v in rep_strict.violations if v.gl_kind == "pattern"]


def test_tuner_scratch_budget_excludes():
    rep = tuner.tune(ops=["allgather"], axis_size=16,
                     backend=tuner.CostModelBackend(cm.BGQ_LIKE),
                     scratch_budget_bytes=0)
    # only zero-extra-memory mock-ups may be selected
    for v in rep.violations:
        if v.gl_kind != "pattern" or v.best_impl is None:
            continue
        impl = REGISTRY[v.op][v.best_impl]
        assert impl.extra_bytes(v.nbytes, 16) == 0


def test_tuner_profiles_pick_fastest():
    backend = tuner.CostModelBackend(cm.BGQ_LIKE)
    rep = tuner.tune(ops=["allreduce"], axis_size=256, backend=backend)
    prof = rep.profiles.get("allreduce", 256)
    assert prof is not None
    for r in prof.ranges:
        cell = OpCell("allreduce", 256, r.lo)
        t_best = backend.latency(cell, r.impl)
        t_def = backend.latency(cell, "default")
        assert t_best < t_def * 0.9


def test_tuner_coalesces_ranges():
    rep = tuner.tune(ops=["allreduce"], axis_size=1024,
                     backend=tuner.CostModelBackend(cm.BGQ_LIKE))
    prof = rep.profiles.get("allreduce", 1024)
    assert prof is not None
    for a, b in zip(prof.ranges, prof.ranges[1:]):
        assert a.impl != b.impl or a.hi < b.lo - 1


def test_tuner_survives_unmeasurable_default():
    """Regression: a size where the default's latency is inf (or the
    backend skips it) used to crash with KeyError: 'default'; it must be
    skipped with a note instead."""
    class InfDefaultBackend:
        name = "stub"

        def latency(self, cell, impl):
            if impl == "default" and cell.nbytes == 8:
                return math.inf
            return 1.0 if impl == "default" else 0.5

        def nrep_for(self, cell, impl):
            return 1

    rep = tuner.tune(ops=["allreduce"], sizes=(8, 64), axis_size=16,
                     backend=InfDefaultBackend())
    assert any("unmeasurable" in n for n in rep.notes)
    assert "note:" in rep.summary()
    # the measurable size still tunes normally
    prof = rep.profiles.get("allreduce", 16)
    assert prof is not None and prof.lookup(64) is not None
    assert prof.lookup(8) is None


# ---------------------------------------------------------------------------
# hierarchical per-axis pricing: the flat-link-cost regression
# ---------------------------------------------------------------------------


def _cell_2d(tier=""):
    """A comm-bound ICI-inner/DCN-outer 2-D fused cell: the streamed
    weight column block dominates (large K, small M/N), so the cell's
    cost is essentially the outer stream's transfer time."""
    p, q, k, m, n, it = 4, 4, 8192, 256, 256, 4
    return OpCell("matmul_reducescatter_2d", p, k * (n // p) * it,
                  "float32", mm_k=k, mm_m=m, mm_n=n, mm_role="2d", p2=q,
                  tier=tier)


def test_2d_cell_outer_stream_priced_on_its_own_tier():
    """Regression for the flat-link cost model: a data(DCN)-outer x
    model(ICI)-inner 2-D cell priced with one flat ICI ``Topo``
    underestimates the outer stream by the full ICI/DCN bandwidth gap
    (4x at v5e numbers).  With a ``MeshTopo`` the ``p`` axis prices on
    the DCN fabric and the ``p2`` axis on ICI — on this comm-bound cell
    the tiered price must come out ~4x the flat-ICI price."""
    mesh = cm.MeshTopo.of(data=cm.V5E_DCN, model=cm.V5E_ICI)
    tiered = _cell_2d(tier="v5e-dcn/v5e-ici")
    flat = _cell_2d()
    for impl in REGISTRY["matmul_reducescatter_2d"]:
        t_mesh = cm.latency_cell(tiered, impl, mesh)
        t_flat = cm.latency_cell(flat, impl, cm.V5E_ICI)
        assert 3.0 <= t_mesh / t_flat <= 4.5, (impl, t_mesh, t_flat)
        # plain-Topo callers keep the pre-hierarchy behaviour bit-for-bit,
        # tier token or not
        assert cm.latency_cell(tiered, impl, cm.V5E_ICI) == t_flat


def test_2d_cell_untiered_prices_on_fastest_axis():
    """An untiered cell under a MeshTopo prices on the fastest axis — the
    flat model's implicit assumption, now explicit — so pre-hierarchy
    traces keep their numbers."""
    mesh = cm.MeshTopo.of(data=cm.V5E_DCN, model=cm.V5E_ICI)
    flat = _cell_2d()
    for impl in REGISTRY["matmul_reducescatter_2d"]:
        assert cm.latency_cell(flat, impl, mesh) == \
            cm.latency_cell(flat, impl, cm.V5E_ICI)


def test_overlapped_ring2d_per_axis_fabrics():
    """``t_overlapped_ring2d`` prices the outer stream on ``t`` and the
    inner ring on ``t_inner``; omitting ``t_inner`` keeps the old flat
    single-fabric behaviour."""
    mm = 1e-5
    outer_dcn = cm.V5E_DCN.alpha + 2 ** 20 * cm.V5E_DCN.beta
    outer_ici = cm.V5E_ICI.alpha + 2 ** 20 * cm.V5E_ICI.beta
    inner = cm.V5E_ICI.alpha + 2 ** 16 * cm.V5E_ICI.beta
    flat = cm.t_overlapped_ring2d(4, 4, outer_ici, inner, mm, cm.V5E_ICI)
    assert cm.t_overlapped_ring2d(4, 4, outer_ici, inner, mm, cm.V5E_ICI,
                                  None) == flat
    tiered = cm.t_overlapped_ring2d(4, 4, outer_dcn, inner, mm,
                                    cm.V5E_DCN, cm.V5E_ICI)
    # the comm-bound outer stream exposes the DCN/ICI bandwidth gap
    assert tiered > flat * 3.0


@pytest.mark.slow
def test_tuner_measured_backend_smoke():
    """Full measured pipeline on host devices (tiny sizes, single device is
    fine — axis size 1 short-circuits latencies to ~0 but the plumbing,
    NREP estimation and profile writing must work)."""
    from repro.core import measure
    backend = tuner.MeasuredBackend(K=2, max_nrep=3)
    p = measure.axis_size()
    rep = tuner.tune(ops=["allreduce"], sizes=(8, 64), axis_size=p,
                     backend=backend)
    assert rep.measurements
    for m in rep.measurements:
        assert m.latency >= 0.0
