"""Fused collective-matmul: kernels, dispatcher ops, VJPs, tuner, fast path.

Interpret-mode / vmap equivalence of ``allgather_matmul`` and
``matmul_reducescatter`` (fused_ring vs the unfused composition) in fwd and
bwd across shapes, dtypes and non-divisible row counts; tuner selection of
fused-vs-unfused per shape (the new guideline); the measured-backend trace
replay skip rule; and the dispatch hot-path short-circuit.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, costmodel as cm, tuner
from repro.core import collectives as C
from repro.core.trace import Trace, TraceEntry
from repro.dist import ops
from repro.kernels.collective_matmul import (pallas_matmul,
                                             ring_allgather_matmul,
                                             ring_matmul_reducescatter)

PS = (4, 8)


def _cot(y):
    return jnp.cos(jnp.arange(y.size, dtype=jnp.float32)).reshape(y.shape)


# ---------------------------------------------------------------------------
# tier-2 Pallas block matmul (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-4),
                                        (jnp.bfloat16, 5e-1)])
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),       # aligned
    (192, 64, 96),         # multi-block
    (100, 33, 17),         # nothing divides the tile
    (5, 256, 128),         # skinny rows
])
def test_pallas_matmul_interpret(rng, dtype, atol, m, k, n):
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), dtype)
    got = pallas_matmul(x, w, bm=64, bn=64, bk=64, interpret=True)
    want = jnp.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# fused rings vs unfused composition (vmap semantic path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("dtype,atol", [(np.float32, 1e-4),
                                        (np.float16, 2e-2)])
@pytest.mark.parametrize("n,k,m", [(4, 8, 6), (5, 3, 7), (1, 16, 2)])
def test_ring_allgather_matmul_matches_unfused(rng, p, dtype, atol, n, k, m):
    x = jnp.asarray(rng.normal(size=(p, n, k)).astype(dtype))
    w = jnp.asarray(rng.normal(size=(k, m)).astype(dtype))
    got = jax.vmap(lambda a: ring_allgather_matmul(a, w, "x"),
                   axis_name="x")(x)
    full = np.asarray(x, np.float32).reshape(p * n, k)
    want = full @ np.asarray(w, np.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32)[0], want,
                               atol=atol)
    # every shard holds the same full product
    for r in range(p):
        np.testing.assert_array_equal(np.asarray(got)[r], np.asarray(got)[0])


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("n,k,m", [(4, 8, 6), (3, 5, 2)])
def test_ring_matmul_reducescatter_matches_unfused(rng, p, n, k, m):
    x = jnp.asarray(rng.normal(size=(p, p * n, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    got = jax.vmap(lambda a: ring_matmul_reducescatter(a, w, "x"),
                   axis_name="x")(x)
    want = (np.asarray(x) @ np.asarray(w)).sum(0).reshape(p, n, m)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-4)


def test_ring_allgather_matmul_returns_gathered(rng):
    p, n, k = 4, 3, 6
    x = jnp.asarray(rng.normal(size=(p, n, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, 5)).astype(np.float32))
    _, gath = jax.vmap(
        lambda a: ring_allgather_matmul(a, w, "x", return_gathered=True),
        axis_name="x")(x)
    np.testing.assert_allclose(np.asarray(gath)[0],
                               np.asarray(x).reshape(p * n, k), atol=1e-6)


@pytest.mark.parametrize("op", ["allgather_matmul", "matmul_reducescatter"])
@pytest.mark.parametrize("impl_check", [True])
def test_registry_impls_semantics(rng, op, impl_check):
    """Every registered impl of the fused ops against the dense oracle."""
    p, n, k, m = 4, 3, 6, 5
    rows = n if op == "allgather_matmul" else p * n
    x = rng.normal(size=(p, rows, k)).astype(np.float32)
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    if op == "allgather_matmul":
        want = np.asarray(x).reshape(p * n, k) @ np.asarray(w)
        want = np.broadcast_to(want, (p,) + want.shape)
    else:
        want = (x @ np.asarray(w)).sum(0).reshape(p, n, m)
    from repro.core.selfcheck import rel_err, wire_hops
    from repro.kernels.quant import wire_tol
    for name in C.impl_names(op):
        impl = C.REGISTRY[op][name]
        got = jax.vmap(lambda a, fn=impl.fn: fn(a, "x", w=w),
                       axis_name="x")(jnp.asarray(x))
        if impl.wire_dtype is not None:
            # quantized-wire impls are approximate by design: gate at
            # their selfcheck tolerance instead of the exact atol
            tol = wire_tol(impl.wire_dtype, wire_hops(op, p))
            assert rel_err(got, want) <= tol, (name, rel_err(got, want))
        else:
            np.testing.assert_allclose(np.asarray(got), want, atol=1e-4,
                                       err_msg=name)


# ---------------------------------------------------------------------------
# dist.ops custom VJPs: fused grads == unfused grads == dense reference
# ---------------------------------------------------------------------------


def _grads(f, *args):
    def loss(*a):
        y = f(*a)
        return jnp.sum(y * _cot(y))
    return jax.vmap(jax.grad(loss, argnums=tuple(range(len(args)))),
                    axis_name="model")(*args)


@pytest.mark.parametrize("impl", ["default", "fused_ring"])
def test_allgather_matmul_grads(rng, impl):
    p, n, k, m = 4, 3, 8, 5
    x = jnp.asarray(rng.normal(size=(p, n, k)).astype(np.float32))
    w = jnp.asarray(np.broadcast_to(
        rng.normal(size=(k, m)).astype(np.float32), (p, k, m)).copy())

    def f(a, ww):
        return ops.allgather_matmul(a, ww, "model")

    with api.tuned(force={"allgather_matmul": impl,
                          "matmul_reducescatter": impl}) as ctx:
        dx, dw = _grads(f, x, w)
    # reference: unfused composition with the same gather<->scatter pairing
    def ref(a, ww):
        full = ops.tp_allgather(a, 0, "model")
        return jnp.matmul(full, ww)

    rx, rw = _grads(ref, x, w)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rw), atol=1e-5)
    # backward pairing: the input grad went through matmul_reducescatter
    assert any(op == "matmul_reducescatter" and ph == "bwd"
               for op, _, _, _, ph in ctx.record)


@pytest.mark.parametrize("impl", ["default", "fused_ring"])
def test_matmul_reducescatter_grads(rng, impl):
    p, n, k, m = 4, 2, 6, 5
    x = jnp.asarray(rng.normal(size=(p, p * n, k)).astype(np.float32))
    w = jnp.asarray(np.broadcast_to(
        rng.normal(size=(k, m)).astype(np.float32), (p, k, m)).copy())

    def f(a, ww):
        return ops.matmul_reducescatter(a, ww, "model")

    with api.tuned(force={"allgather_matmul": impl,
                          "matmul_reducescatter": impl}) as ctx:
        dx, dw = _grads(f, x, w)

    def ref(a, ww):
        return ops.tp_reducescatter(jnp.matmul(a, ww), 0, "model")

    rx, rw = _grads(ref, x, w)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rw), atol=1e-5)
    # fused fwd pairs with allgather_matmul bwd
    assert any(op == "allgather_matmul" and ph == "bwd"
               for op, _, _, _, ph in ctx.record)


@pytest.mark.parametrize("impl", ["default", "fused_ring"])
def test_fsdp_matmul_fuses_weight_gather(rng, impl):
    """x @ AG(w, dim 1) over the data axis — values and grads must match
    the unfused fsdp_gather + matmul composition exactly."""
    p, b, s, f, dloc = 4, 2, 3, 6, 2
    x = jnp.asarray(rng.normal(size=(p, b, s, f)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(p, f, dloc)).astype(np.float32))

    def g_of(fun):
        def loss(a, ww):
            y = fun(a, ww)
            return jnp.sum(y * _cot(y))
        return jax.vmap(jax.grad(loss, argnums=(0, 1)),
                        axis_name="data")(x, w)

    def fused(a, ww):
        return ops.fsdp_matmul(a, ww, "data")

    def unfused(a, ww):
        return jnp.matmul(a, ops.fsdp_gather(ww, 1, "data"))

    with api.tuned(force={"allgather_matmul": impl,
                          "matmul_reducescatter": impl}) as ctx:
        got_y = jax.vmap(fused, axis_name="data")(x, w)
        gx, gw = g_of(fused)
    ref_y = jax.vmap(unfused, axis_name="data")(x, w)
    rx, rw = g_of(unfused)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(ref_y),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-5)
    # fwd weight gather fused; bwd grad reduce-scatter fused
    assert any(op == "allgather_matmul" and ph == "fwd"
               for op, _, _, _, ph in ctx.record)
    assert any(op == "matmul_reducescatter" and ph == "bwd"
               for op, _, _, _, ph in ctx.record)


@pytest.mark.parametrize("rows", [8, 5])     # divisible and not
@pytest.mark.parametrize("impl", ["default", "fused_ring"])
def test_col_row_matmul_rewired_grads_match_legacy(rng, rows, impl):
    """col/row matmul through the fused-selectable decomposition must equal
    the legacy psum formulation in values AND grads (any impl)."""
    p = 4
    x = jnp.asarray(rng.normal(size=(p, rows, 6)).astype(np.float32))
    wc = jnp.asarray(rng.normal(size=(p, 6, 3)).astype(np.float32))
    wr = jnp.asarray(rng.normal(size=(p, 3, 6)).astype(np.float32))

    def f(a, c, r):
        h = ops.col_matmul(a, c, "model")
        return ops.row_matmul(h, r, "model")

    def ref(a, c, r):
        h = jnp.matmul(ops.tp_copy(a, "model"), c)
        return ops.tp_allreduce(jnp.matmul(h, r), "model")

    def grads(fun):
        def loss(a, c, r):
            y = fun(a, c, r)
            return jnp.sum(y * _cot(y))
        return jax.vmap(jax.grad(loss, argnums=(0, 1, 2)),
                        axis_name="model")(x, wc, wr)

    want_y = jax.vmap(ref, axis_name="model")(x, wc, wr)
    want_g = grads(ref)
    with api.tuned(force={"allgather_matmul": impl,
                          "matmul_reducescatter": impl}):
        got_y = jax.vmap(f, axis_name="model")(x, wc, wr)
        got_g = grads(f)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               atol=1e-5)
    for g, r in zip(jax.tree.leaves(got_g), jax.tree.leaves(want_g)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=1e-5)


def test_row_matmul_fsdp_dim1_matches_pregathered(rng):
    p = 4
    x = jnp.asarray(rng.normal(size=(p, 8, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(p, 6, 2)).astype(np.float32))
    # model axis absent, data axis bound: fsdp_dim=1 fuses the data gather
    got = jax.vmap(lambda a, ww: ops.row_matmul(a, ww, fsdp_dim=1),
                   axis_name="data")(x, w)
    ref = jax.vmap(lambda a, ww: jnp.matmul(a, ops.fsdp_gather(ww, 1)),
                   axis_name="data")(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# tuner: the fused-vs-unfused guideline per shape
# ---------------------------------------------------------------------------


def test_tuner_selects_fused_large_default_small():
    rep = tuner.tune(ops=["allgather_matmul", "matmul_reducescatter"],
                     sizes=(64, 1024, 1_048_576, 16_777_216),
                     axis_size=8, backend=tuner.CostModelBackend(cm.V5E_ICI))
    prof = rep.profiles
    for op in ("allgather_matmul", "matmul_reducescatter"):
        assert prof.lookup(op, 8, 16_777_216) == "fused_ring", op
        assert prof.lookup(op, 8, 64) is None, op      # default kept


def test_tune_trace_phase_profiles_pick_fused_for_tp_shapes():
    """A trace with a realistic TP matmul cell and a tiny one: the phase
    store must route the big cell to fused_ring and keep the small cell on
    the default — the acceptance-criterion shape split.  (Geometry-less
    cells: the canonical cost-model pricing still applies.)"""
    t = Trace([TraceEntry.of("allgather_matmul", 8, 4_194_304, "decode",
                             "default", 10),
               TraceEntry.of("allgather_matmul", 8, 256, "decode",
                             "default", 10),
               TraceEntry.of("matmul_reducescatter", 8, 8_388_608, "bwd",
                             "default", 4)])
    rep = tuner.tune_trace(t, backend=tuner.CostModelBackend(cm.V5E_ICI))
    dec = rep.phase_profiles["decode"]
    assert dec.lookup("allgather_matmul", 8, 4_194_304) == "fused_ring"
    assert dec.lookup("allgather_matmul", 8, 256) is None
    bwd = rep.phase_profiles["bwd"]
    assert bwd.lookup("matmul_reducescatter", 8, 8_388_608) == "fused_ring"
    assert rep.est_tuned_s["decode"] < rep.est_default_s["decode"]


def test_lm_train_trace_contains_fused_ops_and_tuner_splits(rng):
    """End-to-end: a recorded fwd+bwd LM step (vmap FSDP) now emits
    allgather_matmul (fused weight gather) and matmul_reducescatter (grad
    reduce-scatter) cells, and trace-replay tuning on the cost model picks
    fused_ring for at least one of them."""
    from jax import lax

    from repro.configs import get_config
    from repro.models import lm
    from repro.models.params import init_tree

    cfg = get_config("llama3.2-3b").smoke()
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32) + 5}
    batch["labels"] = batch["tokens"]

    def init(key):
        return init_tree(lm.model_specs(cfg, tp=1), key,
                         fold=lax.axis_index("data"))

    def grad_fn(params):
        return jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)

    with api.tuned() as ctx:
        params = jax.vmap(init, axis_name="data", axis_size=2,
                          in_axes=None, out_axes=0)(jax.random.key(0))
        jax.vmap(grad_fn, axis_name="data")(params)

    trace = Trace.from_context(ctx)
    assert any(c.op == "allgather_matmul" for c in trace.cells("fwd"))
    assert any(c.op == "matmul_reducescatter"
               for c in trace.cells("bwd"))
    # every fused cell must carry its callsite's true GEMM geometry
    for c in trace.cells():
        if c.op in ("allgather_matmul", "matmul_reducescatter",
                    "matmul_accumulate"):
            assert c.fused and c.mm_role, c
    # smoke-config payloads are tiny (fusion correctly loses there); replay
    # the same op mix with every recorded GEMM grown to production dims
    # (the paper's "profiles are per cell" point, now with true geometry:
    # the overlap is priced from the cell's actual flops) — and the tuner
    # must flip the fused collective-matmul cells of all THREE ring
    # schedules to fused_ring
    import dataclasses as _dc

    def _production(c, k=2048, m=8192, n=8192):
        if not c.fused:
            return _dc.replace(c, nbytes=c.nbytes * 512)
        kk = c.mm_k * -(-k // c.mm_k)
        mm = c.mm_m * -(-m // c.mm_m)
        nn = c.mm_n * -(-n // c.mm_n)
        it = c.itemsize
        nb = {"gather": (mm // c.p) * kk * it,
              "scatter": mm * kk * it,
              "contract": (kk // c.p) * nn * it}[c.mm_role]
        return _dc.replace(c, mm_k=kk, mm_m=mm, mm_n=nn, nbytes=nb)

    scaled = Trace([TraceEntry(_production(e.cell), e.phase, e.impl,
                               e.count) for e in trace.entries])
    rep = tuner.tune_trace(scaled,
                           backend=tuner.CostModelBackend(cm.V5E_ICI))
    # the overlap-ring family: fused_ring plus its quantized-wire variants
    # (wire_q8/wire_fp8 run the same ring schedule with an 8-bit wire and
    # may legitimately out-model fused_ring on comm-bound cells)
    ring_family = ("fused_ring", "wire_q8", "wire_fp8")
    fused = [
        (ph, prof.op, r.impl)
        for ph, store in rep.phase_profiles.items()
        for prof in store
        for r in prof.ranges
        if r.impl in ring_family
    ]
    assert any(op == "allgather_matmul" for _, op, _ in fused), fused
    assert any(op == "matmul_reducescatter" for _, op, _ in fused), fused
    assert any(op == "matmul_accumulate" for _, op, _ in fused), fused
    # the emitted profiles are geometry-keyed — the cells above must
    # resolve through lookup_cell at dispatch
    ph, store = next((ph, s) for ph, s in rep.phase_profiles.items()
                     for p_ in s if p_.op == "allgather_matmul")
    agmm_cells = [c for c, _cnt in Trace(scaled.entries).cells(ph).items()
                  if c.op == "allgather_matmul"]
    assert any(store.lookup_cell(c) in ring_family for c in agmm_cells)


# ---------------------------------------------------------------------------
# measured-backend trace replay: p-mismatch cells skip with a note
# ---------------------------------------------------------------------------


def test_tune_trace_measured_backend_skips_foreign_axis_sizes():
    t = Trace([TraceEntry.of("allreduce", 4, 1024, "fwd", "default", 3)])
    backend = tuner.MeasuredBackend()
    # this process sees 1 host device -> p=4 cells cannot be replayed
    assert backend.supported_axis_size == 1
    rep = tuner.tune_trace(t, backend=backend)
    assert rep.phase_profiles == {}
    assert any("p=4 != host axis size" in n for n in rep.notes)
    assert rep.measurements == []


def test_tune_measured_backend_refuses_foreign_axis_size():
    rep = tuner.tune(ops=["allreduce"], sizes=(64,), axis_size=16,
                     backend=tuner.MeasuredBackend())
    assert len(rep.profiles) == 0
    assert any("host axis size" in n for n in rep.notes)


# ---------------------------------------------------------------------------
# dispatch fast path
# ---------------------------------------------------------------------------


def test_fast_path_records_and_selects_default(rng):
    x = jnp.ones((4, 8), jnp.float32)
    with api.tuned() as ctx:
        jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    assert [tuple(r) for r in ctx.record] == \
        [("allreduce", 4, 32, "default", "fwd")]
    assert ctx.record[0].cell.dtype == "float32"


def test_fast_path_defers_to_profiles_and_env(monkeypatch):
    from repro.core.profiles import Profile, ProfileStore, Range
    x = jnp.ones((4, 8), jnp.float32)
    store = ProfileStore([Profile(op="allreduce", axis_size=4,
                                  ranges=[Range(1, 10 ** 6,
                                                "allreduce_as_doubling")])])
    with api.tuned(profiles=store) as ctx:
        jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    assert ctx.record[0].impl == "allreduce_as_doubling"
    monkeypatch.setenv("PGTUNE_MODULE", "allreduce:alg=allreduce_as_doubling")
    with api.tuned() as ctx2:
        jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    assert ctx2.record[0].impl == "allreduce_as_doubling"
