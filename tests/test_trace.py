"""Workload traces + trace-replay tuning + phase-tagged dispatch.

Covers the ISSUE-2 acceptance path: record a real fwd+bwd LM step,
``tuner.tune_trace`` it into phase-split profiles, and prove ``api``
honors the phase tag at dispatch (bwd reduce-scatters pick a different
mock-up than fwd all-gathers).
"""
import math
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api, tuner
from repro.core.cell import OpCell
from repro.core.profiles import (Profile, ProfileStore, Range, load_stores,
                                 resolve_stores)
from repro.core.trace import Trace, TraceEntry
from repro.dist import ops

P = 4


# ---------------------------------------------------------------------------
# Trace data structure
# ---------------------------------------------------------------------------


def _mk(op="allreduce", p=8, nbytes=1024, phase="fwd", impl="default",
        count=1, **geom):
    return TraceEntry.of(op, p, nbytes, phase, impl, count, **geom)


def test_trace_aggregates_duplicate_cells():
    t = Trace([_mk(count=2), _mk(count=3), _mk(phase="bwd")])
    assert len(t) == 2
    assert t.total() == 6
    assert t.entries[0].count in (1, 5)
    assert {e.phase for e in t} == {"fwd", "bwd"}


def test_trace_jsonl_roundtrip_and_merge():
    t = Trace([_mk(), _mk(op="allgather", phase="decode", nbytes=64,
                          impl="allgather_as_ring", count=7)])
    back = Trace.from_jsonl(t.to_jsonl())
    assert back == t
    m = t.merge(back, back)
    assert m.total() == 3 * t.total()
    assert len(m) == len(t)


def test_trace_save_load(tmp_path):
    t = Trace([_mk(), _mk(phase="bwd", op="reducescatter")])
    t.save(tmp_path / "sub" / "trace.jsonl")
    assert Trace.load(tmp_path / "sub" / "trace.jsonl") == t


def test_trace_histogram_cells_filter():
    t = Trace([_mk(impl="default", count=2),
               _mk(impl="allreduce_as_doubling", count=3),
               _mk(phase="bwd", op="reducescatter", count=5)])
    # histogram keys on the full OpCell and sums over impls (the tuner
    # re-decides the impl)
    ar = OpCell("allreduce", 8, 1024)
    assert t.histogram()[(ar, "fwd")] == 5
    assert t.cells(phase="bwd") == {OpCell("reducescatter", 8, 1024): 5}
    assert t.filter(phase="fwd").ops() == ["allreduce"]
    assert t.phases() == ["bwd", "fwd"]


def test_trace_from_record_matches_api_tuples():
    with api.tuned() as ctx:
        x = jnp.ones((P, 4, 2), jnp.float32)
        with api.phase("decode"):
            jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    t = Trace.from_context(ctx)
    assert t.cells() == {OpCell("allreduce", P, 32): 1}
    assert t.phases() == ["decode"]


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=2 ** 30), min_size=1,
                max_size=12),
       st.sampled_from(["fwd", "bwd", "prefill", "decode"]),
       st.sampled_from(["allreduce", "allgather", "scatter"]))
def test_trace_jsonl_roundtrip_property(sizes, phase, op):
    entries = [TraceEntry.of(op, 1 << (i % 10), nb, phase, "default",
                             (i % 5) + 1)
               for i, nb in enumerate(sizes)]
    t = Trace(entries)
    back = Trace.from_jsonl(t.to_jsonl())
    assert back == t
    assert back.total() == t.total()


# ---------------------------------------------------------------------------
# phase tagging at dispatch
# ---------------------------------------------------------------------------


def test_dispatch_records_phase_tags_fwd_and_bwd():
    """dist/ops backward collectives carry phase="bwd" automatically."""
    w = jnp.arange(P * 4 * 2, dtype=jnp.float32).reshape(P, 4, 2)

    def loss(ws):
        full = ops.fsdp_gather(ws, 0, "data")
        return jnp.sum(full * full)

    with api.tuned() as ctx:
        jax.vmap(jax.grad(loss), axis_name="data")(w)
    phases = {(op, ph) for op, _, _, _, ph in ctx.record}
    assert ("allgather", "fwd") in phases
    assert ("reducescatter", "bwd") in phases


def test_phase_profiles_beat_base_profiles_for_matching_phase():
    base = ProfileStore([Profile(op="allreduce", axis_size=P,
                                 ranges=[Range(1, 10 ** 6,
                                               "allreduce_as_reduce_bcast")])])
    pp = {"decode": ProfileStore([
        Profile(op="allreduce", axis_size=P,
                ranges=[Range(1, 10 ** 6, "allreduce_as_doubling")])])}
    x = jnp.ones((P, 4, 2), jnp.float32)
    with api.tuned(profiles=base, phase_profiles=pp) as ctx:
        with api.phase("decode"):
            jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
        jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    assert (ctx.record[0].impl, ctx.record[0].phase) == \
        ("allreduce_as_doubling", "decode")
    # outside the tagged phase the base store still applies
    assert (ctx.record[1].impl, ctx.record[1].phase) == \
        ("allreduce_as_reduce_bcast", "fwd")


def test_tuned_shared_record_sink():
    sink = []
    x = jnp.ones((P, 2), jnp.float32)
    with api.tuned(record=sink) as ctx:
        jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    assert ctx.record is sink and len(sink) == 1


def test_env_force_memoized(monkeypatch):
    monkeypatch.setenv("PGTUNE_MODULE", "allreduce:alg=allreduce_as_doubling")
    first = api._env_force()
    assert first == {"allreduce": "allreduce_as_doubling"}
    assert api._env_force() is first            # cache hit, no re-parse
    monkeypatch.setenv("PGTUNE_MODULE", "bcast:alg=bcast_as_tree")
    assert api._env_force() == {"bcast": "bcast_as_tree"}
    monkeypatch.delenv("PGTUNE_MODULE")
    assert api._env_force() == {}


# ---------------------------------------------------------------------------
# trace-replay tuning
# ---------------------------------------------------------------------------


class _StubBackend:
    """Deterministic latencies: ``table[(op, impl)]``, else ``fallback``."""

    name = "stub"

    def __init__(self, table, fallback=10.0):
        self.table = table
        self.fallback = fallback

    def latency(self, cell, impl):
        return self.table.get((cell.op, impl), self.fallback)

    def nrep_for(self, cell, impl):
        return 1


def test_tune_trace_weights_cells_by_frequency():
    t = Trace([_mk(op="allreduce", p=8, nbytes=256, phase="decode",
                   count=100)])
    backend = _StubBackend({("allreduce", "default"): 10.0,
                            ("allreduce", "allreduce_as_doubling"): 1.0})
    rep = tuner.tune_trace(t, backend=backend)
    assert rep.est_default_s["decode"] == pytest.approx(1000.0)
    assert rep.est_tuned_s["decode"] == pytest.approx(100.0)
    prof = rep.phase_profiles["decode"].get("allreduce", 8)
    assert prof.lookup(256) == "allreduce_as_doubling"
    assert prof.meta["phase"] == "decode"


def test_tune_trace_respects_min_win_and_default_inf():
    t = Trace([_mk(op="allreduce", nbytes=64, phase="fwd"),
               _mk(op="allgather", nbytes=64, phase="fwd")])
    backend = _StubBackend({("allreduce", "default"): 10.0,
                            ("allreduce", "allreduce_as_doubling"): 9.5,
                            ("allgather", "default"): math.inf})
    rep = tuner.tune_trace(t, backend=backend, min_win=0.10)
    # 5% win < min_win -> no profile; inf default -> noted skip, no crash
    assert "fwd" not in rep.phase_profiles
    assert any("allgather" in n and "unmeasurable" in n for n in rep.notes)


def test_tune_trace_save_roundtrips_through_load_stores(tmp_path):
    t = Trace([_mk(op="allreduce", p=8, nbytes=256, phase="decode"),
               _mk(op="reducescatter", p=8, nbytes=512, phase="bwd")])
    backend = _StubBackend({("allreduce", "default"): 10.0,
                            ("allreduce", "allreduce_as_doubling"): 1.0,
                            ("reducescatter", "default"): 10.0,
                            ("reducescatter", "rsb_as_reduce_scatter"): 1.0})
    rep = tuner.tune_trace(t, backend=backend)
    rep.save(tmp_path)
    base, phases = load_stores(tmp_path)
    assert base is None
    assert set(phases) == {"decode", "bwd"}
    assert phases["decode"].lookup("allreduce", 8, 256) == \
        "allreduce_as_doubling"
    assert phases["bwd"].lookup("reducescatter", 8, 512) == \
        "rsb_as_reduce_scatter"


def test_resolve_stores_precedence(tmp_path, monkeypatch):
    explicit = tmp_path / "explicit"
    env_dir = tmp_path / "env"
    ProfileStore([Profile(op="allreduce", axis_size=4,
                          ranges=[Range(1, 9, "allreduce_as_doubling")])
                  ]).save(explicit)
    ProfileStore([Profile(op="bcast", axis_size=4,
                          ranges=[Range(1, 9, "bcast_as_tree")])
                  ]).save(env_dir)
    monkeypatch.setenv("PGTUNE_PROFILE_DIR", str(env_dir))
    base, _ = resolve_stores(str(explicit))       # arg beats env
    assert base.lookup("allreduce", 4, 5) == "allreduce_as_doubling"
    base_env, _ = resolve_stores(None)            # env fallback
    assert base_env.lookup("bcast", 4, 5) == "bcast_as_tree"
    # stale env var: warn + untuned, never crash a process that didn't
    # ask for profiles; an explicit missing directory still raises
    monkeypatch.setenv("PGTUNE_PROFILE_DIR", str(tmp_path / "missing"))
    with pytest.warns(UserWarning, match="serving untuned"):
        assert resolve_stores(None) == (None, {})
    monkeypatch.delenv("PGTUNE_PROFILE_DIR")
    assert resolve_stores(None) == (None, {})
    with pytest.raises(FileNotFoundError):
        resolve_stores(str(tmp_path / "missing"))


# ---------------------------------------------------------------------------
# acceptance: recorded LM fwd+bwd step -> phase-split profiles -> dispatch
# ---------------------------------------------------------------------------


def _lm_step_ctx(phase_profiles=None):
    """One llama fwd+bwd step under vmap-FSDP, recorded."""
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.params import init_tree

    cfg = get_config("llama3.2-3b").smoke()
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32) + 5}
    batch["labels"] = batch["tokens"]

    def init(key):
        return init_tree(lm.model_specs(cfg, tp=1), key,
                         fold=lax.axis_index("data"))

    def grad_fn(params):
        return jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)

    with api.tuned(phase_profiles=phase_profiles) as ctx:
        params = jax.vmap(init, axis_name="data", axis_size=2,
                          in_axes=None, out_axes=0)(jax.random.key(0))
        jax.vmap(grad_fn, axis_name="data")(params)
    return ctx


def test_tune_trace_lm_step_phase_split_end_to_end():
    # 1. record a real fwd+bwd LM step
    ctx = _lm_step_ctx()
    trace = Trace.from_context(ctx)
    assert {"fwd", "bwd"} <= set(trace.phases())
    assert any(c.op == "allgather" for c in trace.cells("fwd"))
    assert any(c.op == "reducescatter" for c in trace.cells("bwd"))

    # 2. tune the recorded mix; stub latencies make the winners
    #    deterministic: fwd allgathers -> ring, bwd reduce-scatters -> the
    #    reduce+scatter mock-up (a DIFFERENT selection per phase)
    backend = _StubBackend({("allgather", "default"): 10.0,
                            ("allgather", "allgather_as_ring"): 1.0,
                            ("reducescatter", "default"): 10.0,
                            ("reducescatter", "rsb_as_reduce_scatter"): 1.0},
                           fallback=50.0)
    rep = tuner.tune_trace(trace, backend=backend)
    fwd, bwd = rep.phase_profiles["fwd"], rep.phase_profiles["bwd"]
    ag_cells = [c for c in trace.cells("fwd") if c.op == "allgather"]
    rs_cells = [c for c in trace.cells("bwd") if c.op == "reducescatter"]
    for c in ag_cells:
        assert fwd.lookup("allgather", c.p, c.nbytes) == "allgather_as_ring"
    for c in rs_cells:
        assert bwd.lookup("reducescatter", c.p, c.nbytes) == \
            "rsb_as_reduce_scatter"

    # 3. re-run the SAME model step under the phase-split stores: api must
    #    honor the phase tag at dispatch
    ctx2 = _lm_step_ctx(phase_profiles=rep.phase_profiles)
    fwd_ag = {impl for op, _, _, impl, ph in ctx2.record
              if op == "allgather" and ph == "fwd"}
    bwd_rs = {impl for op, _, _, impl, ph in ctx2.record
              if op == "reducescatter" and ph == "bwd"}
    assert fwd_ag == {"allgather_as_ring"}
    assert bwd_rs == {"rsb_as_reduce_scatter"}
    assert fwd_ag != bwd_rs


# ---------------------------------------------------------------------------
# serve builders: phase tagging + profile-dir loading on a 1-device mesh
# ---------------------------------------------------------------------------


def test_serve_decode_builder_records_decode_phase(tmp_path, monkeypatch):
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import build_decode
    from repro.launch.shapes import ShapeCell
    from repro.models import lm as _lm
    from repro.models.params import init_tree

    # a tuned store on disk (wrong axis size on purpose: exercises the
    # loading path without forcing p=1 mock-ups)
    ProfileStore([Profile(op="allreduce", axis_size=16,
                          ranges=[Range(1, 10 ** 6,
                                        "allreduce_as_doubling")])
                  ]).save(tmp_path / "decode")
    monkeypatch.setenv("PGTUNE_PROFILE_DIR", str(tmp_path))

    cfg = get_config("gemma3-1b").smoke()
    cell = ShapeCell("decode_tiny", 32, 2, "decode", n_micro=1)
    mesh = make_host_mesh((1, 1), ("data", "model"))
    record = []
    step, (p_sds, t_sds, c_sds, i_sds) = build_decode(
        cfg, mesh, cell, record=record)

    params = init_tree(_lm.model_specs(cfg, tp=1), jax.random.key(0))
    caches = jax.jit(lambda: _lm.init_caches(cfg, 2, 32))()
    tok = jnp.zeros((2, 1), jnp.int32)
    lg, _ = step(params, tok, caches, jnp.int32(3))
    assert np.asarray(lg).shape[0] == 2
    assert record, "decode step recorded no dispatches"
    assert {ph for *_, ph in record} == {"decode"}


def test_serve_builder_record_only_inherits_ambient_context(monkeypatch):
    """A record-only builder must not shadow a caller-managed api.tuned:
    its inner context inherits the ambient profiles/force."""
    monkeypatch.delenv("PGTUNE_PROFILE_DIR", raising=False)
    from repro.launch.serve import _serving_ctx

    x = jnp.ones((P, 4, 2), jnp.float32)
    sink = []

    def step(a):
        with _serving_ctx("decode", None, None, None, sink):
            return api.allreduce(a, "x")

    with api.tuned(force={"allreduce": "allreduce_as_doubling"}) as outer:
        jax.vmap(step, axis_name="x")(x)
    assert [tuple(r) for r in sink] == \
        [("allreduce", P, 32, "allreduce_as_doubling", "decode")]
    assert outer.record == []          # sink swapped, tuning inherited


# ---------------------------------------------------------------------------
# v1 sunset step: deprecation warnings + mixed-schema shard merging
# ---------------------------------------------------------------------------

V1_LINE = ('{"op": "allreduce", "p": 4, "nbytes": 512, "phase": "bwd", '
           '"impl": "default", "count": 3}\n')


def test_v1_trace_load_warns_naming_the_file(tmp_path):
    """Satellite: loading a v1 trace file now emits a DeprecationWarning
    that names the offending file (the sunset breadcrumb)."""
    f = tmp_path / "old_shard.jsonl"
    f.write_text(V1_LINE)
    with pytest.warns(DeprecationWarning, match="old_shard.jsonl"):
        t = Trace.load(f)
    assert t.total() == 3


def test_v2_trace_load_does_not_warn(tmp_path):
    f = tmp_path / "new_shard.jsonl"
    Trace([TraceEntry.of("allreduce", 4, 512, "bwd")]).save(f)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Trace.load(f)


def test_v1_line_with_v_in_string_value_still_warns():
    """Satellite regression (false negative): a v1 line whose IMPL string
    happens to be the single character "v" satisfied the old substring
    test ('\"v\"' in line) and was silently treated as v2.  Detection must
    key on the decoded object's keys, not the raw text."""
    sneaky_v1 = ('{"op": "allreduce", "p": 4, "nbytes": 512, '
                 '"phase": "bwd", "impl": "v", "count": 3}\n')
    with pytest.warns(DeprecationWarning, match="schema-v1"):
        t = Trace.from_jsonl(sneaky_v1)
    assert t.total() == 3
    assert t.entries[0].impl == "v"


def test_v2_line_with_v_valued_strings_parses_cleanly():
    """The complementary shape: a REAL v2 line carrying "v" inside string
    values must parse without any deprecation path firing and keep its
    recorded geometry."""
    e = TraceEntry.of("allgather_matmul", 4, 2048, "fwd", impl="v",
                      count=2, mm_k=64, mm_m=128, mm_n=32,
                      mm_role="gather")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        back = Trace.from_jsonl(e.to_json() + "\n")
    assert back.entries[0] == e
    assert back.entries[0].cell.mm_k == 64


def test_v1_profile_file_load_warns_naming_schema(tmp_path):
    """A .pgtune file without the 'pgtune profile v2' header is schema v1:
    ProfileStore.load warns (and still serves it)."""
    from repro.core.profiles import Profile, ProfileStore, Range
    d = tmp_path
    (d / "allreduce_p4.pgtune").write_text(
        "# pgtune profile\nMPI_Allreduce\n4 # nb. of. processes\n"
        "1 # nb. of mock-up impl.\n2 allreduce_as_doubling\n"
        "1 # nb. of ranges\n1 4096 2\n")
    with pytest.warns(DeprecationWarning, match="allreduce_p4.pgtune"):
        store = ProfileStore.load(d)
    assert store.lookup("allreduce", 4, 64) == "allreduce_as_doubling"
    # files re-saved by the current code carry the v2 header: no warning
    store2 = ProfileStore([Profile(op="allreduce", axis_size=4,
                                   ranges=[Range(1, 9, "allreduce_as_doubling")])])
    d2 = tmp_path / "v2"
    store2.save(d2, fmt="text")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ProfileStore.load(d2)


def test_merge_mixed_v1_v2_server_shards_roundtrip(tmp_path):
    """Satellite: cross-server shard merging with MIXED schemas — one v1
    shard (defaulted geometry), one v2 shard (full 1-D + 2-D geometry
    cells) — must aggregate cell-wise, and the merged trace must be stable
    under a v2 save/load round-trip (the migration path)."""
    v1 = tmp_path / "server_a.jsonl"
    v1.write_text(V1_LINE + '{"op": "allgather_matmul", "p": 4, '
                            '"nbytes": 2048, "phase": "fwd", '
                            '"impl": "default", "count": 2}\n')
    v2 = tmp_path / "server_b.jsonl"
    Trace([
        TraceEntry.of("allreduce", 4, 512, "bwd", count=5),
        TraceEntry.of("allgather_matmul", 4, 2048, "fwd", count=1,
                      mm_k=64, mm_m=128, mm_n=32, mm_role="gather"),
        TraceEntry.of("matmul_reducescatter_2d", 2, 4096, "fwd", count=4,
                      mm_k=64, mm_m=128, mm_n=32, mm_role="2d", p2=2),
    ]).save(v2)
    with pytest.warns(DeprecationWarning, match="server_a.jsonl"):
        ta = Trace.load(v1)
    tb = Trace.load(v2)
    merged = ta.merge(tb)
    # the v1 allreduce cell and the v2 one share geometry -> one cell
    assert merged.cells()[OpCell("allreduce", 4, 512)] == 8
    # the v1 geometry-less agmm cell stays DISTINCT from the v2 geometry
    # cell (different communication problems)
    agmm = [c for c in merged.cells() if c.op == "allgather_matmul"]
    assert len(agmm) == 2
    cell2d = [c for c in merged.cells()
              if c.op == "matmul_reducescatter_2d"][0]
    assert cell2d.p2 == 2 and cell2d.world() == 4
    out = tmp_path / "merged.jsonl"
    merged.save(out)
    assert '"v": 2' in out.read_text()
    assert Trace.load(out) == merged        # v2 round-trip is identity


def test_trace_2d_cell_jsonl_carries_p2():
    e = TraceEntry.of("matmul_reducescatter_2d", 4, 1 << 20, "bwd",
                      "fused_ring2d", 2, mm_k=256, mm_m=512, mm_n=128,
                      mm_role="2dT", p2=8)
    line = e.to_json()
    assert '"p2": 8' in line and '"role": "2dT"' in line
    back = TraceEntry.from_json(line)
    assert back == e and back.cell.world() == 32
