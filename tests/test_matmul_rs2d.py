"""The weight-stationary 2-D collective matmul: ``matmul_reducescatter_2d``
end-to-end.

Nested-ring kernel (fwd + transpose) vs the dense oracle and the unfused
composition over a REAL two-axis (vmap) mesh, interpret-mode Pallas
blocks, the paired custom VJP (dx via allgather_matmul, dw via the fused
2-D transpose schedule), the rewired ``row_matmul(fsdp_dim=1)`` site
bit-exact vs the legacy ``tp_allreduce(fsdp_matmul(...))`` composition,
and the tuner flipping ``fused_ring2d`` on modeled must-win cells.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, costmodel as cm, tuner
from repro.core import collectives as C
from repro.core.cell import OpCell
from repro.core.profiles import ProfileStore
from repro.core.trace import Trace, TraceEntry
from repro.dist import ops
from repro.kernels.collective_matmul import (
    ring_matmul_reducescatter_2d, ring_matmul_reducescatter_2d_t)

MESHES = ((2, 2), (2, 3), (4, 2))


@pytest.fixture()
def rng():
    """Module-local PRNG: shadows the session-scoped fixture so this new
    file does not shift the shared draw sequence of data-dependent tests
    that run after it (e.g. the MoE local-capacity divergence batch)."""
    return np.random.default_rng(20170701)


def _int_cot(y):
    """Integer-valued cotangent: keeps every sum exactly representable so
    reduction ORDER cannot change bits — the bit-exactness instrument."""
    return jnp.round(
        jnp.cos(jnp.arange(y.size, dtype=jnp.float32)).reshape(y.shape) * 4)


def _shard_fwd(rng, d, q, T, kl, ml, *, integer=False):
    """(x_sh [d,q,T,kl], w_sh [d,q,kl,ml], X, W): the row_matmul layout —
    model rank j holds x's j-th K-slice (replicated over data) and the
    (j K-rows, i col-block) weight shard."""
    def draw(shape):
        a = rng.normal(size=shape)
        return (np.round(a * 2) if integer else a).astype(np.float32)
    X = draw((T, q * kl))
    W = draw((q * kl, d * ml))
    x_sh = np.stack([np.stack([X[:, j * kl:(j + 1) * kl] for j in range(q)])
                     for i in range(d)])
    w_sh = np.stack([np.stack([W[j * kl:(j + 1) * kl, i * ml:(i + 1) * ml]
                               for j in range(q)]) for i in range(d)])
    return jnp.asarray(x_sh), jnp.asarray(w_sh), X, W


def _vmap2(f, outer="ag", inner="rs"):
    return jax.vmap(jax.vmap(f, axis_name=inner), axis_name=outer)


# ---------------------------------------------------------------------------
# the nested-ring kernel vs the dense oracle (two-axis vmap mesh)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,q", MESHES)
@pytest.mark.parametrize("dtype,atol", [(np.float32, 1e-4),
                                        (np.float16, 2e-2)])
def test_ring_2d_matches_oracle(rng, d, q, dtype, atol):
    T, kl, ml = 2 * q, 3, 4
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml)
    x_sh, w_sh = x_sh.astype(dtype), w_sh.astype(dtype)
    got = _vmap2(lambda a, b: ring_matmul_reducescatter_2d(
        a, b, "rs", "ag"))(x_sh, w_sh)
    want = X.astype(np.float32) @ W.astype(np.float32)
    tl = T // q
    for i in range(d):
        for j in range(q):
            np.testing.assert_allclose(
                np.asarray(got, np.float32)[i, j],
                want[j * tl:(j + 1) * tl], atol=atol)


def test_ring_2d_returns_gathered(rng):
    d, q, T, kl, ml = 2, 2, 4, 3, 5
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml)
    _, gath = _vmap2(lambda a, b: ring_matmul_reducescatter_2d(
        a, b, "rs", "ag", return_gathered=True))(x_sh, w_sh)
    for j in range(q):
        np.testing.assert_allclose(np.asarray(gath)[0, j],
                                   W[j * kl:(j + 1) * kl], atol=1e-6)


def test_ring_2d_pallas_interpret_blocks(rng):
    """The per-chunk matmuls of the nested ring run as interpret-mode
    Pallas block kernels (mm='pallas') — same numbers as the jnp path."""
    d, q, T, kl, ml = 2, 2, 4, 3, 4
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml)
    ref = _vmap2(lambda a, b: ring_matmul_reducescatter_2d(
        a, b, "rs", "ag", mm="jnp"))(x_sh, w_sh)
    got = _vmap2(lambda a, b: ring_matmul_reducescatter_2d(
        a, b, "rs", "ag", mm="pallas"))(x_sh, w_sh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    gt = _vmap2(lambda g, b: ring_matmul_reducescatter_2d_t(
        g, b, "rs", "ag", mm="pallas"), outer="rs", inner="ag")(
        *_xpose_operands(rng, 2, 2)[0:2])
    assert np.isfinite(np.asarray(gt)).all()


def _xpose_operands(rng, d, q, T=None, kl=3, M=None):
    T = T or 2 * q
    M = M or 2 * d
    tl = T // q
    G = rng.normal(size=(T, M)).astype(np.float32)
    Xs = [rng.normal(size=(T, kl)).astype(np.float32) for _ in range(d)]
    g_sh = jnp.asarray(np.stack([np.stack([G[j * tl:(j + 1) * tl]
                                           for j in range(q)])
                                 for i in range(d)]))
    x_sh = jnp.asarray(np.stack([np.broadcast_to(Xs[i], (q, T, kl)).copy()
                                 for i in range(d)]))
    want = sum(G.T @ Xs[i] for i in range(d))       # [M, kl]
    return g_sh, x_sh, want, M // d


@pytest.mark.parametrize("d,q", MESHES)
def test_ring_2d_transpose_matches_oracle(rng, d, q):
    """The dw schedule: gather axis CONTRACTED, scatter axis summing the
    per-data-rank contributions (the FSDP gradient sum)."""
    g_sh, x_sh, want, ml = _xpose_operands(rng, d, q)
    got = np.asarray(jax.vmap(jax.vmap(
        lambda g, b: ring_matmul_reducescatter_2d_t(g, b, "rs", "ag"),
        axis_name="ag"), axis_name="rs")(g_sh, x_sh))
    for i in range(d):
        for j in range(q):
            np.testing.assert_allclose(got[i, j],
                                       want[i * ml:(i + 1) * ml], atol=1e-4)


def test_registry_impls_semantics(rng):
    """Every registered impl (both directions) against the dense oracle —
    the streamed operand is the FIRST argument of the impl fn."""
    d, q, T, kl, ml = 2, 2, 4, 3, 4
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml)
    want = X @ W
    tl = T // q
    for name in C.impl_names("matmul_reducescatter_2d"):
        fn = C.REGISTRY["matmul_reducescatter_2d"][name].fn
        got = np.asarray(_vmap2(
            lambda wb, xb, fn=fn: fn(wb, "ag", x=xb, rs_axis="rs"))(
            w_sh, x_sh))
        for i in range(d):
            for j in range(q):
                np.testing.assert_allclose(got[i, j],
                                           want[j * tl:(j + 1) * tl],
                                           atol=1e-4, err_msg=name)
    g_sh, xg_sh, wantT, mlT = _xpose_operands(rng, d, q)
    for name in C.impl_names("matmul_reducescatter_2d"):
        fn = C.REGISTRY["matmul_reducescatter_2d"][name].fn
        got = np.asarray(jax.vmap(jax.vmap(
            lambda gb, xb, fn=fn: fn(gb, "ag", x=xb, rs_axis="rs",
                                     xpose=True),
            axis_name="ag"), axis_name="rs")(g_sh, xg_sh))
        for i in range(d):
            for j in range(q):
                np.testing.assert_allclose(got[i, j],
                                           wantT[i * mlT:(i + 1) * mlT],
                                           atol=1e-4, err_msg=name)


# ---------------------------------------------------------------------------
# dist op: the paired VJP (sharded cotangent), fused vs default
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["default", "fused_ring2d"])
def test_mm2d_dist_op_grads_fused_vs_default(rng, impl):
    d, q, T, kl, ml = 2, 2, 4, 3, 4
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml, integer=True)

    def op2d(a, b):
        return ops.matmul_reducescatter_2d(a, b, "model", "data")

    def inner(a, b):
        y = op2d(a, b)
        g = jax.grad(lambda aa, bb: jnp.sum(op2d(aa, bb) * _int_cot(y)),
                     argnums=(0, 1))(a, b)
        return y, g

    def run(force):
        with api.tuned(force=force) as ctx:
            y, g = jax.vmap(jax.vmap(inner, axis_name="model"),
                            axis_name="data")(x_sh, w_sh)
        return np.asarray(y), np.asarray(g[0]), np.asarray(g[1]), ctx

    yd, xd, wd, _ = run({})
    yf, xf, wf, ctx = run({"matmul_reducescatter_2d": impl,
                           "allgather_matmul":
                               "fused_ring" if impl != "default"
                               else "default"})
    # integer-valued operands: every schedule is bit-exact
    np.testing.assert_array_equal(yd, yf)
    np.testing.assert_array_equal(xd, xf)
    np.testing.assert_array_equal(wd, wf)
    recs = {(r.op, r.cell.mm_role, r.phase) for r in ctx.record}
    assert ("matmul_reducescatter_2d", "2d", "fwd") in recs
    assert ("matmul_reducescatter_2d", "2dT", "bwd") in recs   # fused dw
    assert ("allgather_matmul", "gather", "bwd") in recs       # fused dx
    cell = next(r.cell for r in ctx.record if r.cell.mm_role == "2d")
    assert cell.p == d and cell.p2 == q and cell.world() == d * q


def test_mm2d_grads_match_unfused_autodiff(rng):
    """The custom VJP vs jax's own autodiff THROUGH the unfused default
    composition (all_gather + matmul + psum_scatter) — same math."""
    d, q, T, kl, ml = 2, 3, 6, 2, 3
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml)

    fn = C.REGISTRY["matmul_reducescatter_2d"]["default"].fn

    def raw(a, b):       # plain composition, default autodiff
        return fn(b, "data", x=a, rs_axis="model")

    def op2d(a, b):
        return ops.matmul_reducescatter_2d(a, b, "model", "data")

    def grads(f):
        def inner(a, b):
            y = f(a, b)
            return jax.grad(lambda aa, bb: jnp.sum(f(aa, bb) * _int_cot(y)),
                            argnums=(0, 1))(a, b)
        return jax.vmap(jax.vmap(inner, axis_name="model"),
                        axis_name="data")(x_sh, w_sh)

    gx, gw = grads(op2d)
    rx, rw = grads(raw)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-4)


# ---------------------------------------------------------------------------
# the rewired row_matmul(fsdp_dim=1) site
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["default", "fused_ring2d"])
@pytest.mark.parametrize("d,q", MESHES)
def test_row_matmul_fsdp1_bit_exact_vs_legacy(rng, d, q, impl):
    """Acceptance: row_matmul(fsdp_dim=1) through the 2-D op — under BOTH
    default dispatch and fused_ring2d — must match the legacy
    tp_allreduce(fsdp_matmul(...)) composition BIT-FOR-BIT in fwd and
    grads (integer-valued operands make every reduction order exact)."""
    T, kl, ml = 2 * q, 3, 4
    x_sh, w_sh, X, W = _shard_fwd(rng, d, q, T, kl, ml, integer=True)

    new = lambda a, b: ops.row_matmul(a, b, "model", fsdp_dim=1)
    leg = lambda a, b: ops.tp_allreduce(
        ops.fsdp_matmul(a, b, "data"), "model")

    def run(fun, force):
        def inner(a, b):
            y = fun(a, b)
            g = jax.grad(lambda aa, bb: jnp.sum(fun(aa, bb) * _int_cot(y)),
                         argnums=(0, 1))(a, b)
            return y, g
        with api.tuned(force=force) as ctx:
            y, g = jax.vmap(jax.vmap(inner, axis_name="model"),
                            axis_name="data")(x_sh, w_sh)
        return np.asarray(y), np.asarray(g[0]), np.asarray(g[1]), ctx

    y0, gx0, gw0, ctx = run(new, {"matmul_reducescatter_2d": impl})
    yl, gxl, gwl, _ = run(leg, {})
    np.testing.assert_array_equal(y0, yl)
    np.testing.assert_array_equal(gx0, gxl)
    np.testing.assert_array_equal(gw0, gwl)
    # oracle + the recorded mix: 2-D fwd cell, replicating AG, fused 2-D
    # transpose dw in the bwd phase
    np.testing.assert_allclose(y0[0, 0], X @ W, atol=1e-4)
    recs = {(r.op, r.cell.mm_role, r.phase) for r in ctx.record}
    assert ("matmul_reducescatter_2d", "2d", "fwd") in recs
    assert ("allgather", "", "fwd") in recs
    assert ("matmul_reducescatter_2d", "2dT", "bwd") in recs


def test_row_matmul_fsdp1_records_no_monolithic_allreduce(rng):
    """ROADMAP motivation: the hottest serving path used to pay a
    model-axis allreduce no guideline could price against a fused
    alternative — the rewired site must not emit one."""
    d, q = 2, 2
    x_sh, w_sh, _, _ = _shard_fwd(rng, d, q, 2 * q, 3, 4)
    with api.tuned() as ctx:
        jax.vmap(jax.vmap(
            lambda a, b: ops.row_matmul(a, b, "model", fsdp_dim=1),
            axis_name="model"), axis_name="data")(x_sh, w_sh)
    assert not any(r.op == "allreduce" for r in ctx.record), \
        [tuple(r) for r in ctx.record]
    assert any(r.op == "matmul_reducescatter_2d" for r in ctx.record)


def test_row_matmul_fsdp1_nondivisible_rows_falls_back(rng):
    """T=3 rows on a model axis of 2: the 2-D op needs divisible rows, so
    the site must fall back to the legacy 1-D composition — same values."""
    d, q, T, kl, ml = 2, 2, 3, 3, 4
    X = rng.normal(size=(T, q * kl)).astype(np.float32)
    W = rng.normal(size=(q * kl, d * ml)).astype(np.float32)
    x_sh = jnp.asarray(np.stack([np.stack(
        [X[:, j * kl:(j + 1) * kl] for j in range(q)]) for i in range(d)]))
    w_sh = jnp.asarray(np.stack([np.stack(
        [W[j * kl:(j + 1) * kl, i * ml:(i + 1) * ml] for j in range(q)])
        for i in range(d)]))
    with api.tuned() as ctx:
        y = jax.vmap(jax.vmap(
            lambda a, b: ops.row_matmul(a, b, "model", fsdp_dim=1),
            axis_name="model"), axis_name="data")(x_sh, w_sh)
    np.testing.assert_allclose(np.asarray(y)[0, 0], X @ W, atol=1e-4)
    assert not any(r.op == "matmul_reducescatter_2d" for r in ctx.record)
    assert any(r.op == "allreduce" for r in ctx.record)   # legacy AR path


def test_mm2d_no_axis_degrades(rng):
    x = jnp.asarray(rng.normal(size=(4, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(ops.matmul_reducescatter_2d(x, w)),
        np.asarray(jnp.matmul(x, w)))
    # only the rs axis bound: 1-D matmul_reducescatter semantics
    got = jax.vmap(lambda a: ops.matmul_reducescatter_2d(
        jnp.broadcast_to(x, x.shape), w, "model", "data"),
        axis_name="model")(jnp.zeros((2, 1)))
    assert got.shape == (2, 2, 3)


# ---------------------------------------------------------------------------
# tuner: must-win 2-D cells (the EXT guideline per cell)
# ---------------------------------------------------------------------------


def test_tuner_selects_fused2d_large_default_small():
    rep = tuner.tune(ops=["matmul_reducescatter_2d"],
                     sizes=(64, 1024, 1_048_576, 16_777_216),
                     axis_size=8, backend=tuner.CostModelBackend(cm.V5E_ICI))
    prof = rep.profiles
    assert prof.lookup("matmul_reducescatter_2d", 8, 16_777_216) == \
        "fused_ring2d"
    assert prof.lookup("matmul_reducescatter_2d", 8, 64) is None


def test_latency_cell_prices_nested_overlap():
    """The nested law max(outer_comm, per-step max(inner_comm, compute)):
    a compute-heavy 2-D cell must flip to fused_ring2d, a sliver GEMM on
    the same payload must keep the default (overhead on BOTH axes)."""
    big = OpCell("matmul_reducescatter_2d", 8, 4_194_304, "float32",
                 mm_k=1024, mm_m=8192, mm_n=8 * 1024, mm_role="2d", p2=8)
    assert cm.latency_cell(big, "fused_ring2d", cm.V5E_ICI) < \
        cm.latency_cell(big, "default", cm.V5E_ICI) * 0.9
    sliver = OpCell("matmul_reducescatter_2d", 8, 4096, "float32",
                    mm_k=16, mm_m=8, mm_n=8 * 8, mm_role="2d", p2=8)
    assert not (cm.latency_cell(sliver, "fused_ring2d", cm.V5E_ICI)
                < cm.latency_cell(sliver, "default", cm.V5E_ICI) * 0.9)


def test_tune_trace_emits_2d_geometry_profiles():
    """Trace-replay tuning with recorded 2-D cells (cost-model backend):
    the emitted profile is keyed on the 2-D geometry (incl. p2) and drives
    dispatch through lookup_cell."""
    big = OpCell("matmul_reducescatter_2d", 8, 4_194_304, "float32",
                 mm_k=1024, mm_m=8192, mm_n=8 * 1024, mm_role="2d", p2=8)
    small = OpCell("matmul_reducescatter_2d", 8, 4096, "float32",
                   mm_k=16, mm_m=8, mm_n=8 * 8, mm_role="2d", p2=8)
    t = Trace([TraceEntry(big, "fwd", "default", 10),
               TraceEntry(small, "fwd", "default", 10)])
    rep = tuner.tune_trace(t, backend=tuner.CostModelBackend(cm.V5E_ICI))
    store = rep.store("fwd")
    assert store is not None
    assert store.lookup_cell(big) == "fused_ring2d"
    # the sliver cell earned NO profile of its own (default kept); any hit
    # it gets is the nearest-geometry fallback from the big cell's profile
    assert store.get("matmul_reducescatter_2d", 8, small.geom()) is None
    assert store.get("matmul_reducescatter_2d", 8, big.geom()) is not None
    # nearest-geometry fallback: an unseen near-big shape resolves to the
    # tuned 2-D profile; a different p2 must NOT
    near = OpCell("matmul_reducescatter_2d", 8, 4_194_304, "float32",
                  mm_k=1024, mm_m=16384, mm_n=8 * 1024, mm_role="2d", p2=8)
    assert store.lookup_cell(near) == "fused_ring2d"
    other_p2 = OpCell("matmul_reducescatter_2d", 8, 4_194_304, "float32",
                      mm_k=1024, mm_m=8192, mm_n=8 * 1024, mm_role="2d",
                      p2=4)
    assert store.lookup_cell(other_p2) is None
    assert rep.est_tuned_s["fwd"] < rep.est_default_s["fwd"]


def test_measured_backend_skips_2d_world_mismatch():
    """A 2-D cell whose p*p2 doesn't match the host device count is
    note-skipped by the trace tuner, not crashed on."""
    from repro.core import measure
    cell = OpCell("matmul_reducescatter_2d", 8, 4096, "float32",
                  mm_k=16, mm_m=8, mm_n=8 * 8, mm_role="2d", p2=8)
    assert cell.world() == 64 != measure.axis_size()
    t = Trace([TraceEntry(cell, "fwd", "default", 1)])
    rep = tuner.tune_trace(t, backend=tuner.MeasuredBackend(K=2,
                                                            max_nrep=3))
    assert any("host axis size" in n for n in rep.notes)
    assert rep.measurements == []


def test_dispatch_profile_routes_2d_cell(rng):
    """api.tuned(profiles=...) resolves a live 2-D dispatch through its
    geometry profile."""
    from repro.core.profiles import Profile, Range
    d, q, T, kl, ml = 2, 2, 4, 3, 4
    x_sh, w_sh, _, _ = _shard_fwd(rng, d, q, T, kl, ml)
    geom = OpCell("matmul_reducescatter_2d", d, kl * q * ml * 4, "float32",
                  mm_k=kl, mm_m=T, mm_n=d * ml, mm_role="2d",
                  p2=q).geom()
    store = ProfileStore([Profile(op="matmul_reducescatter_2d",
                                  axis_size=d,
                                  ranges=[Range(1, 10 ** 6, "fused_ring2d")],
                                  geom=geom)])
    with api.tuned(profiles=store) as ctx:
        _vmap2(lambda a, b: api.matmul_reducescatter_2d(
            a, b, "rs", "ag"))(x_sh, w_sh)
    assert [r.impl for r in ctx.record] == ["fused_ring2d"]
    assert ctx.record[0].cell.geom() == geom


def test_mm2d_standalone_ragged_rows_clear_error(rng):
    """The standalone dist op refuses ragged rows with an actionable error
    (the reduce-scatter contract has no well-defined output) instead of
    the raw psum_scatter divisibility crash."""
    d, q = 2, 2
    x_sh = jnp.asarray(rng.normal(size=(d, q, 3, 4)).astype(np.float32))
    w_sh = jnp.asarray(rng.normal(size=(d, q, 4, 2)).astype(np.float32))
    with pytest.raises(ValueError, match="row_matmul"):
        jax.vmap(jax.vmap(
            lambda a, b: ops.matmul_reducescatter_2d(a, b, "model", "data"),
            axis_name="model"), axis_name="data")(x_sh, w_sh)
