"""ft/restart.py + ft/watchdog.py: the elastic-restart path and the
step watchdog, previously only exercised by examples/elastic_restart.py.

The restart contract under test: a run that crashes (injected faults)
and resumes from checkpoints must end in the SAME final state as an
uninterrupted run — determinism comes from keying the step computation
by step number, so a resumed run replays the exact sequence.
"""
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.ft import Heartbeats, StepWatchdog, run_with_restarts


def _init_state():
    return {"w": jnp.zeros((4,), jnp.float32),
            "step_sum": jnp.zeros((), jnp.float32)}


def _step(state, i):
    # keyed by step number: replayable after restore
    g = jnp.full((4,), float(i + 1), jnp.float32)
    return {"w": state["w"] + 0.1 * g,
            "step_sum": state["step_sum"] + float(i)}


# ---------------------------------------------------------------------------
# run_with_restarts
# ---------------------------------------------------------------------------


def test_restart_resumes_to_identical_state(tmp_path):
    clean, clean_stats = run_with_restarts(
        _init_state, _step, n_steps=20, ckpt_dir=tmp_path / "clean",
        ckpt_every=4)
    assert clean_stats["restarts"] == 0
    assert clean_stats["completed"] == 20

    crashes = {5: True, 13: True}    # consumed on first hit

    def faulty_step(state, i):
        if crashes.pop(i, None):
            raise RuntimeError(f"injected fault at step {i}")
        return _step(state, i)

    faulted, stats = run_with_restarts(
        _init_state, faulty_step, n_steps=20,
        ckpt_dir=tmp_path / "faulty", ckpt_every=4)
    assert stats["restarts"] == 2
    # resumed from the newest checkpoint BEFORE each crash site
    assert stats["resumed_from"] == [4, 12]
    # the recovery replayed steps, so completed > 20 — but the final
    # state is bit-identical to the uninterrupted run
    assert stats["completed"] > 20
    np.testing.assert_array_equal(np.asarray(faulted["w"]),
                                  np.asarray(clean["w"]))
    np.testing.assert_array_equal(np.asarray(faulted["step_sum"]),
                                  np.asarray(clean["step_sum"]))


def test_restart_cold_resume_from_existing_checkpoints(tmp_path):
    # first run writes checkpoints; a brand-new invocation (fresh
    # process after a crash) picks up from the newest one
    run_with_restarts(_init_state, _step, n_steps=10, ckpt_dir=tmp_path,
                      ckpt_every=5)
    state, stats = run_with_restarts(_init_state, _step, n_steps=20,
                                     ckpt_dir=tmp_path, ckpt_every=5)
    assert stats["resumed_from"] == [10]
    assert stats["completed"] == 10    # only the remaining steps ran
    clean, _ = run_with_restarts(_init_state, _step, n_steps=20,
                                 ckpt_dir=tmp_path / "clean", ckpt_every=5)
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.asarray(clean["w"]))


def test_restart_gives_up_past_max_restarts(tmp_path):
    def always_fails(state, i):
        raise RuntimeError("permanent fault")

    with pytest.raises(RuntimeError, match="permanent fault"):
        run_with_restarts(_init_state, always_fails, n_steps=5,
                          ckpt_dir=tmp_path, max_restarts=3)


# ---------------------------------------------------------------------------
# StepWatchdog
# ---------------------------------------------------------------------------


def test_watchdog_flags_straggler_step():
    wd = StepWatchdog(ratio=3.0, window=8)
    real_clock = [0.0]
    # drive perf_counter-free: feed times via start/end with sleeps kept
    # tiny — 6 fast steps to warm the median, then one 10x-slower step
    for _ in range(6):
        wd.start_step()
        time.sleep(0.002)
        assert wd.end_step() is False
    wd.start_step()
    time.sleep(0.05)
    assert wd.end_step() is True
    assert wd.straggler_steps == [6]
    assert real_clock == [0.0]      # no hidden global state touched


def test_watchdog_hang_timeout_fires():
    fired = threading.Event()
    wd = StepWatchdog(hang_timeout=0.05, on_hang=fired.set)
    wd.start_step()
    # never call end_step before the timeout: the step "hung"
    assert fired.wait(timeout=2.0), "hang timer never fired"
    wd.end_step()


def test_watchdog_completed_step_cancels_hang_timer():
    fired = threading.Event()
    wd = StepWatchdog(hang_timeout=0.1, on_hang=fired.set)
    wd.start_step()
    wd.end_step()
    time.sleep(0.25)
    assert not fired.is_set()


# ---------------------------------------------------------------------------
# Heartbeats (fleet liveness; deterministic via injected clock)
# ---------------------------------------------------------------------------


def test_heartbeats_death_by_silence():
    now = [0.0]
    hb = Heartbeats(timeout=10.0, clock=lambda: now[0])
    hb.beat("a", epoch=1)
    hb.beat("b", epoch=1)
    assert hb.dead() == [] and hb.alive() == ["a", "b"]
    now[0] = 8.0
    hb.beat("b", epoch=2)
    now[0] = 12.0                   # a silent for 12s, b for 4s
    assert hb.dead() == ["a"]
    assert hb.alive() == ["b"]
    assert hb.epoch_of("b") == 2 and hb.epoch_of("a") == 1
    assert hb.epoch_of("never-seen") is None
