"""Property-based guideline harness (hypothesis; deterministic stub in CI).

Randomly generated ``OpCell``s (op, p/p2, nbytes, dtype, GEMM dims, role)
probe the invariants the guideline machinery promises — the checks the
paper applies to hand-picked cells, here swept across the cell space:

1. nearest-geometry profile lookup only ever resolves to a profile of the
   SAME role + dtype (+ inner axis for 2-D cells);
2. ``costmodel.latency_cell`` is monotone in nbytes for fixed geometry;
3. fused mock-ups never beat their own EXT decomposition's floor in the
   cost model — neither below the pure-compute term nor below the ring's
   communication-only term (and the unfused default never below either);
4. profile text/JSON round-trips are identities (incl. 2-D ``#@geom``
   headers with the trailing p2 token);
5. ``Trace.merge`` conserves dispatch weight under ANY partition of a
   fleet trace into per-server shards — even when one shard round-trips
   through schema-v1 JSONL (the migration path for old recorders).

Each invariant must see >= 8 generated cells per run (asserted at the end
— the deterministic stub makes the draw sequence reproducible).
"""
import json
import math
import warnings

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import costmodel as cm
from repro.core.cell import Geom, OpCell
from repro.core.collectives import REGISTRY
from repro.core.profiles import Profile, ProfileStore, Range
from repro.core.trace import Trace, TraceEntry

TOPO = cm.V5E_ICI
DTYPES = ("float32", "bfloat16", "float16")
ROLE_OF_OP = {"allgather_matmul": ("gather",),
              "matmul_reducescatter": ("scatter",),
              "matmul_accumulate": ("contract",),
              "matmul_reducescatter_2d": ("2d", "2dT")}
FUSED_OPS = tuple(ROLE_OF_OP)

_SEEN = {"nearest": 0, "monotone": 0, "floor": 0, "roundtrip": 0,
         "merge": 0, "tier": 0}


def _mk_cell(op, role_i, p, p2, dt_i, k, m, n, nbytes):
    roles = ROLE_OF_OP[op]
    role = roles[role_i % len(roles)]
    is2d = role in ("2d", "2dT")
    return OpCell(op, p, max(1, nbytes), DTYPES[dt_i % len(DTYPES)],
                  mm_k=k, mm_m=m, mm_n=n, mm_role=role,
                  p2=p2 if is2d else 0)


# ---------------------------------------------------------------------------
# 1. nearest-geometry lookup returns same role + dtype (+ p2)
# ---------------------------------------------------------------------------


def _encoding_store():
    """Profiles whose impl names ENCODE their geometry partition, so any
    lookup_cell hit can be decoded and cross-checked against the query."""
    store = ProfileStore()
    gid = 0
    for op, roles in ROLE_OF_OP.items():
        for role in roles:
            for dt in DTYPES:
                for p2 in ((0,) if role not in ("2d", "2dT") else (2, 4)):
                    for shape in ((64, 128, 32), (512, 4096, 1024)):
                        k, m, n = shape
                        geom = Geom(dt, k, m, n, role, p2)
                        store.add(Profile(
                            op=op, axis_size=4,
                            ranges=[Range(1, 10 ** 9,
                                          f"enc|{role}|{dt}|{p2}|{gid}")],
                            geom=geom))
                        gid += 1
    return store


_STORE = _encoding_store()


@settings(max_examples=24, deadline=None)
@given(st.integers(0, len(FUSED_OPS) - 1), st.integers(0, 3),
       st.integers(0, len(DTYPES) - 1),
       st.integers(1, 3000), st.integers(1, 9000), st.integers(1, 3000),
       st.integers(1, 10 ** 8))
def test_nearest_geometry_lookup_same_role_and_dtype(op_i, role_i, dt_i,
                                                     k, m, n, nbytes):
    op = FUSED_OPS[op_i]
    cell = _mk_cell(op, role_i, 4, 2, dt_i, k, m, n, nbytes)
    hit = _STORE.lookup_cell(cell)
    # the store has profiles for every (role, dtype, p2) partition of this
    # op, so the nearest-geometry fallback must always resolve...
    assert hit is not None and hit.startswith("enc|"), (cell, hit)
    _, role, dt, p2, _ = hit.split("|")
    # ...and NEVER cross a partition boundary
    assert role == cell.mm_role, (cell, hit)
    assert dt == cell.dtype, (cell, hit)
    assert int(p2) == cell.p2, (cell, hit)
    _SEEN["nearest"] += 1


# ---------------------------------------------------------------------------
# 1b. the tier key partitions EVERY lookup path (flat / hierarchical /
#     nearest-geometry fallback) — a profile tuned on one interconnect
#     tier must never answer a cell on another
# ---------------------------------------------------------------------------

HIER_OPS = OpCell.HIER_OPS
FLAT_TIERS = ("", "v5e-dcn", "v5e-ici")
HIER_TIERS = ("", "v5e-dcn/v5e-ici")


def _tier_store():
    """Profiles whose impl names ENCODE their tier key, covering every
    token class: flat untiered, flat on a named tier, hierarchical with
    the inner size folded in, and fused 2-D under two tiers with the SAME
    stored geometry (so an un-pinned nearest-geometry fallback would be
    free to cross tiers)."""
    store = ProfileStore()
    for op in HIER_OPS:
        for tier in FLAT_TIERS:
            store.add(Profile(op=op, axis_size=8,
                              ranges=[Range(1, 10 ** 9, f"tenc|{tier}")],
                              tier=tier))
        for tier in HIER_TIERS:
            for q in (2, 4):
                tok = f"{tier or 'hier'}@q{q}"
                store.add(Profile(op=op, axis_size=8,
                                  ranges=[Range(1, 10 ** 9, f"tenc|{tok}")],
                                  tier=tok))
    for tier in HIER_TIERS:
        store.add(Profile(op="matmul_reducescatter_2d", axis_size=8,
                          ranges=[Range(1, 10 ** 9, f"tenc|{tier}")],
                          geom=Geom("float32", 64, 128, 32, "2d", 4),
                          tier=tier))
    return store


_TIER_STORE = _tier_store()


@settings(max_examples=24, deadline=None)
@given(st.integers(0, len(HIER_OPS) - 1),
       st.integers(0, len(FLAT_TIERS) - 1),
       st.integers(0, len(HIER_TIERS) - 1),
       st.integers(0, 1), st.integers(1, 10 ** 8))
def test_tier_key_partitions_plain_lookups(op_i, ft_i, ht_i, q_i, nbytes):
    op = HIER_OPS[op_i]
    flat = OpCell(op, 8, nbytes, tier=FLAT_TIERS[ft_i])
    hit = _TIER_STORE.lookup_cell(flat)
    assert hit == f"tenc|{flat.profile_tier()}", (flat, hit)
    # the hierarchical sibling of the SAME (op, p, nbytes) resolves to its
    # own tier key — an 8-way flat profile never shadows a 2x4/2x2
    # hierarchical cell, and vice versa
    hcell = OpCell(op, 8, nbytes, p2=(2, 4)[q_i], tier=HIER_TIERS[ht_i])
    hhit = _TIER_STORE.lookup_cell(hcell)
    assert hhit == f"tenc|{hcell.profile_tier()}", (hcell, hhit)
    assert hhit != hit
    _SEEN["tier"] += 1


@settings(max_examples=24, deadline=None)
@given(st.integers(0, len(HIER_TIERS) - 1), st.integers(1, 3000),
       st.integers(2, 9000), st.integers(1, 3000), st.integers(1, 10 ** 8))
def test_tier_key_pins_nearest_geometry_fallback(t_i, k, m, n, nbytes):
    """The stored 2-D geometries are IDENTICAL under both tiers, so a
    random-geometry query exercises the nearest-geometry fallback with a
    cross-tier twin at distance zero — only the tier filter keeps the
    resolution inside the cell's own tier."""
    tier = HIER_TIERS[t_i]
    cell = OpCell("matmul_reducescatter_2d", 8, nbytes, mm_k=k, mm_m=m,
                  mm_n=n, mm_role="2d", p2=4, tier=tier)
    hit = _TIER_STORE.lookup_cell(cell)
    assert hit == f"tenc|{tier}", (cell, hit)
    _SEEN["tier"] += 1


# ---------------------------------------------------------------------------
# 2. latency_cell monotone in nbytes for fixed geometry
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(st.integers(0, len(FUSED_OPS) - 1), st.integers(0, 3),
       st.integers(1, 2), st.integers(1, 1024), st.integers(2, 8192),
       st.integers(1, 1024), st.integers(1, 10 ** 7), st.integers(2, 16))
def test_latency_cell_monotone_in_nbytes(op_i, role_i, logp, k, m, n,
                                         nbytes, factor):
    op = FUSED_OPS[op_i]
    p = 2 ** logp
    small = _mk_cell(op, role_i, p, 2, 0, k, m, n, nbytes)
    big = _mk_cell(op, role_i, p, 2, 0, k, m, n, nbytes * factor)
    for impl in REGISTRY[op]:
        t1 = cm.latency_cell(small, impl, TOPO)
        t2 = cm.latency_cell(big, impl, TOPO)
        assert not math.isnan(t1) and not math.isnan(t2)
        assert t1 <= t2 * (1 + 1e-9), (op, impl, small.nbytes, big.nbytes,
                                       t1, t2)
    _SEEN["monotone"] += 1


# ---------------------------------------------------------------------------
# 3. fused mock-ups never beat their own EXT decomposition's floor
# ---------------------------------------------------------------------------


def _floors(cell, wire_dtype=None):
    """(compute, ring-comm) lower bounds of the cell's EXT decomposition —
    the pure matmul term and the (steps-1) outer-ring transfer term no
    overlap schedule can hide.  A quantized-wire impl legitimately beats
    the full-precision comm floor: its floor scales the travelling bytes
    by ``wire_factor`` (the f32 accumulate γ and the matmul stay full
    width)."""
    t = TOPO
    compute = 2.0 * cell.mm_k * cell.mm_m * cell.mm_n / t.matmul_flops
    B = float(max(cell.nbytes, 1))
    wf = 1.0 if wire_dtype is None else cm.wire_factor(wire_dtype,
                                                       cell.itemsize)
    if cell.mm_role == "scatter":
        bt = float(cell.mm_m * cell.mm_n * cell.itemsize)
        comm = (cell.p - 1) * (t.alpha + bt * wf / cell.p * t.beta
                               + bt / cell.p * t.gamma)
    elif cell.mm_role == "2dT":
        # outer travelling accumulator over the p2 (scatter) axis
        bt = float(cell.mm_m * cell.mm_n * cell.itemsize)
        q = max(cell.p2, 1)
        comm = (q - 1) * (t.alpha + bt / q * (t.beta + t.gamma))
    else:  # gather / contract / 2d: the payload streams (p-1) hops
        comm = (cell.p - 1) * (t.alpha + B * wf * t.beta)
    return compute, comm


@settings(max_examples=24, deadline=None)
@given(st.integers(0, len(FUSED_OPS) - 1), st.integers(0, 3),
       st.integers(1, 3), st.integers(1, 2048), st.integers(2, 8192),
       st.integers(1, 2048), st.integers(1, 10 ** 7))
def test_fused_mockup_never_beats_decomposition_floor(op_i, role_i, logp,
                                                      k, m, n, nbytes):
    op = FUSED_OPS[op_i]
    cell = _mk_cell(op, role_i, 2 ** logp, 2, 0, k, m, n, nbytes)
    eps = 1 + 1e-9
    for impl in REGISTRY[op]:
        compute, comm = _floors(cell, REGISTRY[op][impl].wire_dtype)
        tl = cm.latency_cell(cell, impl, TOPO)
        assert tl * eps >= compute, (op, impl, cell, tl, compute)
        assert tl * eps >= comm, (op, impl, cell, tl, comm)
    _SEEN["floor"] += 1


# ---------------------------------------------------------------------------
# 4. profile text / JSON round-trip identity
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(st.lists(st.integers(1, 10 ** 8), min_size=2, max_size=16,
                unique=True),
       st.integers(0, len(FUSED_OPS) - 1), st.integers(0, 3),
       st.integers(0, len(DTYPES) - 1), st.integers(1, 4096),
       st.integers(1, 4096), st.integers(1, 4096), st.integers(2, 1024),
       st.integers(0, 1))
def test_profile_roundtrip_identity(bounds, op_i, role_i, dt_i, k, m, n,
                                    axis_size, geomless):
    op = FUSED_OPS[op_i]
    roles = ROLE_OF_OP[op]
    role = roles[role_i % len(roles)]
    geom = None if geomless else Geom(
        DTYPES[dt_i % len(DTYPES)], k, m, n, role,
        4 if role in ("2d", "2dT") else 0)
    bounds = sorted(bounds)
    ranges = [Range(bounds[i], bounds[i + 1] - 1,
                    "fused_ring2d" if i % 2 else "default")
              for i in range(0, len(bounds) - 1, 2)]
    if not ranges:
        return
    prof = Profile(op=op, axis_size=axis_size, ranges=ranges, geom=geom)
    t1 = Profile.from_text(prof.to_text())
    assert (t1.op, t1.axis_size, t1.ranges, t1.geom) == \
        (prof.op, prof.axis_size, prof.ranges, prof.geom)
    assert t1.to_text() == prof.to_text()          # fixpoint
    j1 = Profile.from_json(prof.to_json())
    assert (j1.op, j1.axis_size, j1.ranges, j1.geom) == \
        (prof.op, prof.axis_size, prof.ranges, prof.geom)
    _SEEN["roundtrip"] += 1


# ---------------------------------------------------------------------------
# 5. Trace.merge conserves dispatch weight across arbitrary shardings
# ---------------------------------------------------------------------------

PLAIN_OPS = ("allreduce", "allgather", "reducescatter", "alltoall")
PHASES = ("fwd", "prefill", "decode")
IMPLS = ("default", "allreduce_as_doubling")


def _v1_line(entry):
    """Re-encode a geometry-less entry the way a pre-v2 recorder wrote it:
    bare fields, no ``v`` key, no geometry."""
    return json.dumps({"op": entry.op, "p": entry.axis_size,
                       "nbytes": entry.nbytes, "phase": entry.phase,
                       "impl": entry.impl, "count": entry.count})


@settings(max_examples=24, deadline=None)
@given(st.lists(st.tuples(st.integers(0, len(PLAIN_OPS) + len(FUSED_OPS) - 1),
                          st.integers(1, 4),            # log2 axis size
                          st.integers(1, 10 ** 8),      # nbytes
                          st.integers(0, len(PHASES) - 1),
                          st.integers(0, len(IMPLS) - 1),
                          st.integers(1, 60)),          # count
                min_size=1, max_size=8),
       st.integers(2, 4))                               # fleet size
def test_trace_merge_conserves_weight_across_shards(cells, n_shards):
    entries = []
    for op_i, logp, nbytes, ph_i, impl_i, count in cells:
        if op_i < len(PLAIN_OPS):                       # geometry-less cell
            entries.append(TraceEntry.of(
                PLAIN_OPS[op_i], 2 ** logp, nbytes, PHASES[ph_i],
                IMPLS[impl_i], count))
        else:                                           # fused 1-D GEMM cell
            op = FUSED_OPS[op_i - len(PLAIN_OPS)]
            role = ROLE_OF_OP[op][0]
            entries.append(TraceEntry.of(
                op, 2 ** logp, nbytes, PHASES[ph_i], IMPLS[impl_i], count,
                mm_k=64, mm_m=128, mm_n=32, mm_role=role,
                p2=4 if role in ("2d", "2dT") else 0))
    fleet = Trace(entries)

    # partition every cell's count across the fleet (uneven on purpose:
    # server 0 takes the remainder), each server becoming one shard
    shard_entries = [[] for _ in range(n_shards)]
    for e in fleet.entries:
        per, rem = divmod(e.count, n_shards)
        for s in range(n_shards):
            c = per + (rem if s == 0 else 0)
            if c:
                shard_entries[s].append(TraceEntry(e.cell, e.phase,
                                                   e.impl, c))

    # shard 0 additionally round-trips through JSONL with its
    # geometry-less cells re-encoded as schema v1 (mixed-schema shard);
    # the deprecation warning must fire exactly when v1 lines exist
    lines = [(_v1_line(e) if not e.cell.fused else e.to_json())
             for e in shard_entries[0]]
    n_v1 = sum(1 for e in shard_entries[0] if not e.cell.fused)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shard0 = Trace.from_jsonl("\n".join(lines))
    warned = any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert warned == bool(n_v1)

    shards = [shard0] + [Trace(es) for es in shard_entries[1:]]
    merged = shards[0].merge(*shards[1:])
    assert merged.total() == fleet.total()              # global conservation
    assert merged == fleet                              # per-(cell,phase,impl)
    assert sum(s.total() for s in shards) == fleet.total()
    _SEEN["merge"] += 1


# ---------------------------------------------------------------------------
# the acceptance floor: every invariant saw >= 8 generated cells
# ---------------------------------------------------------------------------


def test_harness_generated_enough_cells():
    """Runs after the property tests (file order): the deterministic stub
    must have driven >= 8 distinct probes through every invariant."""
    for name, count in _SEEN.items():
        assert count >= 8, (name, count)
