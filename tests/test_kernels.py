"""Per-kernel validation: shape/dtype sweeps + hypothesis properties, all
against the pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pack import guideline_pack
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ssd_mamba2 import ssd_scan


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bkv", [
    (1, 2, 2, 128, 64, 64, 64),        # MHA
    (2, 4, 2, 256, 64, 128, 64),       # GQA 2:1
    (1, 8, 1, 128, 128, 64, 128),      # MQA, wide head
    (1, 2, 2, 192, 32, 64, 64),        # ragged-ish seq (192 = 3 blocks)
])
def test_flash_shapes_dtypes(rng, dtype, atol, b, hq, hkv, s, d, bq, bkv):
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    o = flash_attention(q, k, v, bq=bq, bkv=bkv, interpret=True)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=atol)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_window(rng, window):
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    o = flash_attention(q, k, v, window=window, bq=64, bkv=64,
                        interpret=True)
    r = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


def test_flash_softcap(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)) * 4, jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)) * 4, jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    o = flash_attention(q, k, v, softcap=30.0, bq=64, bkv=64, interpret=True)
    r = ref.flash_attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=3e-5)


def test_flash_causality_property(rng):
    """Changing future K/V must not change past outputs."""
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    o1 = flash_attention(q, k, v, bq=64, bkv=64, interpret=True)
    k2 = k.at[:, :, 100:].set(9.9)
    v2 = v.at[:, :, 100:].set(-9.9)
    o2 = flash_attention(q, k2, v2, bq=64, bkv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[:, :, :100]),
                               np.asarray(o2[:, :, :100]), atol=1e-6)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,hd,chunk", [
    (2, 64, 16, 16), (1, 128, 32, 32), (3, 96, 64, 16), (1, 32, 8, 32),
])
def test_rwkv6_sweep(rng, bh, s, hd, chunk):
    r = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    w = jnp.asarray(1 / (1 + np.exp(-rng.normal(size=(bh, s, hd)))) * 0.55
                    + 0.4, jnp.float32)
    u = jnp.asarray(rng.normal(size=(bh, hd)), jnp.float32)
    y, sf = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    yr, sr = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=2e-4)


def test_rwkv6_strong_decay_stability(rng):
    """Near-zero decays (the overflow hazard for naive chunking) stay exact."""
    bh, s, hd = 1, 64, 16
    r = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, s, hd)), jnp.float32)
    w = jnp.full((bh, s, hd), 1e-3, jnp.float32)     # brutal decay
    u = jnp.asarray(rng.normal(size=(bh, hd)), jnp.float32)
    y, sf = rwkv6_scan(r, k, v, w, u, chunk=16, interpret=True)
    yr, sr = ref.rwkv6_ref(r, k, v, w, u)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)


# ---------------------------------------------------------------------------
# mamba2 ssd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 64, 32, 16, 16), (1, 128, 64, 64, 64), (4, 96, 16, 8, 32),
])
def test_ssd_sweep(rng, bh, s, p, n, chunk):
    x = jnp.asarray(rng.normal(size=(bh, s, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(bh, s))) * 0.4 + 0.05,
                     jnp.float32)
    a = jnp.asarray(np.abs(rng.normal(size=(bh,))) + 0.3, jnp.float32)
    B = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    y, sf = ssd_scan(x, dt, a, B, C, chunk=chunk, interpret=True)
    yr, sr = ref.ssd_ref(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr), atol=3e-4)


def test_ssd_state_carry_across_chunks(rng):
    """Chunked result must be invariant to the chunk size."""
    bh, s, p, n = 1, 128, 16, 8
    x = jnp.asarray(rng.normal(size=(bh, s, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(bh, s))) * 0.3 + 0.1,
                     jnp.float32)
    a = jnp.asarray([0.7], jnp.float32)
    B = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(bh, s, n)), jnp.float32)
    y16, _ = ssd_scan(x, dt, a, B, C, chunk=16, interpret=True)
    y64, _ = ssd_scan(x, dt, a, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=2e-4)


# ---------------------------------------------------------------------------
# guideline pack
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 16), st.integers(1, 8), st.integers(0, 15))
def test_pack_property(n, p, idx):
    if idx >= p:
        idx = idx % p
    x = jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4) + 1
    o = guideline_pack(x, idx, p, interpret=True)
    r = ref.pack_ref(x, idx, p)
    assert o.shape == (p * n, 4)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(r))
    # one-hot support property: total mass equals x's mass
    assert float(jnp.sum(o)) == pytest.approx(float(jnp.sum(x)), rel=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_pack_dtypes(dtype):
    x = jnp.ones((8, 16), dtype)
    o = guideline_pack(x, 2, 4, interpret=True)
    np.testing.assert_array_equal(np.asarray(o),
                                  np.asarray(ref.pack_ref(x, 2, 4)))


def test_pack_int8_signed_values(rng):
    """int8 payloads (the quantized-wire q tensor) place exactly, sign and
    all — the pack path must not widen, round, or saturate."""
    x = jnp.asarray(rng.integers(-128, 128, size=(8, 16)), jnp.int8)
    for idx in range(4):
        o = guideline_pack(x, idx, 4, interpret=True)
        assert o.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(o),
                                      np.asarray(ref.pack_ref(x, idx, 4)))


@pytest.mark.parametrize("n,d,p,idx", [
    (5, 7, 3, 2),      # nothing divides anything
    (1, 1, 7, 6),      # degenerate single element, last slot
    (13, 3, 5, 0),     # prime rows, first slot
])
def test_pack_non_divisible_shapes(rng, n, d, p, idx):
    """One-hot placement for shapes with no power-of-two alignment: every
    non-idx block is exactly zero and block idx is exactly x (no pad rows
    leak into the output)."""
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    o = np.asarray(guideline_pack(x, idx, p, interpret=True))
    assert o.shape == (p * n, d)
    np.testing.assert_array_equal(o[idx * n:(idx + 1) * n], np.asarray(x))
    mask = np.ones(p * n, bool)
    mask[idx * n:(idx + 1) * n] = False
    np.testing.assert_array_equal(o[mask], 0.0)


# ---------------------------------------------------------------------------
# quantized wire (kernels/quant.py)
# ---------------------------------------------------------------------------

from repro.kernels import quant  # noqa: E402


@pytest.mark.parametrize("wire_dtype", quant.WIRE_DTYPES)
@pytest.mark.parametrize("n,d", [(32, 16), (13, 5), (3, 7), (8, 1)])
def test_quant_pack_matches_jnp_tier(rng, wire_dtype, n, d):
    """The Pallas tier (quant_pack/dequant_unpack, interpret mode) must agree
    with the jnp tier (quantize/dequantize) to within 1 ulp on the scales
    (the two tiers may associate the f32 division differently) and one
    quantization step on the payload — including the non-divisible-n pad
    path, where zero pad rows must not raise any block's abs-max."""
    x = jnp.asarray(rng.normal(size=(n, d)) * 3.0, jnp.float32)
    qj, sj = quant.quantize(x, wire_dtype)
    qk, sk = quant.quant_pack(x, wire_dtype=wire_dtype, interpret=True)
    assert qk.dtype == jnp.dtype(wire_dtype) and qk.shape == x.shape
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sj), rtol=3e-7)
    step = float(np.max(np.asarray(sj)))
    np.testing.assert_allclose(
        np.asarray(quant.dequantize(qk, sk), np.float32),
        np.asarray(quant.dequantize(qj, sj), np.float32), atol=1.01 * step)
    # the dequant kernel itself is a pure multiply: bit-identical to the
    # jnp tier on the SAME (q, scales) wire pair
    dj = quant.dequantize(qj, sj)
    dk = quant.dequant_unpack(qj, sj, interpret=True)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dj))


def test_quant_int8_matches_loop_reference(rng):
    """jnp-tier int8 roundtrip against the explicit per-block numpy loop in
    ref.py (independent derivation of the wire format)."""
    x = jnp.asarray(rng.normal(size=(29, 6)) * 10.0, jnp.float32)
    got = np.asarray(quant.wire_roundtrip(x, "int8"))
    want, scales_ref = ref.quant_roundtrip_ref(x, quant.QMAX["int8"],
                                               quant.BLOCK_ROWS)
    np.testing.assert_allclose(got, want, atol=1e-6)
    _, scales = quant.quantize(x, "int8")
    np.testing.assert_allclose(np.asarray(scales).reshape(-1), scales_ref,
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(1, 9),
       st.sampled_from(quant.WIRE_DTYPES), st.integers(0, 2 ** 31 - 1))
def test_quant_roundtrip_error_bound(n, d, wire_dtype, seed):
    """Single-hop roundtrip error stays inside wire_tol(wd, 1) for benign
    payloads of any (including non-divisible) shape."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)) * 5.0,
                    jnp.float32)
    got = np.asarray(quant.wire_roundtrip(x, wire_dtype), np.float32)
    denom = max(float(np.max(np.abs(np.asarray(x)))), 1e-30)
    rel = float(np.max(np.abs(got - np.asarray(x)))) / denom
    assert rel <= quant.wire_tol(wire_dtype, 1)


def test_quant_scale_is_per_block(rng):
    """A huge value in one block must not degrade other blocks' precision
    (the whole point of per-block scales)."""
    x = np.asarray(rng.normal(size=(16, 4)), np.float32)
    x[0, 0] = 1e4                       # poison block 0 only
    got = np.asarray(quant.wire_roundtrip(jnp.asarray(x), "int8"))
    tail = slice(quant.BLOCK_ROWS, None)     # block 1 unaffected
    denom = max(float(np.max(np.abs(x[tail]))), 1e-30)
    rel = float(np.max(np.abs(got[tail] - x[tail]))) / denom
    assert rel <= quant.wire_tol("int8", 1)
