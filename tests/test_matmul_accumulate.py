"""The contraction-dim collective matmul: ``matmul_accumulate`` end-to-end.

Ring kernel vs the unfused composition (fwd + bwd, incl. non-divisible K
and the padded-shard fallback), the rewired ``col_matmul(fsdp_dim=0)``
K-gather sites vs the legacy ``fsdp_gather(w, 0)`` composition, and the
tuner flipping the accumulate ring on modeled must-win shapes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api, costmodel as cm, tuner
from repro.core import collectives as C
from repro.core.cell import OpCell
from repro.kernels.collective_matmul import ring_matmul_accumulate
from repro.dist import ops

PS = (4, 8)


def _cot(y):
    return jnp.cos(jnp.arange(y.size, dtype=jnp.float32)).reshape(y.shape)


# ---------------------------------------------------------------------------
# the ring kernel vs the dense oracle (vmap semantic path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", PS)
@pytest.mark.parametrize("dtype,atol", [(np.float32, 1e-4),
                                        (np.float16, 2e-2)])
@pytest.mark.parametrize("t,k_loc,m", [(5, 3, 6), (1, 8, 2), (7, 1, 4)])
def test_ring_matmul_accumulate_matches_unfused(rng, p, dtype, atol,
                                                t, k_loc, m):
    w = jnp.asarray(rng.normal(size=(p, k_loc, m)).astype(dtype))
    x = jnp.asarray(np.broadcast_to(
        rng.normal(size=(t, p * k_loc)).astype(dtype), (p, t, p * k_loc))
        .copy())
    got = jax.vmap(lambda a, b: ring_matmul_accumulate(a, b, "x"),
                   axis_name="x")(x, w)
    full = np.asarray(w, np.float32).reshape(p * k_loc, m)
    want = np.asarray(x, np.float32)[0] @ full
    for r in range(p):
        np.testing.assert_allclose(np.asarray(got, np.float32)[r], want,
                                   atol=atol)


def test_ring_matmul_accumulate_returns_gathered(rng):
    p, t, k_loc, m = 4, 3, 2, 5
    w = jnp.asarray(rng.normal(size=(p, k_loc, m)).astype(np.float32))
    x = jnp.asarray(np.broadcast_to(
        rng.normal(size=(t, p * k_loc)).astype(np.float32),
        (p, t, p * k_loc)).copy())
    _, gath = jax.vmap(
        lambda a, b: ring_matmul_accumulate(a, b, "x", return_gathered=True),
        axis_name="x")(x, w)
    np.testing.assert_allclose(np.asarray(gath)[0],
                               np.asarray(w).reshape(p * k_loc, m),
                               atol=1e-6)


def test_registry_impls_semantics(rng):
    """Every registered impl of matmul_accumulate against the dense
    oracle (the streamed operand is the FIRST argument of the impl fn)."""
    p, t, k_loc, m = 4, 5, 2, 3
    w = rng.normal(size=(p, k_loc, m)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(t, p * k_loc)).astype(np.float32))
    want = np.asarray(x) @ w.reshape(p * k_loc, m)
    from repro.core.selfcheck import rel_err, wire_hops
    from repro.kernels.quant import wire_tol
    for name in C.impl_names("matmul_accumulate"):
        impl = C.REGISTRY["matmul_accumulate"][name]
        got = jax.vmap(lambda wb, fn=impl.fn: fn(wb, "x", x=x),
                       axis_name="x")(jnp.asarray(w))
        for r in range(p):
            if impl.wire_dtype is not None:
                # quantized-wire impls gate at the selfcheck tolerance
                tol = wire_tol(impl.wire_dtype,
                               wire_hops("matmul_accumulate", p))
                assert rel_err(np.asarray(got)[r], want) <= tol, name
            else:
                np.testing.assert_allclose(np.asarray(got)[r], want,
                                           atol=1e-4, err_msg=name)


# ---------------------------------------------------------------------------
# dist op: values + grads vs the unfused fsdp_gather composition
# ---------------------------------------------------------------------------


def _acc_grads(fun, x, w, axis="data"):
    def loss(a, b):
        y = fun(a, b)
        return jnp.sum(y * _cot(y))
    return jax.vmap(jax.grad(loss, argnums=(0, 1)), axis_name=axis)(x, w)


@pytest.mark.parametrize("impl", ["default", "fused_ring"])
@pytest.mark.parametrize("p", PS)
def test_matmul_accumulate_grads_match_unfused(rng, p, impl):
    t, k_loc, m = 6, 2, 5
    x = jnp.asarray(np.broadcast_to(
        rng.normal(size=(t, p * k_loc)).astype(np.float32),
        (p, t, p * k_loc)).copy())
    w = jnp.asarray(rng.normal(size=(p, k_loc, m)).astype(np.float32))

    def fused(a, b):
        return ops.matmul_accumulate(a, b, "data")

    def unfused(a, b):
        return jnp.matmul(a, ops.fsdp_gather(b, 0, "data"))

    with api.tuned(force={"matmul_accumulate": impl,
                          "matmul_reducescatter": impl}) as ctx:
        got_y = jax.vmap(fused, axis_name="data")(x, w)
        gx, gw = _acc_grads(fused, x, w)
    ref_y = jax.vmap(unfused, axis_name="data")(x, w)
    rx, rw = _acc_grads(unfused, x, w)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(ref_y),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-5)
    # fwd records the contract-role cell; bwd pairs matmul_reducescatter
    assert any(r.op == "matmul_accumulate" and r.phase == "fwd"
               and r.cell.mm_role == "contract" for r in ctx.record)
    assert any(r.op == "matmul_reducescatter" and r.phase == "bwd"
               for r in ctx.record)


def test_matmul_accumulate_default_is_bit_exact(rng):
    """With the default dispatch the rewired K-gather site is literally the
    unfused composition — outputs must match BIT-FOR-BIT."""
    p, t, k_loc, m = 4, 5, 3, 6
    x = jnp.asarray(np.broadcast_to(
        rng.normal(size=(t, p * k_loc)).astype(np.float32),
        (p, t, p * k_loc)).copy())
    w = jnp.asarray(rng.normal(size=(p, k_loc, m)).astype(np.float32))
    got = jax.vmap(lambda a, b: ops.matmul_accumulate(a, b, "data"),
                   axis_name="data")(x, w)
    ref = jax.vmap(lambda a, b: jnp.matmul(a, ops.fsdp_gather(b, 0, "data")),
                   axis_name="data")(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_matmul_accumulate_nondivisible_k_falls_back(rng):
    """K=10 on a p=4 axis: shards carry ceil(K/p)=3 padded rows; the op must
    fall back to the (tuned) unfused gather + slice and still match the
    dense oracle in values and grads."""
    p, t, k, k_loc, m = 4, 5, 10, 3, 4
    w_full = rng.normal(size=(p * k_loc, m)).astype(np.float32)
    w_full[k:] = 0.0                                    # pad rows
    w = jnp.asarray(w_full.reshape(p, k_loc, m))
    x = jnp.asarray(np.broadcast_to(
        rng.normal(size=(t, k)).astype(np.float32), (p, t, k)).copy())

    with api.tuned() as ctx:
        got = jax.vmap(lambda a, b: ops.matmul_accumulate(a, b, "data"),
                       axis_name="data")(x, w)
        gx, gw = _acc_grads(
            lambda a, b: ops.matmul_accumulate(a, b, "data"), x, w)
    want = np.asarray(x)[0] @ w_full[:k]
    np.testing.assert_allclose(np.asarray(got)[0], want, atol=1e-5)
    # the fallback dispatches a plain (tunable) allgather, not the fused op
    assert any(r.op == "allgather" for r in ctx.record)
    assert not any(r.op == "matmul_accumulate" for r in ctx.record)
    # grads: dense reference through the same padded-slice composition
    rx, rw = _acc_grads(
        lambda a, b: jnp.matmul(a, ops.fsdp_gather(b, 0, "data")[:k]), x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-5)


def test_matmul_accumulate_no_axis_is_local_matmul(rng):
    x = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(8, 2)).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(ops.matmul_accumulate(x, w, "data")),
        np.asarray(jnp.matmul(x, w)))


# ---------------------------------------------------------------------------
# the rewired col_matmul(fsdp_dim=0) K-gather sites
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["default", "fused_ring"])
def test_col_matmul_fsdp_dim0_matches_legacy(rng, impl):
    """col_matmul with the fused K-dim weight gather must equal the legacy
    fsdp_gather(w, 0) + col_matmul composition under BOTH axes (data FSDP
    inside model TP), values and grads."""
    pd, pm, t, k_loc, m_loc = 2, 2, 4, 3, 5
    k = pd * k_loc
    x = jnp.asarray(np.broadcast_to(
        rng.normal(size=(t, k)).astype(np.float32),
        (pm, pd, t, k)).copy())
    w = jnp.asarray(rng.normal(size=(pm, pd, k_loc, m_loc)).astype(
        np.float32))

    def fused(a, b):
        return ops.col_matmul(a, b, "model", fsdp_dim=0)

    def legacy(a, b):
        return ops.col_matmul(a, ops.fsdp_gather(b, 0, "data"), "model")

    def run(fun):
        def inner(a, b):
            def loss(aa, bb):
                y = fun(aa, bb)
                return jnp.sum(y * _cot(y))
            y = fun(a, b)
            g = jax.grad(loss, argnums=(0, 1))(a, b)
            return y, g
        return jax.vmap(jax.vmap(inner, axis_name="data"),
                        axis_name="model")(x, w)

    with api.tuned(force={"matmul_accumulate": impl,
                          "matmul_reducescatter": impl}) as ctx:
        got_y, (gx, gw) = run(fused)
    ref_y, (rx, rw) = run(legacy)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(ref_y),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-5)
    assert any(r.op == "matmul_accumulate" for r in ctx.record)


# ---------------------------------------------------------------------------
# tuner: must-win accumulate shapes (the EXT guideline per cell)
# ---------------------------------------------------------------------------


def test_tuner_selects_fused_accumulate_large_default_small():
    rep = tuner.tune(ops=["matmul_accumulate"],
                     sizes=(64, 1024, 1_048_576, 16_777_216),
                     axis_size=8, backend=tuner.CostModelBackend(cm.V5E_ICI))
    prof = rep.profiles
    assert prof.lookup("matmul_accumulate", 8, 16_777_216) == "fused_ring"
    assert prof.lookup("matmul_accumulate", 8, 64) is None   # default kept


def test_latency_cell_prices_true_flops_for_accumulate():
    """A modeled must-win accumulate cell: compute comparable to comm makes
    the ring overlap win; shrinking the GEMM to a sliver must flip the
    decision back to default — geometry, not just payload, decides."""
    big = OpCell("matmul_accumulate", 8, 4_194_304, "float32",
                 mm_k=8_388_608 // 1024, mm_m=8192, mm_n=1024,
                 mm_role="contract")
    t_def = cm.latency_cell(big, "default", cm.V5E_ICI)
    t_fus = cm.latency_cell(big, "fused_ring", cm.V5E_ICI)
    assert t_fus < t_def * 0.9
    sliver = OpCell("matmul_accumulate", 8, 4_194_304, "float32",
                    mm_k=8_388_608 // 1024, mm_m=1, mm_n=1024,
                    mm_role="contract")
    # with a sliver GEMM there is nothing to overlap: fusion must not clear
    # the 10% violation bar the tuner applies
    assert not (cm.latency_cell(sliver, "fused_ring", cm.V5E_ICI)
                < cm.latency_cell(sliver, "default", cm.V5E_ICI) * 0.9)
