"""dist/ops custom-VJP correctness under vmap axis emulation."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.dist import ops

P = 4


def vrun(f, *xs):
    return jax.vmap(f, axis_name="model")(*xs)


def test_fsdp_gather_fwd_bwd():
    # use the "data" axis name for the vmap emulation
    w = jnp.arange(P * 3 * 2, dtype=jnp.float32).reshape(P, 3, 2)

    def loss(w_shard):
        full = ops.fsdp_gather(w_shard, 0, "data")      # [12, 2]
        return jnp.sum(full * full)

    g = jax.vmap(jax.grad(loss), axis_name="data")(w)
    # d/dw of sum(full^2) = 2*full, reduce-scattered back to the owner shard
    want = 2 * w * P  # each shard's grad summed over the P identical replicas
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-6)


def test_tp_allreduce_identity_bwd():
    x = jnp.ones((P, 3), jnp.float32)

    def f(a):
        return jnp.sum(ops.tp_allreduce(a, "model"))

    g = jax.vmap(jax.grad(f), axis_name="model")(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_tp_copy_psums_grad():
    x = jnp.ones((P, 3), jnp.float32)

    def f(a):
        return jnp.sum(ops.tp_copy(a, "model") * 2.0)

    g = jax.vmap(jax.grad(f), axis_name="model")(x)
    np.testing.assert_allclose(np.asarray(g), 2.0 * P)


def test_tp_psum_grad_marker():
    x = jnp.ones((P, 3), jnp.float32)

    def f(a):
        return jnp.sum(ops.tp_psum_grad(a, "model") * 3.0)

    g = jax.vmap(jax.grad(f), axis_name="model")(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * P)


def test_ep_alltoall_roundtrip_grad():
    x = jnp.arange(P * P * 2, dtype=jnp.float32).reshape(P, P * 2)

    def f(a):
        y = ops.ep_alltoall(a, "model")
        y = ops.ep_alltoall(y, "model")   # inverse
        return jnp.sum(y * a)

    val = jax.vmap(f, axis_name="model")(x)
    np.testing.assert_allclose(np.asarray(val).sum(),
                               float(jnp.sum(x * x)), rtol=1e-6)


def test_identity_without_axis():
    w = jnp.ones((4, 2))
    assert ops.fsdp_gather(w, 0, "data").shape == (4, 2)
    assert ops.tp_allreduce(w, "model").shape == (4, 2)
    y = ops.tp_reducescatter(w, 0, "model")
    np.testing.assert_allclose(np.asarray(y), np.asarray(w))
