"""Required per-arch smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + no NaNs.  Also decode-path smoke + consistency.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.models.config import MoEConfig
from repro.models.params import init_tree

B, S = 2, 16


def _batch(cfg, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    batch["labels"] = batch["tokens"]
    if cfg.encdec is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S * 2, cfg.d_model)), jnp.float32)
    if cfg.vlm is not None:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_patches, cfg.vlm.patch_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_config(arch).smoke()
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(0))
    batch = _batch(cfg)
    logits, _, aux = lm.forward(params, cfg, batch, mode="train")
    exp_len = batch["tokens"].shape[1] + (cfg.vlm.n_patches if cfg.vlm else 0)
    assert logits.shape == (B, exp_len, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).smoke()
    smax = 32 + (cfg.vlm.n_patches if cfg.vlm else 0)
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(0))
    caches = lm.init_caches(cfg, B, smax)
    batch = _batch(cfg)
    batch.pop("labels")
    logits, caches = lm.prefill(params, cfg, batch, caches)
    assert bool(jnp.all(jnp.isfinite(logits)))
    npre = batch["tokens"].shape[1] + (cfg.vlm.n_patches if cfg.vlm else 0)
    tok = jnp.zeros((B, 1), jnp.int32)
    lg, caches2 = lm.decode_step(params, cfg, tok, caches, jnp.int32(npre))
    assert lg.shape == (B, 1, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma3-1b", "gemma2-9b",
                                  "rwkv6-3b", "zamba2-1.2b",
                                  "whisper-medium", "paligemma-3b"])
def test_decode_matches_full_forward(arch):
    """prefill(S-1) + decode(1) == train-mode forward at the last position.
    (MoE archs excluded: capacity drops legitimately differ per batch split —
    verified separately with a no-drop capacity factor below.)"""
    cfg = get_config(arch).smoke()
    smax = 16 + (cfg.vlm.n_patches if cfg.vlm else 0)
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(1))
    batch = _batch(cfg, key=3)
    toks = batch.pop("labels") * 0 + batch["tokens"]
    toks = toks[:, :12]
    batch["tokens"] = toks
    full, _, _ = lm.forward(params, cfg, batch, mode="train")
    caches = lm.init_caches(cfg, B, smax)
    pre = dict(batch)
    pre["tokens"] = toks[:, :-1]
    _, caches = lm.prefill(params, cfg, pre, caches)
    npre = 11 + (cfg.vlm.n_patches if cfg.vlm else 0)
    lg, _ = lm.decode_step(params, cfg, toks[:, -1:], caches,
                           jnp.int32(npre))
    a = np.asarray(full[:, -1], np.float32)
    b = np.asarray(lg[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-2, err


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b",
                                  "deepseek-v3-671b"])
def test_decode_matches_full_forward_moe_nodrop(arch):
    """With a no-drop capacity factor MoE decode is exact too."""
    cfg0 = get_config(arch).smoke()
    cfg = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, capacity_factor=8.0))
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(1))
    toks = jnp.asarray(np.random.default_rng(5).integers(
        0, cfg.vocab_size, (B, 12)), jnp.int32)
    full, _, _ = lm.forward(params, cfg, {"tokens": toks}, mode="train")
    caches = lm.init_caches(cfg, B, 16)
    _, caches = lm.prefill(params, cfg, {"tokens": toks[:, :-1]}, caches)
    lg, _ = lm.decode_step(params, cfg, toks[:, -1:], caches, jnp.int32(11))
    a = np.asarray(full[:, -1], np.float32)
    b = np.asarray(lg[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 2e-2, err


def test_param_counts_match_public_sizes():
    """param_count() should land near the published sizes."""
    expect = {
        "llama3-8b": 8.0e9,
        "llama3.2-3b": 3.2e9,
        "gemma2-9b": 9.2e9,
        "deepseek-v3-671b": 671e9,
        "rwkv6-3b": 3.1e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert active < 0.15 * cfg.param_count()   # 37B active of 671B


def test_tp16_divisibility_all_archs():
    """Every arch must produce integral local shapes on the 16-way TP axis."""
    from repro.models.params import ParamSpec, tree_map_specs
    for arch in ARCHS:
        cfg = get_config(arch)
        specs = lm.model_specs(cfg, tp=16)
        tree_map_specs(
            lambda s: s.local_shape({"model": 16, "data": 16}), specs)
