"""Multi-device SPMD validation (subprocess — keeps this process at 1 dev).

1. core selfcheck: every mock-up through real shard_map on 8 host devices.
2. SPMD equivalence: identical params + batch on 1 device vs a (data=2,
   model=4) mesh produce the same loss and updated params.
3. Pod-axis equivalence: the same check on a (pod, data, model) = (2, 2, 2)
   mesh, exercising the hierarchical RS(data)→AR(pod) gradient sync.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")

EQUIV_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro._compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import lm
from repro.models.params import tree_pspecs
from repro.train.trainer import make_step_fns, opt_state_pspecs
from repro.data import make_batch
from repro.launch.mesh import make_host_mesh

arch = sys.argv[1]
# mesh spec "2x4" -> (data, model); "2x2x2" -> (pod, data, model)
shape = tuple(int(x) for x in (sys.argv[2] if len(sys.argv) > 2
                               else "2x4").split("x"))
axes = ("pod", "data", "model")[-len(shape):]
tp = shape[-1]
dp_axes = axes[:-1]

cfg = get_config(arch).smoke()
init_fn, train_fn = make_step_fns(cfg, n_micro=1)
params1, opt1 = jax.jit(init_fn)(jax.random.key(7))
batch1 = {k: jnp.asarray(v) for k, v in make_batch(cfg, 8, 16, 0).items()}
p1, o1, m1 = jax.jit(train_fn)(params1, opt1, batch1, jnp.int32(50))

mesh = make_host_mesh(shape, axes)
specs = lm.model_specs(cfg, tp=tp)
pspecs = tree_pspecs(specs)
opt_ps = opt_state_pspecs(cfg.optimizer, specs)
put = lambda t, ps: jax.tree.map(
    lambda x, p: jax.device_put(np.asarray(x), NamedSharding(mesh, p)), t, ps)
params8, opt8 = put(params1, pspecs), put(opt1, opt_ps)
batch8 = jax.tree.map(lambda x: jax.device_put(
    np.asarray(x), NamedSharding(mesh, P(dp_axes))), batch1)
sm = shard_map(train_fn, mesh=mesh,
               in_specs=(pspecs, opt_ps,
                         jax.tree.map(lambda _: P(dp_axes), batch1), P()),
               out_specs=(pspecs, opt_ps,
                          {"loss": P(), "grad_norm": P(), "lr": P()}),
               check_vma=False)
p8, o8, m8 = jax.jit(sm)(params8, opt8, batch8, jnp.int32(50))
dl = abs(float(m1["loss"]) - float(m8["loss"]))
dp = max(float(np.max(np.abs(np.asarray(a, np.float32)
                             - np.asarray(b, np.float32))))
         for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)))
import json
print(json.dumps({"dl": dl, "dp": dp}))
"""


def _run(code, *args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code, *args],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


def test_selfcheck_all_mockups_spmd_8dev():
    r = _run("import sys; from repro.core.selfcheck import main; "
             "sys.exit(main(['--devices', '8', '--json']))")
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["failures"] == []
    assert out["total"] >= 40


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-3b", "phi3.5-moe-42b-a6.6b",
                                  "rwkv6-3b", "zamba2-1.2b"])
def test_spmd_equivalence(arch):
    r = _run(EQUIV_SCRIPT, arch)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # updated params (live LR at step 50) are the strict criterion for
    # dense archs; the loss metric is bf16-reduction-order noisy, and MoE
    # archs legitimately differ through capacity drops per batch split
    moe = "moe" in arch or "deepseek" in arch
    # MoE capacity is derived from LOCAL token counts, so the batch split
    # changes which tokens drop — the loss gap is real routing noise, not a
    # collective bug; the tight params bound below is the strict check
    # (observed ~5e-4 on this seed) so a real collective regression still
    # trips even with the looser loss tolerance.
    assert out["dl"] < (1e-1 if moe else 1e-2), out
    assert out["dp"] < 5e-2, out


FUSED_MM_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat import shard_map
from repro.core import api
from repro.dist import ops

mesh = Mesh(np.array(jax.devices()), ("model",))
p, n, k, m = 4, 8, 16, 12
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(p * n, k)).astype(np.float32))
xb = jnp.asarray(rng.normal(size=(p * p * n, k)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
cot = lambda y: jnp.cos(jnp.arange(y.size, dtype=jnp.float32)).reshape(y.shape)

def run(f, xin, force):
    def body(a):
        val = f(a)
        g = jax.grad(lambda b: jnp.sum(f(b) * cot(f(b))))(a)
        return val, g
    sm = shard_map(body, mesh=mesh, in_specs=P("model"),
                   out_specs=(P("model"), P("model")), check_vma=False)
    with api.tuned(force=force):
        val, g = jax.jit(sm)(xin)
    return np.asarray(val), np.asarray(g)

out = {}
for op_name, f, xin in [
        ("agmm", lambda a: ops.allgather_matmul(a, w, "model"), x),
        ("mmrs", lambda a: ops.matmul_reducescatter(a, w, "model"), xb)]:
    vd, gd = run(f, xin, {"allgather_matmul": "default",
                          "matmul_reducescatter": "default"})
    vf, gf = run(f, xin, {"allgather_matmul": "fused_ring",
                          "matmul_reducescatter": "fused_ring"})
    out[op_name] = {"dv": float(np.abs(vd - vf).max()),
                    "dg": float(np.abs(gd - gf).max())}
# oracle: fused allgather_matmul vs dense numpy
vf, _ = run(lambda a: ops.allgather_matmul(a, w, "model"), x,
            {"allgather_matmul": "fused_ring"})
want = np.asarray(x) @ np.asarray(w)
out["oracle_agmm"] = float(np.abs(
    vf.reshape(p, p * n, m) - want[None]).max())

# matmul_accumulate (contraction-dim ring) over a data axis: w K-sharded,
# x shard-local; compare fused vs unfused values + weight grads, and the
# REWIRED col_matmul(fsdp_dim=0) K-gather site vs the legacy composition
# bit-for-bit under default dispatch (the acceptance criterion).
mesh_d = Mesh(np.array(jax.devices()), ("data",))
kloc, T, M = 4, 6, 5
K = p * kloc
xs = jnp.asarray(rng.normal(size=(T, K)).astype(np.float32))
wacc = jnp.asarray(rng.normal(size=(K, M)).astype(np.float32))

def run_acc(f, force):
    def body(wb):
        val = f(wb)
        g = jax.grad(lambda b: jnp.sum(f(b) * cot(f(b))))(wb)
        return val, g
    sm = shard_map(body, mesh=mesh_d, in_specs=P("data"),
                   out_specs=(P(), P("data")), check_vma=False)
    with api.tuned(force=force):
        val, g = jax.jit(sm)(wacc)
    return np.asarray(val), np.asarray(g)

acc_f = lambda wb: ops.matmul_accumulate(xs, wb, "data")
acc_u = lambda wb: jnp.matmul(xs, ops.fsdp_gather(wb, 0, "data"))
vd, gd = run_acc(acc_u, {})
vf_, gf_ = run_acc(acc_f, {"matmul_accumulate": "fused_ring",
                           "matmul_reducescatter": "fused_ring"})
v0, g0 = run_acc(acc_f, {})          # default dispatch = unfused comp
out["acc"] = {"dv": float(np.abs(vd - vf_).max()),
              "dg": float(np.abs(gd - gf_).max())}
out["acc_default_bitexact"] = bool((vd == v0).all() and (gd == g0).all())
out["oracle_acc"] = float(np.abs(vd - np.asarray(xs) @ np.asarray(wacc)
                                 ).max())

col_f = lambda wb: ops.col_matmul(xs, wb, "model", fsdp_dim=0)
col_u = lambda wb: ops.col_matmul(xs, ops.fsdp_gather(wb, 0, "data"),
                                  "model")
vcf, gcf = run_acc(col_f, {})
vcu, gcu = run_acc(col_u, {})
out["col_rewired_bitexact"] = bool((vcf == vcu).all()
                                   and (gcf == gcu).all())
print(json.dumps(out))
"""


ROW2D_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro._compat import shard_map
from repro.core import api
from repro.dist import ops

# (data, model) = (2, 2) mesh; integer-valued operands + cotangent keep
# every reduction order exactly representable -> bit-exact comparisons
d, q = 2, 2
mesh = Mesh(np.array(jax.devices()).reshape(d, q), ("data", "model"))
T, Kl, ml = 4, 3, 5                  # per-shard x [T, Kl], w [Kl, ml]
rng = np.random.default_rng(7)
X = jnp.asarray(np.round(rng.normal(size=(d * T, q * Kl)) * 2)
                .astype(np.float32))
W = jnp.asarray(np.round(rng.normal(size=(q * Kl, d * ml)) * 2)
                .astype(np.float32))

def cot(y):
    return jnp.round(jnp.cos(jnp.arange(y.size, dtype=jnp.float32))
                     .reshape(y.shape) * 4)

def run(fun, force):
    def body(xs, ws):
        y = fun(xs, ws)
        gx, gw = jax.grad(lambda a, b: jnp.sum(fun(a, b) * cot(y)),
                          argnums=(0, 1))(xs, ws)
        return y, gx, gw
    sm = shard_map(body, mesh=mesh,
                   in_specs=(P("data", "model"), P("model", "data")),
                   out_specs=(P("data", None), P("data", "model"),
                              P("model", "data")),
                   check_vma=False)
    with api.tuned(force=force) as ctx:
        y, gx, gw = jax.jit(sm)(X, W)
    return (np.asarray(y), np.asarray(gx), np.asarray(gw),
            [(r.op, r.cell.p, r.cell.p2, r.cell.mm_role, r.impl, r.phase)
             for r in ctx.record])

new = lambda a, b: ops.row_matmul(a, b, "model", fsdp_dim=1)
leg = lambda a, b: ops.tp_allreduce(ops.fsdp_matmul(a, b, "data"), "model")

y0, gx0, gw0, rec0 = run(new, {})
yl, gxl, gwl, recl = run(leg, {})
yf, gxf, gwf, recf = run(new, {"matmul_reducescatter_2d": "fused_ring2d",
                               "allgather_matmul": "fused_ring"})
out = {
  "default_bitexact": bool((y0 == yl).all() and (gx0 == gxl).all()
                           and (gw0 == gwl).all()),
  "fused_bitexact": bool((yf == yl).all() and (gxf == gxl).all()
                         and (gwf == gwl).all()),
  "oracle": float(np.abs(y0 - np.asarray(X) @ np.asarray(W)).max()),
  "cells_2d": [r for r in rec0 if r[0] == "matmul_reducescatter_2d"],
  "fused_impls": sorted({(r[0], r[4]) for r in recf
                         if r[0] == "matmul_reducescatter_2d"}),
  "monolithic_ar": any(r[0] == "allreduce" for r in rec0),
  "legacy_ar": any(r[0] == "allreduce" for r in recl),
}
print(json.dumps(out))
"""


MEASURED_REPLAY_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.core import tuner
from repro.core.trace import Trace, TraceEntry

# a (2,2)-world 2-D cell IS replayable on the 4 host devices (the measured
# backend builds the 2-axis mesh); the p=8 1-D cell is not and notes out
t = Trace([TraceEntry.of("allreduce", 4, 1024, "decode", "default", 5),
           TraceEntry.of("allreduce", 8, 1024, "decode", "default", 5),
           TraceEntry.of("matmul_reducescatter_2d", 2, 2 * 64 * 6 * 4,
                         "decode", "default", 3, mm_k=64, mm_m=8,
                         mm_n=2 * 6, mm_role="2d", p2=2)])
backend = tuner.MeasuredBackend(K=2, max_nrep=3)
rep = tuner.tune_trace(t, backend=backend)
print(json.dumps({
    "sup": backend.supported_axis_size,
    "n_meas": len(rep.measurements),
    "n_meas_2d": sum(1 for m in rep.measurements
                     if m.cell.op == "matmul_reducescatter_2d"),
    "skips": [n for n in rep.notes if "host axis size" in n],
    "est_default": rep.est_default_s.get("decode", 0.0),
}))
"""


@pytest.mark.slow
def test_fused_collective_matmul_spmd_equivalence_4dev():
    """All THREE fused rings (allgather-matmul / matmul-reducescatter /
    matmul-accumulate) vs the unfused composition under REAL shard_map on
    4 host devices — values and grads; the rewired col_matmul(fsdp_dim=0)
    K-gather site must match the legacy fsdp_gather composition
    BIT-FOR-BIT under default dispatch (acceptance criterion)."""
    r = _run(FUSED_MM_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["agmm"]["dv"] < 1e-4 and out["agmm"]["dg"] < 1e-4, out
    assert out["mmrs"]["dv"] < 1e-4 and out["mmrs"]["dg"] < 1e-4, out
    assert out["oracle_agmm"] < 1e-4, out
    assert out["acc"]["dv"] < 1e-4 and out["acc"]["dg"] < 1e-4, out
    assert out["oracle_acc"] < 1e-4, out
    assert out["acc_default_bitexact"] is True, out
    assert out["col_rewired_bitexact"] is True, out


@pytest.mark.slow
def test_row_matmul_2d_spmd_equivalence_4dev():
    """Acceptance: on a REAL (data, model) = (2, 2) shard_map mesh,
    row_matmul(fsdp_dim=1) through `matmul_reducescatter_2d` — under
    default dispatch AND forced fused_ring2d — is bit-exact (fwd and
    grads) vs the legacy tp_allreduce(fsdp_matmul(...)) composition, the
    recorded cells carry the 2-D geometry (p=2, p2=2, roles 2d/2dT), and
    the monolithic model-axis allreduce is GONE from the rewired path."""
    r = _run(ROW2D_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["default_bitexact"] is True, out
    assert out["fused_bitexact"] is True, out
    assert out["oracle"] == 0.0, out
    roles = {(c[1], c[2], c[3], c[5]) for c in out["cells_2d"]}
    assert (2, 2, "2d", "fwd") in roles, out
    assert (2, 2, "2dT", "bwd") in roles, out      # the fused transpose dw
    assert out["fused_impls"] == [["matmul_reducescatter_2d",
                                   "fused_ring2d"]], out
    assert out["monolithic_ar"] is False, out      # ROADMAP item closed
    assert out["legacy_ar"] is True, out           # ...and it WAS there


@pytest.mark.slow
def test_measured_backend_trace_replay_4dev():
    """ROADMAP item: replay a recorded trace's cells on real host devices —
    the p=4 cell AND the (2,2)-world 2-D cell are wall-clock measured
    (both impls each), the p=8 cell skips with a note."""
    r = _run(MEASURED_REPLAY_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["sup"] == 4
    assert out["n_meas"] > 0                 # p=4 cell actually measured
    assert out["n_meas_2d"] >= 2, out        # 2-D replay on the 2x2 mesh
    assert out["skips"], out                 # p=8 cell noted as skipped
    assert out["est_default"] > 0.0


@pytest.mark.slow
def test_spmd_equivalence_pod_axis():
    """ROADMAP's real-`pod`-axis coverage: an 8-device (pod, data, model)
    = (2, 2, 2) mesh — batch split over pod AND data, params FSDP-sharded
    over data only, grads synced via the hierarchical RS(data)→AR(pod)
    schedule — must match the unsharded 1-device step."""
    r = _run(EQUIV_SCRIPT, "llama3.2-3b", "2x2x2")
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["dl"] < 1e-2, out
    assert out["dp"] < 5e-2, out
