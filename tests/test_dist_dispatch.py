"""dist/ops must dispatch through ``repro.core.api`` — never hard-wire
``jax.lax`` — so ``api.tuned(force=...)`` and ``PGTUNE_MODULE`` redirect
model-parallel traffic to guideline mock-ups, forward AND backward.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api
from repro.dist import ops

P = 4


def _w():
    return jnp.arange(P * 4 * 2, dtype=jnp.float32).reshape(P, 4, 2)


def _gather_loss(ws):
    full = ops.fsdp_gather(ws, 0, "data")
    return jnp.sum(full * full)


def _impls(record, op):
    return {impl for o, _, _, impl, _ph in record if o == op}


# ---------------------------------------------------------------------------
# force= context table
# ---------------------------------------------------------------------------


def test_force_reaches_fsdp_gather_fwd_and_bwd():
    w = _w()
    with api.tuned(force={"allgather": "allgather_as_allreduce",
                          "reducescatter": "rsb_as_allreduce"}) as ctx:
        g = jax.vmap(jax.grad(_gather_loss), axis_name="data")(w)
    # forward allgather AND backward reducescatter both went through the
    # context with the forced selections
    assert _impls(ctx.record, "allgather") == {"allgather_as_allreduce"}
    assert _impls(ctx.record, "reducescatter") == {"rsb_as_allreduce"}
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * w * P),
                               rtol=1e-6)


def test_swapping_forced_impl_changes_selection_not_values():
    w = _w()
    results = {}
    for impl in ("default", "allgather_as_ring", "allgather_as_alltoall"):
        with api.tuned(force={"allgather": impl}) as ctx:
            results[impl] = jax.vmap(jax.grad(_gather_loss),
                                     axis_name="data")(w)
        assert _impls(ctx.record, "allgather") == {impl}, impl
    base = np.asarray(results["default"])
    for impl, got in results.items():
        np.testing.assert_allclose(np.asarray(got), base, rtol=1e-6,
                                   err_msg=impl)


def test_force_reaches_every_dist_op():
    x = jnp.arange(P * P * 2 * 3, dtype=jnp.float32).reshape(P, P * 2, 3)
    force = {"allreduce": "allreduce_as_reduce_bcast",
             "alltoall": "alltoall_as_ppermute",
             "allgather": "allgather_as_allreduce",
             "reducescatter": "rsb_as_reduce_scatter"}

    def f(a):
        y = ops.tp_allreduce(a, "model")
        y = ops.tp_copy(y, "model") * 0.5
        y = ops.ep_alltoall(y, "model")
        y = ops.tp_allgather(ops.tp_reducescatter(y, 0, "model"), 0, "model")
        return jnp.sum(y * a)

    with api.tuned(force=force) as ctx:
        jax.vmap(jax.grad(f), axis_name="model")(x)
    for op, impl in force.items():
        assert impl in _impls(ctx.record, op), (op, ctx.record)


# ---------------------------------------------------------------------------
# PGTUNE_MODULE env routing (the paper's CLI --module= syntax)
# ---------------------------------------------------------------------------


def test_env_module_spec_reaches_fsdp_gather(monkeypatch):
    monkeypatch.setenv("PGTUNE_MODULE",
                       "allgather:alg=allgather_as_gather_bcast")
    w = _w()
    with api.tuned() as ctx:
        y = jax.vmap(lambda a: ops.fsdp_gather(a, 0, "data"),
                     axis_name="data")(w)
    assert _impls(ctx.record, "allgather") == {"allgather_as_gather_bcast"}
    np.testing.assert_allclose(
        np.asarray(y), np.broadcast_to(np.asarray(w).reshape(P * 4, 2),
                                       (P, P * 4, 2)), rtol=1e-6)


def test_context_force_beats_env(monkeypatch):
    monkeypatch.setenv("PGTUNE_MODULE", "allgather:alg=allgather_as_alltoall")
    with api.tuned(force={"allgather": "allgather_as_ring"}) as ctx:
        jax.vmap(lambda a: ops.fsdp_gather(a, 0, "data"),
                 axis_name="data")(_w())
    assert _impls(ctx.record, "allgather") == {"allgather_as_ring"}


# ---------------------------------------------------------------------------
# end-to-end: models.lm forward+backward is intercepted (acceptance check)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ag_impl", ["allgather_as_allreduce",
                                     "allgather_as_ring"])
def test_lm_fwd_bwd_dispatches_both_directions(ag_impl):
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.params import init_tree

    cfg = get_config("llama3.2-3b").smoke()
    D = 2  # FSDP degree (vmap axis emulation)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32) + 5}
    batch["labels"] = batch["tokens"]

    def init(key):
        return init_tree(lm.model_specs(cfg, tp=1), key,
                         fold=lax.axis_index("data"))

    def grad_fn(params):
        return jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)

    with api.tuned(force={"allgather": ag_impl,
                          "reducescatter": "rsb_as_allreduce"}) as ctx:
        params = jax.vmap(init, axis_name="data", axis_size=D,
                          in_axes=None, out_axes=0)(jax.random.key(0))
        g = jax.vmap(grad_fn, axis_name="data")(params)

    # forward direction: every FSDP param gather took the forced mock-up
    assert _impls(ctx.record, "allgather") == {ag_impl}
    # backward direction: grads reduce-scattered through the forced mock-up
    assert _impls(ctx.record, "reducescatter") == {"rsb_as_allreduce"}
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_lm_swapped_force_changes_recorded_selection():
    """Same model trace, different force table -> different selections in
    ``TuneContext.record`` — proving dist ops are intercepted, not
    hard-wired to jax.lax."""
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.params import init_tree

    cfg = get_config("llama3.2-3b").smoke()
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32) + 5}
    batch["labels"] = batch["tokens"]

    def run(force):
        def init(key):
            return init_tree(lm.model_specs(cfg, tp=1), key,
                             fold=lax.axis_index("data"))

        def grad_fn(params):
            return jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)

        with api.tuned(force=force) as ctx:
            params = jax.vmap(init, axis_name="data", axis_size=2,
                              in_axes=None, out_axes=0)(jax.random.key(0))
            jax.vmap(grad_fn, axis_name="data")(params)
        return ctx

    a = run({"allgather": "allgather_as_allreduce"})
    b = run({"allgather": "default"})
    assert _impls(a.record, "allgather") == {"allgather_as_allreduce"}
    assert _impls(b.record, "allgather") == {"default"}
    # both directions present in both runs
    for ctx in (a, b):
        assert _impls(ctx.record, "reducescatter"), "no backward collectives"
