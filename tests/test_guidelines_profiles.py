"""Guideline catalog, Table-1 memory model, profiles (incl. the paper's
Listing-1 verbatim), NREP estimator (Alg. 1 / Eq. 1), and dispatch."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import api, nrep
from repro.core.collectives import REGISTRY
from repro.core.guidelines import (GUIDELINES, PAPER_GUIDELINES, by_id,
                                   paper_coverage)
from repro.core.profiles import Profile, ProfileStore, Range

LISTING1 = """# pgtune profile
MPI_Scatter
1024 # nb. of. processes
2 # nb. of mock-up impl.
2 scatter_as_bcast
3 scatter_as_scatterv
8 # nb. of ranges
1 1 2
8 8 2
32 32 2
64 64 2
100 100 2
512 512 2
1024 1024 2
10000 10000 3
"""


def test_all_22_guidelines_present():
    cov = paper_coverage()
    assert len(cov) == 22
    assert cov["GL1"] == "allgather_as_gather_bcast"
    assert cov["GL7"] == "allreduce_as_rs_allgatherv"
    assert cov["GL20"] == "scan_as_exscan_reducelocal"
    assert cov["GL22"] == "scatter_as_scatterv"


def test_guideline_memory_model_table1():
    # GL2/GL3: p-times larger send buffer
    assert by_id("GL2").extra_bytes(1000, 8) == 8000
    assert by_id("GL3").extra_bytes(1000, 8) == 8000
    # GL4: 2p ints for displs+recvcounts
    assert by_id("GL4").extra_bytes(1000, 8) == 2 * 8 * 4
    # GL1 / GL5 / GL20: none
    for gl in ("GL1", "GL5", "GL20"):
        assert by_id(gl).extra_bytes(1000, 8) == 0
    # every guideline has a finite, non-negative cost
    for g in GUIDELINES:
        assert g.extra_bytes(4096, 16) >= 0


def test_every_mockup_is_a_guideline():
    for op, impls in REGISTRY.items():
        for name, impl in impls.items():
            if name == "default":
                continue
            assert impl.guideline is not None, (op, name)


# ---------------------------------------------------------------------------
# profiles
# ---------------------------------------------------------------------------


def test_listing1_roundtrip_verbatim():
    prof = Profile.from_text(LISTING1)
    assert prof.op == "scatter"
    assert prof.axis_size == 1024
    assert prof.lookup(8) == "scatter_as_bcast"
    assert prof.lookup(10_000) == "scatter_as_scatterv"
    assert prof.lookup(9_999) is None
    assert prof.lookup(2) is None
    back = Profile.from_text(prof.to_text())
    assert back.ranges == prof.ranges and back.axis_size == 1024


def test_profile_overlap_rejected():
    with pytest.raises(ValueError):
        Profile(op="bcast", axis_size=4,
                ranges=[Range(1, 100, "a"), Range(50, 200, "b")])


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=10**7), min_size=1,
                max_size=20, unique=True),
       st.integers(min_value=0, max_value=10**7))
def test_profile_lookup_matches_linear_scan(bounds, query):
    """Property: the O(log M) bisect lookup == a linear scan."""
    bounds = sorted(bounds)
    ranges = []
    for i in range(0, len(bounds) - 1, 2):
        ranges.append(Range(bounds[i], bounds[i + 1] - 1,
                            f"impl{i}"))
    if not ranges:
        return
    prof = Profile(op="allgather", axis_size=8, ranges=ranges)
    linear = None
    for r in ranges:
        if r.lo <= query <= r.hi:
            linear = r.impl
    assert prof.lookup(query) == linear


_IMPL_POOL = ("scatter_as_bcast", "scatter_as_scatterv", "scatter_as_tree")


def _ranges_from_bounds(bounds):
    """Random sorted unique ints -> non-overlapping closed ranges with
    impls drawn deterministically from a pool."""
    bounds = sorted(bounds)
    ranges = []
    for i in range(0, len(bounds) - 1, 2):
        ranges.append(Range(bounds[i], bounds[i + 1] - 1,
                            _IMPL_POOL[i % len(_IMPL_POOL)]))
    return ranges


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=10 ** 8), min_size=2,
                max_size=24, unique=True),
       st.integers(min_value=2, max_value=4096))
def test_profile_text_and_json_roundtrip_property(bounds, axis_size):
    """Property: random non-overlapping ranges survive Listing-1 text ->
    parse -> text AND JSON -> parse."""
    ranges = _ranges_from_bounds(bounds)
    if not ranges:
        return
    prof = Profile(op="scatter", axis_size=axis_size, ranges=ranges)
    t1 = Profile.from_text(prof.to_text())
    assert t1.ranges == prof.ranges
    assert t1.axis_size == axis_size and t1.op == "scatter"
    assert prof.to_text() == t1.to_text()          # fixpoint
    j1 = Profile.from_json(prof.to_json())
    assert j1.ranges == prof.ranges and j1.axis_size == axis_size


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=10 ** 6), min_size=2,
                max_size=12, unique=True),
       st.lists(st.integers(min_value=1, max_value=10 ** 6), min_size=2,
                max_size=12, unique=True))
def test_store_save_load_mixed_formats_property(bounds_a, bounds_b):
    """ProfileStore.load must merge .pgtune and .json files from one
    directory and reproduce every lookup.  (tempfile, not the tmp_path
    fixture: ``given`` wrappers take no fixture args.)"""
    import tempfile

    ra, rb = _ranges_from_bounds(bounds_a), _ranges_from_bounds(bounds_b)
    if not ra or not rb:
        return
    with tempfile.TemporaryDirectory() as d:
        ProfileStore([Profile(op="scatter", axis_size=8, ranges=ra)]).save(
            d, fmt="text")
        ProfileStore([Profile(op="allgather", axis_size=16,
                              ranges=rb)]).save(d, fmt="json")
        back = ProfileStore.load(d)
    assert len(back) == 2
    for r in ra:
        assert back.lookup("scatter", 8, r.lo) == r.impl
        assert back.lookup("scatter", 8, r.hi) == r.impl
    for r in rb:
        assert back.lookup("allgather", 16, r.hi) == r.impl
        assert back.lookup("allgather", 8, r.hi) is None


def test_store_save_load(tmp_path):
    store = ProfileStore([
        Profile(op="allreduce", axis_size=16,
                ranges=[Range(1, 1024, "allreduce_as_doubling")]),
        Profile(op="scatter", axis_size=1024,
                ranges=[Range(1, 64, "scatter_as_bcast")]),
    ])
    store.save(tmp_path, fmt="text")
    back = ProfileStore.load(tmp_path)
    assert len(back) == 2
    assert back.lookup("allreduce", 16, 512) == "allreduce_as_doubling"
    assert back.lookup("allreduce", 8, 512) is None   # wrong axis size


def _geom_cell(nbytes, mm_m=128):
    from repro.core.cell import OpCell
    return OpCell("allgather_matmul", 4, nbytes, mm_k=64, mm_m=mm_m,
                  mm_n=32, mm_role="gather")


def test_lookup_cell_exact_geom_range_miss_falls_to_nearest_geom():
    """Satellite regression: an exact-geometry profile whose ranges miss
    ``cell.nbytes`` must fall through to the NEAREST-geometry profile,
    not jump straight to the geometry-less store.  On the pre-fix code
    the nearest-geometry consultation lived in the ``else`` branch of
    the exact-profile hit, so exactly this store shadowed implB with
    implC."""
    exact = _geom_cell(5000)                      # geom G, nbytes miss
    near = _geom_cell(5000, mm_m=256)             # geom G' (distance 1)
    store = ProfileStore([
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1000, 2000, "implA")], geom=exact.geom()),
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1, 10 ** 9, "implB")], geom=near.geom()),
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1, 10 ** 9, "implC")]),   # geometry-less
    ])
    # in-range queries still hit the exact-geometry profile first
    assert store.lookup_cell(_geom_cell(1500)) == "implA"
    # out-of-range: nearest geometry, NOT the geometry-less store
    assert store.lookup_cell(exact) == "implB"


def test_lookup_cell_exact_geom_miss_no_near_falls_to_geomless():
    """Without any other same-role geometry the old geometry-less
    fallback still applies (the fix must not widen beyond the shadow)."""
    exact = _geom_cell(5000)
    store = ProfileStore([
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1000, 2000, "implA")], geom=exact.geom()),
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1, 10 ** 9, "implC")]),
    ])
    assert store.lookup_cell(exact) == "implC"


def test_lookup_cell_nearest_geom_skips_other_role_dtype_axes():
    """The nearest-geometry fallback only consults profiles that share
    role, dtype, and inner axis — a scatter-role or 2-D profile is a
    different communication problem, never a fallback target."""
    from repro.core.cell import Geom
    exact = _geom_cell(5000)
    store = ProfileStore([
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1000, 2000, "implA")], geom=exact.geom()),
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1, 10 ** 9, "implR")],
                geom=Geom("float32", 64, 256, 32, "scatter")),
        Profile(op="allgather_matmul", axis_size=4,
                ranges=[Range(1, 10 ** 9, "implP")],
                geom=Geom("float32", 64, 256, 32, "gather", p2=2)),
    ])
    assert store.lookup_cell(exact) is None


def test_profile_json_roundtrip_carries_version_and_loads_silently(
        tmp_path):
    """Satellite: the JSON round-trip now carries a schema version, so
    current-code artifacts re-load without any deprecation path."""
    import json
    import warnings

    from repro.core.profiles import PROFILE_JSON_VERSION
    store = ProfileStore([Profile(op="allreduce", axis_size=8,
                                  ranges=[Range(1, 99, "allreduce_as_doubling")])])
    store.save(tmp_path, fmt="json")
    f = next(tmp_path.glob("*.json"))
    assert json.loads(f.read_text())["version"] == PROFILE_JSON_VERSION
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        back = ProfileStore.load(tmp_path)
    assert back.lookup("allreduce", 8, 50) == "allreduce_as_doubling"


def test_versionless_json_profile_warns_naming_the_file(tmp_path):
    """Satellite: a .json profile with NO version field is a legacy
    artifact — warn symmetrically with headerless .pgtune files (both
    feed the ROADMAP v1-sunset removal criterion)."""
    import json
    f = tmp_path / "allreduce_p8.json"
    f.write_text(json.dumps({
        "op": "allreduce", "axis_size": 8,
        "ranges": [{"lo": 1, "hi": 99, "impl": "allreduce_as_doubling"}],
        "meta": {}}))
    with pytest.warns(DeprecationWarning, match="allreduce_p8.json"):
        store = ProfileStore.load(tmp_path)
    assert store.lookup("allreduce", 8, 50) == "allreduce_as_doubling"


# ---------------------------------------------------------------------------
# NREP (Alg. 1 / Eq. 1)
# ---------------------------------------------------------------------------


def test_nrep_rse_converges():
    rng = np.random.default_rng(0)

    def sampler(msize, count):
        return list(10e-6 + rng.normal(0, 1e-6, count).clip(0))

    ob = nrep.estimate_1byte(sampler, rse_threshold=0.01, batch0=10)
    assert ob.final_rse < 0.01
    assert ob.nrep >= 10


def test_nrep_eq1_scaling():
    """Eq. (1): nrep_m = max(ceil(t1_nrep / t_m_min), K)."""
    ob = nrep.OneByteEstimate(nrep=100, total_time=1.0, final_rse=0.005,
                              batches=3)

    def sampler(msize, count):
        return [1e-3 * msize] * count          # deterministic latency

    n = nrep.estimate_nrep(sampler, 10, ob, K=5)
    assert n == math.ceil(1.0 / 1e-2) == 100
    n_big = nrep.estimate_nrep(sampler, 10_000, ob, K=5)
    assert n_big == 5                          # K floor kicks in


# ---------------------------------------------------------------------------
# dispatch (api)
# ---------------------------------------------------------------------------


def _run_ar(impl_ctx_kwargs, x):
    with api.tuned(**impl_ctx_kwargs) as ctx:
        y = jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    return y, ctx


def test_dispatch_profile_and_record():
    store = ProfileStore([Profile(op="allreduce", axis_size=8,
                                  ranges=[Range(1, 10**6,
                                                "allreduce_as_rsb_allgather")])])
    x = jnp.ones((8, 4, 2), jnp.float32)
    y, ctx = _run_ar(dict(profiles=store), x)
    assert np.allclose(np.asarray(y), 8.0)
    assert [tuple(r) for r in ctx.record] == \
        [("allreduce", 8, 32, "allreduce_as_rsb_allgather", "fwd")]
    footer = api.format_footer(ctx)
    assert "#@pgpmi" not in footer
    assert "#@pgmpi alg MPI_Allreduce 32 allreduce_as_rsb_allgather" in footer


def test_dispatch_force_module_syntax():
    force = api.parse_module_spec(
        "allreduce:alg=allreduce_as_reduce_bcast;bcast:alg=bcast_as_tree")
    x = jnp.ones((8, 4, 2), jnp.float32)
    y, ctx = _run_ar(dict(force=force), x)
    assert ctx.record[-1].impl == "allreduce_as_reduce_bcast"


def test_dispatch_pow2_guard():
    """Non-power-of-two axis must fall back from doubling to default."""
    force = {"allreduce": "allreduce_as_doubling"}
    x = jnp.ones((6, 4, 2), jnp.float32)      # p=6: not a power of two
    y, ctx = _run_ar(dict(force=force), x)
    assert np.allclose(np.asarray(y), 6.0)
    assert ctx.record[-1].impl == "default"


def test_dispatch_scratch_budget():
    """Table-1 memory larger than the budget -> default (the paper's
    size_msg_buffer_bytes behaviour)."""
    store = ProfileStore([Profile(op="allgather", axis_size=8,
                                  ranges=[Range(1, 10**6,
                                                "allgather_as_alltoall")])])
    x = jnp.ones((8, 64, 4), jnp.float32)     # 1 KiB payload, extra = 8 KiB
    with api.tuned(profiles=store, scratch_budget_bytes=100) as ctx:
        jax.vmap(lambda a: api.allgather(a, "x"), axis_name="x")(x)
    assert ctx.record[-1].impl == "default"
    with api.tuned(profiles=store, scratch_budget_bytes=10**6) as ctx2:
        jax.vmap(lambda a: api.allgather(a, "x"), axis_name="x")(x)
    assert ctx2.record[-1].impl == "allgather_as_alltoall"


# ---------------------------------------------------------------------------
# profile-directory resolution ($PGTUNE_PROFILE_DIR fallback behaviour)
# ---------------------------------------------------------------------------


def test_resolve_stores_env_missing_dir_serves_untuned(tmp_path,
                                                       monkeypatch):
    from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
    monkeypatch.setenv(PROFILE_DIR_ENV, str(tmp_path / "does-not-exist"))
    with pytest.warns(UserWarning, match="does not exist"):
        base, phases = resolve_stores()
    assert base is None
    assert phases == {}


def test_resolve_stores_env_malformed_serves_untuned(tmp_path, monkeypatch):
    """A broken profile file behind the env var must NOT half-initialize a
    store (or crash a process that never asked for profiles) — resolution
    falls back to the full no-profile mode."""
    from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
    d = tmp_path / "profiles"
    d.mkdir()
    (d / "broken.json").write_text("{not valid json")
    monkeypatch.setenv(PROFILE_DIR_ENV, str(d))
    with pytest.warns(UserWarning, match="failed to load"):
        base, phases = resolve_stores()
    assert base is None
    assert phases == {}


def test_resolve_stores_env_malformed_phase_subdir(tmp_path, monkeypatch):
    """Even with a VALID base store, a malformed phase subdirectory makes
    the env path all-or-nothing: no half-initialized (base, {}) result."""
    from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
    d = tmp_path / "profiles"
    d.mkdir()
    ProfileStore([Profile(op="allreduce", axis_size=8,
                          ranges=[Range(1, 1024, "allreduce_as_doubling")])
                  ]).save(d, fmt="text")
    sub = d / "decode"
    sub.mkdir()
    (sub / "broken.json").write_text("]")
    monkeypatch.setenv(PROFILE_DIR_ENV, str(d))
    with pytest.warns(UserWarning, match="failed to load"):
        base, phases = resolve_stores()
    assert base is None
    assert phases == {}


def test_resolve_stores_explicit_dir_still_raises(tmp_path, monkeypatch):
    """The explicit argument is a user request: missing or malformed input
    raises instead of silently serving untuned."""
    from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
    monkeypatch.delenv(PROFILE_DIR_ENV, raising=False)
    with pytest.raises(FileNotFoundError):
        resolve_stores(tmp_path / "does-not-exist")
    d = tmp_path / "profiles"
    d.mkdir()
    (d / "broken.json").write_text("{not valid json")
    with pytest.raises(Exception):
        resolve_stores(d)


def test_resolve_stores_env_valid_dir_loads(tmp_path, monkeypatch):
    from repro.core.profiles import PROFILE_DIR_ENV, resolve_stores
    d = tmp_path / "profiles"
    d.mkdir()
    ProfileStore([Profile(op="allreduce", axis_size=8,
                          ranges=[Range(1, 1024, "allreduce_as_doubling")])
                  ]).save(d, fmt="text")
    monkeypatch.setenv(PROFILE_DIR_ENV, str(d))
    base, phases = resolve_stores()
    assert base is not None
    assert base.lookup("allreduce", 8, 512) == "allreduce_as_doubling"
    assert phases == {}
