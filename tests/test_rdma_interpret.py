"""Interpret-mode CPU tier for the TPU RDMA ring (satellite of the 2-D PR).

The real ``ring_allgather_matmul_rdma`` kernel drives
``make_async_remote_copy`` itself and can only execute on TPU — but its
BLOCK logic (per-step source rank, double-buffer slot rotation, output-row
placement) and its flow-control protocol (credit waits/grants) are pure
schedules.  These tests exercise both on CPU:

* ``ring_allgather_matmul_blocks`` runs one rank's grid schedule as an
  ``interpret=True`` Pallas kernel sharing the indexing helpers with the
  real kernel, and must agree with the ppermute reference ring and the
  dense oracle for every rank.
* a discrete-event simulation replays ``ring_schedule`` over p emulated
  devices and asserts the protocol is safe (no slot overwritten before its
  reader consumed it) and live (credits balance, every chunk delivered).

The real path stays gated behind ``on_tpu()`` — the dispatcher never
routes CPU traffic here (checked below).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import collective_matmul as cmm
from repro.kernels.collective_matmul_rdma import (
    ring_allgather_matmul_blocks, ring_schedule, ring_step_slots,
    ring_step_src)

PS = (2, 3, 4, 8)


@pytest.fixture()
def rng():
    """Module-local PRNG: keeps the session fixture's draw sequence
    untouched for data-dependent tests elsewhere in the suite."""
    return np.random.default_rng(20170701)


# ---------------------------------------------------------------------------
# shared indexing helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", PS)
def test_ring_step_src_covers_all_ranks(p):
    """Across the p grid steps every rank consumes every origin exactly
    once, and step 0 is its own chunk — the all-gather contract."""
    for my in range(p):
        srcs = [ring_step_src(my, s, p) for s in range(p)]
        assert srcs[0] == my
        assert sorted(srcs) == list(range(p))


def test_ring_step_slots_alternate():
    slots = [ring_step_slots(s) for s in range(6)]
    assert slots[0] == (0, 1)
    for s, (slot, nxt) in enumerate(slots):
        assert slot == s % 2 and nxt == (s + 1) % 2
        assert slot != nxt


def test_helpers_accept_traced_ints():
    """The same helper source must serve the TPU kernel (traced ints) and
    the simulation (Python ints)."""
    out = jax.jit(lambda my, s: ring_step_src(my, s, 4))(
        jnp.int32(1), jnp.int32(3))
    assert int(out) == (1 - 3 + 4) % 4


# ---------------------------------------------------------------------------
# protocol simulation (credits / double-buffer safety)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", PS)
def test_ring_protocol_simulation(p):
    """Replay ``ring_schedule`` over p emulated devices step-locked (the
    grid is globally synchronous per step on TPU): the send at step s must
    target a slot its receiver has already consumed, credits must balance
    to zero, and the delivered chunk sequence must equal the ppermute
    reference ring's (chunk s on rank r originates from rank r-s)."""
    sched = ring_schedule(p)
    assert len(sched) == p
    # slot state per device: buffers[dev][slot] = origin rank held, or None
    buffers = [[None, None] for _ in range(p)]
    consumed = [[True, True] for _ in range(p)]   # both slots start free
    credits = [0] * p                             # credits FROM the right
    delivered = [[] for _ in range(p)]
    for my in range(p):
        buffers[my][0] = my                       # step-0 seed
        consumed[my][0] = False
    for st in sched:
        s, slot, nxt = st["s"], st["slot"], st["nxt"]
        if st["wait_credit"]:
            for my in range(p):
                assert credits[my] > 0, (p, s, my, "credit deadlock")
                credits[my] -= 1
        if st["send"]:
            for my in range(p):
                right = (my + 1) % p
                # safety: the receiver must have consumed the target slot
                assert consumed[right][nxt], (p, s, my, "overwrite")
            for my in range(p):
                right = (my + 1) % p
                buffers[right][nxt] = buffers[my][slot]
                consumed[right][nxt] = False
        # every rank consumes its resident chunk (matmul + placement)
        for my in range(p):
            origin = buffers[my][slot]
            assert origin == ring_step_src(my, s, p), (p, s, my)
            delivered[my].append(origin)
            consumed[my][slot] = True
        if st["grant_credit"]:
            for my in range(p):
                left = (my - 1) % p
                credits[left] += 1
    assert all(c == 0 for c in credits), "credits did not drain"
    for my in range(p):
        assert sorted(delivered[my]) == list(range(p))


# ---------------------------------------------------------------------------
# interpret-mode grid equivalence vs the ppermute reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", (2, 4))
def test_interpret_blocks_match_reference_ring(rng, p):
    n, k, m = 3, 5, 4
    x_all = jnp.asarray(rng.normal(size=(p, n, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    ref_out, ref_gath = jax.vmap(
        lambda xs: cmm.ring_allgather_matmul(xs, w, "x", mm="jnp",
                                             return_gathered=True),
        axis_name="x")(x_all)
    want = np.asarray(x_all).reshape(p * n, k) @ np.asarray(w)
    for my in range(p):
        out, gath = ring_allgather_matmul_blocks(x_all, w, my,
                                                 interpret=True)
        np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref_out)[my], atol=1e-5)
        np.testing.assert_array_equal(np.asarray(gath),
                                      np.asarray(x_all).reshape(p * n, k))


def test_interpret_blocks_nontrivial_dtype(rng):
    p, n, k, m = 4, 2, 3, 2
    x_all = jnp.asarray(rng.normal(size=(p, n, k)).astype(np.float16))
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float16))
    out, _ = ring_allgather_matmul_blocks(x_all, w, 1, interpret=True)
    want = np.asarray(x_all, np.float32).reshape(p * n, k) @ \
        np.asarray(w, np.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32), want, atol=2e-2)


def test_real_rdma_path_stays_tpu_gated():
    """CPU CI imports this module now (interpret tier), but the dispatcher
    fused_ring impl must still never take the RDMA path off-TPU."""
    assert not cmm.on_tpu()
    # the fused_ring impl on CPU routes to the ppermute reference; if it
    # tried the RDMA kernel, make_async_remote_copy would fail to lower
    from repro.core import collectives as C
    x = jnp.ones((4, 2, 3), jnp.float32)
    w = jnp.ones((3, 2), jnp.float32)
    out = jax.vmap(lambda a: C.REGISTRY["allgather_matmul"]["fused_ring"].fn(
        a, "x", w=w), axis_name="x")(x)
    np.testing.assert_allclose(np.asarray(out)[0],
                               np.full((8, 2), 3.0), atol=1e-6)
