"""XLA-layer interposition: HLO site -> OpCell mapping, the tuning-
potential report, and rewrite-mode bit-exactness (subprocess SPMD).

Synthetic fixtures pin the mapping rules (fused-matmul adjacency roles);
subprocess tests drive the real pipeline: a scanned decode-like jitted
module (trip-count multipliers on real XLA output), the two-model zoo scan
(zero unmapped collectives — the acceptance gate), and a >=4-device
rewrite with movement mock-ups substituted, asserted bit-exact.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.analysis.interpose import (PotentialReport, _match_records_to_sites,
                                      map_sites, scan_potential)
from repro.core.api import DispatchRecord
from repro.core.cell import OpCell

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def _run(code, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code, *args],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


# ---------------------------------------------------------------------------
# mapping rules (synthetic fixture)
# ---------------------------------------------------------------------------

FUSED_FIXTURE = """
HloModule t_fused, num_partitions=4

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,16], w: f32[16,32], xk: f32[8,16], w2: f32[32,24]) -> f32[8,32] {
  %x = f32[8,16]{1,0} parameter(0)
  %w = f32[16,32]{1,0} parameter(1)
  %xk = f32[8,16]{1,0} parameter(2)
  %w2 = f32[32,24]{1,0} parameter(3)
  %ag = f32[32,16]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %dot = f32[32,32]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %agk = f32[32,16]{1,0} all-gather(%xk), replica_groups={{0,1,2,3}}, dimensions={0}
  %dotk = f32[16,16]{1,0} dot(%agk, %agk), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %dot2 = f32[32,24]{1,0} dot(%dot, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[32,24]{1,0} all-reduce(%dot2), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %rs = f32[8,32]{1,0} reduce-scatter(%dot), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
}
"""


def test_map_sites_fused_roles():
    mapped, unmapped = map_sites(FUSED_FIXTURE)
    assert unmapped == []
    by_name = {sc.site.name: sc for sc in mapped}

    # all-gather on the dot's ROW dim (not contracted) -> allgather_matmul
    ag = by_name["ag"]
    assert ag.fused and ag.adjacent_dot == "dot"
    assert ag.cell.op == "allgather_matmul"
    assert ag.cell.mm_role == "gather"
    assert (ag.cell.mm_k, ag.cell.mm_m, ag.cell.mm_n) == (16, 32, 32)
    assert ag.cell.nbytes == 8 * 16 * 4          # the pre-gather shard
    assert ag.cell.p == 4

    # all-gather whose gathered dim IS contracted -> matmul_accumulate
    agk = by_name["agk"]
    assert agk.cell.op == "matmul_accumulate"
    assert agk.cell.mm_role == "contract"
    assert agk.cell.mm_k == 32

    # dot -> reduce-scatter -> matmul_reducescatter; payload = dot's lhs
    rs = by_name["rs"]
    assert rs.cell.op == "matmul_reducescatter"
    assert rs.cell.mm_role == "scatter"
    assert rs.cell.nbytes == 32 * 16 * 4
    assert (rs.cell.mm_k, rs.cell.mm_m, rs.cell.mm_n) == (16, 32, 32)

    # dot -> all-reduce: stays a plain cell, flagged as a fused candidate
    ar = by_name["ar"]
    assert not ar.fused and ar.adjacent_dot == "dot2"
    assert ar.cell.op == "allreduce"
    assert ar.cell.nbytes == 32 * 24 * 4


def test_scan_potential_report():
    rep = scan_potential(FUSED_FIXTURE, label="fixture")
    assert isinstance(rep, PotentialReport)
    assert rep.ok and len(rep.rows) == 4
    assert rep.world == 4
    assert rep.potential() >= 1.0
    assert rep.total_default() >= rep.total_best() > 0
    table = rep.table()
    assert "collectives vs. best mock-ups:" in table
    assert "x on the table" in table
    j = rep.to_json()
    assert j["ok"] and j["n_sites"] == 4 and j["n_unmapped"] == 0
    json.dumps(j)        # artifact-serializable


def test_match_records_to_sites():
    sites = [sc.site for sc in map_sites(FUSED_FIXTURE)[0]]
    recs = [
        DispatchRecord(OpCell.plain("allgather", 4, 8 * 16 * 4), "default",
                       ""),
        DispatchRecord(OpCell.plain("allreduce", 4, 32 * 24 * 4),
                       "default", ""),
        DispatchRecord(OpCell.plain("allreduce", 4, 999), "default", ""),
        DispatchRecord(OpCell.plain("allgather", 1, 64), "default", ""),
    ]
    matched, unmatched, free = _match_records_to_sites(recs, sites)
    assert [(r.op, s.name) for r, s in matched] == [
        ("allgather", "ag"), ("allreduce", "ar")]
    assert [r.nbytes for r in unmatched] == [999]   # no such site
    assert {s.name for s in free} == {"agk", "rs"}  # p=1 rec never matches


# ---------------------------------------------------------------------------
# real scanned module: trip-count multipliers on compiled XLA output
# ---------------------------------------------------------------------------

SCAN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro._compat import shard_map
from repro.launch.mesh import make_host_mesh
from repro.analysis.hlo import _loop_multipliers, collective_bytes, collective_sites

mesh = make_host_mesh((4,), ("model",))
STEPS = 6

def body(x):
    # decode-like loop: per-step partial matmul + psum over the TP axis
    def step(carry, _):
        y = carry @ carry.T @ carry
        return lax.psum(y, "model"), ()
    out, _ = lax.scan(step, x, None, length=STEPS)
    return out

fn = shard_map(body, mesh=mesh, in_specs=(P(None, "model"),),
               out_specs=P(None, "model"), check_vma=False)
x = jnp.ones((8, 16), jnp.float32)
with mesh:
    hlo = jax.jit(fn).lower(x).compile().as_text()
mults = _loop_multipliers(hlo)
cb = collective_bytes(hlo)
sites = collective_sites(hlo)
print(json.dumps({
    "mults": sorted(mults.values()),
    "ar": cb.get("all-reduce", {}),
    "site_mults": [s.mult for s in sites if s.base_op == "all-reduce"],
}))
"""


@pytest.mark.slow
def test_loop_multiplier_real_scanned_module():
    """A jitted scan (decode-step shape) compiles to a while loop; the
    collectives inside must be weighted by the recovered trip count."""
    r = _run(SCAN_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert 6 in out["mults"], out
    assert out["site_mults"] and all(m == 6 for m in out["site_mults"]), out
    # one psum per iteration: bytes scale with the trip count
    assert out["ar"]["count"] == 6, out
    assert out["ar"]["bytes"] == 6 * 8 * 4 * 4, out


# ---------------------------------------------------------------------------
# zoo scan: every collective of two real models maps (the acceptance gate)
# ---------------------------------------------------------------------------

ZOO_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from repro.analysis.hlo import parse_instructions
from repro.analysis.interpose import compile_zoo_hlo, scan_potential

out = {}
for arch in ("gemma3-1b", "llama3.2-3b"):
    hlo, _info = compile_zoo_hlo(arch, kind="decode", mesh_shape=(2, 4))
    rep = scan_potential(hlo, label=arch)
    instrs = parse_instructions(hlo)
    out[arch] = {
        "ok": rep.ok,
        "n_sites": len(rep.rows),
        "unmapped": [s.hlo_op for s in rep.unmapped],
        "potential": rep.potential(),
        "n_instrs": len(instrs),
        "n_scalar": sum(1 for i in instrs if i.type_str.endswith("[]")),
        "n_tuple": sum(1 for i in instrs
                       if i.type_str.startswith("(")),
        "table_ok": "x on the table" in rep.table(),
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_zoo_scan_zero_unmapped_two_models():
    """Report-only mode must map EVERY collective instruction of >=2 zoo
    models to a priced OpCell — zero unmapped ops (and the real compiled
    modules double as parser fixtures: scalar + tuple result types)."""
    r = _run(ZOO_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert set(out) == {"gemma3-1b", "llama3.2-3b"}
    for arch, d in out.items():
        assert d["ok"] and d["unmapped"] == [], (arch, d)
        assert d["n_sites"] > 0, (arch, d)
        assert d["potential"] >= 1.0, (arch, d)
        assert d["table_ok"], (arch, d)
        # hardening coverage on real compiled text: scalar results
        # (f32[]/s32[] loop counters) and tuple-typed instructions parse
        assert d["n_scalar"] > 0, (arch, d)
        assert d["n_tuple"] > 0, (arch, d)


# ---------------------------------------------------------------------------
# rewrite mode: >=4-device SPMD bit-exactness
# ---------------------------------------------------------------------------

REWRITE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro._compat import shard_map
from repro.launch.mesh import make_host_mesh
from repro.core import api
from repro.analysis.interpose import assert_bitexact, rewrite

mesh = make_host_mesh((4,), ("model",))

def body(x, w):
    g = api.allgather(x, "model")
    y = g @ w
    s = api.reducescatter(y, "model")
    z = api.allreduce(s * 2.0, "model")
    return api.alltoall(z, "model")

fn = shard_map(body, mesh=mesh, in_specs=(P("model"), P()),
               out_specs=P("model"), check_vma=False)
x = jnp.arange(16 * 16, dtype=jnp.float32).reshape(16, 16) / 7.0
w = jnp.ones((16, 16), jnp.float32) * 0.5

with mesh:
    # movement mock-ups only: reduction mock-ups reorder the sum and are
    # legitimately not bit-exact
    res = rewrite(fn, x, w,
                  force={"allgather": "allgather_as_ring",
                         "alltoall": "alltoall_as_ppermute"})
assert_bitexact(res)
print(json.dumps({
    "matched": [(r.op, s.name) for r, s in res.matched],
    "unmatched": [r.op for r in res.unmatched_records],
    "extra": [s.name for s in res.extra_sites],
    "changed": sorted((r.op, r.impl) for r in res.changed),
    "bitexact": res.bitexact,
}))
"""


@pytest.mark.slow
def test_rewrite_bitexact_4dev_spmd():
    """Rewrite mode substitutes tuned mock-ups at matched dist-shaped
    sites and the program output stays bit-for-bit identical."""
    r = _run(REWRITE_SCRIPT)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["bitexact"] is True
    assert out["changed"] == [["allgather", "allgather_as_ring"],
                              ["alltoall", "alltoall_as_ppermute"]]
    # every dispatch matched an HLO site, and vice versa
    assert out["unmatched"] == [] and out["extra"] == []
    assert {op for op, _ in out["matched"]} == {
        "allgather", "reducescatter", "allreduce", "alltoall"}
