"""Minimal deterministic stand-in for ``hypothesis`` (not installable in
this container; conftest.py registers this module in ``sys.modules`` only
when the real package is missing).

Implements exactly the surface the suite uses — ``@given`` over
``integers`` / ``sampled_from`` / ``lists`` / ``tuples`` strategies and
``@settings(max_examples=..., deadline=...)``.  Draws come from a
fixed-seed PRNG, so the property tests become deterministic sweeps:
weaker than real hypothesis (no shrinking, no adaptive search) but the
properties still get ``max_examples`` distinct probes per run.
"""
from __future__ import annotations

import random
import types

_SEED = 20170701


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def _integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else int(min_value)
    hi = 2 ** 16 if max_value is None else int(max_value)
    return _Strategy(lambda r: r.randint(lo, hi))


def _sampled_from(elements):
    seq = list(elements)
    if not seq:
        raise ValueError("sampled_from requires a non-empty collection")
    return _Strategy(lambda r: seq[r.randrange(len(seq))])


def _lists(elements, min_size=0, max_size=None, unique=False):
    hi = (min_size + 10) if max_size is None else max_size

    def draw(r):
        n = r.randint(min_size, hi)
        if not unique:
            return [elements.example(r) for _ in range(n)]
        out: dict = {}
        for _ in range(100 * max(n, 1)):
            if len(out) >= n:
                break
            out[elements.example(r)] = None
        return list(out)

    return _Strategy(draw)


def _tuples(*strats):
    return _Strategy(lambda r: tuple(s.example(r) for s in strats))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.tuples = _tuples


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        # a zero-arg wrapper on purpose: pytest must not mistake the
        # strategy-filled parameters for fixtures (real hypothesis hides
        # them the same way)
        def runner():
            n = getattr(runner, "_max_examples", None) or \
                getattr(fn, "_max_examples", 20)
            rnd = random.Random(_SEED)
            for _ in range(n):
                args = [s.example(rnd) for s in strats]
                kw = {k: s.example(rnd) for k, s in kwstrats.items()}
                fn(*args, **kw)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
