"""Fault-tolerant fleet retuning (ISSUE 8): shard quarantine with exact
weight accounting, epoch history/rollback/poisoning, the content-based
poll stamp, manifest↔profile digest verification, the EpochTripwire,
MAD-robust feedback statistics, and the drift/failure coordinator.

Everything here is deterministic: injected faults come from a seeded
``ft.ChaosMonkey``, liveness from an injected fake clock.
"""
import json
import os
import warnings

import pytest

from repro.core import api
from repro.core.api import EpochTripwire
from repro.core.cell import OpCell
from repro.core.profiles import (MANIFEST_NAME, Profile, ProfileStore,
                                 Range, StoreRef, profiles_digest,
                                 read_manifest, write_manifest)
from repro.core.trace import (ShardRecorder, Trace, load_shard_latencies,
                              shard_meta)
from repro.core.tuner import CostModelBackend, FeedbackBackend, _mad_filter
from repro.core import costmodel
from repro.ft import ChaosMonkey, FleetCoordinator


IMPL = "allreduce_as_rsb_allgather"       # a registered allreduce mock-up


def _rec(op="allreduce", p=4, nbytes=512, impl="default", phase="fwd"):
    return api.DispatchRecord(OpCell(op, p, nbytes), impl, phase)


def _flush_shard(tmp_path, server="srv0", epoch=1, n=6, obs=None,
                 seed=0):
    r = ShardRecorder(server, seed=seed)
    for i in range(n):
        r.append(_rec(nbytes=256 * (1 + i % 2)))
    for lat in obs or []:
        r.observe(OpCell("allreduce", 4, 512), IMPL, lat)
    return r.flush(tmp_path, epoch=epoch)


def _store(impl=IMPL):
    return ProfileStore([Profile("allreduce", 4,
                                 [Range(0, 1 << 30, impl)])])


# ---------------------------------------------------------------------------
# quarantine: every chaos fault lands in the right bucket, weight exact
# ---------------------------------------------------------------------------


def test_torn_shard_quarantined_with_digest_mismatch(tmp_path):
    good = _flush_shard(tmp_path, "srv0", n=4)
    torn = _flush_shard(tmp_path, "srv1", n=6)
    ChaosMonkey(seed=1).tear_shard(torn, keep_frac=0.4)
    with pytest.warns(UserWarning, match="quarantined"):
        report = Trace.merge_shards(tmp_path)
    assert [n.path for n in report.merged] == [good]
    (q,) = report.quarantined
    assert q.path == torn and "digest-mismatch" in q.reason
    assert q.claimed == 6 and q.dropped == 6
    # merged weight == surviving shards' weight, exactly
    assert report.trace.total() == 4
    assert report.dropped_weight == 6


def test_corrupt_line_quarantined_as_parse_error(tmp_path):
    p = _flush_shard(tmp_path, "srv0", n=4)
    ChaosMonkey(seed=2).corrupt_line(p, line=0)
    # without digest verification the parse-error path must catch it
    with pytest.warns(UserWarning, match="quarantined"):
        report = Trace.merge_shards(tmp_path, verify_digest=False)
    (q,) = report.quarantined
    assert "parse-error" in q.reason
    assert q.claimed == 4
    # with digest verification the (earlier) digest check catches it
    with pytest.warns(UserWarning, match="quarantined"):
        report2 = Trace.merge_shards(tmp_path)
    assert "digest-mismatch" in report2.quarantined[0].reason


def test_header_corruption_and_meta_skew_quarantined(tmp_path):
    skewed = _flush_shard(tmp_path, "srv0", n=3)
    ChaosMonkey(seed=3).skew_header(skewed, epoch=9)
    broken = _flush_shard(tmp_path, "srv1", n=3)
    text = broken.read_text()
    broken.write_text("#@shard {not json" + text.partition("\n")[2])
    with pytest.warns(UserWarning, match="quarantined"):
        report = Trace.merge_shards(tmp_path)
    reasons = {n.path.name: n.reason for n in report.quarantined}
    assert "meta-skew" in reasons[skewed.name]
    assert "header-corrupt" in reasons[broken.name]
    assert report.trace.total() == 0


def test_salvaged_weight_accounted_never_merged(tmp_path):
    p = _flush_shard(tmp_path, "srv0", n=6)
    # drop the header's digest claim AND truncate: the claim is gone, so
    # accounting falls back to the parseable-prefix weight
    head, _sep, body = p.read_text().partition("\n")
    meta = json.loads(head[len("#@shard "):])
    del meta["dispatches"]
    lines = body.splitlines()
    p.write_text("#@shard " + json.dumps(meta) + "\n"
                 + "\n".join(lines[:1]) + "\ngarbage{{{\n")
    with pytest.warns(UserWarning, match="quarantined"):
        report = Trace.merge_shards(tmp_path, verify_digest=False)
    (q,) = report.quarantined
    assert q.claimed is None
    assert q.salvaged == 3            # the surviving first line's count
    assert q.dropped == 3
    assert report.trace.total() == 0  # salvage is accounting, not data


def test_headerless_legacy_file_still_merges(tmp_path):
    t = Trace([  # a plain v2 trace file dropped into the shard dir
        __import__("repro.core.trace", fromlist=["TraceEntry"])
        .TraceEntry.of("allreduce", 4, 512, count=7)])
    (tmp_path / "shard-legacy-e000001.jsonl").write_text(t.to_jsonl())
    report = Trace.merge_shards(tmp_path)
    assert report.trace.total() == 7
    (n,) = report.merged
    assert n.server is None and n.claimed is None


def test_quarantined_shard_latencies_skippable(tmp_path):
    good = _flush_shard(tmp_path, "srv0", obs=[1e-3, 1e-3], seed=0)
    bad = _flush_shard(tmp_path, "srv1", obs=[5e-2, 5e-2], seed=1)
    ChaosMonkey(seed=4).tear_shard(bad, keep_frac=0.9)
    with pytest.warns(UserWarning):
        report = Trace.merge_shards(tmp_path)
    obs = load_shard_latencies(
        tmp_path, skip=[n.path for n in report.quarantined])
    samples = obs[(OpCell("allreduce", 4, 512), IMPL)]
    assert samples == [1e-3, 1e-3]    # the torn shard's 5e-2 not trusted


# ---------------------------------------------------------------------------
# flush atomicity
# ---------------------------------------------------------------------------


def test_flush_leaves_no_tmp_and_digest_roundtrips(tmp_path):
    p = _flush_shard(tmp_path, "srv0", n=5, obs=[1e-3])
    assert not list(tmp_path.glob("*.tmp"))
    meta = shard_meta(p)
    assert meta["sha256"].startswith("sha256:")
    report = Trace.merge_shards(tmp_path)
    assert not report.quarantined and report.trace.total() == 5
    # one flipped byte in the body breaks the digest
    raw = p.read_bytes()
    p.write_bytes(raw[:-2] + b"X" + raw[-1:])
    with pytest.warns(UserWarning, match="digest-mismatch"):
        report = Trace.merge_shards(tmp_path)
    assert report.quarantined


# ---------------------------------------------------------------------------
# S1 regression: content-based poll stamp
# ---------------------------------------------------------------------------


def test_poll_adopts_same_size_same_mtime_manifest_replacement(tmp_path):
    """A manifest replaced by one of the SAME byte length and SAME mtime
    must still be adopted — the old ``(st_mtime_ns, st_size)`` stat
    stamp provably misses this (this test fails on pre-ISSUE-8 HEAD)."""
    _store().save(tmp_path, epoch=1)
    ref = StoreRef(directory=tmp_path)
    assert ref.poll() and ref.epoch == 1
    man = tmp_path / MANIFEST_NAME
    st = man.stat()
    text = man.read_text()
    assert '"epoch": 1' in text
    man.write_text(text.replace('"epoch": 1', '"epoch": 2'))
    assert man.stat().st_size == st.st_size
    os.utime(man, ns=(st.st_atime_ns, st.st_mtime_ns))
    assert man.stat().st_mtime_ns == st.st_mtime_ns
    assert ref.poll(), ("same-size same-mtime manifest replacement "
                        "missed by the poll stamp")
    assert ref.epoch == 2


def test_manifest_records_and_poll_verifies_profiles_digest(tmp_path):
    _store().save(tmp_path, epoch=1)
    man = read_manifest(tmp_path)
    assert man["profiles_digest"] == profiles_digest(tmp_path)
    ref = StoreRef(directory=tmp_path)
    assert ref.poll() and ref.epoch == 1
    # skew: profiles change after the manifest was written
    _store().save(tmp_path)
    write_manifest(tmp_path, 2)
    ChaosMonkey(seed=5).skew_profiles(tmp_path)
    with pytest.warns(UserWarning, match="skew"):
        assert not ref.poll()
    assert ref.epoch == 1
    # the skew persists: every poll re-checks (and re-warns) rather
    # than short-circuiting on the unchanged manifest
    with pytest.warns(UserWarning, match="skew"):
        assert not ref.poll()
    # repairing the PROFILES alone — manifest byte-identical — must be
    # enough to adopt; a stamp committed at refusal time would hide it
    _store().save(tmp_path)
    assert read_manifest(tmp_path)["profiles_digest"] \
        == profiles_digest(tmp_path)
    assert ref.poll() and ref.epoch == 2


# ---------------------------------------------------------------------------
# epoch history, rollback, poisoning
# ---------------------------------------------------------------------------


def test_storeref_retains_history_and_rolls_back():
    ref = StoreRef(history=2)
    for e in range(4):
        assert ref.swap(_store(), {}, e)
    assert ref.epoch == 3
    assert len(ref._history) == 2      # bounded retention
    with pytest.warns(UserWarning, match="rolled back"):
        assert ref.rollback() == 2
    with pytest.warns(UserWarning, match="rolled back"):
        assert ref.rollback() == 1
    with pytest.warns(UserWarning, match="no retained"):
        assert ref.rollback() is None
    assert ref.epoch == 1


def test_rolled_back_epoch_is_poisoned_for_swap_and_poll(tmp_path):
    _store().save(tmp_path, epoch=1)
    ref = StoreRef(directory=tmp_path)
    assert ref.poll()
    _store("allreduce_as_doubling").save(tmp_path, epoch=2)
    assert ref.poll() and ref.epoch == 2
    with pytest.warns(UserWarning, match="rolled back"):
        ref.rollback()
    assert ref.epoch == 1
    # the on-disk manifest still says epoch 2; poll must not re-adopt,
    # even when the manifest text changes (publisher retry)
    write_manifest(tmp_path, 2, source_digest="sha256:retry")
    with pytest.warns(UserWarning, match="poisoned"):
        assert not ref.poll()
    assert ref.epoch == 1
    with pytest.warns(UserWarning, match="poisoned"):
        assert not ref.swap(_store(), {}, 2)
    # a FRESH epoch recovers
    _store("allreduce_as_doubling").save(tmp_path, epoch=3)
    assert ref.poll() and ref.epoch == 3


def test_rollback_restores_lookup_results():
    cell = OpCell("allreduce", 4, 512)
    ref = StoreRef()
    ref.swap(_store(IMPL), {}, 1)
    ref.swap(_store("allreduce_as_doubling"), {}, 2)
    assert ref.lookup(cell, "fwd") == "allreduce_as_doubling"
    with pytest.warns(UserWarning):
        ref.rollback()
    assert ref.lookup(cell, "fwd") == IMPL


# ---------------------------------------------------------------------------
# EpochTripwire
# ---------------------------------------------------------------------------


def _tripwire_ref():
    ref = StoreRef()
    ref.swap(_store(IMPL), {}, 1)
    return ref


def test_tripwire_rolls_back_regressing_epoch():
    ref = _tripwire_ref()
    tw = EpochTripwire(ref, threshold=1.5, window=4, min_samples=3)
    for _ in range(4):
        assert not tw.observe(1.0)
    ref.swap(_store("allreduce_as_doubling"), {}, 2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert not tw.observe(2.0)     # below min_samples: no verdict yet
        assert not tw.observe(2.0)
        fired = tw.observe(2.0)        # median 2.0 > 1.5 x baseline 1.0
    assert fired
    assert tw.fired == [(2, 1)]
    assert ref.epoch == 1
    assert 1 in {e for e, *_ in [ref._state]}  # restored generation live


def test_tripwire_tolerates_single_spike_and_ok_epoch():
    ref = _tripwire_ref()
    tw = EpochTripwire(ref, threshold=1.5, window=5, min_samples=3)
    for _ in range(5):
        tw.observe(1.0)
    ref.swap(_store("allreduce_as_doubling"), {}, 2)
    # new epoch is FINE (1.1x); one 10x spike must not trip the median
    seq = [1.1, 1.1, 10.0, 1.1, 1.1, 1.1]
    assert not any(tw.observe(c) for c in seq)
    assert ref.epoch == 2 and not tw.fired


def test_tripwire_without_history_keeps_serving():
    ref = StoreRef()
    ref.swap(_store(), {}, 1)          # first epoch: nothing retained
    tw = EpochTripwire(ref, threshold=1.2, window=3, min_samples=2)
    tw._baseline = 1.0                 # pretend a prior epoch existed
    with pytest.warns(UserWarning, match="no retained"):
        fired = [tw.observe(5.0) for _ in range(3)]
    assert not any(fired) and ref.epoch == 1


# ---------------------------------------------------------------------------
# FeedbackBackend MAD rejection
# ---------------------------------------------------------------------------


def test_mad_filter_drops_spikes_keeps_tight_samples():
    assert _mad_filter([1.0, 1.1, 0.9, 1.05, 100.0], 4.0) == \
        [1.0, 1.1, 0.9, 1.05]
    # identical samples: the 5%-of-median floor keeps them all
    assert _mad_filter([2.0, 2.0, 2.0, 2.0], 4.0) == [2.0] * 4
    # tiny sets and k=0 pass through untouched
    assert _mad_filter([1.0, 50.0], 4.0) == [1.0, 50.0]
    assert _mad_filter([1.0, 1.0, 99.0], 0.0) == [1.0, 1.0, 99.0]


def test_feedback_backend_rejects_outliers_and_counts():
    cell = OpCell("allreduce", 4, 512)
    backend = CostModelBackend(costmodel.V5E_ICI)
    clean = [1e-3, 1.05e-3, 0.95e-3, 1e-3]
    fb = FeedbackBackend(backend, {(cell, IMPL): clean + [0.5]})
    assert fb.rejected == 1
    assert fb.latency(cell, IMPL) == pytest.approx(1e-3, rel=0.1)
    assert fb.nrep_for(cell, IMPL) == len(clean)
    # the spike would have dragged a plain median's neighbors; compare
    # against the unspiked backend: medians must agree exactly
    fb_clean = FeedbackBackend(backend, {(cell, IMPL): clean})
    assert fb.latency(cell, IMPL) == fb_clean.latency(cell, IMPL)
    # mad_k=0 disables rejection
    fb_off = FeedbackBackend(backend, {(cell, IMPL): clean + [0.5]},
                             mad_k=0)
    assert fb_off.rejected == 0


# ---------------------------------------------------------------------------
# ChaosMonkey determinism
# ---------------------------------------------------------------------------


def test_chaos_monkey_is_deterministic(tmp_path):
    (tmp_path / "a").mkdir()
    (tmp_path / "b").mkdir()
    events = []
    for sub in ("a", "b"):
        p = _flush_shard(tmp_path / sub, "srv0", n=6, obs=[1e-3, 2e-3])
        m = ChaosMonkey(seed=42)
        m.tear_shard(p)
        m.spike_latencies(p, factor=10.0)
        m.kill_server("srv1", at_epoch=3)
        events.append([(e.kind, e.detail) for e in m.events])
    assert events[0] == events[1]
    m = ChaosMonkey(seed=42)
    m.kill_server("s", at_epoch=3)
    assert m.alive("s", 2) and not m.alive("s", 3)
    assert m.alive("other", 99)


# ---------------------------------------------------------------------------
# FleetCoordinator
# ---------------------------------------------------------------------------


def test_coordinator_flags_dead_and_straggler_servers(tmp_path):
    now = [0.0]
    ref = StoreRef(base=_store(), epoch=1)
    co = FleetCoordinator(tmp_path, ref, heartbeat_timeout=10.0,
                          straggler_epochs=1, clock=lambda: now[0])
    for s in ("s0", "s1", "s2"):
        _flush_shard(tmp_path, s, epoch=1)
    st = co.scan()
    assert st.alive == ["s0", "s1", "s2"] and not st.dead and not st.retune
    # s2 dies; s1 straggles at epoch 2 while s0 reaches epoch 4
    now[0] += 8.0
    _flush_shard(tmp_path, "s0", epoch=2)
    _flush_shard(tmp_path, "s1", epoch=2)
    now[0] += 8.0
    _flush_shard(tmp_path, "s0", epoch=3)
    now[0] += 1.0
    _flush_shard(tmp_path, "s0", epoch=4)
    st = co.scan()
    assert st.fleet_epoch == 4
    assert st.dead == ["s2"]
    assert st.stragglers == ["s1"]
    assert st.retune and any("dead" in r for r in st.reasons)
    assert "RETUNE" in st.summary()


def test_coordinator_drift_triggers_retune(tmp_path):
    now = [0.0]
    ref = StoreRef(base=_store(), epoch=1)
    backend = CostModelBackend(costmodel.V5E_ICI)
    co = FleetCoordinator(tmp_path, ref, backend=backend,
                          heartbeat_timeout=100.0, drift_threshold=1.5,
                          clock=lambda: now[0])
    cell = OpCell("allreduce", 4, 512)
    t_model = backend.latency(cell, IMPL)
    # fleet observes the stores' selected impl running 2x the model
    for s in ("s0", "s1"):
        _flush_shard(tmp_path, s, epoch=1,
                     obs=[2.0 * t_model] * 3, seed=hash(s) % 100)
    st = co.scan()
    assert st.drift == pytest.approx(2.0, rel=0.25)
    assert st.retune and any("drift" in r for r in st.reasons)


def test_coordinator_empty_and_quarantined_dirs(tmp_path):
    ref = StoreRef(base=_store(), epoch=0)
    co = FleetCoordinator(tmp_path / "missing", ref, clock=lambda: 0.0)
    st = co.scan()
    assert st.fleet_epoch == -1 and st.drift is None and not st.retune
    # a directory of ONLY corrupt shards: all quarantined, no drift
    p = _flush_shard(tmp_path, "s0", obs=[1e-3] * 3)
    ChaosMonkey(seed=6).tear_shard(p, keep_frac=0.5)
    co2 = FleetCoordinator(tmp_path, ref, clock=lambda: 0.0)
    st2 = co2.scan()
    assert st2.quarantined == 1 and st2.drift is None
