"""HLO collective-bytes parser: synthetic fixtures + a real lowered module."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo import _shape_bytes, collective_bytes

FIXTURE = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128,4]) -> f32[1024,4] {
  %x = f32[128,4]{1,0} parameter(0)
  %ag = f32[1024,4]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[1024,4]{1,0} all-reduce(%ag), to_apply=%add
  ROOT %out = f32[1024,4]{1,0} add(%ar, %ar)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,4]{1,0}") == 128 * 4 * 4
    assert _shape_bytes("bf16[16]") == 32
    assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert _shape_bytes("f32[]") == 4


def test_fixture_collective_bytes():
    out = collective_bytes(FIXTURE)
    assert out["all-gather"]["bytes"] == 128 * 4 * 4
    assert out["all-reduce"]["bytes"] == 1024 * 4 * 4
    assert out["total_bytes"] == 128 * 16 + 1024 * 16


def test_real_lowered_module_has_collectives():
    """vmap-free single-device modules have zero collectives; a psum under
    jit with one device lowers away -- use a fixture-free sanity check that
    the parser tolerates real compiler output."""
    f = jax.jit(lambda x: (x @ x.T).sum())
    hlo = f.lower(jnp.ones((8, 8))).compile().as_text()
    out = collective_bytes(hlo)
    assert out["total_bytes"] == 0
