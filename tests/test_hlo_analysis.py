"""HLO collective-bytes parser: synthetic fixtures + a real lowered module."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo import _shape_bytes, collective_bytes

FIXTURE = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128,4]) -> f32[1024,4] {
  %x = f32[128,4]{1,0} parameter(0)
  %ag = f32[1024,4]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[1024,4]{1,0} all-reduce(%ag), to_apply=%add
  ROOT %out = f32[1024,4]{1,0} add(%ar, %ar)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,4]{1,0}") == 128 * 4 * 4
    assert _shape_bytes("bf16[16]") == 32
    assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert _shape_bytes("f32[]") == 4


def test_fixture_collective_bytes():
    out = collective_bytes(FIXTURE)
    assert out["all-gather"]["bytes"] == 128 * 4 * 4
    assert out["all-reduce"]["bytes"] == 1024 * 4 * 4
    assert out["total_bytes"] == 128 * 16 + 1024 * 16


def test_real_lowered_module_has_collectives():
    """vmap-free single-device modules have zero collectives; a psum under
    jit with one device lowers away -- use a fixture-free sanity check that
    the parser tolerates real compiler output."""
    f = jax.jit(lambda x: (x @ x.T).sum())
    hlo = f.lower(jnp.ones((8, 8))).compile().as_text()
    out = collective_bytes(hlo)
    assert out["total_bytes"] == 0


# ---------------------------------------------------------------------------
# async -start/-done pairs (the historical rstrip("-start") bug)
# ---------------------------------------------------------------------------

ASYNC_FIXTURE = """
HloModule test_async
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[256,4]) -> f32[64,4] {
  %x = f32[256,4]{1,0} parameter(0)
  %rs = ((f32[256,4]), (f32[64,4])) reduce-scatter-start(%x), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %rsd = f32[64,4]{1,0} reduce-scatter-done(%rs)
  %ag = ((f32[64,4]), (f32[256,4])) all-gather-start(%rsd), replica_groups={{0,1,2,3}}, dimensions={0}
  %agd = f32[256,4]{1,0} all-gather-done(%ag)
  ROOT %out = f32[64,4]{1,0} reduce-scatter(%agd), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
}
"""


def test_split_async_exact_suffix():
    from repro.analysis.hlo import split_async
    # str.rstrip("-start") strips a character CLASS:
    # "reduce-scatter-start".rstrip("-start") == "reduce-scatte"
    assert split_async("reduce-scatter-start") == ("reduce-scatter",
                                                   "start")
    assert split_async("reduce-scatter-done") == ("reduce-scatter", "done")
    assert split_async("all-gather-start") == ("all-gather", "start")
    assert split_async("reduce-scatter") == ("reduce-scatter", "")
    assert split_async("all-to-all") == ("all-to-all", "")


def test_async_reduce_scatter_bytes_counted():
    """Regression: reduce-scatter-start's operand bytes must be counted
    (the rstrip bug mapped it to op 'reduce-scatte' and dropped them)."""
    out = collective_bytes(ASYNC_FIXTURE)
    # async rs-start (256*4*4 B) + sync ROOT rs (256*4*4 B), counted once
    assert out["reduce-scatter"]["bytes"] == 2 * 256 * 4 * 4
    assert out["reduce-scatter"]["count"] == 2
    assert out["all-gather"]["bytes"] == 64 * 4 * 4
    assert out["all-gather"]["count"] == 1


def test_async_sites_collapse_onto_start():
    from repro.analysis.hlo import collective_sites
    sites = collective_sites(ASYNC_FIXTURE)
    by_name = {s.name: s for s in sites}
    assert "rsd" not in by_name and "agd" not in by_name
    assert by_name["rs"].async_role == "start"
    assert by_name["rs"].operand_bytes == 256 * 4 * 4
    assert by_name["rs"].group_size == 4
    assert by_name["out"].async_role == ""


def test_unpaired_async_raises():
    import pytest

    from repro.analysis.hlo import HloParseError, collective_sites
    bad = ASYNC_FIXTURE.replace(
        "  %rsd = f32[64,4]{1,0} reduce-scatter-done(%rs)\n", "")
    with pytest.raises(HloParseError, match="unpaired"):
        collective_sites(bad)


# ---------------------------------------------------------------------------
# shape/instr hardening: scalars, nested tuples, spaces
# ---------------------------------------------------------------------------


def test_parse_instr_scalar_and_tuple_types():
    from repro.analysis.hlo import parse_instructions
    text = """
HloModule t
ENTRY %main (x: f32[8,4]) -> f32[] {
  %x = f32[8,4]{1,0} parameter(0)
  %c = f32[] constant(0)
  %t = (f32[8,4]{1,0}, s32[]) tuple(%x, %c)
  %nested = ((f32[8, 4]), (f32[8, 4])) all-reduce-start(%x), to_apply=%add
  %d = f32[8,4]{1,0} all-reduce-done(%nested)
  ROOT %r = f32[] reduce(%x, %c), dimensions={0,1}, to_apply=%add
}
"""
    ins = {i.name: i for i in parse_instructions(text)}
    assert ins["c"].type_str == "f32[]"         # scalar result parsed
    assert ins["t"].op == "tuple"               # tuple-typed result parsed
    assert ins["nested"].op == "all-reduce-start"   # nested tuple + spaces
    assert _shape_bytes(ins["nested"].type_str) == 2 * 8 * 4 * 4
    assert ins["r"].op == "reduce"


def test_shape_bytes_spaces_and_scalars():
    assert _shape_bytes("(f32[8, 4], s32[])") == 8 * 4 * 4 + 4
    assert _shape_bytes("((f32[2, 3, 4]), (bf16[2, 3, 4]))") == \
        24 * 4 + 24 * 2


# ---------------------------------------------------------------------------
# loop trip-count multipliers (scanned modules)
# ---------------------------------------------------------------------------

WHILE_FIXTURE = """
HloModule t_while
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond (s: (s32[], f32[8,4])) -> pred[] {
  %s = (s32[], f32[8,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (s: (s32[], f32[8,4])) -> (s32[], f32[8,4]) {
  %s = (s32[], f32[8,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %x = f32[8,4]{1,0} get-tuple-element(%s), index=1
  %ar = f32[8,4]{1,0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[8,4]{1,0}) tuple(%ip, %ar)
}

ENTRY %main (x: f32[8,4]) -> (s32[], f32[8,4]) {
  %x = f32[8,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,4]{1,0}) tuple(%z, %x)
  ROOT %w = (s32[], f32[8,4]{1,0}) while((s32[], f32[8,4]{1,0}) %init), condition=%cond, body=%body
}
"""


def test_loop_multiplier_synthetic_while():
    """The while operand prints with its full inline tuple type — the old
    regex could not cross the nested parens and every trip count silently
    fell back to 1."""
    from repro.analysis.hlo import _loop_multipliers
    assert _loop_multipliers(WHILE_FIXTURE) == {"body": 7}
    out = collective_bytes(WHILE_FIXTURE)
    assert out["all-reduce"]["count"] == 7
    assert out["all-reduce"]["bytes"] == 7 * 8 * 4 * 4
