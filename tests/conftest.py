"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device SPMD tests run in subprocesses (see
tests/test_spmd_subprocess.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20170701)
