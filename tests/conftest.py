"""Shared fixtures + hermeticity guards.

The environment mutations here run at conftest import — BEFORE any test
module imports jax — and are inherited by the subprocess tests
(test_dryrun_subprocess, test_spmd_subprocess copy ``os.environ``), so the
whole suite is hermetic on CPU-only runners:

* ``JAX_PLATFORMS=cpu``  — never try to initialize an accelerator;
* ``PYTHONHASHSEED=0``   — deterministic hashing for any subprocess;
* the ``rng`` fixture is the single seeded PRNG for test data.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device;
multi-device SPMD tests run in subprocesses (see tests/test_spmd_subprocess)
which set their own ``--xla_force_host_platform_device_count``.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("PYTHONHASHSEED", "0")

import numpy as np
import pytest

SEED = 20170701

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess SPMD / dryrun)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(SEED)
