"""Quantized-wire admissibility: the tolerance gate, the demotion ledger,
and the dtype threading that makes both possible.

Satellite coverage for the wire_q8/wire_fp8 mock-up family:

* selfcheck.run_gate demotes a wire impl on an adversarial payload the wire
  format cannot represent (large in-block dynamic range / cancellation),
  and passes it on benign payloads;
* a demoted impl disappears from every selection surface — static dispatch
  (api._select falls back to default), runtime plans (_admissible_impls),
  the tuner (never selected, cost estimates fall back to default);
* OpCell.dtype round-trips dispatch -> trace JSONL -> geometry profile key
  -> lookup_cell (regression for the dtype-threading audit: a bfloat16
  callsite must not come back as float32).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import api, collectives as C, costmodel, selfcheck, tuner
from repro.core.cell import OpCell
from repro.core.trace import Trace, TraceEntry
from repro.kernels.quant import wire_tol

P = 4


@pytest.fixture(autouse=True)
def _clean_ledger():
    C.clear_demotions()
    yield
    C.clear_demotions()


def _cancellation_payload(p=P, n=16, d=4, scale=1e3):
    """Shards with large magnitudes that sum to nearly zero: the allreduce
    answer is O(1) but every wire hop quantizes O(scale) values, so the
    absolute quantization error (~scale/254 per hop for int8) dwarfs the
    true result — exactly the payload class the tolerance gate exists for."""
    rng = np.random.default_rng(7)
    tiny = rng.normal(size=(p, n, d)).astype(np.float32)
    x = tiny.copy()
    x[0] += scale
    x[1] -= scale
    return x


# ---------------------------------------------------------------------------
# tolerance gate -> demotion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["wire_q8", "wire_fp8"])
def test_selfcheck_gate_demotes_wire_on_cancellation(name):
    ok, rel, tol = selfcheck.run_gate("allreduce", name,
                                      _cancellation_payload())
    assert not ok
    assert rel > tol
    assert C.is_demoted("allreduce", name)
    assert ("allreduce", name) in C.demotions()


def test_selfcheck_gate_passes_wire_on_benign_payload():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(P, 16, 4)).astype(np.float32)
    ok, rel, tol = selfcheck.run_gate("allreduce", "wire_q8", x)
    assert ok
    assert rel <= tol == wire_tol("int8", selfcheck.wire_hops("allreduce", P))
    assert not C.is_demoted("allreduce", "wire_q8")


def test_selfcheck_gate_demote_false_only_reports():
    ok, _, _ = selfcheck.run_gate("allreduce", "wire_q8",
                                  _cancellation_payload(), demote=False)
    assert not ok
    assert not C.is_demoted("allreduce", "wire_q8")


def test_default_impl_cannot_be_demoted():
    with pytest.raises(ValueError):
        C.demote("allreduce", "default")
    with pytest.raises(KeyError):
        C.demote("allreduce", "no_such_impl")


# ---------------------------------------------------------------------------
# demotion is respected everywhere an impl can be chosen
# ---------------------------------------------------------------------------


def test_demoted_impl_falls_back_to_default_in_dispatch():
    C.demote("allreduce", "wire_q8", "tolerance")
    x = jnp.asarray(np.random.default_rng(0).normal(size=(P, 8, 4)),
                    jnp.float32)
    with api.tuned(force={"allreduce": "wire_q8"}) as ctx:
        got = jax.vmap(lambda a: api.allreduce(a, "x"), axis_name="x")(x)
    # the forced-but-demoted impl was swapped for default: exact result
    np.testing.assert_allclose(np.asarray(got),
                               np.broadcast_to(np.asarray(x).sum(0),
                                               x.shape), atol=1e-5)
    assert [r.impl for r in ctx.record] == ["default"]


def test_demoted_impl_left_out_of_admissible_set_and_plans():
    cell = OpCell("allreduce", P, 1 << 20)
    with api.tuned() as ctx:
        before = api._admissible_impls("allreduce", cell, ctx)
        assert "wire_q8" in before and "wire_fp8" in before
        C.demote("allreduce", "wire_q8", "tolerance")
        after = api._admissible_impls("allreduce", cell, ctx)
    assert "wire_q8" not in after
    assert "wire_fp8" in after                   # only the breaker goes
    assert set(before) - set(after) == {"wire_q8"}


def test_tuner_never_selects_demoted_wire_impls():
    """On a comm-bound DCN cell the wire family wins by construction; after
    demoting both wire impls the tuner must re-select from the rest."""
    t = Trace([TraceEntry.of("allreduce", 8, 4 << 20)])
    backend = tuner.CostModelBackend(costmodel.V5E_DCN)

    rep = tuner.tune_trace(t, backend=backend)
    sel = rep.phase_profiles["fwd"].lookup("allreduce", 8, 4 << 20)
    assert sel in ("wire_q8", "wire_fp8")

    C.demote("allreduce", "wire_q8", "tolerance")
    C.demote("allreduce", "wire_fp8", "tolerance")
    rep2 = tuner.tune_trace(t, backend=backend)
    store2 = rep2.phase_profiles.get("fwd")
    sel2 = store2.lookup("allreduce", 8, 4 << 20) if store2 else None
    assert sel2 not in ("wire_q8", "wire_fp8")   # None or a non-wire winner

    # cost estimation prices the (stale) wire selection as default, never
    # the demoted impl's cheaper wire latency
    est = tuner.estimate_trace_cost(t, backend, phases=rep.phase_profiles)
    est_def = tuner.estimate_trace_cost(t, backend)
    assert est["fwd"] == pytest.approx(est_def["fwd"])


# ---------------------------------------------------------------------------
# dtype threading regression (satellite 1)
# ---------------------------------------------------------------------------


def test_non_f32_dispatch_roundtrips_dtype_to_profile_lookup():
    """bfloat16 fused callsite -> recorded cell -> JSONL -> geometry profile
    keyed on dtype -> lookup_cell resolves for bf16 and (correctly) NOT for
    an identical f32 cell."""
    n, k, m = 256, 512, 64
    x = jnp.ones((P, n, k), jnp.bfloat16)
    w = jnp.ones((k, m), jnp.bfloat16)
    with api.tuned() as ctx:
        jax.vmap(lambda a: api.allgather_matmul(a, w, "x"),
                 axis_name="x")(x)
    t = Trace.from_context(ctx)
    (cell,) = t.cells().keys()
    assert cell.dtype == "bfloat16"
    assert cell.fused and cell.nbytes == n * k * 2

    back = Trace.from_jsonl(t.to_jsonl())
    assert back == t
    (bcell,) = back.cells().keys()
    assert bcell.dtype == "bfloat16"
    assert bcell.geom() is not None and bcell.geom().dtype == "bfloat16"

    rep = tuner.tune_trace(back,
                           backend=tuner.CostModelBackend(costmodel.V5E_DCN))
    store = rep.phase_profiles["fwd"]
    sel = store.lookup_cell(bcell)
    assert sel is not None                       # tuned under the bf16 key
    f32_twin = dataclasses.replace(bcell, dtype="float32")
    assert store.lookup_cell(f32_twin) is None   # dtype is part of the key


# ---------------------------------------------------------------------------
# wire_hops audit: count error-ADDING quantization events, not ring hops
# ---------------------------------------------------------------------------

PA = 8           # accumulate-audit axis size
K_LOC, M_A, T_A = 8, 16, 4


def test_wire_hops_counts_error_adding_events():
    """The tolerance multiplier is the number of independently-quantized
    error terms that can ADD into one output element — NOT the number of
    times the travelling payload crosses the wire."""
    # gather-style: each block quantized once at its origin, errors never meet
    assert selfcheck.wire_hops("allgather", PA) == 1
    assert selfcheck.wire_hops("allgather_matmul", PA) == 1
    # travelling accumulators: p-1 requantized partial sums
    assert selfcheck.wire_hops("reducescatter", PA) == PA - 1
    assert selfcheck.wire_hops("matmul_reducescatter", PA) == PA - 1
    # allreduce = RS (p-1 requantizes) + the AG-phase re-quantize on top
    assert selfcheck.wire_hops("allreduce", PA) == PA
    # matmul_accumulate streams blocks quantized ONCE each, but the
    # stationary-x contraction sums all p-1 wire-crossed blocks' errors
    # into every output element (the audited fix: the old travelling-data
    # rule said 1)
    assert selfcheck.wire_hops("matmul_accumulate", PA) == PA - 1
    # a 2-D cell's budget comes from its INNER reduction ring of size p2
    assert selfcheck.wire_hops("matmul_reducescatter_2d", PA, 4) == 3
    assert selfcheck.wire_hops("matmul_reducescatter_2d", PA) == PA - 1
    # degenerate axes never multiply below the single-roundtrip base
    for op in ("reducescatter", "allreduce", "matmul_accumulate"):
        assert selfcheck.wire_hops(op, 1) == 1
    # the multiplier is monotone in the tolerance it produces
    assert wire_tol("int8", PA - 1) == (PA - 1) * wire_tol("int8", 1)


def _accumulate_payload(gamma, seed=11, p=PA):
    """Stacked weight K-blocks [p, k_loc, m] + stationary x [T, K].

    Weight columns are near-constant with sub-quantization-step dither, so
    each block's int8 rounding residuals are independent k-varying noise
    (NO in-block dynamic-range abuse — every value sits in [1, 2]); the
    stationary rows have their sum suppressed by ``gamma``, so the true
    output shrinks with gamma while the p-1 accumulated per-block errors
    random-walk undiminished.  gamma=0.1 lands the relative error ABOVE
    the single-roundtrip bound but UNDER the (p-1)-event bound; gamma=0
    is the full-cancellation adversarial payload.
    """
    rng = np.random.default_rng(seed)
    K = p * K_LOC
    c = rng.uniform(1.0, 2.0, size=(1, M_A))
    dither = rng.uniform(-0.004, 0.004, size=(K, M_A))
    wblocks = (np.broadcast_to(c, (K, M_A)) + dither).astype(
        np.float32).reshape(p, K_LOC, M_A)
    z = rng.normal(size=(T_A, K))
    xstat = (z - (1.0 - gamma) * z.mean(axis=1, keepdims=True)).astype(
        np.float32)
    return wblocks, xstat


def test_accumulate_error_adding_payload_needs_p_minus_1_events():
    """Regression for the hops audit: a benign error-ADDING payload whose
    measured error exceeds the old hops=1 bound (spurious demotion on
    HEAD) but sits inside the corrected (p-1)-event budget."""
    wb, xs = _accumulate_payload(gamma=0.1)
    ok, rel, tol = selfcheck.run_gate("matmul_accumulate", "wire_q8",
                                      wb, w=xs)
    assert rel > wire_tol("int8", 1)       # the old bound would demote this
    assert ok and rel <= tol == wire_tol("int8", PA - 1)
    assert not C.is_demoted("matmul_accumulate", "wire_q8")


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1), st.integers(0, 10 ** 6))
def test_accumulate_benign_payloads_never_demote(wd_i, seed):
    """Property: random normal payloads stay under the (p-1)-event bound
    for both wire dtypes — the gate never spuriously demotes."""
    name = ("wire_q8", "wire_fp8")[wd_i]
    rng = np.random.default_rng(seed)
    wb = rng.normal(size=(PA, K_LOC, M_A)).astype(np.float32)
    xs = rng.normal(size=(T_A, PA * K_LOC)).astype(np.float32)
    C.clear_demotions()
    ok, rel, tol = selfcheck.run_gate("matmul_accumulate", name, wb, w=xs)
    assert ok and rel <= tol
    assert not C.is_demoted("matmul_accumulate", name)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 1), st.integers(0, 10 ** 6))
def test_accumulate_adversarial_cancellation_always_fires(wd_i, seed):
    """Property: on full-cancellation payloads the measured error exceeds
    even the widened (p-1)-event bound — the gate bound is never looser
    than the error the payload class actually produces, so widening the
    multiplier did not open a demotion hole."""
    name = ("wire_q8", "wire_fp8")[wd_i]
    wb, xs = _accumulate_payload(gamma=0.0, seed=seed)
    C.clear_demotions()
    ok, rel, tol = selfcheck.run_gate("matmul_accumulate", name, wb, w=xs)
    assert not ok and rel > tol
    assert C.is_demoted("matmul_accumulate", name)
