"""Gradient properties for every ``dist.ops`` primitive under
``vmap(axis_name=...)`` emulation: ``jax.grad`` of the api-routed op must
match a pure-``lax.psum``/``all_gather`` reference implementing the same
fwd/bwd pairing — to rtol 1e-6, with defaults AND with guideline mock-ups
forced, so the tuner can swap algorithms without perturbing training.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import api
from repro.core._axis import tie_to_axis
from repro.dist import ops

P = 4
AXIS = "model"


# ---------------------------------------------------------------------------
# pure-lax references with the same custom-VJP pairing
# ---------------------------------------------------------------------------


def _moved(fn, x, dim):
    if dim in (0, -x.ndim):
        return fn(x)
    return jnp.moveaxis(fn(jnp.moveaxis(x, dim, 0)), 0, dim)


def _lax_ag(x, axis):
    return lax.all_gather(x, axis, axis=0, tiled=True)


def _lax_rs(x, axis):
    return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def ref_gather(dim, axis, x):
    return _moved(lambda a: _lax_ag(a, axis), x, dim)


ref_gather.defvjp(
    lambda dim, axis, x: (ref_gather(dim, axis, x), None),
    lambda dim, axis, _, g: (_moved(lambda a: _lax_rs(a, axis), g, dim),))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def ref_scatter(dim, axis, x):
    return _moved(lambda a: _lax_rs(a, axis), x, dim)


ref_scatter.defvjp(
    lambda dim, axis, x: (ref_scatter(dim, axis, x), None),
    lambda dim, axis, _, g: (_moved(lambda a: _lax_ag(a, axis), g, dim),))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def ref_allreduce(axis, x):
    return lax.psum(x, axis)


ref_allreduce.defvjp(lambda axis, x: (ref_allreduce(axis, x), None),
                     lambda axis, _, g: (g,))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def ref_psum_grad(axis, x):
    return x


ref_psum_grad.defvjp(lambda axis, x: (x, None),
                     lambda axis, _, g: (lax.psum(g, axis),))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def ref_alltoall(axis, x):
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)


ref_alltoall.defvjp(
    lambda axis, x: (ref_alltoall(axis, x), None),
    lambda axis, _, g: (ref_alltoall(axis, tie_to_axis(g, axis)),))


# ---------------------------------------------------------------------------
# harness: grad of <y, c(y)> with a fixed deterministic cotangent
# ---------------------------------------------------------------------------


def _cotangent(y):
    return jnp.cos(jnp.arange(y.size, dtype=jnp.float32)).reshape(y.shape)


def _grad_of(f, x):
    def loss(a):
        y = f(a)
        return jnp.sum(y * _cotangent(y))
    return np.asarray(jax.vmap(jax.grad(loss), axis_name=AXIS)(x))


def _x(rows=P * 2, width=6):
    k = jax.random.key(0)
    return jax.random.normal(k, (P, rows, width), jnp.float32)


MOCKUP_FORCE = {"allgather": "allgather_as_allreduce",
                "reducescatter": "rsb_as_allreduce",
                "allreduce": "allreduce_as_reduce_bcast",
                "alltoall": "alltoall_as_ppermute"}

FORCES = [pytest.param(None, id="defaults"),
          pytest.param(MOCKUP_FORCE, id="mockups")]

CASES = [
    ("fsdp_gather_d0", lambda a: ops.fsdp_gather(a, 0, AXIS),
     lambda a: ref_gather(0, AXIS, a)),
    ("fsdp_gather_d1", lambda a: ops.fsdp_gather(a, 1, AXIS),
     lambda a: ref_gather(1, AXIS, a)),
    ("tp_allgather_last", lambda a: ops.tp_allgather(a, a.ndim - 1, AXIS),
     lambda a: ref_gather(1, AXIS, a)),
    ("tp_reducescatter", lambda a: ops.tp_reducescatter(a, 0, AXIS),
     lambda a: ref_scatter(0, AXIS, a)),
    ("tp_allreduce", lambda a: ops.tp_allreduce(a, AXIS),
     lambda a: ref_allreduce(AXIS, a)),
    ("tp_copy", lambda a: ops.tp_copy(a, AXIS),
     lambda a: ref_psum_grad(AXIS, a)),
    ("tp_psum_grad", lambda a: ops.tp_psum_grad(a, AXIS),
     lambda a: ref_psum_grad(AXIS, a)),
    ("ep_alltoall", lambda a: ops.ep_alltoall(a, AXIS),
     lambda a: ref_alltoall(AXIS, a)),
]


@pytest.mark.parametrize("force", FORCES)
@pytest.mark.parametrize("name,f_ops,f_ref", CASES,
                         ids=[c[0] for c in CASES])
def test_grad_matches_pure_lax_reference(name, f_ops, f_ref, force):
    x = _x()
    want = _grad_of(f_ref, x)
    with api.tuned(force=force or {}) as ctx:
        got = _grad_of(f_ops, x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    assert ctx.record, "op did not dispatch through the api"


@pytest.mark.parametrize("force", FORCES)
def test_matmul_grads_match_reference(force):
    x = _x(rows=5, width=8)                       # replicated activation
    w = jax.random.normal(jax.random.key(1), (P, 8, 3))   # col-sharded
    wr = jax.random.normal(jax.random.key(2), (P, 3, 8))  # row-sharded

    def f_ops(a, wc, wrr):
        h = ops.col_matmul(a, wc, AXIS)
        return ops.row_matmul(h, wrr, AXIS)

    def f_ref(a, wc, wrr):
        h = jnp.matmul(ref_psum_grad(AXIS, a), wc)
        return ref_allreduce(AXIS, jnp.matmul(h, wrr))

    def grads(f):
        def loss(a, wc, wrr):
            y = f(a, wc, wrr)
            return jnp.sum(y * _cotangent(y))
        return jax.vmap(jax.grad(loss, argnums=(0, 1, 2)),
                        axis_name=AXIS)(x, w, wr)

    want = grads(f_ref)
    with api.tuned(force=force or {}):
        got = grads(f_ops)
    for g, r in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-6, atol=1e-6)


def test_second_order_through_tp_allreduce():
    """grad-of-grad still routes through the dispatcher (hessian-vector
    products during e.g. sharpness probes must stay tuned)."""
    x = jnp.ones((P, 3), jnp.float32)

    def f(a):
        return jnp.sum(ops.tp_allreduce(a * a, AXIS))

    with api.tuned() as ctx:
        g = jax.vmap(jax.grad(lambda a: jnp.sum(jax.grad(f)(a) * a)),
                     axis_name=AXIS)(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert any(op == "allreduce" for op, *_ in ctx.record)
