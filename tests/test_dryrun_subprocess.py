"""Multi-pod dry-run smoke (subprocess: 512 host devices stay isolated).

Full 80-cell results live in results/dryrun/ (see EXPERIMENTS.md §Dry-run);
this test pins the machinery: lower+compile on the production meshes, the
roofline fields, collective-bytes parsing, and the skip logic.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def _run_cells(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    rows = [json.loads(l) for l in r.stdout.splitlines()
            if l.startswith("{")]
    return r, rows


@pytest.mark.slow
def test_dryrun_single_and_multi_pod_cell():
    r, rows = _run_cells(["--arch", "gemma3-1b", "--shape", "decode_32k",
                          "--multi-pod", "both"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert [x["mesh"] for x in rows] == ["16x16", "2x16x16"]
    for d in rows:
        assert d["status"] == "ok"
        assert d["devices"] in (256, 512)
        assert d["collectives"]["total_bytes"] > 0
        assert d["roofline"]["bottleneck"] in ("compute", "memory",
                                               "collective")
        assert float(d["roofline"]["useful_flops_ratio"]) > 0
        mem = d["memory"]
        assert mem["argument_bytes"] > 0


@pytest.mark.slow
def test_dryrun_skip_rule_and_force():
    r, rows = _run_cells(["--arch", "llama3.2-3b", "--shape", "long_500k"])
    assert rows[0]["status"] == "skip"
    # forcing a mock-up changes the lowered collective schedule
    r1, base = _run_cells(["--arch", "rwkv6-3b", "--shape", "decode_32k"])
    r2, forced = _run_cells(
        ["--arch", "rwkv6-3b", "--shape", "decode_32k", "--force",
         "allreduce:alg=allreduce_as_rsb_allgather"])
    assert base[0]["status"] == forced[0]["status"] == "ok"
    assert "allreduce_as_rsb_allgather" in forced[0]["pgmpi_footer"]
    b0 = base[0]["collectives"]
    b1 = forced[0]["collectives"]
    # GL6 replaces all-reduces with reduce-scatter + all-gather pairs
    assert b1.get("reduce-scatter", {}).get("count", 0) > \
        b0.get("reduce-scatter", {}).get("count", 0)
