"""§Perf attention variants must match the paper-faithful reference path:
flash (chunked online-softmax, grouped GQA), absorbed MLA, windowed decode.
All in fp32 so only algorithmic differences would show."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.models.params import init_tree

B = 2


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


@pytest.mark.parametrize("arch", ["llama3.2-3b", "gemma3-1b", "gemma2-9b",
                                  "paligemma-3b", "whisper-medium"])
def test_flash_matches_ref_train(arch, rng):
    cfg = _f32(get_config(arch).smoke())
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(1))
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)}
    if cfg.encdec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, 32, cfg.d_model)),
                                      jnp.float32)
    if cfg.vlm:
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm.n_patches, cfg.vlm.patch_dim)),
            jnp.float32)
    ref, _, _ = lm.forward(params, cfg, batch, mode="train")
    fl, _, _ = lm.forward(params, dataclasses.replace(cfg, attn_impl="flash"),
                          batch, mode="train")
    err = float(jnp.max(jnp.abs(ref - fl))) / (float(jnp.max(jnp.abs(ref)))
                                               + 1e-9)
    assert err < 1e-4, err


def test_absorbed_mla_matches_naive(rng):
    cfg = _f32(dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                                   moe=None, n_layers=2))
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 12)), jnp.int32)
    ref, _, _ = lm.forward(params, cfg, {"tokens": toks}, mode="train")
    fl, _, _ = lm.forward(params, dataclasses.replace(cfg, attn_impl="flash"),
                          {"tokens": toks}, mode="train")
    err = float(jnp.max(jnp.abs(ref - fl))) / float(jnp.max(jnp.abs(ref)))
    assert err < 1e-4, err


def test_absorbed_mla_decode_consistent(rng):
    cfg = _f32(dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                                   moe=None, n_layers=2,
                                   attn_impl="flash"))
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 12)), jnp.int32)
    full, _, _ = lm.forward(params, cfg, {"tokens": toks}, mode="train")
    caches = lm.init_caches(cfg, B, 16)
    _, caches = lm.prefill(params, cfg, {"tokens": toks[:, :-1]}, caches)
    lg, _ = lm.decode_step(params, cfg, toks[:, -1:], caches, jnp.int32(11))
    err = float(jnp.max(jnp.abs(full[:, -1] - lg[:, 0]))) / \
        float(jnp.max(jnp.abs(full[:, -1])))
    assert err < 1e-4, err


def test_windowed_decode_matches_ref(rng):
    """Sliced-cache local-attention decode == full-cache reference."""
    cfg = _f32(get_config("gemma3-1b").smoke())
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(2))
    S = 40                      # > window (32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    def decode_logits(c):
        caches = lm.init_caches(c, B, 64)
        _, caches = lm.prefill(params, c, {"tokens": toks[:, :-1]}, caches)
        lg, _ = lm.decode_step(params, c, toks[:, -1:], caches,
                               jnp.int32(S - 1))
        return lg

    ref = decode_logits(cfg)
    fl = decode_logits(dataclasses.replace(cfg, attn_impl="flash"))
    err = float(jnp.max(jnp.abs(ref - fl))) / float(jnp.max(jnp.abs(ref)))
    assert err < 1e-4, err


def test_flash_gradients_match(rng):
    """Backward through the flash scan == backward through dense SDPA."""
    cfg = _f32(get_config("llama3.2-3b").smoke())
    params = init_tree(lm.model_specs(cfg, tp=1), jax.random.key(1))
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, 16)), jnp.int32)}
    batch["labels"] = batch["tokens"]

    def g(c):
        return jax.grad(lambda p: lm.loss_fn(p, c, batch)[0])(params)

    gr = g(cfg)
    gf = g(dataclasses.replace(cfg, attn_impl="flash"))
    for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gf)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)
