"""Shape-aware tuning cells: OpCell, trace schema v2 (+v1 back-compat),
geometry-keyed profiles with nearest-cell fallback, and the measured
backend replaying the RECORDED GEMM (the MM_WIDTH regression)."""
import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro._compat as compat
from repro.core import api, costmodel as cm, measure, tuner
from repro.core.cell import Geom, OpCell
from repro.core.profiles import (Profile, ProfileStore, Range,
                                 resolve_stores)
from repro.core.trace import Trace, TraceEntry


# ---------------------------------------------------------------------------
# OpCell
# ---------------------------------------------------------------------------


def test_opcell_plain_vs_fused():
    plain = OpCell("allreduce", 8, 1024)
    assert not plain.fused and plain.geom() is None
    fused = OpCell("allgather_matmul", 8, 4096, "bfloat16",
                   mm_k=256, mm_m=128, mm_n=64, mm_role="gather")
    assert fused.fused
    assert fused.geom() == Geom("bfloat16", 256, 128, 64, "gather")
    assert fused.itemsize == 2
    assert fused.flops() == 2 * 256 * 128 * 64
    with pytest.raises(ValueError):
        OpCell("allgather_matmul", 8, 4, mm_k=2, mm_m=2, mm_n=2,
               mm_role="bogus")


def test_opcell_scaled_to_keeps_geometry_consistent():
    c = OpCell("allgather_matmul", 4, 4096, "float32",
               mm_k=64, mm_m=64, mm_n=32, mm_role="gather")
    s = c.scaled_to(4096 * 16)
    assert s.mm_k == 64 and s.mm_n == 32          # aspect preserved
    assert s.nbytes == (s.mm_m // 4) * 64 * 4     # payload consistent
    acc = OpCell("matmul_accumulate", 4, 1024, "float32",
                 mm_k=16, mm_m=8, mm_n=64, mm_role="contract")
    s2 = acc.scaled_to(1024 * 8)
    assert s2.mm_n == 64 and s2.mm_m == 8
    assert s2.nbytes == (s2.mm_k // 4) * 64 * 4


# ---------------------------------------------------------------------------
# trace schema v2 + v1 back-compat
# ---------------------------------------------------------------------------


def test_trace_v2_roundtrips_geometry():
    e = TraceEntry.of("allgather_matmul", 8, 4096, "bwd", "fused_ring", 3,
                      dtype="bfloat16", mm_k=512, mm_m=1024, mm_n=64,
                      mm_role="gather")
    t = Trace([e])
    back = Trace.from_jsonl(t.to_jsonl())
    assert back == t
    cell = next(iter(back.cells()))
    assert (cell.dtype, cell.mm_k, cell.mm_m, cell.mm_n, cell.mm_role) == \
        ("bfloat16", 512, 1024, 64, "gather")
    assert '"v": 2' in e.to_json()


def test_trace_v1_lines_load_with_defaulted_geometry():
    """Satellite: old 5-field JSONL lines still parse — geometry defaulted,
    fused ops marked unknown (fused=False)."""
    v1 = ('{"op": "reducescatter", "p": 8, "nbytes": 4096, "phase": "bwd", '
          '"impl": "default", "count": 24}\n'
          '{"op": "allgather_matmul", "p": 4, "nbytes": 2048, '
          '"phase": "fwd", "impl": "fused_ring", "count": 2}\n')
    t = Trace.from_jsonl(v1)
    assert t.total() == 26
    ag, rs = sorted(t.cells(), key=lambda c: c.op)
    assert ag.op == "allgather_matmul" and not ag.fused
    assert rs.op == "reducescatter" and rs.dtype == "float32"


def test_trace_v1_to_v2_migration_roundtrip(tmp_path):
    """v1 file -> load -> save (v2) -> load: identical cells, and the v2
    form is stable under a further round-trip."""
    v1_path = tmp_path / "old.jsonl"
    v1_path.write_text(
        '{"op": "allreduce", "p": 16, "nbytes": 512, "phase": "decode", '
        '"impl": "allreduce_as_doubling", "count": 7}\n')
    t1 = Trace.load(v1_path)
    v2_path = tmp_path / "new.jsonl"
    t1.save(v2_path)
    assert '"v": 2' in v2_path.read_text()
    t2 = Trace.load(v2_path)
    assert t2 == t1
    assert Trace.from_jsonl(t2.to_jsonl()) == t2


def test_from_record_accepts_legacy_tuples():
    t = Trace.from_record([("allreduce", 4, 128, "default", "fwd")])
    assert t.cells() == {OpCell("allreduce", 4, 128): 1}


# ---------------------------------------------------------------------------
# geometry-keyed profiles + nearest-cell fallback
# ---------------------------------------------------------------------------

G = Geom("float32", 512, 1024, 256, "gather")


def _geom_profile(geom=G, impl="fused_ring", lo=1, hi=10**7):
    return Profile(op="allgather_matmul", axis_size=8,
                   ranges=[Range(lo, hi, impl)], geom=geom)


def test_profile_geom_text_and_json_roundtrip():
    prof = _geom_profile()
    t = Profile.from_text(prof.to_text())
    assert t.geom == G and t.ranges == prof.ranges
    j = Profile.from_json(prof.to_json())
    assert j.geom == G and j.ranges == prof.ranges


def test_v1_profile_text_still_loads_geomless():
    """Satellite: a v1 .pgtune file (no #@geom line) loads with geom=None
    and keeps serving geometry-less lookups."""
    prof = Profile(op="allgather", axis_size=8,
                   ranges=[Range(1, 100, "allgather_as_ring")])
    text = prof.to_text()
    assert "#@geom" not in text
    back = Profile.from_text(text)
    assert back.geom is None
    store = ProfileStore([back])
    assert store.lookup("allgather", 8, 50) == "allgather_as_ring"


def test_resolve_stores_loads_v1_profile_files(tmp_path, monkeypatch):
    d = tmp_path / "profiles"
    d.mkdir()
    # a hand-written v1 Listing-1 file, no geometry anywhere
    (d / "allreduce_p4.pgtune").write_text(
        "# pgtune profile\nMPI_Allreduce\n4 # nb. of. processes\n"
        "1 # nb. of mock-up impl.\n2 allreduce_as_doubling\n"
        "1 # nb. of ranges\n1 4096 2\n")
    monkeypatch.delenv("PGTUNE_PROFILE_DIR", raising=False)
    base, phases = resolve_stores(str(d))
    assert phases == {}
    assert base.lookup("allreduce", 4, 64) == "allreduce_as_doubling"


def test_store_lookup_cell_exact_nearest_and_fallback():
    near = Geom("float32", 512, 2048, 256, "gather")       # 2x rows off
    far = Geom("float32", 64, 64, 64, "gather")
    other_role = Geom("float32", 512, 1024, 256, "scatter")
    store = ProfileStore([
        _geom_profile(G, "fused_ring"),
        _geom_profile(far, "default", lo=1, hi=10),
        Profile(op="matmul_reducescatter", axis_size=8,
                ranges=[Range(1, 10**7, "fused_ring")], geom=other_role),
        Profile(op="allgather_matmul", axis_size=8,
                ranges=[Range(1, 10**7, "default")]),      # geom-less base
    ])
    exact = OpCell("allgather_matmul", 8, 4096, "float32",
                   512, 1024, 256, "gather")
    assert store.lookup_cell(exact) == "fused_ring"
    # unseen shape: resolves to the NEAREST tuned geometry (near > far)
    store.add(_geom_profile(near, "fused_ring"))
    unseen = OpCell("allgather_matmul", 8, 4096, "float32",
                    512, 4096, 256, "gather")
    assert store.lookup_cell(unseen) == "fused_ring"
    # nbytes outside the nearest profile's ranges: lookup_nearest covers it
    unseen_big = OpCell("allgather_matmul", 8, 10**9, "float32",
                        512, 10**6, 256, "gather")
    assert store.lookup_cell(unseen_big) == "fused_ring"
    # plain cells never consult geometry profiles
    plain = OpCell("allgather_matmul", 8, 4096)
    assert store.lookup_cell(plain) == "default"


def test_store_save_load_geometry_files(tmp_path):
    store = ProfileStore([_geom_profile(),
                          Profile(op="allreduce", axis_size=8,
                                  ranges=[Range(1, 9, "allreduce_as_doubling")])])
    store.save(tmp_path, fmt="text")
    names = sorted(p.name for p in tmp_path.glob("*.pgtune"))
    assert any("k512m1024n256" in n for n in names), names
    back = ProfileStore.load(tmp_path)
    assert len(back) == 2
    cell = OpCell("allgather_matmul", 8, 4096, "float32",
                  512, 1024, 256, "gather")
    assert back.lookup_cell(cell) == "fused_ring"
    assert back.lookup("allreduce", 8, 5) == "allreduce_as_doubling"


def test_dispatch_uses_geometry_profile_for_exact_cell(rng):
    """api.tuned(profiles=...) routes a fused dispatch through its geometry
    profile; a different-geometry callsite falls back per nearest/geomless
    rules."""
    p, n, k, m = 4, 4, 8, 6
    geom = Geom("float32", k, p * n, m, "gather")
    store = ProfileStore([Profile(op="allgather_matmul", axis_size=p,
                                  ranges=[Range(1, 10**6, "fused_ring")],
                                  geom=geom)])
    x = jnp.asarray(rng.normal(size=(p, n, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, m)).astype(np.float32))
    with api.tuned(profiles=store) as ctx:
        jax.vmap(lambda a: api.allgather_matmul(a, w, "x"),
                 axis_name="x")(x)
    assert [r.impl for r in ctx.record] == ["fused_ring"]
    assert ctx.record[0].cell.geom() == geom


# ---------------------------------------------------------------------------
# measured backend replays the RECORDED GEMM (MM_WIDTH regression)
# ---------------------------------------------------------------------------


def test_problem_shapes_use_recorded_gemm_not_square():
    """Regression: replay of an allgather_matmul cell must build the
    recorded (mm_k, mm_m, mm_n) problem — not a 64-wide square weight."""
    cell = OpCell("allgather_matmul", 1, 48 * 10 * 4, "float32",
                  mm_k=48, mm_m=10, mm_n=24, mm_role="gather")
    shapes = measure.problem_shapes(cell)
    assert shapes == {"x": (10, 48), "w": (48, 24)}
    mmrs = OpCell("matmul_reducescatter", 2, 0, "float32",
                  mm_k=16, mm_m=6, mm_n=10, mm_role="scatter")
    assert measure.problem_shapes(mmrs) == {"x": (6, 16), "w": (16, 10)}
    acc = OpCell("matmul_accumulate", 2, 0, "float32",
                 mm_k=12, mm_m=7, mm_n=5, mm_role="contract")
    assert measure.problem_shapes(acc) == {"x": (6, 5), "w": (7, 12)}


def test_problem_shapes_reject_unknown_geometry():
    with pytest.raises(ValueError, match="no recorded matmul geometry"):
        measure.problem_shapes(OpCell("allgather_matmul", 1, 4096))


def test_measured_replay_of_recorded_agmm_cell():
    """End-to-end on the host device(s): a recorded allgather_matmul cell
    with a non-square GEMM is wall-clock replayed; a v1-style cell without
    geometry is note-skipped instead of silently replaying a canonical
    weight."""
    p = measure.axis_size()
    cell = measure.host_cell("allgather_matmul", 5 * 48 * 4,
                             mm_k=48, mm_m=p * 5, mm_n=12, mm_role="gather")
    lats = measure.sample_latency(cell, "default", 2)
    assert len(lats) == 2 and all(t >= 0.0 for t in lats)

    backend = tuner.MeasuredBackend(K=2, max_nrep=3)
    assert math.isinf(backend.latency(
        measure.host_cell("allgather_matmul", 4096), "default"))
    t = Trace([TraceEntry(measure.host_cell("allgather_matmul", 4096),
                          "fwd", "default", 2)])
    rep = tuner.tune_trace(t, backend=backend)
    assert any("unmeasurable" in n for n in rep.notes)
    assert rep.measurements == []


def test_tune_sweep_emits_geomless_profiles_for_fused_ops():
    """The sweep tuner (synthetic sizes, canonical pricing) and the trace
    tuner (recorded geometry) share _measure_cell; sweep profiles stay
    geometry-less so both lookup paths coexist in one store."""
    rep = tuner.tune(ops=["allgather_matmul"], sizes=(16_777_216,),
                     axis_size=8,
                     backend=tuner.CostModelBackend(cm.V5E_ICI))
    prof = rep.profiles.get("allgather_matmul", 8)
    assert prof is not None and prof.geom is None


# ---------------------------------------------------------------------------
# _compat self-disabling shims
# ---------------------------------------------------------------------------


def test_compat_shims_probe_native_api():
    """Each shim self-disables when the native jax surface exists: the
    LIVE_SHIMS registry must agree with what this jax actually provides."""
    assert isinstance(compat.LIVE_SHIMS, list)
    has_native_sm = hasattr(jax, "shard_map")
    assert any("shard_map" in s for s in compat.LIVE_SHIMS) == \
        (not has_native_sm)
    has_fwp = hasattr(jax.tree, "flatten_with_path")
    assert any("flatten_with_path" in s for s in compat.LIVE_SHIMS) == \
        (not has_fwp)
    # the wrappers keep working regardless of which branch is live
    leaves, _ = compat.tree_flatten_with_path({"a": 1, "b": [2, 3]})
    assert len(leaves) == 3
    mesh = compat.mesh_with_axis_types(np.array(jax.devices()[:1]), ("x",))
    assert mesh.shape["x"] == 1


def test_tune_trace_geometryless_fused_cell_note_in_footer():
    """Regression (v1-trace inf skip): a fused cell with no recorded GEMM
    used to vanish into a generic 'default impl unmeasurable' note; the
    report must now say WHY (no geometry — re-record) and the note must
    surface in the tuner report's summary footer."""
    t = Trace([TraceEntry(OpCell("allgather_matmul", measure.axis_size(),
                                 4096), "decode", "default", 4)])
    backend = tuner.MeasuredBackend(K=2, max_nrep=3)
    rep = tuner.tune_trace(t, backend=backend)
    geom_notes = [n for n in rep.notes if "no recorded GEMM geometry" in n]
    assert geom_notes, rep.notes
    assert "re-record" in geom_notes[0]
    assert "v1 trace" in geom_notes[0]
    # the note reaches the human-facing report footer
    assert "no recorded GEMM geometry" in rep.summary()
    # and the cell contributed nothing silently: no measurement, no est
    assert rep.measurements == []
    assert rep.est_default_s.get("decode", 0.0) == 0.0


def test_opcell_2d_scaled_to_keeps_geometry_consistent():
    """NREP probes of 2-D cells rescale the payload-tied dim: the forward
    scales the streamed weight's width (mm_n), the transpose the streamed
    cotangent's rows (mm_k)."""
    fwd = OpCell("matmul_reducescatter_2d", 4, 64 * 8 * 4, "float32",
                 mm_k=64, mm_m=32, mm_n=4 * 8, mm_role="2d", p2=2)
    s = fwd.scaled_to(64 * 8 * 4 * 16)
    assert s.mm_k == 64 and s.mm_m == 32 and s.p2 == 2
    assert s.nbytes == (s.mm_n // 4) * 64 * 4
    xp = OpCell("matmul_reducescatter_2d", 2, 6 * 32 * 4, "float32",
                mm_k=2 * 6, mm_m=32, mm_n=16, mm_role="2dT", p2=4)
    s2 = xp.scaled_to(6 * 32 * 4 * 8)
    assert s2.mm_m == 32 and s2.mm_n == 16 and s2.p2 == 4
    assert s2.nbytes == (s2.mm_k // 2) * 32 * 4
    # minimal floor: one row/col block, never a literal byte
    assert fwd.scaled_to(1).mm_n == 4
    assert xp.scaled_to(1).mm_k == 2


def test_problem_shapes_2d_cells():
    """2-D replay shapes: the payload keeps its per-shard form (weight col
    block / cotangent row block), the stationary operand the recorded
    per-rank shape, rows padded to divide the inner axis."""
    fwd = OpCell("matmul_reducescatter_2d", 2, 0, "float32",
                 mm_k=8, mm_m=6, mm_n=2 * 5, mm_role="2d", p2=2)
    assert measure.problem_shapes(fwd) == {"x": (8, 5), "w": (6, 8)}
    xp = OpCell("matmul_reducescatter_2d", 2, 0, "float32",
                mm_k=2 * 3, mm_m=8, mm_n=4, mm_role="2dT", p2=2)
    assert measure.problem_shapes(xp) == {"x": (3, 8), "w": (6, 4)}
    with pytest.raises(ValueError, match="no recorded matmul geometry"):
        measure.problem_shapes(
            OpCell("matmul_reducescatter_2d", 2, 64))
