"""MoE global-capacity mode: data-sharded keep decisions == single device.

The local-capacity GShard dispatch derives capacity and position-in-expert
from LOCAL token counts, so a data-sharded run drops different tokens than
the same batch on one device (the tolerance note in
tests/test_spmd_subprocess.py).  ``moe.global_capacity`` computes the keep
decision from the token's position in the GLOBAL per-expert order via one
extra tunable ``api.allreduce`` of router stats — the sharded run must then
match the single-device run bit-for-bit.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import api
from repro.models import moe
from repro.models.config import ModelConfig, MoEConfig

D, E, F, K = 8, 4, 16, 2
B, S, DP = 4, 4, 2


def _cfg(global_capacity):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=D, n_heads=2,
        n_kv_heads=2, d_ff=F, vocab_size=32, dtype="float32",
        moe=MoEConfig(n_experts=E, top_k=K, d_ff_expert=F,
                      capacity_factor=0.75,        # force real drops
                      global_capacity=global_capacity))


@pytest.fixture()
def data(rng):
    x = rng.normal(size=(B, S, D)).astype(np.float32)
    params = {
        "router": rng.normal(size=(D, E)).astype(np.float32),
        "w_in": rng.normal(size=(E, D, F)).astype(np.float32) * 0.1,
        "w_gate": rng.normal(size=(E, D, F)).astype(np.float32) * 0.1,
        "w_out": rng.normal(size=(E, F, D)).astype(np.float32) * 0.1,
    }
    return x, params


def _shard(params):
    """Split each param along its FSDP ("data") dim into DP stacked shards,
    matching moe_specs' placement."""
    return {
        "router": jnp.asarray(params["router"].reshape(DP, D // DP, E)),
        "w_in": jnp.asarray(params["w_in"].reshape(
            E, DP, D // DP, F).transpose(1, 0, 2, 3)),
        "w_gate": jnp.asarray(params["w_gate"].reshape(
            E, DP, D // DP, F).transpose(1, 0, 2, 3)),
        "w_out": jnp.asarray(params["w_out"].reshape(
            E, F, DP, D // DP).transpose(2, 0, 1, 3)),
    }


def _run_sharded(cfg, params, x):
    xs = jnp.asarray(x.reshape(DP, B // DP, S, D))
    f = lambda p, xin: moe.moe_block(p, cfg, xin)[0]
    y = jax.vmap(f, axis_name="data")(_shard(params), xs)
    return np.asarray(y).reshape(B, S, D)


def test_global_capacity_matches_single_device_exactly(data):
    x, params = data
    want = np.asarray(moe.moe_block(params, _cfg(True), jnp.asarray(x))[0])
    got = _run_sharded(_cfg(True), params, x)
    np.testing.assert_array_equal(got, want)


def test_local_capacity_diverges_on_this_batch(data):
    """The divergence the mode removes must actually exist here, or the
    exact-equality test above proves nothing."""
    x, params = data
    want = np.asarray(moe.moe_block(params, _cfg(False), jnp.asarray(x))[0])
    got = _run_sharded(_cfg(False), params, x)
    assert np.abs(got - want).max() > 1e-6


def test_global_capacity_router_allreduce_is_tunable(data):
    """The router-stats exchange is one extra dispatcher allreduce over the
    data axis — visible in the record and redirectable like any mock-up."""
    x, params = data
    cfg = _cfg(True)
    xs = jnp.asarray(x.reshape(DP, B // DP, S, D))
    f = lambda p, xin: moe.moe_block(p, cfg, xin)[0]
    with api.tuned(force={"allreduce": "allreduce_as_doubling"}) as ctx:
        jax.vmap(f, axis_name="data")(_shard(params), xs)
    stats_cells = [(op, p, nb, impl) for op, p, nb, impl, _ in ctx.record
                   if op == "allreduce" and nb == DP * E * 4]
    assert stats_cells, ctx.record
    assert all(impl == "allreduce_as_doubling"
               for *_, impl in stats_cells)


def test_global_capacity_noop_without_data_axis(data):
    """Outside any data binding the mode must be inert (single-device jit
    runs identical code)."""
    x, params = data
    a = np.asarray(moe.moe_block(params, _cfg(True), jnp.asarray(x))[0])
    b = np.asarray(moe.moe_block(params, _cfg(False), jnp.asarray(x))[0])
    np.testing.assert_array_equal(a, b)


def test_global_capacity_with_expert_parallelism(data):
    """Global capacity composes with EP over the model axis: a (data=2,
    model=2)-style nested vmap run still matches single device."""
    x, params = data
    cfg = _cfg(True)
    tp = 2
    want = np.asarray(moe.moe_block(params, cfg, jnp.asarray(x))[0])
    sharded = _shard(params)
    # additionally shard experts over the model axis (dim 0 of w_*, after
    # the data stacking dim)
    def ep_split(t, dim):
        parts = jnp.split(t, tp, axis=dim)
        return jnp.stack(parts, axis=0)            # [tp, DP, ...]
    pp = {
        "router": jnp.broadcast_to(sharded["router"],
                                   (tp,) + sharded["router"].shape),
        "w_in": ep_split(sharded["w_in"], 1),
        "w_gate": ep_split(sharded["w_gate"], 1),
        "w_out": ep_split(sharded["w_out"], 1),
    }
    xs = jnp.asarray(x.reshape(DP, B // DP, S, D))
    xs2 = jnp.broadcast_to(xs, (tp,) + xs.shape)

    f = lambda p, xin: moe.moe_block(p, cfg, xin)[0]
    fd = jax.vmap(f, axis_name="data")
    y = jax.vmap(fd, axis_name="model")(pp, xs2)   # [tp, DP, B/DP, S, D]
    got = np.asarray(y)[0].reshape(B, S, D)
    np.testing.assert_allclose(got, want, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y)[1].reshape(B, S, D), got,
                               atol=1e-5)
